"""Version-compat shims for jax APIs used across the codebase.

``shard_map`` moved from ``jax.experimental.shard_map`` (where the
replication check is named ``check_rep``) to ``jax.shard_map`` (renamed to
``check_vma``); this wrapper accepts the modern signature on either
version.
"""
from __future__ import annotations

import jax


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
