"""Deterministic, resumable synthetic data pipeline.

Each global step's batch is a pure function of (seed, step) — restart at
step N reproduces exactly the batches a failed run would have seen
(checkpoint/restart determinism), and each data shard slices its rows, so
the pipeline works for any mesh size (elastic restart).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0
    d_model: int = 0


class SyntheticLM:
    """Markov-ish token stream: next token = (a*tok + b + noise) % V, so a
    model can actually reduce loss on it (examples/train_lm.py)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        f = cfg.frontend_tokens
        text = s - f if f else s
        toks = np.empty((b, text + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, b)
        noise = rng.random((b, text)) < 0.05
        rnd = rng.integers(0, cfg.vocab_size, (b, text))
        for t in range(text):
            nxt = (toks[:, t] * 31 + 7) % cfg.vocab_size
            toks[:, t + 1] = np.where(noise[:, t], rnd[:, t], nxt)
        out = {
            "tokens": toks[:, :-1],
            "labels": np.pad(toks[:, 1:], ((0, 0), (f, 0))),
            "mask": np.pad(np.ones((b, text), np.float32), ((0, 0), (f, 0))),
        }
        if f:
            out["frontend_embeds"] = rng.normal(
                size=(b, f, cfg.d_model)).astype(np.float32)
        return out

    def sharded_batch(self, step: int, shardings: dict):
        host = self.batch(step)
        return {k: jax.device_put(v, shardings[k]) if k in shardings
                else jax.numpy.asarray(v) for k, v in host.items()}
