"""jit'd wrappers: full sorted-run merge composed from kernel tile merges.

``merge_sorted_runs`` merges two sorted non-negative int32 runs:
merge-path *diagonal* splits (vectorized binary search, one per output
tile) bound every tile's work to exactly ``tile`` outputs, then the Pallas
kernel merges each co-tile pair in VMEM. Ties resolve toward run A (the
newer run); the global keep-mask drops duplicate keys (reconciliation).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..bloom.bloom import probe_filters_tiered
from ..sizing import next_pow2
from .merge import merge_tiles
from .ref import merge_tiles_ref

INT_MAX = np.int32(2**31 - 1)


def _diag_splits(ka, kb, diags):
    """For each output diagonal d, the largest ai with ka[ai-1] <= kb[d-ai]
    (run-A priority). Vectorized binary search (max-true)."""
    na, nb = ka.shape[0], kb.shape[0]
    lo = jnp.maximum(0, diags - nb)
    hi = jnp.minimum(diags, na)

    int_min = np.int32(-2**31)

    def a_at(i):        # ka[i-1], -inf sentinel below the run
        return jnp.where(i <= 0, int_min, ka[jnp.clip(i - 1, 0, na - 1)])

    def b_at(i):        # kb[i], +inf sentinel past the run
        return jnp.where(i >= nb, INT_MAX, kb[jnp.clip(i, 0,
                                               max(nb - 1, 0))])

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        ok = a_at(mid) <= b_at(diags - mid)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo, hi = jax.lax.fori_loop(0, 32, body, (lo, hi))
    return lo


def _gather_window(x, starts, lens, width, fill):
    idx = starts[:, None] + jnp.arange(width)[None, :]
    valid = jnp.arange(width)[None, :] < lens[:, None]
    safe = jnp.clip(idx, 0, max(x.shape[0] - 1, 0))
    return jnp.where(valid, x[safe], fill)


@partial(jax.jit, static_argnames=("tile", "use_kernel", "interpret"))
def merge_sorted_runs(ka, va, kb, vb, *, tile: int = 512,
                      use_kernel: bool = True, interpret: bool = True):
    """Merge two sorted non-negative int32 runs with newest-wins dedup.

    Returns (keys [ceil((Na+Nb)/tile)*tile], vals, keep); padding slots
    carry key=INT_MAX and keep=False.
    """
    na, nb = ka.shape[0], kb.shape[0]
    if na == 0 or nb == 0:                  # degenerate: copy the other run
        keys = jnp.concatenate([ka, kb])
        vals = jnp.concatenate([va, vb])
        g0 = max(1, -(-keys.shape[0] // tile))
        pad = g0 * tile - keys.shape[0]
        keys = jnp.pad(keys, (0, pad), constant_values=INT_MAX)
        vals = jnp.pad(vals, (0, pad))
        return keys, vals, keys != INT_MAX
    n = na + nb
    g = -(-n // tile)
    diags = jnp.minimum(jnp.arange(g + 1) * tile, n)
    ai = _diag_splits(ka, kb, diags)
    bi = diags - ai
    a_len, b_len = jnp.diff(ai), jnp.diff(bi)
    ka_t = _gather_window(ka, ai[:-1], a_len, tile, INT_MAX)
    va_t = _gather_window(va, ai[:-1], a_len, tile, 0)
    kb_t = _gather_window(kb, bi[:-1], b_len, tile, INT_MAX)
    vb_t = _gather_window(vb, bi[:-1], b_len, tile, 0)
    if use_kernel:
        keys2, vals2, _ = merge_tiles(ka_t, va_t, kb_t, vb_t,
                                      interpret=interpret)
    else:
        keys2, vals2, _ = merge_tiles_ref(ka_t, va_t, kb_t, vb_t)
    keys = keys2[:, :tile].reshape(-1)      # first `tile` outputs are real
    vals = vals2[:, :tile].reshape(-1)
    prev = jnp.concatenate([keys[:1] - 1, keys[:-1]])
    keep = (keys != prev) & (keys != INT_MAX)
    return keys, vals, keep


def merge_runs_dedup(ka, va, kb, vb, **kw):
    """Host-friendly wrapper returning dense deduped numpy arrays."""
    keys, vals, keep = merge_sorted_runs(jnp.asarray(ka, jnp.int32),
                                         jnp.asarray(va, jnp.int32),
                                         jnp.asarray(kb, jnp.int32),
                                         jnp.asarray(vb, jnp.int32), **kw)
    keys, vals, keep = map(np.asarray, (keys, vals, keep))
    return keys[keep], vals[keep]


def _pad_run(k, v, n):
    pad = n - k.shape[0]
    if pad:
        k = np.concatenate([k, np.full(pad, INT_MAX, np.int32)])
        v = np.concatenate([v, np.zeros(pad, np.int32)])
    return k, v


def ingest_run(keys, src, *, tile: int = 512, use_kernel: bool = True,
               interpret: bool = True):
    """Run-sized write-ingest entry point: dedup a pre-ordered write batch
    through the tile-merge kernel.

    ``keys`` (int32, >= 2 entries, in [0, INT_MAX)) is sorted ascending
    with the newest occurrence of each key *first* among equals; ``src``
    carries each entry's original batch position. The batch is split at
    its midpoint into two sorted halves (any contiguous slice of a sorted
    run is sorted) and merged by the Pallas kernel: run-A tie priority
    plus the global keep-mask keep exactly the first -- i.e. newest --
    occurrence of every key, whether its duplicates sit inside one half
    or span the split. Operands are padded to power-of-two lengths with
    INT_MAX sentinels (same size bucketing as the read path) so the jit
    compiles once per batch-size bucket.

    Returns dense int32 (unique_keys, surviving_src).
    """
    keys = np.asarray(keys, np.int32)
    src = np.asarray(src, np.int32)
    h = keys.shape[0] // 2
    ka, va = _pad_run(keys[:h], src[:h], next_pow2(h))
    kb, vb = _pad_run(keys[h:], src[h:], next_pow2(keys.shape[0] - h))
    return merge_runs_dedup(ka, va, kb, vb, tile=tile,
                            use_kernel=use_kernel, interpret=interpret)


@jax.jit
def _ranged_lookup(keys, vals, lo, hi, q):
    """Per-query lower-bound binary search of q[i] in keys[lo[i]:hi[i]]
    (each slice sorted), plus the hit test and payload gather -- one
    fused device invocation for a whole tier of concatenated runs.
    Same vectorized open-interval scheme as ``_diag_splits``."""
    n = keys.shape[0]

    def body(_, lohi):
        lo, hi = lohi
        open_ = lo < hi
        mid = (lo + hi) // 2
        less = keys[jnp.clip(mid, 0, max(n - 1, 0))] < q
        return (jnp.where(open_ & less, mid + 1, lo),
                jnp.where(open_ & ~less, mid, hi))

    pos, _ = jax.lax.fori_loop(0, 32, body, (lo, hi))
    safe = jnp.clip(pos, 0, max(n - 1, 0))
    hit = (pos < hi) & (keys[safe] == q)   # hi: the original range end
    return pos, hit, jnp.where(hit, vals[safe], 0)


def lookup_runs_device(keys, vals, lo, hi, queries):
    """Run-sized fused sorted probe: ``queries[i]`` against the sorted
    slice ``keys[lo[i]:hi[i]]`` of a tier's concatenated runs (device
    arrays, INT_MAX-padded). Queries are bucketed to a power of two
    (>= 256) with empty ranges so tiers sharing the (N, K-bucket) shape
    share the compiled search. Returns numpy (abs_pos, hit, val)."""
    q = jnp.asarray(queries, jnp.int32)
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    n = q.shape[0]
    m = next_pow2(max(1, n), lo=256)
    if m > n:
        z = jnp.zeros((m - n,), jnp.int32)
        q = jnp.concatenate([q, z])
        lo = jnp.concatenate([lo, z])
        hi = jnp.concatenate([hi, z])
    pos, hit, val = _ranged_lookup(keys, vals, lo, hi, q)
    return (np.asarray(pos[:n]).astype(np.int64),
            np.asarray(hit[:n]).astype(bool),
            np.asarray(val[:n]).astype(np.int64))


@partial(jax.jit, static_argnames=("tier_of", "k_hashes", "btile",
                                   "interpret"))
def _store_probe(fstack, keys, vals, q, gti_t, ns_t, w_t, lo, hi, *,
                 tier_of, k_hashes, btile, interpret):
    """The whole cross-tier read in ONE jitted invocation: the stacked
    tiered Bloom probe (per-table rows, segment-summed into per-tier
    membership by ``tier_of``), the ranged sorted probe of every
    (tier, query) pair over the store-wide concatenation, and the
    newest-wins tier argmin. Per tier, results are exactly what the
    per-tier fused pair (``probe_filters_multi`` + ``_ranged_lookup``)
    would produce."""
    per_table = probe_filters_tiered(fstack.astype(jnp.int32), q,
                                     gti_t, ns_t, w_t, k_hashes=k_hashes,
                                     tile=btile,
                                     interpret=interpret)    # [Tg, kpad]
    r, kpad = lo.shape
    member = jax.ops.segment_sum(per_table,
                                 jnp.asarray(tier_of, jnp.int32),
                                 num_segments=r) > 0         # [R, kpad]
    qf = jnp.broadcast_to(q[None, :], (r, kpad)).reshape(-1)
    pos, hit, val = _ranged_lookup(keys, vals, lo.reshape(-1),
                                   hi.reshape(-1), qf)
    pos = pos.reshape(r, kpad)
    hit = hit.reshape(r, kpad)
    val = val.reshape(r, kpad)
    # Newest-wins: the smallest tier rank whose probe hit, -1 when none
    # did (a hit implies a covering table, so ranking `hit` alone is the
    # staged path's first-resolving-tier order).
    ridx = jax.lax.broadcasted_iota(jnp.int32, (r, kpad), 0)
    win = jnp.min(jnp.where(hit, ridx, r), axis=0)
    return member, pos, hit, val, jnp.where(win < r, win, -1)


def lookup_store_device(fstack, keys, vals, queries, gti, ns, w, lo, hi, *,
                        tier_of: tuple, k_hashes: int = 7, btile: int = 256,
                        interpret: bool = True):
    """Store-sized fused cross-tier probe: ``queries`` against every
    lookup tier of a tree in a single device launch.

    ``fstack`` [Tg*128, Wmax] stacks all tables of all tiers tier-major
    (``tier_of``: global table index -> tier rank, static); ``keys``/
    ``vals`` are the store-wide INT_MAX-padded concatenation. Per
    (tier, query) metadata is [R, K]: ``gti`` the GLOBAL covering-table
    index (clipped, as ``assign_bounds`` leaves it), ``ns``/``w`` that
    table's filter geometry, ``lo``/``hi`` its run's span in the
    concatenation. Queries bucket to a power of two (>= 256); padding
    probes nothing (gti=-1) and searches nothing (lo=hi=0).

    Returns numpy (member [R,K] bool, abs_pos [R,K], hit [R,K], val
    [R,K], win [K]) with ``win`` the newest-wins tier rank (-1 = miss).
    """
    q = np.asarray(queries, np.int32)
    tmap = np.asarray(tier_of, np.int64)         # [Tg] table -> tier rank
    # Expand per-tier metadata to per-table rows (the constant-free block
    # layout the kernel grids over): row t repeats its tier's row.
    gti_t = np.asarray(gti, np.int32)[tmap]
    ns_t = np.asarray(ns, np.int32)[tmap]
    w_t = np.asarray(w, np.int32)[tmap]
    lo = np.asarray(lo, np.int32)
    hi = np.asarray(hi, np.int32)
    r_count = lo.shape[0]
    t_count = len(tier_of)
    n = q.shape[0]
    m = next_pow2(max(1, n), lo=256)
    if m > n:
        pad = m - n
        q = np.concatenate([q, np.zeros(pad, np.int32)])
        zt = np.zeros((t_count, pad), np.int32)
        gti_t = np.concatenate([gti_t, zt - 1], axis=1)
        ns_t = np.concatenate([ns_t, zt + 128], axis=1)
        w_t = np.concatenate([w_t, zt + 1], axis=1)
        zr = np.zeros((r_count, pad), np.int32)
        lo = np.concatenate([lo, zr], axis=1)
        hi = np.concatenate([hi, zr], axis=1)
    member, pos, hit, val, win = _store_probe(
        jnp.asarray(fstack), keys, vals, jnp.asarray(q),
        jnp.asarray(gti_t), jnp.asarray(ns_t), jnp.asarray(w_t),
        jnp.asarray(lo), jnp.asarray(hi), tier_of=tier_of,
        k_hashes=k_hashes, btile=btile, interpret=interpret)
    return (np.asarray(member[:, :n]).astype(bool),
            np.asarray(pos[:, :n]).astype(np.int64),
            np.asarray(hit[:, :n]).astype(bool),
            np.asarray(val[:, :n]).astype(np.int64),
            np.asarray(win[:n]).astype(np.int64))


def merge_runs_device(runs, *, tile: int = 512, use_kernel: bool = True,
                      interpret: bool = True):
    """Run-sized engine entry point: fold k sorted runs (ordered newest
    first, keys in [0, INT_MAX)) into one deduped run with newest-wins
    reconciliation.

    Each operand is padded to a power-of-two length with INT_MAX sentinels
    -- dropped by the kernel's keep-mask -- so the jitted tile composition
    compiles once per size bucket rather than once per exact run length.
    Returns dense int32 numpy (keys, vals).
    """
    rs = [(np.asarray(k, np.int32), np.asarray(v, np.int32))
          for k, v in runs if len(k)]
    if not rs:
        return np.empty(0, np.int32), np.empty(0, np.int32)
    ka, va = rs[0]
    for kb, vb in rs[1:]:
        ka_p, va_p = _pad_run(ka, va, next_pow2(ka.shape[0]))
        kb_p, vb_p = _pad_run(kb, vb, next_pow2(kb.shape[0]))
        ka, va = merge_runs_dedup(ka_p, va_p, kb_p, vb_p, tile=tile,
                                  use_kernel=use_kernel,
                                  interpret=interpret)
    return ka, va
