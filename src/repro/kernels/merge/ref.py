"""Pure-jnp oracle for the tile-merge kernel.

Semantics: given two sorted int32 key tiles (with int32 payloads), produce
the stable merged tile (a-keys first among equals — "newer run wins") plus
a keep-mask that drops all but the first occurrence of each key (LSM
reconciliation).
"""
from __future__ import annotations

import jax.numpy as jnp


def merge_tiles_ref(ka, va, kb, vb):
    """ka,kb: [G, Ba], [G, Bb] sorted int32 keys; va/vb payloads.

    Returns (keys [G, Ba+Bb], vals, keep [G, Ba+Bb] bool).
    """
    ga, ba = ka.shape
    _, bb = kb.shape
    # target position of each a[i]: i + #{b < a[i]}  (strict: a wins ties)
    rank_a = jnp.sum(kb[:, None, :] < ka[:, :, None], axis=-1) \
        + jnp.arange(ba)[None, :]
    # target of b[j]: j + #{a <= b[j]}
    rank_b = jnp.sum(ka[:, None, :] <= kb[:, :, None], axis=-1) \
        + jnp.arange(bb)[None, :]
    n = ba + bb
    keys = jnp.zeros((ga, n), ka.dtype)
    vals = jnp.zeros((ga, n), va.dtype)
    gi = jnp.arange(ga)[:, None]
    keys = keys.at[gi, rank_a].set(ka).at[gi, rank_b].set(kb)
    vals = vals.at[gi, rank_a].set(va).at[gi, rank_b].set(vb)
    keep = jnp.concatenate(
        [jnp.ones((ga, 1), bool), keys[:, 1:] != keys[:, :-1]], axis=1)
    return keys, vals, keep
