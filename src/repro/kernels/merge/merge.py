"""Pallas TPU kernel: batched sorted-tile merge (LSM compaction inner loop).

TPU adaptation (vs a CUDA merge-path kernel): no per-thread pointer
chasing. Each grid step merges one pair of sorted VMEM tiles:

  1. ranks by vectorized cross-tile comparison counts (VPU, 8x128 lanes)
     — ties break toward run A ("newer run wins"),
  2. scatter-by-rank through a one-hot matmul (MXU — the TPU-native way
     to permute data-dependently),
  3. reconciliation keep-mask via a shifted key compare.

The composition of tile merges into full-run compaction (merge-path block
boundaries) happens in ops.py via jnp.searchsorted on tile boundaries; the
kernel does the dense inner work.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_kernel(ka_ref, va_ref, kb_ref, vb_ref, ko_ref, vo_ref, keep_ref):
    ka = ka_ref[...]            # [1, Ba] int32 (sorted)
    kb = kb_ref[...]            # [1, Bb]
    va = va_ref[...]
    vb = vb_ref[...]
    ba = ka.shape[-1]
    bb = kb.shape[-1]
    n = ba + bb
    # ranks: a[i] -> i + #{b < a[i]};  b[j] -> j + #{a <= b[j]}
    rank_a = jnp.sum((kb[:, None, :] < ka[:, :, None]).astype(jnp.int32),
                     axis=-1) + jax.lax.broadcasted_iota(jnp.int32,
                                                         (1, ba), 1)
    rank_b = jnp.sum((ka[:, None, :] <= kb[:, :, None]).astype(jnp.int32),
                     axis=-1) + jax.lax.broadcasted_iota(jnp.int32,
                                                         (1, bb), 1)
    # one-hot scatter via MXU: out[t] = sum_s onehot[s,t] * v[s]
    tgt = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    oh_a = (rank_a[0][:, None] == tgt[0][None, :]).astype(jnp.float32)
    oh_b = (rank_b[0][:, None] == tgt[0][None, :]).astype(jnp.float32)

    def scatter(xa, xb):
        # exact int32 permute via two f32 matmuls (hi/lo 15-bit halves stay
        # well inside f32's 24-bit exact-integer range)
        def halves(x):
            return ((x >> 15).astype(jnp.float32),
                    (x & 0x7FFF).astype(jnp.float32))

        ha, la = halves(xa[0][None, :])
        hb, lb = halves(xb[0][None, :])
        dot = partial(jax.lax.dot, precision=jax.lax.Precision.HIGHEST)
        hi = dot(ha, oh_a) + dot(hb, oh_b)
        lo = dot(la, oh_a) + dot(lb, oh_b)
        return (hi.astype(jnp.int32) << 15) | lo.astype(jnp.int32)

    ko = scatter(ka, kb)
    vo = scatter(va, vb)
    ko_ref[...] = ko
    vo_ref[...] = vo
    prev = jnp.concatenate([ko[:, :1] - 1, ko[:, :-1]], axis=-1)
    keep_ref[...] = (ko != prev).astype(jnp.int32)


@partial(jax.jit, static_argnames=("interpret",))
def merge_tiles(ka, va, kb, vb, *, interpret: bool = True):
    """ka,kb: [G, Ba]/[G, Bb] sorted int32; returns (keys, vals, keep)."""
    g, ba = ka.shape
    bb = kb.shape[1]
    n = ba + bb
    grid = (g,)
    bspec = lambda b: pl.BlockSpec((1, b), lambda i: (i, 0))
    out_shapes = (
        jax.ShapeDtypeStruct((g, n), jnp.int32),
        jax.ShapeDtypeStruct((g, n), jnp.int32),
        jax.ShapeDtypeStruct((g, n), jnp.int32),
    )
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[bspec(ba), bspec(ba), bspec(bb), bspec(bb)],
        out_specs=(bspec(n), bspec(n), bspec(n)),
        out_shape=out_shapes,
        interpret=interpret,
    )(ka, va, kb, vb)
