"""Pure-jnp oracle for flash attention (causal / sliding-window / softcap)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q,k,v: [B, S, H, hd] (same H — expand GQA beforehand)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    sq, sk = q.shape[1], k.shape[1]
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    keep = jnp.ones((sq, sk), bool)
    if causal:
        keep &= kp <= qp
        if window > 0:
            keep &= kp > qp - window
    s = jnp.where(keep[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshk->bqhk", w.astype(v.dtype), v)
