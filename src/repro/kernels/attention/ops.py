"""jit'd wrapper: [B,S,H,hd] flash attention with GQA expansion and head-dim
padding to the TPU lane width (128)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bk",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    bq=128, bk=128, interpret=True):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd] with H % KV == 0. -> [B,Sq,H,hd]."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    hd_pad = -(-hd // 128) * 128 if hd > 128 or hd % 128 else hd
    if hd_pad != hd:
        pad = [(0, 0)] * 3 + [(0, hd_pad - hd)]
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, sq, q.shape[-1])
    kb = k.transpose(0, 2, 1, 3).reshape(b * h, sk, k.shape[-1])
    vb = v.transpose(0, 2, 1, 3).reshape(b * h, sk, v.shape[-1])
    # scale must use the ORIGINAL head dim: pre-scale q accordingly
    if hd_pad != hd:
        qb = qb * ((hd_pad / hd) ** 0.5)
    o = flash_attention_bhsd(qb, kb, vb, causal=causal, window=window,
                             softcap=softcap, bq=bq, bk=bk,
                             interpret=interpret)
    o = o.reshape(b, h, sq, -1).transpose(0, 2, 1, 3)
    return o[..., :hd]
