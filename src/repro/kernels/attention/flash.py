"""Pallas TPU kernel: flash attention (online softmax over KV tiles).

Grid (BH, nq, nk): each step loads one [BQ, hd] query tile and one
[BK, hd] KV tile into VMEM, updates the running (acc, m, l) online-softmax
state in VMEM scratch, and writes the normalized output at the last KV
step. Causal + sliding-window masking and gemma2's score softcap are
compile-time options. MXU work: the [BQ,hd]x[hd,BK] score matmul and the
[BQ,BK]x[BK,hd] value matmul; block sizes default to 128/256 so both fit
the 128x128 systolic tiles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m, l, *,
                  causal, window, softcap, bq, bk, nk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    q = q_ref[0]                                   # [BQ, hd]
    k = k_ref[0]                                   # [BK, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s *= q.shape[-1] ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kp = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    keep = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        keep &= kp <= qp
        if window > 0:
            keep &= kp > qp - window
    s = jnp.where(keep, s, NEG_INF)
    m_new = jnp.maximum(m[...], s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m[...] - m_new)
    l[...] = l[...] * corr + p.sum(axis=-1)
    acc[...] = acc[...] * corr[:, None] + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0],
        preferred_element_type=jnp.float32)
    m[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0] = (acc[...] / jnp.maximum(l[...], 1e-30)[:, None]) \
            .astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bk",
                                   "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=0, softcap=0.0,
                         bq=128, bk=128, interpret=True):
    """q: [BH, Sq, hd]; k,v: [BH, Sk, hd] (GQA pre-expanded). -> [BH,Sq,hd]"""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    grid = (bh, sq // bq, sk // bk)
    kernel = partial(_flash_kernel, causal=causal, window=window,
                     softcap=softcap, bq=bq, bk=bk, nk=sk // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[                  # VMEM online-softmax state
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
