"""Shared, jax-free sizing helpers for the kernel entry points and the
execution backends.

These are the single source of truth for run/batch size bucketing and
Bloom slot counts: cross-backend bit-parity of Bloom false positives
depends on every caller agreeing on them.
"""
from __future__ import annotations


def next_pow2(n: int, lo: int = 16) -> int:
    """Smallest power of two >= max(n, lo). Used to bucket operand sizes
    so jitted kernels compile once per bucket, not once per exact shape."""
    m = lo
    while m < n:
        m <<= 1
    return m


def slots_for(n_keys: int, bits_per_key: int = 10) -> int:
    """Bloom slot count for ``n_keys`` keys, rounded up to the kernel's
    128-row filter layout."""
    return max(128, -(-n_keys * bits_per_key // 128) * 128)
