# TPU Pallas kernels: merge (compaction), bloom (point lookups), attention (prefill).
