"""Pure-jnp oracle for the Bloom filter kernels.

Filter layout: int32 counts [128, W] (unpacked "byte-per-slot" — the TPU
adaptation: VPU-friendly saturating adds instead of read-modify-write bit
ops; packing to bits happens on flush to disk, outside the hot path).
Double hashing: slot_j(key) = (h1 + j*h2) mod n_slots, j = 0..k-1.
"""
from __future__ import annotations

import jax.numpy as jnp

import numpy as np

C1 = np.int32(0x9E3779B1 - 2**32)   # golden-ratio Knuth multiplier (int32)
C2 = np.int32(0x85EBCA77 - 2**32)


def _hashes(keys, n_slots: int, k_hashes: int):
    h1 = (keys * C1) % n_slots
    h2 = ((keys * C2) | 1) % n_slots
    j = jnp.arange(k_hashes, dtype=jnp.int32)
    return (h1[:, None] + j[None, :] * h2[:, None]) % n_slots   # [K, k]


def build_ref(keys, n_slots: int, k_hashes: int = 7):
    """keys: [N] non-negative int32 -> filter counts [128, n_slots//128]."""
    assert n_slots % 128 == 0
    slots = _hashes(keys.astype(jnp.int32), n_slots, k_hashes).reshape(-1)
    flat = jnp.zeros((n_slots,), jnp.int32).at[slots].add(1)
    return flat.reshape(128, n_slots // 128)


def probe_ref(filt, keys, k_hashes: int = 7):
    """filt: [128, W]; keys: [K] -> int32 membership mask [K]."""
    n_slots = filt.shape[0] * filt.shape[1]
    slots = _hashes(keys.astype(jnp.int32), n_slots, k_hashes)   # [K, k]
    vals = filt.reshape(-1)[slots]
    return jnp.all(vals > 0, axis=-1).astype(jnp.int32)


def probe_tiered_ref(fstack, keys, ti, nslots, w, k_hashes: int = 7):
    """Cross-tier oracle for ``probe_filters_tiered``. ``keys`` [K];
    ``ti``/``nslots``/``w`` [Tg, K] per (table, query), ``ti`` the
    *global* assigned-table index of each table's tier (-1 = none).
    out[t, q] = 1 iff ``ti[t, q] == t`` and table t's filter reports
    membership; tier membership is the segment-sum over its tables."""
    keys = keys.astype(jnp.int32)
    h1 = (keys[None, :] * C1) % nslots
    h2 = ((keys[None, :] * C2) | 1) % nslots
    j = jnp.arange(k_hashes, dtype=jnp.int32)
    slots = (h1[..., None] + j * h2[..., None]) % nslots[..., None]
    row = ti[..., None] * 128 + slots // w[..., None]
    col = slots % w[..., None]
    safe = jnp.clip(row, 0, fstack.shape[0] - 1)
    vals = fstack[safe, col]
    rows = jnp.arange(ti.shape[0], dtype=ti.dtype)[:, None]
    return (jnp.all(vals > 0, axis=-1)
            & (ti == rows)).astype(jnp.int32)


def probe_multi_ref(fstack, keys, ti, nslots, w, k_hashes: int = 7):
    """Fused multi-filter oracle: probe each key against *its own* table's
    filter in a stack of T filters.

    fstack: [T*128, Wmax] -- table t's [128, W_t] filter at rows
    [t*128, (t+1)*128), columns zero-padded to Wmax. Per-query arrays:
    ``ti`` (table index; -1 = padding, always a miss), ``nslots``/``w``
    (that table's slot count and column width). Same double-hash int32
    math as ``probe_ref``, with the modulus taken per-query.
    """
    keys = keys.astype(jnp.int32)
    h1 = (keys * C1) % nslots
    h2 = ((keys * C2) | 1) % nslots
    j = jnp.arange(k_hashes, dtype=jnp.int32)
    slots = (h1[:, None] + j[None, :] * h2[:, None]) % nslots[:, None]
    row = ti[:, None] * 128 + slots // w[:, None]
    col = slots % w[:, None]
    safe = jnp.clip(row, 0, fstack.shape[0] - 1)
    vals = fstack[safe, col]
    return (jnp.all(vals > 0, axis=-1)
            & (ti >= 0)).astype(jnp.int32)
