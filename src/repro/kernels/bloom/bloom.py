"""Pallas TPU kernels: Bloom filter build + probe.

TPU adaptation: no scatter/gather by data-dependent addresses (that is a
CUDA idiom). Both directions run through MXU one-hot matmuls over the
filter's factorized [128 rows x W cols] layout:

  build:  counts += onehot_rows^T @ onehot_cols      (per key-tile)
  probe:  rows = onehot_rows @ filter ; value = sum(rows * onehot_cols)

The filter stays resident in VMEM across grid steps (accumulator pattern:
initialized at step 0, revisited by every key tile).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import C1, C2


def _hash_onehots(keys, n_slots, w, k_hashes):
    """Per key and hash j: row/col one-hots. keys [K] -> ([K*k,128],[K*k,W])."""
    h1 = (keys * C1) % n_slots
    h2 = ((keys * C2) | 1) % n_slots
    j = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], k_hashes), 1)
    slots = (h1[:, None] + j * h2[:, None]) % n_slots            # [K, k]
    slots = slots.reshape(-1)                                    # [K*k]
    row = slots // w
    col = slots % w
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (slots.shape[0], 128), 1)
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (slots.shape[0], w), 1)
    oh_r = (row[:, None] == r_iota).astype(jnp.float32)
    oh_c = (col[:, None] == c_iota).astype(jnp.float32)
    return oh_r, oh_c


def _build_kernel(keys_ref, filt_ref, *, n_slots, w, k_hashes):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        filt_ref[...] = jnp.zeros_like(filt_ref)

    keys = keys_ref[...].reshape(-1)
    oh_r, oh_c = _hash_onehots(keys, n_slots, w, k_hashes)
    counts = jax.lax.dot(oh_r.T, oh_c,
                         precision=jax.lax.Precision.HIGHEST)    # [128, W]
    filt_ref[...] += counts.astype(jnp.int32)


def _probe_kernel(keys_ref, filt_ref, out_ref, *, n_slots, w, k_hashes):
    keys = keys_ref[...].reshape(-1)
    k = keys.shape[0]
    oh_r, oh_c = _hash_onehots(keys, n_slots, w, k_hashes)
    rows = jax.lax.dot(oh_r, filt_ref[...].astype(jnp.float32),
                       precision=jax.lax.Precision.HIGHEST)      # [K*k, W]
    vals = jnp.sum(rows * oh_c, axis=-1).reshape(k, k_hashes)
    out_ref[...] = jnp.all(vals > 0, axis=-1).astype(jnp.int32)[None, :]


@partial(jax.jit, static_argnames=("n_slots", "k_hashes", "tile",
                                   "interpret"))
def build_filter(keys, *, n_slots: int, k_hashes: int = 7, tile: int = 256,
                 interpret: bool = True):
    """keys: [N] (N % tile == 0, pad with a key whose slots you tolerate);
    returns int32 counts [128, n_slots//128]."""
    n = keys.shape[0]
    assert n % tile == 0 and n_slots % 128 == 0
    w = n_slots // 128
    return pl.pallas_call(
        partial(_build_kernel, n_slots=n_slots, w=w, k_hashes=k_hashes),
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((128, w), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((128, w), jnp.int32),
        interpret=interpret,
    )(keys.reshape(1, -1))


def _probe_multi_kernel(keys_ref, ti_ref, ns_ref, w_ref, filt_ref, out_ref,
                        *, wmax, k_hashes):
    """One grid step probes one query tile against one table's filter
    block; contributions land only where the query is assigned to that
    table (accumulator over the table axis -- no data-dependent filter
    selection needed)."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...].reshape(-1)
    ti = ti_ref[...].reshape(-1)
    ns = ns_ref[...].reshape(-1)
    w = w_ref[...].reshape(-1)
    k = keys.shape[0]
    # Same double hash as _hash_onehots, modulus per query.
    h1 = (keys * C1) % ns
    h2 = ((keys * C2) | 1) % ns
    j = jax.lax.broadcasted_iota(jnp.int32, (k, k_hashes), 1)
    slots = (h1[:, None] + j * h2[:, None]) % ns[:, None]        # [K, k]
    row = (slots // w[:, None]).reshape(-1)
    col = (slots % w[:, None]).reshape(-1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (row.shape[0], 128), 1)
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (row.shape[0], wmax), 1)
    oh_r = (row[:, None] == r_iota).astype(jnp.float32)
    oh_c = (col[:, None] == c_iota).astype(jnp.float32)
    rows = jax.lax.dot(oh_r, filt_ref[...].astype(jnp.float32),
                       precision=jax.lax.Precision.HIGHEST)      # [K*k, Wmax]
    vals = jnp.sum(rows * oh_c, axis=-1).reshape(k, k_hashes)
    member = jnp.all(vals > 0, axis=-1)
    out_ref[...] += jnp.where(ti == t, member,
                              False).astype(jnp.int32)[None, :]


@partial(jax.jit, static_argnames=("k_hashes", "tile", "interpret"))
def probe_filters_multi(fstack, keys, ti, nslots, w, *, k_hashes: int = 7,
                        tile: int = 256, interpret: bool = True):
    """fstack [T*128, Wmax] (T filters, columns zero-padded to Wmax);
    keys/ti/nslots/w [K] (K % tile == 0; ti = -1 marks padding) ->
    int32 mask [K]. Grid sweeps (query tile, table); the filter stays
    one [128, Wmax] block per step, so VMEM holds one table's filter at
    a time regardless of tier width."""
    k = keys.shape[0]
    assert k % tile == 0 and fstack.shape[0] % 128 == 0
    t_count = fstack.shape[0] // 128
    wmax = fstack.shape[1]
    out = pl.pallas_call(
        partial(_probe_multi_kernel, wmax=wmax, k_hashes=k_hashes),
        grid=(k // tile, t_count),
        in_specs=[pl.BlockSpec((1, tile), lambda i, t: (0, i)),
                  pl.BlockSpec((1, tile), lambda i, t: (0, i)),
                  pl.BlockSpec((1, tile), lambda i, t: (0, i)),
                  pl.BlockSpec((1, tile), lambda i, t: (0, i)),
                  pl.BlockSpec((128, wmax), lambda i, t: (t, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda i, t: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.int32),
        interpret=interpret,
    )(keys.reshape(1, -1), ti.reshape(1, -1), nslots.reshape(1, -1),
      w.reshape(1, -1), fstack)
    return out.reshape(-1)


def _probe_tiered_kernel(keys_ref, ti_ref, ns_ref, w_ref, filt_ref, out_ref,
                         *, wmax, k_hashes):
    """Cross-tier twin of ``_probe_multi_kernel``: grid step (i, t) probes
    query tile i against *global* table t's filter block and writes table
    t's own output row -- each (t, i) block is visited exactly once, so no
    accumulation is needed (and the index maps stay constant-free, a
    Pallas requirement). The caller segment-sums table rows into tier
    rows."""
    t = pl.program_id(1)
    keys = keys_ref[...].reshape(-1)
    ti = ti_ref[...].reshape(-1)                 # GLOBAL assigned table
    ns = ns_ref[...].reshape(-1)
    w = w_ref[...].reshape(-1)
    k = keys.shape[0]
    # Same double hash as _hash_onehots, modulus per query.
    h1 = (keys * C1) % ns
    h2 = ((keys * C2) | 1) % ns
    j = jax.lax.broadcasted_iota(jnp.int32, (k, k_hashes), 1)
    slots = (h1[:, None] + j * h2[:, None]) % ns[:, None]        # [K, k]
    row = (slots // w[:, None]).reshape(-1)
    col = (slots % w[:, None]).reshape(-1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (row.shape[0], 128), 1)
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (row.shape[0], wmax), 1)
    oh_r = (row[:, None] == r_iota).astype(jnp.float32)
    oh_c = (col[:, None] == c_iota).astype(jnp.float32)
    rows = jax.lax.dot(oh_r, filt_ref[...].astype(jnp.float32),
                       precision=jax.lax.Precision.HIGHEST)      # [K*k, Wmax]
    vals = jnp.sum(rows * oh_c, axis=-1).reshape(k, k_hashes)
    member = jnp.all(vals > 0, axis=-1)
    out_ref[...] = jnp.where(ti == t, member,
                             False).astype(jnp.int32)[None, :]


@partial(jax.jit, static_argnames=("k_hashes", "tile", "interpret"))
def probe_filters_tiered(fstack, keys, ti, nslots, w, *, k_hashes: int = 7,
                         tile: int = 256, interpret: bool = True):
    """fstack [Tg*128, Wmax]: ALL tables of ALL tiers of a store, stacked
    tier-major. keys [K]; ti/nslots/w are per (table, query) [Tg, K]: row
    t carries the GLOBAL covering-table index (and its filter geometry)
    that *t's tier* assigned each query (-1 = none, never a member).
    Returns int32 [Tg, K]: out[t, q] = 1 iff table t is q's assigned
    table in its tier AND the filter reports membership -- tier
    membership is the segment-sum of its tables' rows. One grid
    (K/tile, Tg), the same total step count as per-tier
    ``probe_filters_multi`` sweeps over every tier, collapsed into ONE
    launch; VMEM still holds one [128, Wmax] filter block per step."""
    k = keys.shape[0]
    assert k % tile == 0 and fstack.shape[0] % 128 == 0
    t_count = fstack.shape[0] // 128
    assert ti.shape[0] == t_count
    wmax = fstack.shape[1]
    row_of = lambda i, t: (t, i)                 # noqa: E731
    return pl.pallas_call(
        partial(_probe_tiered_kernel, wmax=wmax, k_hashes=k_hashes),
        grid=(k // tile, t_count),
        in_specs=[pl.BlockSpec((1, tile), lambda i, t: (0, i)),
                  pl.BlockSpec((1, tile), row_of),
                  pl.BlockSpec((1, tile), row_of),
                  pl.BlockSpec((1, tile), row_of),
                  pl.BlockSpec((128, wmax), lambda i, t: (t, 0))],
        out_specs=pl.BlockSpec((1, tile), row_of),
        out_shape=jax.ShapeDtypeStruct((t_count, k), jnp.int32),
        interpret=interpret,
    )(keys.reshape(1, -1), ti, nslots, w, fstack)


@partial(jax.jit, static_argnames=("k_hashes", "tile", "interpret"))
def probe_filter(filt, keys, *, k_hashes: int = 7, tile: int = 256,
                 interpret: bool = True):
    """filt [128, W]; keys [K] (K % tile == 0) -> int32 mask [K]."""
    k = keys.shape[0]
    assert k % tile == 0
    rows, w = filt.shape
    n_slots = rows * w
    out = pl.pallas_call(
        partial(_probe_kernel, n_slots=n_slots, w=w, k_hashes=k_hashes),
        grid=(k // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i)),
                  pl.BlockSpec((128, w), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.int32),
        interpret=interpret,
    )(keys.reshape(1, -1), filt)
    return out.reshape(-1)
