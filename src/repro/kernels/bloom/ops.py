"""jit'd wrappers for the Bloom kernels, with padding and a numpy facade
used by the LSM engine when running with --device-kernels."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .bloom import build_filter, probe_filter
from .ref import build_ref, probe_ref


def slots_for(n_keys: int, bits_per_key: int = 10) -> int:
    return max(128, -(-n_keys * bits_per_key // 128) * 128)


def bloom_build(keys, *, bits_per_key: int = 10, k_hashes: int = 7,
                use_kernel: bool = True, interpret: bool = True):
    keys = jnp.asarray(keys, jnp.int32)
    n_slots = slots_for(keys.shape[0], bits_per_key)
    tile = 256
    pad = (-keys.shape[0]) % tile
    if pad:
        # pad by repeating the first key (idempotent for membership)
        keys = jnp.concatenate([keys, jnp.broadcast_to(keys[:1], (pad,))])
    if use_kernel:
        return build_filter(keys, n_slots=n_slots, k_hashes=k_hashes,
                            interpret=interpret)
    return build_ref(keys, n_slots, k_hashes)


def bloom_probe(filt, keys, *, k_hashes: int = 7, use_kernel: bool = True,
                interpret: bool = True):
    keys = jnp.asarray(keys, jnp.int32)
    n = keys.shape[0]
    tile = 256
    pad = (-n) % tile
    if pad:
        keys = jnp.concatenate([keys, jnp.zeros((pad,), jnp.int32)])
    if use_kernel:
        out = probe_filter(filt, keys, k_hashes=k_hashes,
                           interpret=interpret)
    else:
        out = probe_ref(filt, keys, k_hashes)
    return np.asarray(out[:n]).astype(bool)
