"""jit'd wrappers for the Bloom kernels, with padding and a numpy facade
used by the LSM engine when running with --device-kernels."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..sizing import next_pow2, slots_for  # noqa: F401  (re-exported)
from .bloom import (build_filter, probe_filter, probe_filters_multi,
                    probe_filters_tiered)
from .ref import build_ref, probe_multi_ref, probe_ref, probe_tiered_ref


def bloom_build(keys, *, bits_per_key: int = 10, k_hashes: int = 7,
                use_kernel: bool = True, interpret: bool = True):
    keys = jnp.asarray(keys, jnp.int32)
    n_slots = slots_for(keys.shape[0], bits_per_key)
    tile = 256
    pad = (-keys.shape[0]) % tile
    if pad:
        # pad by repeating the first key (idempotent for membership)
        keys = jnp.concatenate([keys, jnp.broadcast_to(keys[:1], (pad,))])
    if use_kernel:
        return build_filter(keys, n_slots=n_slots, k_hashes=k_hashes,
                            interpret=interpret)
    return build_ref(keys, n_slots, k_hashes)


def bloom_build_run(keys, *, n_keys_padded: int | None = None,
                    n_slots: int | None = None, bits_per_key: int = 10,
                    k_hashes: int = 7, use_kernel: bool = True,
                    interpret: bool = True):
    """Run-sized engine entry point: build a filter over one SSTable's keys.

    Pads the key set to ``n_keys_padded`` (default: next power of two) by
    repeating the first key -- idempotent for membership -- and sizes the
    filter at exactly ``n_slots``, so an engine that buckets run sizes
    reuses compiled kernels across SSTables of similar size.
    """
    keys = jnp.asarray(keys, jnp.int32)
    n = keys.shape[0]
    assert n >= 1, "empty key set"
    if n_keys_padded is None:
        n_keys_padded = next_pow2(n, lo=256)
    if n_slots is None:
        n_slots = slots_for(n_keys_padded, bits_per_key)
    tile = 256
    total = -(-max(n_keys_padded, n) // tile) * tile
    if total > n:
        keys = jnp.concatenate(
            [keys, jnp.broadcast_to(keys[:1], (total - n,))])
    if use_kernel:
        return build_filter(keys, n_slots=n_slots, k_hashes=k_hashes,
                            interpret=interpret)
    return build_ref(keys, n_slots, k_hashes)


def bloom_probe_run(filt, keys, *, k_hashes: int = 7,
                    use_kernel: bool = True, interpret: bool = True):
    """Run-sized probe: bucket the query batch to a power of two (>= 256)
    so per-batch probes against many SSTables share compiled kernels.

    ``filt`` may be any integer/bool dtype (engines cache membership bits
    as bool to cut resident size); it is widened to the kernel's int32
    on-device, so only the 1-byte representation crosses the host boundary.
    """
    filt = jnp.asarray(filt).astype(jnp.int32)
    keys = jnp.asarray(keys, jnp.int32)
    n = keys.shape[0]
    m = next_pow2(max(1, n), lo=256)
    if m > n:
        keys = jnp.concatenate([keys, jnp.zeros((m - n,), jnp.int32)])
    if use_kernel:
        out = probe_filter(filt, keys, k_hashes=k_hashes,
                           interpret=interpret)
    else:
        out = probe_ref(filt, keys, k_hashes)
    return np.asarray(out[:n]).astype(bool)


def bloom_probe_multi(fstack, keys, ti, nslots, w, *, k_hashes: int = 7,
                      use_kernel: bool = True, interpret: bool = True):
    """Run-sized fused probe: each key against its assigned table's filter
    inside a stacked [T*128, Wmax] tier filter, one device invocation for
    the whole tier. Queries are bucketed to a power of two (>= 256) and
    padded with ti=-1 (never a member), so fused probes across tiers of
    the same (T, Wmax, K-bucket) share compiled kernels.
    """
    fstack = jnp.asarray(fstack).astype(jnp.int32)
    keys = jnp.asarray(keys, jnp.int32)
    ti = jnp.asarray(ti, jnp.int32)
    nslots = jnp.asarray(nslots, jnp.int32)
    w = jnp.asarray(w, jnp.int32)
    n = keys.shape[0]
    m = next_pow2(max(1, n), lo=256)
    if m > n:
        keys = jnp.concatenate([keys, jnp.zeros((m - n,), jnp.int32)])
        ti = jnp.concatenate([ti, jnp.full((m - n,), -1, jnp.int32)])
        nslots = jnp.concatenate([nslots,
                                  jnp.full((m - n,), 128, jnp.int32)])
        w = jnp.concatenate([w, jnp.ones((m - n,), jnp.int32)])
    if use_kernel:
        out = probe_filters_multi(fstack, keys, ti, nslots, w,
                                  k_hashes=k_hashes, interpret=interpret)
    else:
        out = probe_multi_ref(fstack, keys, ti, nslots, w, k_hashes)
    return np.asarray(out[:n]).astype(bool)


def bloom_probe_tiered(fstack, keys, ti, nslots, w, *, k_hashes: int = 7,
                       use_kernel: bool = True, interpret: bool = True):
    """Cross-tier fused probe: every query against its assigned table in
    EVERY tier of a store, one device invocation for the whole stack.

    ``fstack`` [Tg*128, Wmax] holds all tables of all tiers tier-major.
    ``keys`` [K]; ``ti``/``nslots``/``w`` [Tg, K] per (table, query) --
    row t carries the GLOBAL covering-table index (and geometry) that
    t's tier assigned each query (-1 = none, never a member). Queries
    are bucketed to a power of two (>= 256). Returns a bool [Tg, K]
    per-table matrix; a tier's membership is the OR over its tables'
    rows.
    """
    fstack = jnp.asarray(fstack).astype(jnp.int32)
    keys = jnp.asarray(keys, jnp.int32)
    ti = jnp.asarray(ti, jnp.int32)
    nslots = jnp.asarray(nslots, jnp.int32)
    w = jnp.asarray(w, jnp.int32)
    t_count = ti.shape[0]
    n = keys.shape[0]
    m = next_pow2(max(1, n), lo=256)
    if m > n:
        pad = m - n
        keys = jnp.concatenate([keys, jnp.zeros((pad,), jnp.int32)])
        ti = jnp.concatenate(
            [ti, jnp.full((t_count, pad), -1, jnp.int32)], axis=1)
        nslots = jnp.concatenate(
            [nslots, jnp.full((t_count, pad), 128, jnp.int32)], axis=1)
        w = jnp.concatenate(
            [w, jnp.ones((t_count, pad), jnp.int32)], axis=1)
    if use_kernel:
        out = probe_filters_tiered(fstack, keys, ti, nslots, w,
                                   k_hashes=k_hashes,
                                   interpret=interpret)
    else:
        out = probe_tiered_ref(fstack, keys, ti, nslots, w, k_hashes)
    return np.asarray(out[:, :n]).astype(bool)


def bloom_probe(filt, keys, *, k_hashes: int = 7, use_kernel: bool = True,
                interpret: bool = True):
    keys = jnp.asarray(keys, jnp.int32)
    n = keys.shape[0]
    tile = 256
    pad = (-n) % tile
    if pad:
        keys = jnp.concatenate([keys, jnp.zeros((pad,), jnp.int32)])
    if use_kernel:
        out = probe_filter(filt, keys, k_hashes=k_hashes,
                           interpret=interpret)
    else:
        out = probe_ref(filt, keys, k_hashes)
    return np.asarray(out[:n]).astype(bool)
