"""Parameter specs: one tree drives init, abstract (dry-run) params, and
NamedShardings.

Every model builds a pytree whose leaves are ``P(shape, axes)`` — logical
axis names per dimension. From that single tree we derive:

  * ``init_params``      — materialized arrays (per-leaf folded RNG),
  * ``abstract_params``  — ShapeDtypeStructs (dry-run: zero allocation),
  * ``make_shardings``   — NamedShardings via logical→mesh rules, skipping
                            axes that do not divide evenly.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclass(frozen=True)
class P:
    shape: tuple
    axes: tuple                     # logical axis names (len == len(shape))
    init: str = "normal"            # normal | zeros | ones
    scale: float = 1.0              # stddev for normal init
    dtype: str = ""                 # "" -> model param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, P)


def tree_map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def init_params(tree, key, default_dtype: str = "float32"):
    """Materialize arrays; each leaf gets a key folded from its path hash."""
    leaves = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)[0]

    def one(path, spec):
        dt = jnp.dtype(spec.dtype or default_dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        k = jax.random.fold_in(key, abs(hash(jax.tree_util.keystr(path)))
                               % (2**31))
        return (jax.random.normal(k, spec.shape, jnp.float32)
                * spec.scale).astype(dt)

    flat = {jax.tree_util.keystr(p): one(p, s) for p, s in leaves}
    treedef = jax.tree_util.tree_structure(tree, is_leaf=is_spec)
    return jax.tree_util.tree_unflatten(
        treedef, [flat[jax.tree_util.keystr(p)] for p, _ in leaves])


def abstract_params(tree, default_dtype: str = "float32"):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape,
                                       jnp.dtype(s.dtype or default_dtype)),
        tree)


def num_params(tree) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(tree, is_leaf=is_spec))


def spec_to_pspec(spec: P, mesh: Mesh, rules: dict) -> PartitionSpec:
    """Logical axes -> PartitionSpec, skipping non-divisible shardings and
    double-use of a mesh axis within one leaf. A rule value may be a LIST
    of candidates — the first that divides evenly and is unused wins
    (e.g. kv_seq: ["data", "model"] keeps decode KV caches resident when
    the batch already took the data axis)."""
    used = set()
    out = []
    for dim, ax in zip(spec.shape, spec.axes):
        rule = rules.get(ax)
        candidates = rule if isinstance(rule, list) else [rule]
        chosen = None
        for mesh_ax in candidates:
            if mesh_ax is None:
                continue
            axes = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
            size = int(np.prod([mesh.shape[m] for m in axes]))
            if dim % size == 0 and not any(m in used for m in axes):
                chosen = mesh_ax
                used.update(axes)
                break
        out.append(chosen)
    return PartitionSpec(*out)


def make_shardings(tree, mesh: Mesh, rules: dict):
    return tree_map_specs(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, mesh, rules)), tree)


def make_pspecs(tree, mesh: Mesh, rules: dict):
    return tree_map_specs(lambda s: spec_to_pspec(s, mesh, rules), tree)
