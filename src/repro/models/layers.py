"""Shared neural-net layers (pure functions over param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import shard_map
from .params import P


def softcap(x, cap: float):
    return jnp.where(cap > 0, cap * jnp.tanh(x / jnp.maximum(cap, 1e-6)), x) \
        if cap else x


# -- RMSNorm -----------------------------------------------------------------
def rmsnorm_spec(d: int) -> dict:
    return {"scale": P((d,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


# -- gated MLP (SwiGLU) --------------------------------------------------------
def mlp_spec(d: int, ff: int) -> dict:
    s = d ** -0.5
    return {
        "wi_gate": P((d, ff), ("embed", "mlp"), scale=s),
        "wi_up": P((d, ff), ("embed", "mlp"), scale=s),
        "wo": P((ff, d), ("mlp", "embed"), scale=ff ** -0.5),
    }


def mlp(p, x, compute_dtype):
    g = jnp.einsum("...d,df->...f", x, p["wi_gate"].astype(compute_dtype))
    u = jnp.einsum("...d,df->...f", x, p["wi_up"].astype(compute_dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(compute_dtype))


def mlp_psum_bf16(p, x, compute_dtype, mesh, data_axes=("pod", "data")):
    """Manual-collective TP MLP: shard_map over the model axis with an
    explicit bf16 psum. GSPMD's auto-partitioned path all-reduces the f32
    dot accumulator; reducing in bf16 halves the dominant TP collective."""
    from jax.sharding import PartitionSpec as PS
    dp = tuple(a for a in data_axes if a in mesh.shape)
    pspec = {"wi_gate": PS(None, "model"), "wi_up": PS(None, "model"),
             "wo": PS("model", None)}
    xspec = PS(dp)

    def fn(p_l, x_l):
        y = mlp(p_l, x_l, compute_dtype).astype(jnp.bfloat16)
        return jax.lax.psum(y, "model").astype(compute_dtype)

    return shard_map(fn, mesh=mesh, in_specs=(pspec, xspec),
                     out_specs=xspec, check_vma=False)(p, x)


# -- embeddings (tied; gemma-style sqrt(d) input scaling keeps both the
# embedding output and the tied-unembed logits at unit variance) -------------
def embed_spec(vocab: int, d: int) -> dict:
    return {"table": P((vocab, d), ("vocab", "embed"), scale=d ** -0.5)}


def embed(p, tokens, compute_dtype):
    d = p["table"].shape[-1]
    return p["table"].astype(compute_dtype)[tokens] * (d ** 0.5)


def unembed(p, x, compute_dtype):
    return jnp.einsum("...d,vd->...v", x, p["table"].astype(compute_dtype))


# -- rotary position embedding ----------------------------------------------------
def rope(x, positions, theta: float = 10_000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32)
                    / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]                        # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)
