"""State-space blocks: a shared chunked linear-recurrence helper + Mamba2.

The recurrence  h_t = a_t * h_{t-1} + u_t w_t^T,   y_t = h_t q_t
(with per-step scalar decay a_t and outer-product updates, state [dv, dk])
covers both Mamba2's SSD (u = dt*x, w = B, q = C) and mLSTM's matrix memory
(u = i*v, w = k, q = q). We evaluate it chunkwise — intra-chunk with dense
matmuls (MXU-friendly) and a lax.scan carrying the state across chunks —
which is the TPU-native adaptation of the CUDA "selective scan": instead of
a warp-level sequential scan we restructure the work into [chunk x chunk]
matmul tiles (see DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import P


def chunked_decay_scan(log_a, u, w, q, h0, chunk: int):
    """Evaluate the recurrence above for all t.

    log_a: [B,H,S] per-step log decay (<= 0)
    u:     [B,H,S,dv]   w,q: [B,H,S,dk]   h0: [B,H,dv,dk]
    Returns (y [B,H,S,dv], h_final [B,H,dv,dk]).
    """
    b, h, s = log_a.shape
    dv, dk = u.shape[-1], w.shape[-1]
    c = min(chunk, s)
    s_orig = s
    if s % c:                       # pad with identity steps (a=1, u=w=0)
        pad = c - s % c
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pad)))
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        s = s + pad
    n = s // c

    def to_chunks(x, extra):
        return x.reshape((b, h, n, c) + extra).transpose(
            (2, 0, 1, 3) + tuple(4 + i for i in range(len(extra))))

    la = to_chunks(log_a, ())                       # [n,B,H,c]
    uc = to_chunks(u, (dv,))
    wc = to_chunks(w, (dk,))
    qc = to_chunks(q, (dk,))

    def body(hc, xs):
        lai, ui, wi, qi = xs
        cum = jnp.cumsum(lai, axis=-1)              # [B,H,c] inclusive
        Ai = jnp.exp(cum)                           # decay from chunk start
        # intra-chunk: M[t,s] = exp(cum_t - cum_s) for s<=t else 0
        M = jnp.exp(cum[..., :, None] - cum[..., None, :])
        M = jnp.where(jnp.tril(jnp.ones((c, c), bool)), M, 0.0)
        qw = jnp.einsum("bhtk,bhsk->bhts", qi, wi).astype(jnp.float32)
        y_intra = jnp.einsum("bhts,bhsv->bhtv", qw * M, ui.astype(jnp.float32))
        y_inter = jnp.einsum("bhvk,bhtk->bhtv", hc,
                             qi.astype(jnp.float32)) * Ai[..., None]
        # state update: h' = A_c h + sum_s exp(cum_c - cum_s) u_s w_s^T
        suffix = jnp.exp(cum[..., -1:] - cum)       # [B,H,c]
        h_new = hc * jnp.exp(cum[..., -1])[..., None, None] + jnp.einsum(
            "bhs,bhsv,bhsk->bhvk", suffix, ui.astype(jnp.float32),
            wi.astype(jnp.float32))
        return h_new, (y_intra + y_inter).astype(u.dtype)

    h_fin, ys = jax.lax.scan(body, h0.astype(jnp.float32), (la, uc, wc, qc))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dv)
    return y[:, :, :s_orig], h_fin


def decay_scan_step(log_a, u, w, q, h):
    """Single-step (decode) version. log_a:[B,H] u:[B,H,dv] w,q:[B,H,dk]."""
    h_new = h * jnp.exp(log_a)[..., None, None].astype(h.dtype) \
        + jnp.einsum("bhv,bhk->bhvk", u, w).astype(h.dtype)
    y = jnp.einsum("bhvk,bhk->bhv", h_new, q).astype(u.dtype)
    return y, h_new


# ----------------------------- Mamba2 block -----------------------------------
CONV_K = 4   # depthwise causal conv width


def mamba2_spec(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d                 # inner width
    hd = cfg.ssm_head_dim
    nh = di // hd                           # ssm heads
    ds = cfg.ssm_state
    s = d ** -0.5
    return {
        # in_proj -> [z (di), x (di), B (ds), C (ds), dt (nh)]
        "in_proj": P((d, 2 * di + 2 * ds + nh), ("embed", "ssm_in"), scale=s),
        "conv": P((CONV_K, di + 2 * ds), ("conv_k", "ssm_conv"), scale=0.3),
        "A_log": P((nh,), ("ssm_heads",), init="zeros"),
        "D": P((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": P((nh,), ("ssm_heads",), init="zeros"),
        "norm": P((di,), ("ssm_inner",), init="ones"),
        "out_proj": P((di, d), ("ssm_inner", "embed"), scale=di ** -0.5),
    }


def _mamba_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    return di, nh, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_cache_spec(cfg, batch: int) -> dict:
    di, nh, hd, ds = _mamba_dims(cfg)
    return {
        "h": P((batch, nh, hd, ds),
               ("batch", "ssm_heads", "ssm_hd", "ssm_state"), init="zeros"),
        "conv": P((batch, CONV_K - 1, di + 2 * ds),
                  ("batch", "conv_k", "ssm_conv"), init="zeros"),
    }


def _causal_conv(x, kernel, conv_state=None):
    """Depthwise causal conv. x: [B,S,C], kernel: [K,C]."""
    k = kernel.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # [B, S+K-1, C]
    out = sum(xp[:, i:i + x.shape[1], :] * kernel[i][None, None, :]
              for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return out, new_state


def mamba2_block(cfg, p, x, cache=None):
    """x: [B,S,d]. cache: {"h","conv"} or None. Returns (out, new_cache)."""
    dt_ = x.dtype
    di, nh, hd, ds = _mamba_dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    z, xin, Bc, Cc, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + ds, 2 * di + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, p["conv"].astype(dt_),
        None if cache is None else cache["conv"])
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + ds], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B,S,nh]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # [nh] (<0)
    log_a = (dt * A).transpose(0, 2, 1)                        # [B,nh,S]
    b, s, _ = x.shape
    xh = xin.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)       # [B,nh,S,hd]
    u = xh * dt.transpose(0, 2, 1)[..., None].astype(dt_)
    w = jnp.broadcast_to(Bc[:, None], (b, nh, s, ds))
    q = jnp.broadcast_to(Cc[:, None], (b, nh, s, ds))
    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32) if cache is None \
        else cache["h"].astype(jnp.float32)
    if s == 1 and cache is not None:
        y, h_fin = decay_scan_step(log_a[..., 0], u[..., 0, :],
                                   w[..., 0, :], q[..., 0, :], h0)
        y = y[:, :, None, :]
    else:
        y, h_fin = chunked_decay_scan(log_a, u, w, q, h0, cfg.ssm_chunk)
    y = y + xh.astype(y.dtype) * p["D"].astype(y.dtype)[None, :, None, None]
    y = y.transpose(0, 2, 1, 3).reshape(b, s, di)
    # gated RMSNorm (Mamba2) then out-projection
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    new_cache = None if cache is None else {"h": h_fin.astype(cache["h"].dtype),
                                            "conv": conv_state.astype(cache["conv"].dtype)}
    return out, new_cache
