"""Attention: GQA with RoPE, sliding-window + score softcap variants,
full-softmax and chunked (flash-style, memory-bounded) implementations, and
KV-cache decode. Pure JAX; the Pallas flash kernel in ``repro.kernels`` is
the TPU-optimized drop-in for the same math (same oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import P, rope, softcap

NEG_INF = -2.0e38


def padded_heads(cfg):
    """(h_pad, kv_pad): head counts padded to cfg.head_pad_to multiples.

    Padded heads are masked to zero after attention, so they are
    mathematically dead — this only buys TP divisibility (e.g. minicpm's
    36 heads -> 48 over a 16-way model axis)."""
    h, kv = cfg.num_heads, cfg.num_kv_heads
    m = cfg.head_pad_to
    if not m:
        return h, kv
    h_pad = -(-h // m) * m
    kv_pad = kv if h_pad % kv == 0 else -(-kv // m) * m
    return h_pad, kv_pad


def attn_spec(cfg) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = padded_heads(cfg)
    s = d ** -0.5
    return {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim"), scale=s),
        "wk": P((d, kv, hd), ("embed", "kv_heads", "head_dim"), scale=s),
        "wv": P((d, kv, hd), ("embed", "kv_heads", "head_dim"), scale=s),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed"),
                scale=(h * hd) ** -0.5),
    }


def _expand_kv(k, n_rep: int):
    """[B,S,KV,hd] -> [B,S,KV*n_rep,hd] by repeating each kv head."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)) \
        .reshape(b, s, kv * n_rep, hd)


def qkv(cfg, p, x, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(q_pos, k_pos, window: int, causal: bool = True):
    """Causal (+ optional sliding window) mask: [.., Sq, Sk] bool keep."""
    if not causal:
        return jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1],
                                            k_pos.shape[-1]), bool)
    keep = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        keep &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return keep


def full_attention(cfg, q, k, v, q_pos, k_pos, window: int = 0,
                   softcap_val: float = 0.0, causal: bool = True):
    """Reference full-softmax attention. q:[B,Sq,H,hd] k,v:[B,Sk,KV,hd]."""
    h, kv = q.shape[2], k.shape[2]
    k = _expand_kv(k, h // kv)
    v = _expand_kv(v, h // kv)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    if softcap_val:
        scores = softcap(scores, softcap_val)
    keep = _mask(q_pos, k_pos, window, causal)[:, None, :, :]
    scores = jnp.where(keep, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", w, v)


def chunked_attention(cfg, q, k, v, q_pos, k_pos, window: int = 0,
                      softcap_val: float = 0.0, causal: bool = True):
    """Flash-style online-softmax attention, double scan over Q/KV chunks.

    Never materializes the [Sq, Sk] score matrix; memory is bounded by
    (chunk_q x chunk_kv). This is the XLA analogue of the Pallas kernel in
    ``repro.kernels.attention`` and is used for long-context lowering.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    cq = min(cfg.attn_chunk_q, sq)
    ck = min(cfg.attn_chunk_kv, sk)
    assert sq % cq == 0 and sk % ck == 0, (sq, cq, sk, ck)
    k = _expand_kv(k, h // kvh)
    v = _expand_kv(v, h // kvh)
    scale = hd ** -0.5
    qc = q.reshape(b, sq // cq, cq, h, hd).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(b, sq // cq, cq).transpose(1, 0, 2)
    kc = k.reshape(b, sk // ck, ck, h, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, sk // ck, ck, h, hd).transpose(1, 0, 3, 2, 4)
    kp = k_pos.reshape(b, sk // ck, ck).transpose(1, 0, 2)

    def q_block(_, qb):
        qi, qpi = qb                                  # [B,H,cq,hd], [B,cq]

        def kv_block(carry, kb):
            acc, m, l = carry
            ki, vi, kpi = kb
            s = jnp.einsum("bhqk,bhsk->bhqs", qi, ki).astype(jnp.float32) \
                * scale
            if softcap_val:
                s = softcap(s, softcap_val)
            keep = _mask(qpi, kpi, window, causal)[:, None, :, :]
            s = jnp.where(keep, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bhsk->bhqk", p.astype(vi.dtype), vi).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, h, cq, hd), jnp.float32)
        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_block, (acc0, m0, l0), (kc, vc, kp))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, (qc, qp))   # [nq,B,H,cq,hd]
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, hd)
    return out


def full_attention_ds(cfg, q, k, v, q_pos, k_pos, window: int = 0,
                      softcap_val: float = 0.0, causal: bool = True):
    """Dim-major cache layout: k,v: [B,KV,hd,Sk] — the decode-optimized
    layout. Two wins vs the baseline path: (1) scores consume K directly
    (no [S,hd]->[hd,S] transpose copies of the whole cache per layer);
    (2) GQA via grouped einsums — the KV cache is never expanded to H
    heads (the baseline materializes an H/KV-times-larger copy).
    q: [B,Sq,H,hd]."""
    b, sq, h, hd = q.shape
    kv, sk = k.shape[1], k.shape[3]
    rep = h // kv
    q5 = q.reshape(b, sq, kv, rep, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bqkrd,bkds->bkrqs", q5, k).astype(jnp.float32) \
        * scale
    if softcap_val:
        scores = softcap(scores, softcap_val)
    keep = _mask(q_pos, k_pos, window, causal)[:, None, None, :, :]
    scores = jnp.where(keep, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bkds->bqkrd", w, v)
    return out.reshape(b, sq, h, hd)


def attention_block(cfg, p, x, positions, *, layer_window: int = 0,
                    cache=None, impl: str = "auto", causal: bool = True):
    """Full block: qkv -> attention -> out-proj. With ``cache`` (decode/
    prefill-extend), k/v are written at ``positions`` into the cache.

    cache: dict(k, v) in the layout given by cfg.kv_layout, or None.
    Returns (out [B,S,d], new_cache).
    """
    dt = x.dtype
    q, k, v = qkv(cfg, p, x, positions)
    if cache is not None and cfg.kv_layout == "paged":
        # Paged pool layout (the device-side analogue of the runtime's
        # LSM-managed page tables): pool [P, page_tok, KV, hd] + per-row
        # page table. Decode writes one token into its page (scatter) and
        # gathers the row's pages into a dense view for attention.
        pt = cfg.kv_page_tokens
        kp, vp, table = cache["k_pool"], cache["v_pool"], cache["page_table"]
        b = x.shape[0]
        pos0 = positions[0, 0]
        page = table[:, pos0 // pt]                     # [B] pool rows
        off = pos0 % pt
        kp = kp.at[page, off].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[page, off].set(v[:, 0].astype(vp.dtype))
        k_all = kp[table].reshape(b, -1, *kp.shape[2:]).astype(dt)
        v_all = vp[table].reshape(b, -1, *vp.shape[2:]).astype(dt)
        k_pos = jnp.broadcast_to(jnp.arange(k_all.shape[1])[None, :],
                                 (b, k_all.shape[1]))
        out = full_attention(cfg, q, k_all, v_all, positions, k_pos,
                             window=layer_window,
                             softcap_val=cfg.attn_softcap, causal=causal)
        out = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))
        return out, {"k_pool": kp, "v_pool": vp, "page_table": table}
    ds = cache is not None and cfg.kv_layout == "ds"
    if cache is not None:
        ck, cv = cache["k"], cache["v"]
        idx = positions[0]                      # same positions per batch row
        if ds:                                  # cache: [B, KV, hd, S]
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.transpose(0, 2, 3, 1).astype(ck.dtype), idx[0], axis=3)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.transpose(0, 2, 3, 1).astype(cv.dtype), idx[0], axis=3)
            sk = ck.shape[3]
        else:                                   # cache: [B, S, KV, hd]
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), idx[0], axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), idx[0], axis=1)
            sk = ck.shape[1]
        k_all, v_all = ck.astype(dt), cv.astype(dt)
        k_pos = jnp.broadcast_to(jnp.arange(sk)[None, :], (x.shape[0], sk))
        new_cache = {"k": ck, "v": cv}
    else:
        k_all, v_all = k, v
        k_pos = positions
        new_cache = None
    if ds:
        out = full_attention_ds(cfg, q, k_all, v_all, positions, k_pos,
                                window=layer_window,
                                softcap_val=cfg.attn_softcap, causal=causal)
    else:
        use_chunked = (impl == "chunked"
                       or (impl == "auto"
                           and k_all.shape[1] > cfg.chunked_attn_threshold
                           and x.shape[1] > 1))
        fn = chunked_attention if use_chunked else full_attention
        out = fn(cfg, q, k_all, v_all, positions, k_pos,
                 window=layer_window, softcap_val=cfg.attn_softcap,
                 causal=causal)
    if cfg.head_pad_to and q.shape[2] != cfg.num_heads:
        # padded heads are dead: zero them so wo receives no gradient
        mask = (jnp.arange(q.shape[2]) < cfg.num_heads).astype(out.dtype)
        out = out * mask[None, None, :, None]
    out = jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))
    return out, new_cache
