"""Mixture-of-Experts block with expert parallelism.

Experts are sharded over the ``model`` mesh axis. Activations on the
residual stream are replicated across ``model`` (megatron-TP layout), so
dispatch needs **no all-to-all**: every model shard routes the full token
set to its local experts (capacity-bounded, sort-based dispatch with static
shapes), and a single psum over ``model`` combines expert outputs — the
same collective a dense TP FFN needs. Used by arctic-480b (top-2 of 128 +
dense residual) and granite-moe (top-8 of 32).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from ..compat import shard_map
from .layers import P


def moe_spec(cfg) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    s = d ** -0.5
    spec = {
        "router": P((d, e), ("embed", "experts_r"), scale=s),
        "wi_gate": P((e, d, ff), ("experts", "embed", "mlp"), scale=s),
        "wi_up": P((e, d, ff), ("experts", "embed", "mlp"), scale=s),
        "wo": P((e, ff, d), ("experts", "mlp", "embed"), scale=ff ** -0.5),
    }
    return spec


def _capacity(cfg, tokens: int) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor
              / max(cfg.num_experts, 1))
    return max(8, -(-cap // 8) * 8)        # round up to a multiple of 8


def _moe_local(cfg, p, x, e_start: int, e_local: int):
    """Per-shard MoE: route all local tokens to the shard's experts.

    x: [T, d] (this shard's tokens, replicated over the model axis).
    Returns this shard's contribution [T, d] (sum over shards = full MoE).
    """
    t, d = x.shape
    k = cfg.top_k
    cap = _capacity(cfg, t)
    logits = jnp.einsum("td,de->te", x, p["router"].astype(x.dtype))
    gates_full = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_k, eid_k = jax.lax.top_k(gates_full, k)                # [T,k]
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)
    eid = eid_k.reshape(-1)                                     # [T*k]
    gate = gate_k.reshape(-1).astype(x.dtype)
    tok = jnp.repeat(jnp.arange(t), k)
    le = eid - e_start
    valid = (le >= 0) & (le < e_local)
    le_sort = jnp.where(valid, le, e_local)                     # invalid last
    order = jnp.argsort(le_sort, stable=True)
    le_s, tok_s, gate_s = le_sort[order], tok[order], gate[order]
    # position of each pair within its expert segment
    seg_start = jnp.searchsorted(le_s, jnp.arange(e_local + 1))
    pos = jnp.arange(t * k) - seg_start[le_s]
    keep = (le_s < e_local) & (pos < cap)
    slot = jnp.where(keep, le_s * cap + pos, e_local * cap)     # drop slot
    # Receive-side dispatch: scatter only int32 indices, then gather rows —
    # avoids materializing a [t*k, d] send buffer.
    src = jnp.full((e_local * cap + 1,), t, jnp.int32).at[slot].set(tok_s)
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], 0)
    h = x_pad[src[:-1]].reshape(e_local, cap, d)
    g = jnp.einsum("ecd,edf->ecf", h, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, p["wi_up"].astype(x.dtype))
    o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   p["wo"].astype(x.dtype))
    o_flat = jnp.concatenate([o.reshape(e_local * cap, d),
                              jnp.zeros((1, d), x.dtype)], 0)
    y = jnp.zeros((t, d), x.dtype).at[tok_s].add(
        o_flat[slot] * gate_s[:, None])
    return y


def moe_block(cfg, p, x, mesh=None, data_axes=("data",), dense_mlp=None):
    """x: [B,S,d]. With a mesh: shard_map over (data..., model); experts are
    split over ``model`` and outputs psum-combined. Without a mesh: single
    shard holding all experts (smoke tests).

    ``dense_mlp`` (arctic's dense-residual FFN params) may be passed to
    compute the TP MLP *inside* the same shard_map so its reduction fuses
    into the MoE psum — one all-reduce per layer instead of two (perf
    knob ``fuse_moe_dense_ar``)."""
    b, s, d = x.shape
    if mesh is None or "model" not in mesh.shape:
        y = _moe_local(cfg, p, x.reshape(b * s, d), 0, cfg.num_experts)
        y = y.reshape(b, s, d)
        if dense_mlp is not None:
            from .layers import mlp
            y = y + mlp(dense_mlp, x, x.dtype)
        return y
    m = mesh.shape["model"]
    e_local = cfg.num_experts // m
    data_axes = tuple(a for a in data_axes if a in mesh.shape)

    # params: experts sharded over model on axis 0; router replicated
    pspec = {"router": PS(), "wi_gate": PS("model"), "wi_up": PS("model"),
             "wo": PS("model")}
    xspec = PS(data_axes)                 # batch sharded, model-replicated
    specs = (pspec, xspec)
    args = (p, x)
    if dense_mlp is not None:
        specs += ({"wi_gate": PS(None, "model"), "wi_up": PS(None, "model"),
                   "wo": PS("model", None)},)
        args += (dense_mlp,)

    def shard_fn(p_l, x_l, *rest):
        ax = jax.lax.axis_index("model")
        bl = x_l.shape[0] * x_l.shape[1]
        y = _moe_local(cfg, p_l, x_l.reshape(bl, d), ax * e_local, e_local)
        y = y.reshape(x_l.shape)
        if rest:                          # dense-residual partial sums
            from .layers import mlp
            y = y + mlp(rest[0], x_l, x_l.dtype)
        return jax.lax.psum(y, "model")   # ONE fused reduction

    return shard_map(shard_fn, mesh=mesh,
                     in_specs=specs, out_specs=xspec,
                     check_vma=False)(*args)
