"""CausalLM: assembles the assigned architectures from block slots.

Layers are evaluated with ``lax.scan`` over stacked per-layer params
(grouped into super-blocks of ``cfg.layer_period`` slots for alternating
structures: gemma2 local/global pairs, xLSTM mLSTM/sLSTM pairs, zamba2
groups of N mamba layers + one *shared-weight* attention block).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..runtime.sharding import constrain, current_mesh
from .attention import attention_block, attn_spec, padded_heads
from .layers import (P, embed, embed_spec, mlp, mlp_psum_bf16, mlp_spec,
                     rmsnorm, rmsnorm_spec, softcap, unembed)
from .moe import moe_block, moe_spec
from .ssm import mamba2_block, mamba2_cache_spec, mamba2_spec
from .xlstm import (mlstm_block, mlstm_cache_spec, mlstm_spec, slstm_block,
                    slstm_cache_spec, slstm_spec)


# ----------------------------- slot layout -----------------------------------
def block_slots(cfg) -> list:
    if cfg.family == "hybrid" and cfg.attn_every:
        return ["mamba"] * cfg.attn_every          # + shared attn per group
    if cfg.xlstm:
        return ["mlstm", "slstm"]
    if cfg.family == "moe":
        return ["attn_moe"] * max(1, len(cfg.attn_types))
    return [f"attn:{t}" for t in cfg.attn_types]


def n_super(cfg) -> int:
    period = len(block_slots(cfg))
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    return cfg.num_layers // period


def _slot_spec(cfg, slot: str) -> dict:
    d = cfg.d_model
    if slot == "mamba":
        return {"ln": rmsnorm_spec(d), "mamba": mamba2_spec(cfg)}
    if slot == "mlstm":
        return {"ln": rmsnorm_spec(d), "cell": mlstm_spec(cfg)}
    if slot == "slstm":
        return {"ln": rmsnorm_spec(d), "cell": slstm_spec(cfg)}
    if slot == "attn_moe":
        spec = {"ln": rmsnorm_spec(d), "attn": attn_spec(cfg),
                "ln2": rmsnorm_spec(d), "moe": moe_spec(cfg)}
        if cfg.moe_dense_ff:
            spec["mlp"] = mlp_spec(d, cfg.moe_dense_ff)
        return spec
    assert slot.startswith("attn:"), slot
    return {"ln": rmsnorm_spec(d), "attn": attn_spec(cfg),
            "ln2": rmsnorm_spec(d), "mlp": mlp_spec(d, cfg.d_ff)}


def _slot_cache_spec(cfg, slot: str, batch: int, max_len: int):
    kv, hd = padded_heads(cfg)[1], cfg.resolved_head_dim
    if slot.startswith("attn"):
        if cfg.kv_layout == "paged":  # page pool + page table (gather)
            pt = cfg.kv_page_tokens
            n_pages = -(-max_len // pt)
            pool = (batch * n_pages, pt, kv, hd)
            pax = ("kv_pool", "kv_seq", "kv_heads", "head_dim")
            return {"k_pool": P(pool, pax, init="zeros",
                                dtype=cfg.compute_dtype),
                    "v_pool": P(pool, pax, init="zeros",
                                dtype=cfg.compute_dtype),
                    "page_table": P((batch, n_pages), ("batch", None),
                                    init="zeros", dtype="int32")}
        if cfg.kv_layout == "ds":     # dim-major (decode-optimized) layout
            shape = (batch, kv, hd, max_len)
            axes = ("batch", "kv_heads", "head_dim", "kv_seq")
        else:
            shape = (batch, max_len, kv, hd)
            axes = ("batch", "kv_seq", "kv_heads", "head_dim")
        return {"k": P(shape, axes, init="zeros", dtype=cfg.compute_dtype),
                "v": P(shape, axes, init="zeros", dtype=cfg.compute_dtype)}
    if slot == "mamba":
        return mamba2_cache_spec(cfg, batch)
    if slot == "mlstm":
        return mlstm_cache_spec(cfg, batch)
    if slot == "slstm":
        return slstm_cache_spec(cfg, batch)
    raise ValueError(slot)


def _apply_slot(cfg, slot, p, x, positions, cache, mesh):
    if slot == "mamba":
        y, nc = mamba2_block(cfg, p["mamba"], rmsnorm(p["ln"], x,
                                                      cfg.norm_eps), cache)
        return x + y, nc
    if slot == "mlstm":
        y, nc = mlstm_block(cfg, p["cell"], rmsnorm(p["ln"], x, cfg.norm_eps),
                            cache)
        return x + y, nc
    if slot == "slstm":
        y, nc = slstm_block(cfg, p["cell"], rmsnorm(p["ln"], x, cfg.norm_eps),
                            cache)
        return x + y, nc
    window = cfg.window if slot == "attn:local" else 0
    h_in = rmsnorm(p["ln"], x, cfg.norm_eps)
    if cfg.seq_shard_attn and cache is None:
        # sequence-parallel attention: shard S over the model axis so the
        # qkv/o projections and scores stay balanced even when the head
        # count does not divide the TP size (minicpm 36H, arctic 56H)
        h_in = constrain(h_in, "batch", "seq_model", None)
    y, nc = attention_block(cfg, p["attn"], h_in, positions,
                            layer_window=window, cache=cache)
    x = x + y
    x = constrain(x, "batch", None, None)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if slot == "attn_moe":
        fuse = (cfg.fuse_moe_dense_ar and cfg.moe_dense_ff
                and mesh is not None and "model" in mesh.shape)
        y2 = moe_block(cfg, p["moe"], h, mesh=mesh,
                       data_axes=("pod", "data"),
                       dense_mlp=p["mlp"] if fuse else None)
        if cfg.moe_dense_ff and not fuse:
            y2 = y2 + mlp(p["mlp"], h, x.dtype)
    elif cfg.mlp_psum_bf16 and mesh is not None and "model" in mesh.shape:
        y2 = mlp_psum_bf16(p["mlp"], h, x.dtype, mesh)
    else:
        y2 = mlp(p["mlp"], h, x.dtype)
    return x + y2, nc


# ----------------------------- the model --------------------------------------
class CausalLM:
    """Decoder-only LM (also hosts the VLM with a patch-embedding stub)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.slots = block_slots(cfg)
        self.n_super = n_super(cfg)
        self.shared_attn = cfg.family == "hybrid" and cfg.attn_every > 0

    # -- specs ------------------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg

        def stack(tree):
            return jax.tree.map(
                lambda s: P((self.n_super,) + s.shape, ("layers",) + s.axes,
                            init=s.init, scale=s.scale, dtype=s.dtype),
                tree, is_leaf=lambda s: isinstance(s, P))

        spec = {
            "embed": embed_spec(cfg.padded_vocab, cfg.d_model),
            "final_norm": rmsnorm_spec(cfg.d_model),
            "blocks": [stack(_slot_spec(cfg, s)) for s in self.slots],
        }
        if self.shared_attn:
            spec["shared_attn"] = {"ln": rmsnorm_spec(cfg.d_model),
                                   "attn": attn_spec(cfg),
                                   "ln2": rmsnorm_spec(cfg.d_model),
                                   "mlp": mlp_spec(cfg.d_model, cfg.d_ff)}
        if cfg.frontend:
            spec["frontend_proj"] = {
                "w": P((cfg.d_model, cfg.d_model), ("embed", None),
                       scale=cfg.d_model ** -0.5)}
        return spec

    def cache_specs(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg

        def stack(tree):
            return jax.tree.map(
                lambda s: P((self.n_super,) + s.shape, ("layers",) + s.axes,
                            init=s.init, dtype=s.dtype),
                tree, is_leaf=lambda s: isinstance(s, P))

        cache = {"blocks": [stack(_slot_cache_spec(cfg, s, batch, max_len))
                            for s in self.slots]}
        if self.shared_attn:
            cache["shared_attn"] = stack(
                _slot_cache_spec(cfg, "attn:global", batch, max_len))
        return cache

    # -- forward ------------------------------------------------------------------
    def _embed_inputs(self, params, tokens, frontend_embeds):
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        x = embed(params["embed"], tokens, dt)
        if cfg.frontend and frontend_embeds is not None:
            fe = jnp.einsum("bfd,de->bfe", frontend_embeds.astype(dt),
                            params["frontend_proj"]["w"].astype(dt))
            x = jnp.concatenate([fe, x], axis=1)
        return x

    def _run_blocks(self, params, x, positions, cache):
        cfg = self.cfg
        mesh = current_mesh()
        use_cache = cache is not None

        def body(x, xs):
            blocks_p, sh_cache, block_caches = xs
            new_caches = []
            for i, slot in enumerate(self.slots):
                c = block_caches[i] if use_cache else None
                x2, nc = _apply_slot(cfg, slot, blocks_p[i], x, positions, c,
                                     mesh)
                x = x2
                new_caches.append(nc if use_cache else 0)
            if self.shared_attn:
                x2, nsh = _apply_slot(cfg, "attn:global",
                                      params["shared_attn"], x, positions,
                                      sh_cache if use_cache else None, mesh)
                x = x2
            else:
                nsh = 0
            return x, (nsh if use_cache else 0, tuple(new_caches))

        if cfg.remat != "none":
            policy = (jax.checkpoint_policies.nothing_saveable
                      if cfg.remat == "full"
                      else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            body = jax.checkpoint(body, policy=policy,
                                  prevent_cse=False)
        xs = (tuple(params["blocks"]),
              cache.get("shared_attn") if use_cache else None,
              tuple(cache["blocks"]) if use_cache else tuple(
                  None for _ in self.slots))
        # lax.scan needs uniform xs pytrees; in no-cache mode feed zeros
        if not use_cache:
            xs = (tuple(params["blocks"]),
                  jnp.zeros((self.n_super,), jnp.int32),
                  tuple(jnp.zeros((self.n_super,), jnp.int32)
                        for _ in self.slots))
        x, new_caches = jax.lax.scan(body, x, xs)
        if use_cache:
            sh, blocks = new_caches
            out_cache = {"blocks": list(blocks)}
            if self.shared_attn:
                out_cache["shared_attn"] = sh
            return x, out_cache
        return x, None

    def _logits(self, params, x):
        cfg = self.cfg
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, x.dtype)
        logits = constrain(logits, "batch", None, "vocab_logits")
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        if cfg.padded_vocab != cfg.vocab_size:   # mask vocab padding
            logits = jnp.where(jnp.arange(cfg.padded_vocab)
                               < cfg.vocab_size, logits, -1e30)
        return logits

    def apply(self, params, tokens, frontend_embeds=None):
        """Teacher-forcing forward: tokens [B,S_text] -> logits [B,S,V]."""
        x = self._embed_inputs(params, tokens, frontend_embeds)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = constrain(x, "batch", None, None)
        x, _ = self._run_blocks(params, x, positions, None)
        return self._logits(params, x)

    def prefill(self, params, tokens, cache, frontend_embeds=None):
        x = self._embed_inputs(params, tokens, frontend_embeds)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x, cache = self._run_blocks(params, x, positions, cache)
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, token, cache, pos):
        """token: [B,1]; pos: scalar int32 position. One decode step."""
        x = embed(params["embed"], token, jnp.dtype(self.cfg.compute_dtype))
        positions = jnp.broadcast_to(pos, (x.shape[0], 1)).astype(jnp.int32)
        x, cache = self._run_blocks(params, x, positions, cache)
        return self._logits(params, x), cache
