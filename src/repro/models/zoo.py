"""Model zoo: build any assigned architecture from its config."""
from __future__ import annotations

from .encdec import EncDecLM
from .transformer import CausalLM


def build_model(cfg):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return CausalLM(cfg)
