"""Encoder-decoder LM (seamless-m4t backbone). The audio frontend is a stub:
``frontend_embeds`` [B, frames, d_model] are provided pre-computed.

Decoder KV caches: self-attention cache (grows during decode) + cross-
attention KV (computed once at prefill from the encoder output).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..runtime.sharding import constrain
from .attention import attention_block, attn_spec, full_attention, qkv
from .layers import (P, embed, embed_spec, mlp, mlp_spec, rmsnorm,
                     rmsnorm_spec, unembed)


def _enc_layer_spec(cfg):
    return {"ln": rmsnorm_spec(cfg.d_model), "attn": attn_spec(cfg),
            "ln2": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff)}


def _dec_layer_spec(cfg):
    return {"ln": rmsnorm_spec(cfg.d_model), "attn": attn_spec(cfg),
            "lnx": rmsnorm_spec(cfg.d_model), "xattn": attn_spec(cfg),
            "ln2": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff)}


def _stack(tree, n):
    return jax.tree.map(
        lambda s: P((n,) + s.shape, ("layers",) + s.axes, init=s.init,
                    scale=s.scale, dtype=s.dtype),
        tree, is_leaf=lambda s: isinstance(s, P))


def _cross_attend(cfg, p, x, enc_k, enc_v):
    """Cross attention: q from decoder x, precomputed encoder k/v."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    out = full_attention(cfg, q, enc_k.astype(dt), enc_v.astype(dt),
                         jnp.zeros(x.shape[:2], jnp.int32),
                         jnp.zeros(enc_k.shape[:2], jnp.int32),
                         causal=False)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"].astype(dt))


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    def param_specs(self) -> dict:
        cfg = self.cfg
        return {
            "embed": embed_spec(cfg.padded_vocab, cfg.d_model),
            "frontend_proj": {"w": P((cfg.d_model, cfg.d_model),
                                     ("embed", None),
                                     scale=cfg.d_model ** -0.5)},
            "enc": _stack(_enc_layer_spec(cfg), cfg.enc_layers),
            "enc_norm": rmsnorm_spec(cfg.d_model),
            "dec": _stack(_dec_layer_spec(cfg), cfg.dec_layers),
            "final_norm": rmsnorm_spec(cfg.d_model),
        }

    def cache_specs(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        f = cfg.frontend_tokens
        kvspec = {
            "k": P((batch, max_len, kv, hd),
                   ("batch", "kv_seq", "kv_heads", "head_dim"),
                   init="zeros", dtype=cfg.compute_dtype),
            "v": P((batch, max_len, kv, hd),
                   ("batch", "kv_seq", "kv_heads", "head_dim"),
                   init="zeros", dtype=cfg.compute_dtype)}
        xspec = {
            "k": P((batch, f, kv, hd),
                   ("batch", "frames", "kv_heads", "head_dim"),
                   init="zeros", dtype=cfg.compute_dtype),
            "v": P((batch, f, kv, hd),
                   ("batch", "frames", "kv_heads", "head_dim"),
                   init="zeros", dtype=cfg.compute_dtype)}
        return {"self": _stack(kvspec, cfg.dec_layers),
                "cross": _stack(xspec, cfg.dec_layers)}

    # -- encoder -------------------------------------------------------------
    def encode(self, params, frontend_embeds):
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        x = jnp.einsum("bfd,de->bfe", frontend_embeds.astype(dt),
                       params["frontend_proj"]["w"].astype(dt))
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

        def body(x, p):
            y, _ = attention_block(cfg, p["attn"],
                                   rmsnorm(p["ln"], x, cfg.norm_eps), pos,
                                   causal=False)
            x = x + y
            x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), dt)
            return x, None

        if cfg.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc"])
        return rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    def _precompute_cross_kv(self, params, enc_out):
        cfg = self.cfg
        b, f, _ = enc_out.shape
        pos = jnp.zeros((b, f), jnp.int32)

        def body(_, p):
            _, k, v = qkv(cfg, p["xattn"], enc_out, pos)
            return None, {"k": k, "v": v}

        _, kv = jax.lax.scan(body, None, params["dec"])
        return kv

    # -- decoder -------------------------------------------------------------
    def _decode_blocks(self, params, x, positions, self_cache, cross_kv):
        cfg = self.cfg
        use_cache = self_cache is not None

        def body(x, xs):
            p, sc, xkv = xs
            y, nc = attention_block(cfg, p["attn"],
                                    rmsnorm(p["ln"], x, cfg.norm_eps),
                                    positions, cache=sc if use_cache else None)
            x = x + y
            x = constrain(x, "batch", None, None)
            x = x + _cross_attend(cfg, p["xattn"],
                                  rmsnorm(p["lnx"], x, cfg.norm_eps),
                                  xkv["k"], xkv["v"])
            x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), x.dtype)
            return x, nc if use_cache else 0

        if cfg.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False)
        sc = self_cache if use_cache else jnp.zeros((cfg.dec_layers,),
                                                    jnp.int32)
        x, new_sc = jax.lax.scan(body, x, (params["dec"], sc, cross_kv))
        return x, (new_sc if use_cache else None)

    def _logits(self, params, x):
        cfg = self.cfg
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, x.dtype)
        logits = constrain(logits, "batch", None, "vocab_logits")
        logits = logits.astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            logits = jnp.where(jnp.arange(cfg.padded_vocab)
                               < cfg.vocab_size, logits, -1e30)
        return logits

    # -- public API -----------------------------------------------------------
    def apply(self, params, tokens, frontend_embeds=None):
        """Training forward: encoder on frames, decoder teacher-forcing."""
        cfg = self.cfg
        enc_out = self.encode(params, frontend_embeds)
        cross_kv = self._precompute_cross_kv(params, enc_out)
        dt = jnp.dtype(cfg.compute_dtype)
        x = embed(params["embed"], tokens, dt)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x, _ = self._decode_blocks(params, x, pos, None, cross_kv)
        return self._logits(params, x)

    def prefill(self, params, tokens, cache, frontend_embeds=None):
        cfg = self.cfg
        enc_out = self.encode(params, frontend_embeds)
        cross_kv = self._precompute_cross_kv(params, enc_out)
        cross_kv = jax.tree.map(
            lambda a, c: a.astype(c.dtype), cross_kv, cache["cross"])
        dt = jnp.dtype(cfg.compute_dtype)
        x = embed(params["embed"], tokens, dt)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x, self_c = self._decode_blocks(params, x, pos, cache["self"],
                                        cross_kv)
        logits = self._logits(params, x[:, -1:])
        return logits, {"self": self_c, "cross": cross_kv}

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        x = embed(params["embed"], token, dt)
        positions = jnp.broadcast_to(pos, (x.shape[0], 1)).astype(jnp.int32)
        x, self_c = self._decode_blocks(params, x, positions, cache["self"],
                                        cache["cross"])
        logits = self._logits(params, x)
        return logits, {"self": self_c, "cross": cache["cross"]}
