from .params import (P, abstract_params, init_params, make_pspecs,  # noqa: F401
                     make_shardings, num_params)
from .transformer import CausalLM  # noqa: F401
from .encdec import EncDecLM  # noqa: F401
from .zoo import build_model  # noqa: F401
