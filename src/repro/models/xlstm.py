"""xLSTM blocks: mLSTM (matrix memory, parallelizable — shares the chunked
decayed-outer-product scan with Mamba2) and sLSTM (scalar memory with true
hidden-to-hidden recurrence — evaluated with lax.scan; inherently
sequential, as in the paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import P
from .ssm import chunked_decay_scan, decay_scan_step


# ----------------------------- mLSTM -----------------------------------------
def mlstm_spec(cfg) -> dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    s = d ** -0.5
    return {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim"), scale=s),
        "wk": P((d, h, hd), ("embed", "heads", "head_dim"), scale=s),
        "wv": P((d, h, hd), ("embed", "heads", "head_dim"), scale=s),
        "wi": P((d, h), ("embed", "heads"), scale=s * 0.1),
        "wf": P((d, h), ("embed", "heads"), scale=s * 0.1),
        "bf": P((h,), ("heads",), init="ones"),
        "wo_gate": P((d, h, hd), ("embed", "heads", "head_dim"), scale=s),
        "norm": P((h * hd,), ("ssm_inner",), init="ones"),
        "wo": P((h * hd, d), ("ssm_inner", "embed"), scale=(h * hd) ** -0.5),
    }


def mlstm_cache_spec(cfg, batch: int) -> dict:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    return {"C": P((batch, h, hd + 1, hd),
                   ("batch", "heads", "ssm_hd", "ssm_state"), init="zeros")}


def mlstm_block(cfg, p, x, cache=None):
    """x: [B,S,d]. Matrix-memory LSTM: C' = f C + i v k^T ; y = C q / n.q.

    The normalizer n is carried as an extra state row (dv+1 trick).
    """
    dt = x.dtype
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(dt)) * hd ** -0.5
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(dt))
    i_gate = jnp.exp(jnp.einsum("bsd,dh->bhs", x, p["wi"].astype(dt))
                     .astype(jnp.float32).clip(-10, 10))
    f_raw = jnp.einsum("bsd,dh->bhs", x, p["wf"].astype(dt)) \
        .astype(jnp.float32) + p["bf"].astype(jnp.float32)[None, :, None]
    log_f = jax.nn.log_sigmoid(f_raw)                       # decay in (0,1)
    # stack v with ones so the same scan tracks the normalizer n
    u = jnp.concatenate([v, jnp.ones_like(v[..., :1])], -1) \
        * i_gate[..., None].astype(dt)                      # [B,H,S,hd+1]
    h0 = jnp.zeros((b, h, hd + 1, hd), jnp.float32) if cache is None \
        else cache["C"].astype(jnp.float32)
    if s == 1 and cache is not None:
        y, h_fin = decay_scan_step(log_f[..., 0], u[:, :, 0], k[:, :, 0],
                                   q[:, :, 0], h0)
        y = y[:, :, None, :]
    else:
        y, h_fin = chunked_decay_scan(log_f, u, k, q, h0, cfg.ssm_chunk)
    num, den = y[..., :hd], y[..., hd:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    o = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bhsk", x, p["wo_gate"].astype(dt)))
    y = (y.astype(dt) * o).transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(dt)
    out = jnp.einsum("bsz,zd->bsd", y, p["wo"].astype(dt))
    new_cache = None if cache is None else \
        {"C": h_fin.astype(cache["C"].dtype)}
    return out, new_cache


# ----------------------------- sLSTM -----------------------------------------
def slstm_spec(cfg) -> dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    s = d ** -0.5
    return {
        # input projections for gates z,i,f,o
        "wx": P((d, 4, h, hd), ("embed", "gates", "heads", "head_dim"),
                scale=s),
        # block-diagonal (per-head) recurrent weights
        "wr": P((h, hd, 4, hd), ("heads", "head_dim", "gates", "ssm_state"),
                scale=hd ** -0.5),
        "b": P((4, h, hd), ("gates", "heads", "head_dim"), init="zeros"),
        "norm": P((h * hd,), ("ssm_inner",), init="ones"),
        "wo": P((h * hd, d), ("ssm_inner", "embed"), scale=(h * hd) ** -0.5),
    }


def slstm_cache_spec(cfg, batch: int) -> dict:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    ax = ("batch", "heads", "head_dim")
    return {"c": P((batch, h, hd), ax, init="zeros"),
            "n": P((batch, h, hd), ax, init="zeros"),
            "h": P((batch, h, hd), ax, init="zeros"),
            "m": P((batch, h, hd), ax, init="zeros")}


def _slstm_step(p_wr, p_b, xg, state):
    """One sLSTM step. xg: [B,4,H,hd] pre-computed input projections."""
    c, n, hh, m = state
    rec = jnp.einsum("bhk,hkgs->bghs", hh, p_wr)            # [B,4,H,hd]
    g = xg.astype(jnp.float32) + rec.astype(jnp.float32) \
        + p_b.astype(jnp.float32)[None]
    z, i_raw, f_raw, o_raw = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_raw)
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)                   # stabilizer
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(log_f + m - m_new)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * (c_new / jnp.maximum(n_new, 1.0))
    return (c_new, n_new, h_new, m_new)


def slstm_block(cfg, p, x, cache=None):
    dt = x.dtype
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    xg = jnp.einsum("bsd,dghk->bsghk", x, p["wx"].astype(dt))
    if cache is None:
        zeros = jnp.zeros((b, h, hd), jnp.float32)
        state = (zeros, zeros, zeros, zeros)   # m=0 matches the cache init
    else:
        state = tuple(cache[k].astype(jnp.float32)
                      for k in ("c", "n", "h", "m"))

    def body(st, xg_t):
        st2 = _slstm_step(p["wr"], p["b"], xg_t, st)
        return st2, st2[2]

    state, hs = jax.lax.scan(body, state, xg.transpose(1, 0, 2, 3, 4))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, h * hd).astype(dt)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(dt)
    out = jnp.einsum("bsz,zd->bsd", y, p["wo"].astype(dt))
    new_cache = None if cache is None else dict(zip(
        ("c", "n", "h", "m"), (st.astype(cache["c"].dtype)
                               for st in state)))
    return out, new_cache
