"""Model/run configuration + registry for the assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, replace

_REGISTRY: dict = {}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # attention variants
    attn_types: tuple = ("global",)   # cycled per layer ("local","global")
    window: int = 4096                # sliding window for local layers
    attn_softcap: float = 0.0         # gemma2: softcap on attention scores
    logit_softcap: float = 0.0        # gemma2: softcap on final logits
    rope_theta: float = 10_000.0

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0        # arctic: dense residual FFN beside the MoE
    capacity_factor: float = 1.25

    # SSM / hybrid (zamba2-style: shared attention block every N ssm layers)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0          # 0 -> no interleaved shared attention

    # xLSTM (alternating mLSTM/sLSTM)
    xlstm: bool = False

    # encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0

    # modality frontend stub: tokens are replaced/prefixed by embeddings
    frontend: str = ""           # "" | "vit_stub" | "audio_stub"
    frontend_tokens: int = 0     # patch/frame positions supplied as embeddings

    # numerics / training
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optstate_dtype: str = "float32"
    norm_eps: float = 1e-5
    remat: str = "full"          # full | dots | none
    fsdp: bool = True            # shard params over the data axis (ZeRO-3)
    scan_layers: bool = True

    # serving
    attn_chunk_q: int = 2048     # chunked-attention block sizes (long seq)
    attn_chunk_kv: int = 1024
    chunked_attn_threshold: int = 8192
    ssm_chunk: int = 256         # mamba2/mLSTM SSD chunk length

    # perf knobs (§Perf hillclimbing; defaults = paper-faithful baseline)
    seq_shard_attn: bool = False  # shard attention over seq on the model
    #                               axis (fixes head-indivisible TP waste)
    kv_layout: str = "sd"         # "sd" [B,S,KV,hd] | "ds" [B,KV,hd,S]
    #                               | "paged" (page-pool + page-table gather)
    kv_page_tokens: int = 64      # tokens per KV page (paged layout)
    head_pad_to: int = 0          # pad attention heads to a multiple (TP
    #                               divisibility); padded heads are masked
    #                               dead, so the math is unchanged
    mlp_psum_bf16: bool = False   # manual-collective TP MLP (shard_map +
    #                               bf16 psum) — halves TP all-reduce bytes
    fuse_moe_dense_ar: bool = False  # arctic: fuse the dense-residual MLP
    #                                  reduction into the MoE psum (1 AR)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so logits/embeddings shard
        evenly over the model axis (standard production practice)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def layer_period(self) -> int:
        """Layers per scan super-block (alternating structures)."""
        if self.family == "hybrid" and self.attn_every:
            return self.attn_every
        if self.xlstm:
            return 2
        return max(1, len(self.attn_types))

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs where long_500k applies (sub-quadratic decoding); see DESIGN.md §6
LONG_CONTEXT_OK = {"zamba2-2.7b", "xlstm-350m"}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import the arch modules lazily so registration runs
        from . import archs  # noqa: F401
    return _REGISTRY[name]


def all_archs() -> list:
    from . import archs  # noqa: F401
    return sorted(_REGISTRY)


def cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells; skips filtered unless requested."""
    out = []
    for a in all_archs():
        for s in SHAPES.values():
            skip = ""
            if s.name == "long_500k" and a not in LONG_CONTEXT_OK:
                skip = "full-attention arch: long_500k needs sub-quadratic attention"
            out.append((a, s.name, skip))
    return out if include_skips else [(a, s) for a, s, sk in out if not sk]
