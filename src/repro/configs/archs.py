"""The 10 assigned architectures (exact configs from the assignment).

Each is registered under its public id and selectable via ``--arch <id>``.
``reduced()`` returns a family-preserving small config for CPU smoke tests.
"""
from __future__ import annotations

from .base import ModelConfig, register

# -- hybrid: Mamba2 backbone + shared attention blocks [arXiv:2411.15242] ----
zamba2_2p7b = register(ModelConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6, head_dim=80))

# -- vlm: InternViT stub + InternLM2 backbone [arXiv:2404.16821] --------------
internvl2_2b = register(ModelConfig(
    name="internvl2-2b", family="vlm", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=8, d_ff=8192, vocab_size=92553, head_dim=128,
    frontend="vit_stub", frontend_tokens=256))

# -- dense: pruned nemotron [arXiv:2407.14679] ---------------------------------
minitron_4b = register(ModelConfig(
    name="minitron-4b", family="dense", num_layers=32, d_model=3072,
    num_heads=24, num_kv_heads=8, d_ff=9216, vocab_size=256000,
    head_dim=128))

# -- dense: WSD schedule, llama-like [arXiv:2404.06395] -------------------------
minicpm_2b = register(ModelConfig(
    name="minicpm-2b", family="dense", num_layers=40, d_model=2304,
    num_heads=36, num_kv_heads=36, d_ff=5760, vocab_size=122753,
    head_dim=64))

# -- dense: llama-arch GQA [arXiv:2403.04652] -----------------------------------
yi_6b = register(ModelConfig(
    name="yi-6b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=4, d_ff=11008, vocab_size=64000,
    head_dim=128))

# -- dense: local+global alternating, logit softcap [arXiv:2408.00118] -----------
gemma2_27b = register(ModelConfig(
    name="gemma2-27b", family="dense", num_layers=46, d_model=4608,
    num_heads=32, num_kv_heads=16, d_ff=36864, vocab_size=256000,
    head_dim=128, attn_types=("local", "global"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0))

# -- moe: 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]
arctic_480b = register(ModelConfig(
    name="arctic-480b", family="moe", num_layers=35, d_model=7168,
    num_heads=56, num_kv_heads=8, d_ff=4864, vocab_size=32000, head_dim=128,
    num_experts=128, top_k=2, moe_dense_ff=4864,
    # 480B params: fp32 states would need >16GB/chip on one pod; see DESIGN.md
    param_dtype="bfloat16", optstate_dtype="bfloat16"))

# -- moe: 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base] ------------
granite_moe_1b = register(ModelConfig(
    name="granite-moe-1b-a400m", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, d_ff=512, vocab_size=49155, head_dim=64,
    num_experts=32, top_k=8))

# -- ssm: sLSTM + mLSTM blocks [arXiv:2405.04517] ---------------------------------
xlstm_350m = register(ModelConfig(
    name="xlstm-350m", family="ssm", num_layers=24, d_model=1024,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304, xlstm=True,
    head_dim=256))

# -- audio: enc-dec, multimodal [arXiv:2308.11596] ---------------------------------
seamless_m4t_medium = register(ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=256206,
    head_dim=64, enc_layers=12, dec_layers=12,
    frontend="audio_stub", frontend_tokens=1024))


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving tiny config for CPU smoke tests."""
    kw = dict(
        num_layers=max(2, cfg.layer_period * 2), d_model=64,
        num_heads=4, num_kv_heads=min(4, max(1, cfg.num_kv_heads)),
        d_ff=0 if cfg.d_ff == 0 else 128, vocab_size=256, head_dim=16,
        window=32, frontend_tokens=8 if cfg.frontend else 0,
        param_dtype="float32", optstate_dtype="float32",
        compute_dtype="float32",
        attn_chunk_q=16, attn_chunk_kv=16, ssm_chunk=8,
        chunked_attn_threshold=64)
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=min(2, cfg.top_k), d_ff=64,
                  moe_dense_ff=64 if cfg.moe_dense_ff else 0,
                  capacity_factor=8.0)   # no token drops at smoke scale
    if cfg.family == "hybrid":
        kw.update(attn_every=3, num_layers=6, ssm_state=8, ssm_head_dim=8,
                  head_dim=16)
    if cfg.xlstm:
        kw.update(num_heads=2, num_kv_heads=2, head_dim=32)
    if cfg.family == "encdec":
        kw.update(enc_layers=2, dec_layers=2, num_layers=4)
    return cfg.with_(name=cfg.name + "-smoke", **kw)
