from .base import (LONG_CONTEXT_OK, SHAPES, ModelConfig,  # noqa: F401
                   ShapeConfig, all_archs, cells, get_config)
from .archs import reduced  # noqa: F401
