"""Training driver: config -> mesh -> (restore) -> loop -> checkpoints.

CPU-scale use (smoke/CI/examples):
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt /tmp/ck
On a real cluster the same driver runs under the production mesh
(--mesh 16x16 / 2x16x16) with per-host data sharding.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as make_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model, init_params, make_shardings
from repro.runtime.checkpoint import Checkpointer
from repro.runtime.elastic import Preemption, StragglerMonitor
from repro.runtime.sharding import activation_sharding, param_rules
from repro.runtime.training import TrainConfig, make_train_step, opt_state_specs


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("pod", "data", "model")[-len(dims):]
    return jax.make_mesh(dims, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    mesh = parse_mesh(args.mesh)
    rules = param_rules(fsdp=cfg.fsdp, multi_pod="pod" in mesh.shape)
    model = build_model(cfg)
    pspec = model.param_specs()
    ospec = opt_state_specs(pspec, cfg)
    p_sh = make_shardings(pspec, mesh, rules)
    o_sh = make_shardings(ospec, mesh, rules)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(2, args.steps // 20),
                       microbatches=args.microbatches)
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, frontend_tokens=cfg.frontend_tokens,
        d_model=cfg.d_model))
    ck = Checkpointer(args.ckpt) if args.ckpt else None
    mon = StragglerMonitor()
    pre = Preemption()

    with mesh, activation_sharding(mesh, rules):
        params = jax.jit(
            lambda k: init_params(pspec, k, cfg.param_dtype),
            out_shardings=p_sh)(jax.random.key(0))
        opt = jax.jit(lambda k: init_params(ospec, k, cfg.optstate_dtype),
                      out_shardings=o_sh)(jax.random.key(1))
        start = 0
        if ck and ck.latest_step() is not None:
            restored, start = ck.restore({"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            print(f"[train] restored checkpoint at step {start}")
        step_fn = jax.jit(make_train_step(model, tcfg),
                          donate_argnums=(0, 1))
        losses = []
        for step in range(start, args.steps):
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            params, opt, metrics = step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if mon.observe(dt):
                print("[train] straggler monitor tripped: checkpoint+restart")
                break
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={dt*1e3:.0f}ms")
            if ck and ((step + 1) % args.ckpt_every == 0 or pre.requested):
                ck.save(step + 1, {"params": params, "opt": opt})
            if pre.requested:
                print("[train] preemption requested: exiting cleanly")
                break
        if ck:
            ck.save(args.steps, {"params": params, "opt": opt})
            ck.wait()
        print(f"[train] done. first loss={losses[0]:.4f} "
              f"last loss={losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()
