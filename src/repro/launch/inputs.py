"""input_specs(): ShapeDtypeStruct stand-ins (with shardings) for every
model input of every (arch x shape) cell — weak-type-correct, shardable, and
allocation-free, so dry-runs never touch device memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from ..configs.base import ModelConfig, ShapeConfig
from ..models import abstract_params, make_shardings
from ..models.zoo import build_model
from ..runtime.sharding import param_rules
from ..runtime.training import opt_state_specs
from .mesh import data_axes


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _batch_pspec(mesh, batch):
    axes = data_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return PS(axes) if batch % size == 0 else PS()


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """Training/prefill batch: tokens, labels, mask (+frontend embeds)."""
    B, S = shape.global_batch, shape.seq_len
    bp = _batch_pspec(mesh, B)
    f = cfg.frontend_tokens if cfg.frontend else 0
    text = S if cfg.family == "encdec" else S - f
    out = {
        "tokens": _sds((B, text), jnp.int32, mesh, PS(*bp, None)),
        "labels": _sds((B, S), jnp.int32, mesh, PS(*bp, None)),
        "mask": _sds((B, S), jnp.float32, mesh, PS(*bp, None)),
    }
    if cfg.frontend:
        out["frontend_embeds"] = _sds((B, f, cfg.d_model), jnp.bfloat16,
                                      mesh, PS(*bp, None, None))
    return out


def abstract_state(cfg: ModelConfig, mesh, *, with_opt: bool,
                   multi_pod: bool):
    """(params, opt, shardings) as ShapeDtypeStructs with shardings."""
    rules = param_rules(fsdp=cfg.fsdp, multi_pod=multi_pod)
    model = build_model(cfg)
    pspec = model.param_specs()
    p_sh = make_shardings(pspec, mesh, rules)
    params = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_params(pspec, cfg.param_dtype), p_sh)
    opt = None
    o_sh = None
    if with_opt:
        ospec = opt_state_specs(pspec, cfg)
        o_sh = make_shardings(ospec, mesh, rules)
        opt = jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
            abstract_params(ospec, cfg.optstate_dtype), o_sh)
    return model, params, opt, (p_sh, o_sh), rules


def abstract_cache(model, cfg: ModelConfig, shape: ShapeConfig, mesh,
                   multi_pod: bool):
    rules = param_rules(fsdp=cfg.fsdp, multi_pod=multi_pod)
    cspec = model.cache_specs(shape.global_batch, shape.seq_len)
    c_sh = make_shardings(cspec, mesh, rules)
    cache = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_params(cspec, cfg.compute_dtype), c_sh)
    return cache, c_sh


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    B = shape.global_batch
    bp = _batch_pspec(mesh, B)
    return {
        "token": _sds((B, 1), jnp.int32, mesh, PS(*bp, None)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def sharded_bytes(tree_abstract, mesh) -> int:
    """Per-device bytes of a sharded abstract tree (analytic)."""
    total = 0
    n_dev = mesh.size
    for leaf in jax.tree.leaves(tree_abstract):
        sh = leaf.sharding
        shard_shape = sh.shard_shape(leaf.shape)
        total += int(np.prod(shard_shape)) * leaf.dtype.itemsize
    return total
