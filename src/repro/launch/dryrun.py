import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory/cost analysis + roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod/--single-pod]
Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import SHAPES, cells, get_config  # noqa: E402
from repro.launch.inputs import (abstract_cache, abstract_state,  # noqa: E402
                                 batch_specs, decode_inputs, sharded_bytes)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import num_params  # noqa: E402
from repro.runtime.serving import make_prefill_step, make_serve_step  # noqa: E402
from repro.runtime.sharding import activation_sharding, param_rules  # noqa: E402
from repro.runtime.training import TrainConfig, make_train_step  # noqa: E402
from repro.utils.flops import model_flops  # noqa: E402
from repro.utils.hlo import analyze_hlo, roofline_terms  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _parse_override(s: str):
    k, v = s.split("=", 1)
    if v in ("true", "false"):
        v = v == "true"
    else:
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
    return k, v


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               save_hlo: bool = False, overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    model, params, opt, _, rules = abstract_state(
        cfg, mesh, with_opt=shape.kind == "train", multi_pod=multi_pod)

    with mesh, activation_sharding(mesh, rules):
        if shape.kind == "train":
            step = make_train_step(model, TrainConfig())
            args = (params, opt, batch_specs(cfg, shape, mesh))
            # donate params+opt: the step returns their updated versions
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(*args)
        elif shape.kind == "prefill":
            cache, _ = abstract_cache(model, cfg, shape, mesh, multi_pod)
            step = make_prefill_step(model)
            args = (params, cache, batch_specs(cfg, shape, mesh))
            lowered = jax.jit(step, donate_argnums=(1,)).lower(*args)
        else:  # decode
            cache, _ = abstract_cache(model, cfg, shape, mesh, multi_pod)
            dec = decode_inputs(cfg, shape, mesh)
            step = make_serve_step(model)
            args = (params, cache, dec["token"], dec["pos"])
            lowered = jax.jit(step, donate_argnums=(1,)).lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    # -- analyses ---------------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        mem_report = {k: int(getattr(mem, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        mem_report = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        cost_report = {k: float(ca[k]) for k in ("flops", "bytes accessed",
                                                 "transcendentals")
                       if k in ca}
    except Exception as e:  # pragma: no cover
        cost_report = {"error": str(e)}

    hlo = compiled.as_text()
    costs = analyze_hlo(hlo, n_dev)
    terms = roofline_terms(costs.dot_flops, costs.bytes_accessed,
                           costs.collective_bytes)
    n_params = num_params(model.param_specs())
    mf = model_flops(cfg, shape, n_params)
    hlo_total = costs.dot_flops * n_dev
    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "compile_s": round(t_compile, 1),
        "n_params": n_params,
        "params_bytes_per_device": sharded_bytes(params, mesh),
        "opt_bytes_per_device": sharded_bytes(opt, mesh) if opt else 0,
        "memory_analysis": mem_report,
        "cost_analysis_raw": cost_report,
        "per_device": {
            "dot_flops": costs.dot_flops,
            "bytes_accessed": costs.bytes_accessed,
            "collective_bytes": costs.collective_bytes,
            "collectives": costs.per_collective_bytes,
            "collective_counts": costs.collective_counts,
        },
        "roofline": terms,
        "model_flops_total": mf,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": mf / hlo_total if hlo_total else 0.0,
        "trip_counts": costs.trip_counts,
    }
    if shape.kind in ("decode", "prefill"):
        cache_bytes = sharded_bytes(cache, mesh)
        report["cache_bytes_per_device"] = cache_bytes
        # analytic step floor: read params once + stream the KV cache once
        report["analytic_memory_floor_s"] = \
            (report["params_bytes_per_device"] + cache_bytes) / 819e9
    if save_hlo:
        (RESULTS / f"{arch}__{shape_name}__{report['mesh']}.hlo.txt") \
            .write_text(hlo)
    return report


def run_and_save(arch, shape_name, multi_pod, save_hlo=False,
                 overrides=None, tag=""):
    RESULTS.mkdir(parents=True, exist_ok=True)
    mesh_tag = ("2x16x16" if multi_pod else "16x16") + tag
    out = RESULTS / f"{arch}__{shape_name}__{mesh_tag}.json"
    try:
        rep = lower_cell(arch, shape_name, multi_pod=multi_pod,
                         save_hlo=save_hlo, overrides=overrides)
        rep["mesh"] = mesh_tag
        rep["overrides"] = overrides or {}
        print(f"[ok] {arch} {shape_name} {mesh_tag}: "
              f"compile={rep['compile_s']}s "
              f"bottleneck={rep['roofline']['bottleneck']} "
              f"frac={rep['roofline']['roofline_fraction']:.3f}")
    except Exception as e:
        rep = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        print(f"[FAIL] {arch} {shape_name} {mesh_tag}: {type(e).__name__}: {e}")
    out.write_text(json.dumps(rep, indent=2, default=float))
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override key=value (repeatable)")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    args = ap.parse_args()
    overrides = dict(_parse_override(s) for s in args.override)
    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    meshes = sorted(set(meshes))            # False (single) first
    todo = cells() if args.all else [(args.arch, args.shape)]
    ok = fail = skip = 0
    for arch, shape in todo:
        for mp in meshes:
            mesh_tag = ("2x16x16" if mp else "16x16") + args.tag
            out = RESULTS / f"{arch}__{shape}__{mesh_tag}.json"
            if args.skip_existing and out.exists() and \
                    "error" not in json.loads(out.read_text()):
                skip += 1
                continue
            rep = run_and_save(arch, shape, mp, save_hlo=args.save_hlo,
                               overrides=overrides, tag=args.tag)
            ok += "error" not in rep
            fail += "error" in rep
    print(f"done: {ok} ok, {fail} failed, {skip} skipped")


if __name__ == "__main__":
    main()
