"""Serving driver: batched requests over a paged KV pool with the adaptive
HBM split (KV pool vs prefix cache) driven by the paper's memory tuner.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --requests 64 --prompt-len 48 --gen 16
"""
from __future__ import annotations

import argparse
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.models import build_model, init_params
from repro.runtime.hbm_tuner import HBMGovernor, HBMTunerConfig
from repro.runtime.kvcache import KVPoolConfig, PagedKVPool
from repro.runtime.serving import make_prefill_step, make_serve_step


def chunk_hashes(tokens: np.ndarray, page_tokens: int):
    out = []
    for i in range(0, len(tokens) - len(tokens) % page_tokens, page_tokens):
        out.append(zlib.crc32(tokens[i:i + page_tokens].tobytes()))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--shared-prefix-frac", type=float, default=0.6)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0),
                         cfg.param_dtype)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_serve_step(model), donate_argnums=(1,))

    pool = PagedKVPool(KVPoolConfig(page_tokens=16, total_pages=1024,
                                    pool_pages=512, policy="opt"))
    # the HBM split is governed through the same MemoryGovernor interface
    # the LSM StorageService uses (observe-per-step -> MemoryPlan)
    governor = HBMGovernor(pool, HBMTunerConfig(ops_cycle=256))

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, args.prompt_len // 2)
    max_len = args.prompt_len + args.gen
    total_tokens = 0
    for r in range(0, args.requests, args.batch):
        b = min(args.batch, args.requests - r)
        prompts = []
        for i in range(b):
            if rng.random() < args.shared_prefix_frac:
                head = shared
            else:
                head = rng.integers(0, cfg.vocab_size, args.prompt_len // 2)
            tail = rng.integers(0, cfg.vocab_size,
                                args.prompt_len - len(head))
            prompts.append(np.concatenate([head, tail]))
        prompts = np.stack(prompts).astype(np.int32)
        # prefix-cache accounting (host metadata; device prefill recomputes
        # missed chunks — here the whole prompt for simplicity)
        for i in range(b):
            for h in chunk_hashes(prompts[i], pool.cfg.page_tokens):
                pool.lookup_prefix(h)
        cache = init_params(model.cache_specs(b, max_len),
                            jax.random.key(1), cfg.param_dtype)
        tok, cache = prefill(params, cache, {"tokens": jnp.asarray(prompts)})
        name = f"req{r}"
        pool.append_tokens(name, args.prompt_len * b)
        for g in range(args.gen):
            tok, cache = decode(params, cache, tok[:, None],
                                jnp.int32(args.prompt_len + g))
            pool.append_tokens(name, b)
            plan = governor.observe()
            if plan:
                rec = governor.records[-1]
                print(f"[governor] pool={int(rec['x'])}->{int(rec['x_next'])} "
                      f"pages miss_rate={rec['miss_rate']:.2f} "
                      f"offload/op={rec['offload_per_op']:.3f}")
        pool.finish_stream(name)
        total_tokens += b * (args.prompt_len + args.gen)
    st = pool.stats
    hit = st["prefix_hits"] / max(1, st["prefix_hits"] + st["prefix_misses"])
    print(f"[serve] tokens={total_tokens} prefix_hit_rate={hit:.2f} "
          f"offload_pages={st['offload_pages']} "
          f"pool_pages={pool.cfg.pool_pages} "
          f"tuner_steps={len(governor.records)}")
    return st


if __name__ == "__main__":
    main()
