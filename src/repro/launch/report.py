"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Dry-run/§Roofline tables.

  PYTHONPATH=src python -m repro.launch.report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs.base import SHAPES, all_archs, cells

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def load(mesh_tag: str) -> dict:
    out = {}
    for f in RESULTS.glob(f"*__{mesh_tag}.json"):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"])] = r
    return out


def one_sentence(r) -> str:
    b = r["roofline"]["bottleneck"]
    if b == "collective":
        ar = r["per_device"]["collectives"]
        top = max(ar, key=ar.get)
        return (f"reduce {top} volume (resharding/overlap) — "
                f"{ar[top]/1e9:.1f}GB/dev of {top}")
    if b == "memory":
        return "cut materialized activation/cache traffic (fusion, dtype, layout)"
    return "compute-bound: increase per-chip utilization (larger tiles/batch)"


def table(mesh_tag: str) -> str:
    reps = load(mesh_tag)
    skips = {(a, s): why for a, s, why in cells(include_skips=True) if why}
    lines = [
        f"### Roofline — mesh {mesh_tag} "
        f"({'512' if 'x16x16' in mesh_tag and mesh_tag.startswith('2') else '256'} chips, "
        "TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck |"
        " roofline_frac | MODEL_FLOPS | useful/HLO | next move |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in all_archs():
        for sname in SHAPES:
            if (arch, sname) in skips:
                lines.append(f"| {arch} | {sname} | — | — | — | skip | — | — "
                             f"| — | {skips[(arch, sname)]} |")
                continue
            r = reps.get((arch, sname))
            if r is None or "error" in r:
                err = (r or {}).get("error", "missing")
                lines.append(f"| {arch} | {sname} | ? | ? | ? | ERROR | ? | ? "
                             f"| ? | {err[:60]} |")
                continue
            t = r["roofline"]
            lines.append(
                f"| {arch} | {sname} | {t['compute_s']:.4g} "
                f"| {t['memory_s']:.4g} | {t['collective_s']:.4g} "
                f"| {t['bottleneck']} | {t['roofline_fraction']:.3f} "
                f"| {r['model_flops_total']:.3g} "
                f"| {r['useful_flops_ratio']:.2f} | {one_sentence(r)} |")
    return "\n".join(lines)


def dryrun_table(mesh_tag: str) -> str:
    reps = load(mesh_tag)
    lines = [
        f"### Dry-run — mesh {mesh_tag}",
        "",
        "| arch | shape | compile_s | params/dev MB | opt/dev MB "
        "| arg bytes/dev GB | temp bytes/dev GB | collective GB/dev "
        "(AR/AG/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, sname), r in sorted(reps.items()):
        if "error" in r:
            continue
        ma = r.get("memory_analysis", {})
        col = r["per_device"]["collectives"]
        cg = "/".join(f"{col.get(k, 0)/1e9:.2f}"
                      for k in ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"))
        lines.append(
            f"| {arch} | {sname} | {r['compile_s']} "
            f"| {r['params_bytes_per_device']/1e6:.0f} "
            f"| {r['opt_bytes_per_device']/1e6:.0f} "
            f"| {ma.get('argument_size_in_bytes', 0)/1e9:.2f} "
            f"| {ma.get('temp_size_in_bytes', 0)/1e9:.2f} | {cg} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--dryrun", action="store_true")
    args = ap.parse_args()
    if args.dryrun:
        print(dryrun_table(args.mesh))
    else:
        print(table(args.mesh))


if __name__ == "__main__":
    main()
