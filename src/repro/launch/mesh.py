"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
axis composes with "data" for batch/FSDP sharding, so DCN-crossing
collectives are the gradient reductions only.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many (host) devices exist — for tests."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
