"""GPipe-style pipeline parallelism over a ``stage`` mesh axis (shard_map +
collective_permute), for homogeneous dense stacks.

Forward schedule: with S stages and M microbatches, run T = M + S - 1
ticks; at tick t, stage s applies its layer block to microbatch (t - s) and
passes the activation ring-wise to stage s+1. Stage s holds the stacked
params slice for its layers only (weight-stationary). This composes with
the TP/data axes of the production mesh — the stage axis can be mapped to
"pod" for cross-pod pipelining where DCN bandwidth favors point-to-point
transfers over gradient all-reduces.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from ..compat import shard_map


def pipeline_forward(stage_fn, params_stacked, x, mesh, *,
                     stage_axis: str = "stage", microbatches: int = None):
    """Run ``y = stage_S(...stage_1(x))`` as a pipeline.

    stage_fn(stage_params, x_mb) -> y_mb, applied by each stage to each
    microbatch. params_stacked: pytree with leading dim S (= stage count).
    x: [M, mb, ...] microbatched input. Returns [M, mb, ...] outputs.
    """
    s_count = mesh.shape[stage_axis]
    m = x.shape[0] if microbatches is None else microbatches
    assert x.shape[0] == m

    p_spec = jax.tree.map(lambda _: PS(stage_axis), params_stacked)
    x_spec = PS(None, None)          # microbatch dim replicated per stage

    def shard_fn(p_l, x_all):
        # p_l: this stage's params (leading dim 1) ; x_all: [M, mb, ...]
        sid = jax.lax.axis_index(stage_axis)
        p_mine = jax.tree.map(lambda a: a[0], p_l)
        mb_shape = x_all.shape[1:]
        buf = jnp.zeros(mb_shape, x_all.dtype)      # activation in flight
        outs = jnp.zeros((m,) + mb_shape, x_all.dtype)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t; others use the ring buffer
            mb_idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(sid == 0, x_all[mb_idx], buf)
            active = (t - sid >= 0) & (t - sid < m)
            y = stage_fn(p_mine, x_in)
            y = jnp.where(active, y, buf)
            # last stage commits its finished microbatch
            done_idx = jnp.clip(t - (s_count - 1), 0, m - 1)
            commit = active & (sid == s_count - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(commit, y, outs[done_idx]), done_idx, 0)
            # ring-shift activations to the next stage
            perm = [(i, (i + 1) % s_count) for i in range(s_count)]
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, m + s_count - 1, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast them ring-wise
        outs = jax.lax.ppermute(outs, stage_axis,
                                [(i, (i + 1) % s_count)
                                 for i in range(s_count)])
        outs = jax.lax.psum(
            jnp.where(sid == 0, outs, jnp.zeros_like(outs)), stage_axis)
        return outs

    return shard_map(shard_fn, mesh=mesh,
                     in_specs=(p_spec, x_spec), out_specs=x_spec,
                     check_vma=False)(params_stacked, x)
