"""HBM tuner: the paper's §5 memory tuner driving the KV-pool / prefix-cache
split (the TPU analogue of write memory vs buffer cache).

cost(x) = ω * offload(x) + γ * recompute(x)   [page-transfers per op]

  offload'(x)   — more pool ⇒ fewer offloads. Estimated from observed
                  offload pages/op with the paper's Eq.4 shape
                  (-offload/(x ln(T/x)) with T the stream's total footprint)
                  — diminishing returns in pool size.
  recompute'(x) — more pool ⇒ smaller prefix cache ⇒ more prefill
                  recompute. Ghost-cache marginal utility, Eq.6 first term.

Newton–Raphson step + clamps reuse repro.core.tuner.tuner.newton_step
verbatim — the white-box machinery is identical, only the cost sources
changed (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.service.governor import (DevicePoolGovernor,  # noqa: F401
                                     MemoryGovernor, MemoryPlan)
# DevicePoolGovernor moved to core/service/governor.py (it is a
# storage-service policy, not a serving-runtime tuner); re-exported
# here for existing importers.
from ..core.tuner.tuner import TunerConfig, newton_step
from .kvcache import PagedKVPool


@dataclass
class HBMTunerConfig:
    omega: float = 1.0          # offload weight (HBM<->host bytes)
    gamma: float = 1.0          # recompute weight (prefill FLOPs as pages)
    ops_cycle: int = 2048
    min_pool_pages: int = 64


class HBMTuner:
    def __init__(self, pool: PagedKVPool, cfg: HBMTunerConfig | None = None):
        self.pool = pool
        self.cfg = cfg or HBMTunerConfig()
        self._last = dict(pool.stats)
        self.hist_x: list = []
        self.hist_cp: list = []
        self.records: list = []
        base = TunerConfig()
        self.ncfg = TunerConfig(
            omega=self.cfg.omega, gamma=self.cfg.gamma,
            fixed_step_frac=base.fixed_step_frac,
            max_shrink_frac=base.max_shrink_frac,
            min_step_bytes=8,                 # pages, not bytes, here
            min_rel_gain=0.0,
            min_write_mem=self.cfg.min_pool_pages)

    def maybe_tune(self) -> dict | None:
        delta_ops = self.pool.stats["ops"] - self._last["ops"]
        if delta_ops < self.cfg.ops_cycle:
            return None
        return self.tune_now()

    def tune_now(self) -> dict:
        p, st = self.pool, self.pool.stats
        d = {k: st[k] - self._last[k] for k in st}
        ops = max(1, d["ops"])
        x = float(p.cfg.pool_pages)
        total = float(p.cfg.total_pages)
        # offload'(x): Eq.4 shape — footprint T = live + offloaded pages
        offload_per_op = d["offload_pages"] / ops
        footprint = max(sum(len(s.pages) + s.offloaded
                            for s in p.streams.values()), x + 1)
        off_prime = -offload_per_op / (x * np.log(max(footprint / x,
                                                      np.e)))
        # recompute'(x): ghost-cache marginal utility of the prefix cache
        saved_q, _ = p.ghost.take_counters()
        rec_prime = (saved_q / ops) / max(p.cfg.sim_pages, 1)
        cp = self.cfg.omega * off_prime + self.cfg.gamma * rec_prime
        self.hist_x.append(x)
        self.hist_cp.append(cp)
        x_next = newton_step(self.hist_x[-3:], self.hist_cp[-3:], x, cp,
                             total, 0.0, self.ncfg)
        rec = {"x": x, "cost_prime": cp, "offload_prime": off_prime,
               "recompute_prime": rec_prime, "x_next": x_next,
               "offload_per_op": offload_per_op,
               "miss_rate": d["prefix_misses"] / max(1, d["prefix_misses"]
                                                     + d["prefix_hits"])}
        self.records.append(rec)
        if int(x_next) != int(x):
            p.set_pool_pages(int(x_next))
        self._last = dict(st)
        return rec


class HBMGovernor(MemoryGovernor):
    """The HBM split behind the storage-service governor interface: the
    same ``observe() -> MemoryPlan`` contract the LSM ``StorageService``
    uses, driving the KV-pool / prefix-cache boundary instead of write
    memory / buffer cache. Serving loops call ``observe`` per decode step
    (see ``repro.runtime.serving.greedy_generate``).

    ``device_pool_bytes`` optionally rides along: an HBM governor that
    also owns a store's fused-read page pool emits the budget through the
    plan's ``device_pool_bytes`` field -- the exact actuation path
    ``StorageService._apply_plan`` already runs for the serving split."""

    def __init__(self, pool: PagedKVPool, cfg: HBMTunerConfig | None = None,
                 *, device_pool_bytes: int | None = None):
        self.tuner = HBMTuner(pool, cfg)
        self.device_pool_bytes = device_pool_bytes

    @property
    def records(self):
        return self.tuner.records

    def observe(self, service=None) -> MemoryPlan | None:
        rec = self.tuner.maybe_tune()
        if rec is None:
            return None
        # The tuner actuates set_pool_pages itself; the plan only reports
        # the decision. write_memory_bytes stays None -- the quantity here
        # is POOL PAGES, and populating the byte field would make a
        # StorageService mis-actuate it as an LSM write-memory size.
        return MemoryPlan(device_pool_bytes=self.device_pool_bytes,
                          note=f"hbm-pool-pages:{int(rec['x_next'])}")
