"""Streaming latency / stall-duration histograms for tail-latency SLOs.

``LatencyHistogram`` is a log-bucketed (HDR-style) streaming histogram:
bucket edges grow geometrically by ``gamma`` (default ``2**(1/8)``, i.e.
8 buckets per doubling), so any quantile estimate ``est`` of a true value
``v`` satisfies ``v <= est <= v * gamma`` -- a bounded ~9% relative error
at any scale, from sub-microsecond stalls to multi-second pauses, with
O(log(range)) memory and O(1) record cost. Counts are exact integers, so
histograms **merge exactly** (merge is associative and commutative --
per-shard or per-window histograms aggregate without error accumulation),
and ``delta(prev)`` recovers a measurement window from two snapshots the
same way ``IOStats.delta`` does.

The exact min and max are tracked on the side: ``max_value`` (the
max-stall column) is exact, and quantile estimates clamp into
``[min, max]`` so a one-sample histogram reports that sample exactly.

A serving system is judged on its tail: ``StorageService`` records every
``submit()`` into one of these (plus a second histogram of maintenance
stall durations), and ``benchmarks/`` emits ``p99_us`` / ``p999_us`` /
``max_stall_us`` columns from window deltas next to throughput.
"""
from __future__ import annotations

import math


class LatencyHistogram:
    """Log-bucketed streaming histogram with exact mergeable counts."""

    def __init__(self, *, gamma: float = 2.0 ** 0.125, v0: float = 1e-3):
        if gamma <= 1.0:
            raise ValueError(f"gamma must be > 1, got {gamma}")
        if v0 <= 0.0:
            raise ValueError(f"v0 must be > 0, got {v0}")
        self.gamma = float(gamma)
        self.v0 = float(v0)          # upper edge of bucket 0
        self._lg = math.log(self.gamma)
        self._counts: dict[int, int] = {}
        self.count = 0
        self._min = math.inf
        self._max = -math.inf

    # -- recording -------------------------------------------------------------
    def _bucket(self, value: float) -> int:
        """Index of the bucket whose range ``(v0*g^(i-1), v0*g^i]``
        contains ``value``; everything at or below ``v0`` lands in 0."""
        if value <= self.v0:
            return 0
        return max(0, math.ceil(math.log(value / self.v0) / self._lg))

    def record(self, value: float, n: int = 1) -> None:
        """Record ``n`` observations of ``value`` (must be >= 0)."""
        if value < 0:
            raise ValueError(f"latency values must be >= 0, got {value}")
        if n <= 0:
            return
        i = self._bucket(value)
        self._counts[i] = self._counts.get(i, 0) + n
        self.count += n
        if value < self._min:
            self._min = value
        if value > self._max:
            self._max = value

    # -- quantiles -------------------------------------------------------------
    def quantile(self, q: float) -> float:
        """Estimate of the ``q``-quantile (0 <= q <= 1); 0.0 when empty.
        The estimate is a bucket's upper edge clamped into the exact
        ``[min, max]``, so ``true <= estimate <= true * gamma``."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for i in sorted(self._counts):
            seen += self._counts[i]
            if seen >= rank:
                edge = self.v0 * self.gamma ** i
                return min(max(edge, self._min), self._max)
        return self._max                              # pragma: no cover

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def p999(self) -> float:
        return self.quantile(0.999)

    @property
    def min_value(self) -> float:
        """Exact minimum recorded value (0.0 when empty)."""
        return self._min if self.count else 0.0

    @property
    def max_value(self) -> float:
        """Exact maximum recorded value (0.0 when empty)."""
        return self._max if self.count else 0.0

    def summary(self) -> dict:
        """The standard BENCH/governor view of this histogram: exact
        count/min/max plus the bounded-error quantile ladder."""
        return {"count": self.count, "p50": self.p50, "p99": self.p99,
                "p999": self.p999, "min": self.min_value,
                "max": self.max_value}

    # -- composition -----------------------------------------------------------
    def _compatible(self, other: "LatencyHistogram") -> None:
        if (self.gamma, self.v0) != (other.gamma, other.v0):
            raise ValueError(
                f"histogram geometry mismatch: (gamma={self.gamma}, "
                f"v0={self.v0}) vs (gamma={other.gamma}, v0={other.v0})")

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Exact combination of two histograms (new object; associative
        and commutative -- per-shard histograms aggregate without error)."""
        self._compatible(other)
        out = LatencyHistogram(gamma=self.gamma, v0=self.v0)
        for h in (self, other):
            for i, c in h._counts.items():
                out._counts[i] = out._counts.get(i, 0) + c
        out.count = self.count + other.count
        out._min = min(self._min, other._min)
        out._max = max(self._max, other._max)
        return out

    def copy(self) -> "LatencyHistogram":
        out = LatencyHistogram(gamma=self.gamma, v0=self.v0)
        out._counts = dict(self._counts)
        out.count = self.count
        out._min = self._min
        out._max = self._max
        return out

    def delta(self, prev: "LatencyHistogram") -> "LatencyHistogram":
        """The window between snapshot ``prev`` and now (``prev`` must be
        an earlier ``copy()`` of this histogram). Counts subtract exactly;
        the window max is exact when the window grew it, else the highest
        nonzero delta bucket's upper edge (within the gamma bound)."""
        self._compatible(prev)
        out = LatencyHistogram(gamma=self.gamma, v0=self.v0)
        for i, c in self._counts.items():
            d = c - prev._counts.get(i, 0)
            if d < 0:
                raise ValueError(
                    "delta(prev): prev is not an earlier snapshot "
                    f"(bucket {i} shrank {prev._counts.get(i, 0)} -> {c})")
            if d:
                out._counts[i] = d
        out.count = self.count - prev.count
        if out.count:
            buckets = sorted(out._counts)
            # window extrema: exact when the window moved the global
            # extremum, else bucket-edge bounds (<= gamma error)
            out._max = self._max if self._max > prev._max \
                else self.v0 * self.gamma ** buckets[-1]
            out._min = self._min if self._min < prev._min \
                else self.v0 * self.gamma ** max(0, buckets[0] - 1)
        return out
