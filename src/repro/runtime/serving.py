"""Serving steps: prefill (builds the KV cache) and decode (one token).

``make_serve_step`` is what decode_* / long_* dry-run cells lower: one new
token against a cache of ``seq_len``. Sampling is greedy argmax (the
systems-relevant part is the memory/compute path, not the sampler).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill_step(model):
    def prefill_step(params, cache, batch):
        logits, cache = model.prefill(
            params, batch["tokens"], cache,
            frontend_embeds=batch.get("frontend_embeds"))
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_serve_step(model):
    def serve_step(params, cache, token, pos):
        """token [B,1] int32, pos scalar int32 -> (next_token [B], cache)."""
        logits, cache = model.decode_step(params, token, cache, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def greedy_generate(model, params, cache, prompt, steps: int,
                    governor=None):
    """Host-side loop for examples/tests (jit per-step).

    ``governor`` is an optional ``MemoryGovernor`` (e.g. the HBM split's
    ``repro.runtime.hbm_tuner.HBMGovernor``) observed once per decode step
    -- the serving-loop analogue of the StorageService observing its
    governor once per submit."""
    prefill = jax.jit(make_prefill_step(model))
    step = jax.jit(make_serve_step(model))
    tok, cache = prefill(params, cache, {"tokens": prompt})
    out = [tok]
    pos = prompt.shape[1]
    for i in range(steps - 1):
        tok, cache = step(params, cache, tok[:, None], jnp.int32(pos + i))
        out.append(tok)
        if governor is not None:
            governor.observe(None)     # no storage service in this loop
    return jnp.stack(out, axis=1), cache
