"""Elasticity + fault tolerance glue for the training driver.

* ``StragglerMonitor`` — per-step wall-time EWMA; steps slower than
  ``threshold x`` the EWMA are flagged; ``trip`` fires after N consecutive
  flags (at which point the driver checkpoints and requests a restart —
  SPMD programs cannot drop a single slow participant mid-step, so
  checkpoint-restart-reshard *is* the straggler mitigation at scale).
* ``Preemption`` — SIGTERM-aware flag so the loop exits via a clean
  checkpoint on eviction notice.
* ``run_elastic`` — the restart loop: restore-latest → train → on failure
  restore and continue; the mesh may differ between attempts (elastic
  re-scaling is exercised in tests/test_checkpoint.py by resharding to a
  different device count).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field


@dataclass
class StragglerMonitor:
    threshold: float = 2.0
    patience: int = 5
    _ewma: float = field(default=0.0)
    _flags: int = 0
    steps: int = 0

    def observe(self, step_seconds: float) -> bool:
        """Returns True when mitigation (checkpoint+restart) should fire."""
        self.steps += 1
        if self._ewma == 0.0:
            self._ewma = step_seconds
            return False
        slow = step_seconds > self.threshold * self._ewma
        self._flags = self._flags + 1 if slow else 0
        # slow steps do not poison the baseline
        if not slow:
            self._ewma = 0.9 * self._ewma + 0.1 * step_seconds
        return self._flags >= self.patience


class Preemption:
    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        for s in signals:
            try:
                signal.signal(s, self._handler)
            except ValueError:           # non-main thread (tests)
                pass

    def _handler(self, *_):
        self.requested = True


def run_elastic(make_state, train_loop, checkpointer, *, max_restarts=3):
    """Restart loop: each attempt restores the latest checkpoint (if any)
    and runs ``train_loop(state, start_step)``; exceptions trigger a
    restore+retry up to max_restarts."""
    attempts = 0
    while True:
        state = make_state()
        start = 0
        if checkpointer.latest_step() is not None:
            state, start = checkpointer.restore(state)
        try:
            return train_loop(state, start)
        except Exception:
            attempts += 1
            if attempts > max_restarts:
                raise
            time.sleep(0.01)
