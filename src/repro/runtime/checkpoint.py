"""Fault-tolerant checkpointing: async, atomic, keep-N, elastic restore.

Layout: <dir>/step_<N>/ with one .npy per flattened leaf + manifest.json
(tree structure, shapes, dtypes, mesh that wrote it). Writes go to a
temp dir + atomic rename; a checkpoint without MANIFEST_DONE is ignored on
restore (crash-safe). Restore reassembles full arrays and re-shards to the
*current* mesh — elastic scaling = save on M devices, restore on N.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state) -> None:
        # snapshot to host BEFORE the async write (device buffers may be
        # donated by the next step)
        leaves, treedef = _flatten(state)
        host = [np.asarray(x) for x in leaves]
        if self._thread is not None:
            self._thread.join()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, treedef), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, treedef)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, host_leaves, treedef) -> None:
        tmp = self.dir / f".tmp_step_{step}_{os.getpid()}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(),
                    "treedef": str(treedef),
                    "leaves": []}
        for i, arr in enumerate(host_leaves):
            np.save(tmp / f"leaf_{i}.npy", arr)
            manifest["leaves"].append({"i": i, "shape": list(arr.shape),
                                       "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        (tmp / "MANIFEST_DONE").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "MANIFEST_DONE").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None):
        """Restore into the structure/shardings of ``state_like`` (arrays or
        ShapeDtypeStructs with .sharding) — reshards to the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        d = self.dir / f"step_{step}"
        leaves, treedef = _flatten(state_like)
        out = []
        for i, like in enumerate(leaves):
            arr = np.load(d / f"leaf_{i}.npy")
            assert tuple(arr.shape) == tuple(like.shape), \
                (i, arr.shape, like.shape)
            sharding = getattr(like, "sharding", None)
            if sharding is not None and hasattr(sharding, "mesh"):
                out.append(jax.device_put(arr.astype(like.dtype), sharding))
            else:
                out.append(jax.numpy.asarray(arr.astype(like.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out), step
