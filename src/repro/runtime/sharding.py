"""Sharding rules: logical axes -> mesh axes, plus an activation-sharding
context so model code can constrain the residual stream without threading
mesh objects everywhere.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

# Logical parameter/cache axes -> mesh axes. ``make_shardings`` skips any
# entry whose dimension does not divide evenly, so one table serves every
# arch; within a leaf a mesh axis is never used twice (first dim wins).
def param_rules(fsdp: bool = True, multi_pod: bool = False) -> dict:
    fs = (("pod", "data") if multi_pod else ("data",)) if fsdp else None
    return {
        # tensor-parallel axes
        "vocab": "model", "vocab_logits": "model",
        "heads": "model", "kv_heads": "model",
        "mlp": "model", "experts": "model", "ssm_heads": "model",
        "ssm_inner": "model", "ssm_in": "model", "ssm_conv": "model",
        # FSDP (ZeRO-3) axis
        "embed": fs,
        # replicated / stacked axes
        "layers": None, "head_dim": None, "gates": None, "conv_k": None,
        "experts_r": None, "ssm_state": None, "ssm_hd": None,
        # data axes (caches / activations). kv_seq falls back to the model
        # axis when the batch already occupies data — without this, narrow
        # GQA caches (kv_heads < model size) would be model-replicated and
        # blow the per-chip HBM budget at decode_32k.
        "batch": ("pod", "data") if multi_pod else ("data",),
        "kv_seq": ["data", "model"],
        "kv_pool": ("pod", "data") if multi_pod else ("data",),
        "frames": None,
        # sequence-parallel attention (perf knob: head-indivisible archs)
        "seq_model": "model",
    }


_ACT = {"mesh": None, "rules": None}


@contextmanager
def activation_sharding(mesh: Mesh, rules: dict):
    old = dict(_ACT)
    _ACT.update(mesh=mesh, rules=rules)
    try:
        yield
    finally:
        _ACT.update(old)


def constrain(x, *logical_axes):
    """with_sharding_constraint if an activation context is active."""
    mesh, rules = _ACT["mesh"], _ACT["rules"]
    if mesh is None:
        return x
    spec, used = [], set()
    for dim, ax in zip(x.shape, logical_axes):
        m = rules.get(ax) if ax else None
        if isinstance(m, str):
            m = (m,)
        if m and all(a not in used for a in m):
            size = 1
            for a in m:
                size *= mesh.shape[a]
            if dim % size == 0:
                spec.append(tuple(m) if len(m) > 1 else m[0])
                used.update(m)
                continue
        spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PS(*spec)))


def current_mesh() -> Mesh | None:
    return _ACT["mesh"]
