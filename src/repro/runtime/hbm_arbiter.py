"""Unified HBM arbiter: ONE device-byte budget leased across the read and
serving planes (DESIGN §2; the paper's §5 tuner logic re-targeted at HBM).

PR 6 and the serving runtime each carved a private, independently-governed
HBM region -- the lookup-side ``DevicePagePool`` and the serving-side
``PagedKVPool`` (KV pool + prefix cache). That is exactly the memory wall
the paper breaks down between write memory and the buffer cache: when the
workload flips read-heavy -> serving-heavy, bytes idle on one side while
the other thrashes. The arbiter owns the TOTAL budget and leases it:

    leases = {"device": B_d, "kv": B_k, "prefix": B_p},  B_d+B_k+B_p = B

Every ``ops_cycle`` operations it measures each region's observed
miss pressure per op (device tier/store residency misses, KV offload
pages, prefix-cache misses) and treats marginal hit-rate gain as the
paper's diminishing-returns shape: utility'_i ~ pressure_i / lease_i.
One ``step_frac`` slice of the lowest-utility region's lease moves to the
highest-utility region -- byte-exact by construction (the shift is a
single integer subtracted from one lease and added to another).

Actuation reuses the existing single-writer paths:

    HBMArbiter.observe() --> MemoryPlan.device_pool_bytes
                               --> StorageService._apply_plan
                               --> MemoryArena.set_device_pool_bytes
                         \\-> PagedKVPool.set_regions(kv, prefix)

so the device pool's budget is still only ever written by the service's
plan actuator, and the KV pool's total footprint moves through its own
region actuator (growth mints fresh page ids, shrink drains the free
list -- never invalidating live pages).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.service.governor import MemoryGovernor, MemoryPlan
from .kvcache import PagedKVPool


@dataclass
class HBMArbiterConfig:
    total_bytes: int = 64 << 20     # the one budget all regions share
    kv_page_bytes: int = 16 << 10   # device bytes per KV/prefix page
    ops_cycle: int = 2048           # ops between lease decisions
    step_frac: float = 0.125        # slice of the TOTAL budget per shift
    min_lease_bytes: int = 1 << 20  # no region is ever starved below this
    min_pressure: int = 4           # miss events/window below this = noise
    # A device residency miss is a BATCH-level event (the whole lookup
    # batch falls back to the staged probe), while a KV offload / prefix
    # miss costs one op -- this weight puts them in the same op currency.
    device_weight: float = 64.0
    # Windows a region must stay below min_pressure before it may donate:
    # without this, a just-resident device pool reads as idle, donates,
    # misses, grabs the bytes back -- a lease thrash.
    donate_dwell: int = 2


class HBMArbiter(MemoryGovernor):
    """Marginal-utility lease arbiter over {device, kv, prefix} HBM."""

    REGIONS = ("device", "kv", "prefix")

    def __init__(self, kv_pool: PagedKVPool | None = None,
                 cfg: HBMArbiterConfig | None = None,
                 *, leases: dict | None = None):
        self.kv_pool = kv_pool
        self.cfg = cfg or HBMArbiterConfig()
        total = int(self.cfg.total_bytes)
        if leases is None:
            third = total // 3
            leases = {"device": total - 2 * third, "kv": third,
                      "prefix": third}
        assert sum(leases[r] for r in self.REGIONS) == total, \
            "initial leases must sum byte-exactly to total_bytes"
        self.leases = dict(leases)
        self._last_ops = 0
        self._last_dev: dict = {}
        self._last_kv: dict = {}
        # Consecutive calm (sub-min_pressure) windows per region; regions
        # start calm so a cold-start imbalance corrects immediately.
        self._calm = {r: self.cfg.donate_dwell for r in self.REGIONS}
        self._dev_resident = 0          # device pool's resident bytes
        self.records: list = []
        self.shift_bytes_total = 0      # sum of |shift| over all decisions

    # -- lifecycle -----------------------------------------------------------
    def attach(self, store) -> None:
        pool = store.device_pool
        self._last_dev = dict(pool.stats()) if pool is not None else {}
        if self.kv_pool is not None:
            self._last_kv = dict(self.kv_pool.stats)

    def total_leased(self) -> int:
        return sum(self.leases[r] for r in self.REGIONS)

    # -- pressure measurement ------------------------------------------------
    def _pressures(self, service) -> tuple[dict, int]:
        """Per-region miss pressure over the cycle window, plus the window's
        op count. Each pressure is a count of missed-service events: device
        residency misses, KV pages offloaded, prefix-cache misses."""
        ops = 0
        press = {r: 0.0 for r in self.REGIONS}
        if service is not None:
            pool = service.store.device_pool
            if pool is not None:
                st = pool.stats()
                prev = self._last_dev
                press["device"] = (
                    st["tier_misses"] - prev.get("tier_misses", 0)
                    + st["store_misses"] - prev.get("store_misses", 0))
                self._last_dev = dict(st)
                # The pool's proven working set: bytes currently resident.
                # Donating below this evicts pages the workload is using
                # (a guaranteed regret), so it floors device donations.
                bpp = pool.budget_bytes / max(1, st["capacity_pages"])
                self._dev_resident = int(st["resident_pages"] * bpp)
            ops += service.store.disk.stats.ops - self._last_ops
        if self.kv_pool is not None:
            st = dict(self.kv_pool.stats)
            prev = self._last_kv
            press["kv"] = st["offload_pages"] - prev.get("offload_pages", 0)
            press["prefix"] = (st["prefix_misses"]
                               - prev.get("prefix_misses", 0))
            ops += st["ops"] - prev.get("ops", 0)
            self._last_kv = st
        return press, max(1, ops)

    def _window_ops(self, service) -> int:
        ops = 0
        if service is not None:
            ops += service.store.disk.stats.ops - self._last_ops
        if self.kv_pool is not None:
            ops += (self.kv_pool.stats["ops"]
                    - self._last_kv.get("ops", 0))
        return ops

    # -- the decision --------------------------------------------------------
    def observe(self, service=None) -> MemoryPlan | None:
        if self._window_ops(service) < self.cfg.ops_cycle:
            return None
        press, ops = self._pressures(service)
        if service is not None:
            self._last_ops = service.store.disk.stats.ops
        press["device"] *= self.cfg.device_weight
        for r in self.REGIONS:
            self._calm[r] = self._calm[r] + 1 \
                if press[r] < self.cfg.min_pressure else 0
        # Marginal utility of one more byte in region i: the paper's 1/x
        # diminishing-returns shape scaled by observed miss pressure.
        util = {r: (press[r] / ops) / max(1, self.leases[r])
                for r in self.REGIONS}
        recipient = max(self.REGIONS, key=lambda r: util[r])
        # Donor: lowest utility among regions that have headroom above
        # their floor AND have dwelt calm -- a floored or
        # recently-pressured region cannot donate, but must not block the
        # shift when another idle region still has bytes to give. The
        # device floor includes its resident working set.
        floor = {r: self.cfg.min_lease_bytes for r in self.REGIONS}
        floor["device"] = max(floor["device"], self._dev_resident)
        cands = [r for r in self.REGIONS if r != recipient
                 and self.leases[r] > floor[r]
                 and self._calm[r] >= self.cfg.donate_dwell]
        donor, shift = recipient, 0
        if cands and press[recipient] >= self.cfg.min_pressure:
            donor = min(cands, key=lambda r: (util[r], -self.leases[r]))
            if util[recipient] > util[donor]:
                room = self.leases[donor] - floor[donor]
                # Fixed step relative to the TOTAL budget: a step scaled
                # by the donor's lease decays as the donor drains and
                # stalls convergence toward a large reallocation.
                shift = min(int(self.cfg.step_frac
                                * self.cfg.total_bytes), room)
        if shift > 0:
            # The conservation invariant: one integer moves between two
            # leases -- the sum cannot drift even by a byte.
            self.leases[donor] -= shift
            self.leases[recipient] += shift
            self.shift_bytes_total += shift
        rec = {"leases": dict(self.leases), "pressure": press,
               "utility": util, "donor": donor, "recipient": recipient,
               "shift_bytes": shift}
        self.records.append(rec)
        if shift == 0:
            return None
        # Self-actuate the serving regions through the KV pool's region
        # actuator; the device lease rides the MemoryPlan to the service's
        # single-writer budget path.
        if self.kv_pool is not None:
            self.kv_pool.set_regions(
                self.leases["kv"] // self.cfg.kv_page_bytes,
                self.leases["prefix"] // self.cfg.kv_page_bytes)
        return MemoryPlan(device_pool_bytes=self.leases["device"],
                          note=f"hbm-arbiter:{donor}->{recipient}"
                               f":{shift}")
