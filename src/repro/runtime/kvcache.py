"""Paged KV-cache management — the paper's memory walls on a TPU (DESIGN §2).

HBM regions:
  * **KV page pool** = the paper's *write memory*: fixed-size KV pages,
    allocated on demand per request stream ("tree"), no static per-stream
    limit. When pool pressure is high, a victim stream is chosen by the
    §4.2 flush policies (max-memory / min-LSN / optimal write-rate) and its
    oldest pages are *flushed* (offloaded to host / dropped for recompute).
  * **Prefix cache** = the *buffer cache*: immutable KV pages of shared
    prompt prefixes, clock-replaced, hit = prefill FLOPs saved.

The HBM tuner (hbm_tuner.py) moves the boundary between the two regions
with the paper's §5 machinery (ghost cache + cost derivatives).

Device tensors hold the page pool; this module is the host-side metadata
layer (page tables, LSNs, policies) — exactly the split AsterixDB uses
between its buffer pool and Java metadata.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..core.lsm.cache import ClockCache
from ..core.tuner.simcache import GhostCache


@dataclass
class KVPoolConfig:
    page_tokens: int = 64               # tokens per KV page
    total_pages: int = 4096             # HBM budget in pages (both regions)
    pool_pages: int = 2048              # "write memory" share (tunable)
    sim_pages: int = 256                # ghost cache
    policy: str = "opt"                 # mem | lsn | opt
    rate_window: int = 4096             # page-allocations window for OPT


@dataclass
class Stream:
    """One request stream / tenant (the 'LSM-tree' analogue)."""
    name: str
    pages: deque = field(default_factory=deque)   # (page_id, lsn)
    tokens: int = 0
    allocated: int = 0                  # lifetime pages allocated
    offloaded: int = 0                  # pages flushed out of the pool


class PagedKVPool:
    """Host-side page-table layer over a device page-pool tensor."""

    def __init__(self, cfg: KVPoolConfig):
        self.cfg = cfg
        self.free: list[int] = list(range(cfg.total_pages))
        # Monotonic page-id source: region GROWTH (set_regions) mints ids
        # that never collide with any id ever handed out before.
        self._page_id_seq = cfg.total_pages
        self.streams: dict[str, Stream] = {}
        self.lsn = 0
        self._alloc_window: deque = deque()
        # prefix cache: page_id keyed by (hash of prefix chunk)
        self.ghost = GhostCache(cfg.sim_pages)
        self.prefix = ClockCache(cfg.total_pages - cfg.pool_pages,
                                 on_evict=self._on_prefix_evict)
        self.prefix_store: dict = {}     # chunk_hash -> page_id
        self.stats = {"pool_flushes": 0, "prefix_hits": 0,
                      "prefix_misses": 0, "recompute_tokens": 0,
                      "offload_pages": 0, "ops": 0}

    # -- region sizing (the tuner's actuator) --------------------------------
    @property
    def pool_pages_used(self) -> int:
        return sum(len(s.pages) for s in self.streams.values())

    def set_pool_pages(self, n: int) -> None:
        n = int(np.clip(n, 64, self.cfg.total_pages - 64))
        self.cfg.pool_pages = n
        self.prefix.resize(self.cfg.total_pages - n)
        self._enforce_pool()

    def set_regions(self, pool_pages: int, prefix_pages: int) -> None:
        """Resize the pool's TOTAL footprint (the HBM arbiter's lease
        actuator): unlike ``set_pool_pages``, which only moves the
        internal pool/prefix boundary, this grows or shrinks the whole
        region to ``pool_pages + prefix_pages`` device pages.

        Growth mints fresh page ids from a monotonic sequence (never
        reusing an id that may still name a resident device page);
        shrink flushes streams until enough free pages exist, then
        retires ids from the free list. Shrink is clamped to what the
        free list can yield -- live pages are never invalidated out from
        under a stream.
        """
        pool_pages = max(64, int(pool_pages))
        prefix_pages = max(64, int(prefix_pages))
        total = pool_pages + prefix_pages
        if total > self.cfg.total_pages:          # grow: mint fresh ids
            grow = total - self.cfg.total_pages
            self.free.extend(range(self._page_id_seq,
                                   self._page_id_seq + grow))
            self._page_id_seq += grow
        elif total < self.cfg.total_pages:        # shrink: drain free ids
            need = self.cfg.total_pages - total
            guard = 0
            while len(self.free) < need and guard < 10_000:
                guard += 1
                live = [s for s in self.streams.values() if s.pages]
                if not live:
                    break
                self._flush_stream(self._pick_victim(), pages=1)
            drop = min(need, len(self.free))
            if drop:
                del self.free[:drop]
            total = self.cfg.total_pages - drop
            pool_pages = min(pool_pages, total - 64)
        self.cfg.total_pages = total
        self.cfg.pool_pages = int(np.clip(pool_pages, 64, total - 64))
        self.prefix.resize(total - self.cfg.pool_pages)
        self._enforce_pool()

    @property
    def total_pages(self) -> int:
        return self.cfg.total_pages

    # -- stream management -----------------------------------------------------
    def stream(self, name: str) -> Stream:
        if name not in self.streams:
            self.streams[name] = Stream(name)
        return self.streams[name]

    def append_tokens(self, name: str, n_tokens: int) -> None:
        """Decode/prefill appended n_tokens to a stream; allocate pages."""
        s = self.stream(name)
        self.stats["ops"] += 1
        s.tokens += n_tokens
        need = -(-s.tokens // self.cfg.page_tokens) - len(s.pages) \
            - s.offloaded
        for _ in range(max(0, need)):
            self.lsn += 1
            self._alloc_window.append((self.lsn, name))
            if len(self._alloc_window) > self.cfg.rate_window:
                self._alloc_window.popleft()
            if not self.free:
                self._enforce_pool(force_one=True)
            pid = self.free.pop() if self.free else None
            if pid is None:
                self._flush_stream(self._pick_victim(), pages=1)
                pid = self.free.pop()
            s.pages.append((pid, self.lsn))
            s.allocated += 1
        self._enforce_pool()

    def finish_stream(self, name: str) -> None:
        s = self.streams.pop(name, None)
        if s:
            self.free.extend(pid for pid, _ in s.pages)

    # -- §4.2 flush policies ------------------------------------------------------
    def _pick_victim(self) -> Stream:
        live = [s for s in self.streams.values() if s.pages]
        assert live, "no pages to flush"
        pol = self.cfg.policy
        if pol == "mem":
            return max(live, key=lambda s: len(s.pages))
        if pol == "lsn":
            return min(live, key=lambda s: s.pages[0][1])
        # opt: page share proportional to allocation rate
        rates = {s.name: 0 for s in live}
        for _, name in self._alloc_window:
            if name in rates:
                rates[name] += 1
        total_r = max(1, sum(rates.values()))
        total_u = max(1, sum(len(s.pages) for s in live))
        return max(live, key=lambda s: len(s.pages) / total_u
                   - rates[s.name] / total_r)

    def _flush_stream(self, s: Stream, pages: int = 1) -> None:
        """Offload the oldest pages of a stream (partial flush)."""
        for _ in range(min(pages, len(s.pages))):
            pid, _ = s.pages.popleft()
            s.offloaded += 1
            self.free.append(pid)
            self.stats["offload_pages"] += 1
        self.stats["pool_flushes"] += 1

    def _enforce_pool(self, force_one: bool = False) -> None:
        guard = 0
        while (self.pool_pages_used > self.cfg.pool_pages
               or (force_one and not self.free)) and guard < 10_000:
            guard += 1
            live = [s for s in self.streams.values() if s.pages]
            if not live:
                break
            self._flush_stream(self._pick_victim(), pages=1)
            force_one = False

    # -- prefix cache ("buffer cache") ------------------------------------------
    def lookup_prefix(self, chunk_hash: int) -> bool:
        """One prompt chunk: hit avoids page_tokens of prefill recompute."""
        self.stats["ops"] += 1
        hit = self.prefix.pin(chunk_hash)
        if hit:
            self.stats["prefix_hits"] += 1
        else:
            self.stats["prefix_misses"] += 1
            self.stats["recompute_tokens"] += self.cfg.page_tokens
            self.ghost.on_disk_read(chunk_hash, merge=False)
        return hit

    def _on_prefix_evict(self, chunk_hash) -> None:
        self.ghost.add_evicted(chunk_hash)
