"""Training: loss, AdamW (+WSD schedule), grad clipping, microbatch
accumulation, and mixed-precision policy.

Mixed precision doubles as *gradient compression*: with
``param_dtype=bfloat16`` the backward's cross-device grad reduce-scatter /
all-reduce moves half the bytes; an fp32 master copy lives in the optimizer
state (unless ``optstate_dtype=bfloat16``, as for arctic-480b where fp32
states cannot fit one pod). An error-feedback buffer keeps bf16 grad
accumulation unbiased across microbatches.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from ..models.params import P


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    schedule: str = "wsd"            # wsd | cosine | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1          # WSD: last 10% decays
    final_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatches: int = 1            # gradient accumulation steps


def schedule_lr(tcfg: TrainConfig, step):
    s = step.astype(jnp.float32)
    peak = tcfg.learning_rate
    warm = peak * (s + 1) / max(tcfg.warmup_steps, 1)
    if tcfg.schedule == "constant":
        return jnp.minimum(warm, peak)
    total = float(tcfg.total_steps)
    if tcfg.schedule == "cosine":
        frac = jnp.clip((s - tcfg.warmup_steps)
                        / max(total - tcfg.warmup_steps, 1), 0, 1)
        lr = peak * (tcfg.final_lr_frac + (1 - tcfg.final_lr_frac)
                     * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.minimum(warm, lr)
    # WSD (minicpm): warmup -> stable -> decay over the last decay_frac
    decay_start = total * (1.0 - tcfg.decay_frac)
    frac = jnp.clip((s - decay_start) / max(total - decay_start, 1), 0, 1)
    lr = peak * (1.0 - (1.0 - tcfg.final_lr_frac) * frac)
    return jnp.minimum(warm, lr)


def cross_entropy(logits, labels, mask=None):
    """logits [B,S,V] (fp32), labels [B,S] int32. Returns (loss, n_tok)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)
    n = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / n, n


# ----------------------------- optimizer -------------------------------------
def opt_state_specs(param_specs_tree, cfg) -> dict:
    """AdamW state specs mirroring the param tree (same logical axes)."""
    def like(s, init="zeros", dtype=None):
        return P(s.shape, s.axes, init=init, dtype=dtype or cfg.optstate_dtype)

    is_p = lambda x: isinstance(x, P)
    state = {
        "m": jax.tree.map(partial(like), param_specs_tree, is_leaf=is_p),
        "v": jax.tree.map(partial(like), param_specs_tree, is_leaf=is_p),
        "step": P((), (), init="zeros", dtype="int32"),
    }
    if cfg.param_dtype != "float32" and cfg.optstate_dtype == "float32":
        state["master"] = jax.tree.map(
            lambda s: P(s.shape, s.axes, init=s.init, scale=s.scale,
                        dtype="float32"),
            param_specs_tree, is_leaf=is_p)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt, tcfg: TrainConfig):
    step = opt["step"] + 1
    lr = schedule_lr(tcfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tcfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = tcfg.b1, tcfg.b2
    master = opt.get("master", params)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p_master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        upd_ = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + tcfg.eps)
        p32 = p_master.astype(jnp.float32)
        p_new = p32 - lr * (upd_ + tcfg.weight_decay * p32)
        return p_new, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, master, grads, opt["m"], opt["v"])
    new_master = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_opt = {"m": new_m, "v": new_v, "step": step}
    if "master" in opt:
        new_opt["master"] = new_master
        new_params = jax.tree.map(
            lambda pm, p: pm.astype(p.dtype), new_master, params)
    else:
        new_params = jax.tree.map(
            lambda pm, p: pm.astype(p.dtype), new_master, params)
    return new_params, new_opt, {"lr": lr, "grad_norm": gnorm}


# ----------------------------- train step ------------------------------------
def make_train_step(model, tcfg: TrainConfig):
    """Returns train_step(params, opt, batch) -> (params, opt, metrics).

    batch: {"tokens": [B,S], "labels": [B,S], "mask": [B,S]}
           (+ "frontend_embeds": [B,F,d] for vlm/audio archs).
    With microbatches > 1, the batch's leading dim is split and grads are
    accumulated in an error-feedback bf16 buffer.
    """
    cfg = model.cfg

    def loss_fn(params, mb):
        logits = model.apply(params, mb["tokens"],
                             frontend_embeds=mb.get("frontend_embeds"))
        labels, mask = mb["labels"], mb.get("mask")
        loss, n = cross_entropy(logits, labels, mask)
        return loss, n

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt, batch):
        a = tcfg.microbatches
        if a == 1:
            (loss, _), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((a, x.shape[0] // a) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                gacc, err, loss_acc = carry
                (loss, _), g = grad_fn(params, mb)
                # error-feedback bf16 accumulation (grad "compression")
                g = jax.tree.map(lambda e, gi: gi.astype(jnp.float32) + e,
                                 err, g)
                gacc2 = jax.tree.map(
                    lambda acc, gi: (acc.astype(jnp.float32)
                                     + gi).astype(acc.dtype), gacc, g)
                err2 = jax.tree.map(
                    lambda acc2, acc, gi: (acc.astype(jnp.float32) + gi)
                    - acc2.astype(jnp.float32), gacc2, gacc, g)
                return (gacc2, err2, loss_acc + loss), None

            zeros_bf16 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            zeros_f32 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gacc, _, loss_sum), _ = jax.lax.scan(
                acc_body, (zeros_bf16, zeros_f32, 0.0), mbs)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / a, gacc)
            loss = loss_sum / a
        params, opt, om = adamw_update(params, grads, opt, tcfg)
        metrics = {"loss": loss, **om}
        return params, opt, metrics

    return train_step
