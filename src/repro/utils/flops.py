"""Analytical MODEL_FLOPS estimates (the 'useful compute' numerator).

Dense train: 6*N*D; MoE: 6*N_active*D; inference fwd: 2*N_active per token.
Attention adds 12*B*Sq*Skv*H*hd per layer for training (4 for inference),
with causal halving and sliding-window capping for local layers.
"""
from __future__ import annotations

from ..configs.base import ModelConfig, ShapeConfig
from ..models import num_params
from ..models.zoo import build_model
from ..models.transformer import block_slots


def active_param_fraction(cfg: ModelConfig) -> float:
    if not cfg.num_experts:
        return 1.0
    model = build_model(cfg)
    spec = model.param_specs()
    total = num_params(spec)
    expert = 0
    for blk in spec["blocks"]:
        moe = blk.get("moe")
        if moe:
            for k in ("wi_gate", "wi_up", "wo"):
                s = moe[k]
                n = 1
                for d in s.shape:
                    n *= d
                expert += n
    active = total - expert + expert * cfg.top_k / cfg.num_experts
    return active / total


def attention_layer_count(cfg: ModelConfig):
    """Returns [(count, window)] attention layer groups."""
    out = []
    if cfg.family == "encdec":
        out.append((cfg.enc_layers + 2 * cfg.dec_layers, 0))
        return out
    slots = block_slots(cfg)
    n_super = cfg.num_layers // len(slots)
    n_global = sum(1 for s in slots if s in ("attn:global", "attn_moe")) \
        * n_super
    n_local = sum(1 for s in slots if s == "attn:local") * n_super
    if cfg.family == "hybrid" and cfg.attn_every:
        n_global += n_super            # shared attention applications
    if n_global:
        out.append((n_global, 0))
    if n_local:
        out.append((n_local, cfg.window))
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig, n_params: int) -> float:
    B, S = shape.global_batch, shape.seq_len
    act = active_param_fraction(cfg)
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * act * n_params * tokens
        for count, window in attention_layer_count(cfg):
            kv_span = min(S / 2, window) if window else S / 2
            flops += 12.0 * B * S * kv_span * h * hd * count
        return flops
    if shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * act * n_params * tokens
        for count, window in attention_layer_count(cfg):
            kv_span = min(S / 2, window) if window else S / 2
            flops += 4.0 * B * S * kv_span * h * hd * count
        return flops
    # decode: one token per sequence against a cache of length S
    flops = 2.0 * act * n_params * B
    for count, window in attention_layer_count(cfg):
        kv_span = min(S, window) if window else S
        flops += 4.0 * B * kv_span * h * hd * count
    return flops
