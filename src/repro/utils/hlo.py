"""HLO text analysis for the roofline report.

XLA's ``cost_analysis()`` visits a ``while`` body **once** (verified
empirically), so layer-scanned models would be undercounted by ~num_layers.
This module parses the optimized HLO text, builds the computation call graph
plus a per-computation symbol table (name -> shape), extracts scan trip
counts from while-condition constants, and aggregates — per device —

  * dot FLOPs           (compute roofline term; operand shapes resolved
                          through the symbol table)
  * bytes accessed      (result + operand bytes per instruction, skipping
                          shape-only ops; post-fusion HLO, upper bound)
  * collective bytes    (all-reduce / all-gather / reduce-scatter /
                          all-to-all / collective-permute), group-size aware.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPCODE_RE = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops that move no real data (layout/metadata only)
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "while", "conditional", "call", "custom-call",
             "bitcast-convert", "reshape", "get-dimension-size", "domain",
             "opt-barrier", "partition-id", "replica-id"}


def _size_of_shapes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_part(line: str) -> str:
    """Text between '=' and the opcode's '(' — i.e. the result shape(s)."""
    m = _OPCODE_RE.search(line)
    if not m:
        return ""
    return line[line.index("=") + 1:m.start(1)]


@dataclass
class Computation:
    name: str
    lines: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)     # %name -> result bytes
    dims: dict = field(default_factory=dict)       # %name -> first shape dims


def parse_computations(hlo: str) -> dict:
    comps, cur = {}, None
    for raw in hlo.splitlines():
        s = raw.strip()
        if cur is None:
            if ("{" in s and (s.startswith("%") or s.startswith("ENTRY"))):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", s)
                if m:
                    cur = Computation(m.group(1))
                    comps[cur.name] = cur
                    if s.startswith("ENTRY"):
                        comps["__entry__"] = cur
            continue
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        cur.lines.append(s)
        if "=" in s:
            nm = _NAME_RE.match(s)
            if nm:
                res = _result_part(s)
                cur.shapes[nm.group(1)] = _size_of_shapes(res)
                sm = _SHAPE_RE.search(res)
                if sm:
                    cur.dims[nm.group(1)] = \
                        [int(d) for d in sm.group(2).split(",")] \
                        if sm.group(2) else []
    return comps


def _dot_flops(line: str, comp: Computation) -> float:
    """2 * prod(result_dims) * prod(contracting_dims of lhs)."""
    res = _result_part(line)
    rm = _SHAPE_RE.search(res)
    if not rm:
        return 0.0
    out_elems = 1
    if rm.group(2):
        for d in rm.group(2).split(","):
            out_elems *= int(d)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if not m:
        return 0.0
    args = line[line.index("dot(") + 4:]
    lhs_name_m = _NAME_RE.search(args)
    if not lhs_name_m:
        return 0.0
    lhs_dims = comp.dims.get(lhs_name_m.group(1))
    if lhs_dims is None:
        return 2.0 * out_elems  # unknown operand: count output only
    contract = 1
    for d in [int(x) for x in m.group(1).split(",") if x != ""]:
        if d < len(lhs_dims):
            contract *= lhs_dims[d]
    return 2.0 * out_elems * contract


def _line_bytes(line: str, op: str, comp: Computation, comps=None) -> float:
    """Write-once HBM-traffic proxy: each tensor is charged 2x its bytes
    (one write where produced + one read downstream). Counting operands per
    use would charge every consumer of a tensor separately — post-fusion
    chains over the residual stream then overcount by the fan-out — while
    write-once matches what a perfectly-fused pipeline actually moves.
    Slice/scatter ops are charged for the moved sub-array, not the buffer
    (otherwise scanned stacked params would be charged fully per layer).
    Entry parameters/outputs are added once by the caller."""
    if op in _FREE_OPS:
        return 0.0
    result = float(_size_of_shapes(_result_part(line)))
    if op in ("dynamic-update-slice", "scatter"):
        m = _OPCODE_RE.search(line)
        args = line[m.end():] if m else ""
        cut = args.find(")")
        if cut >= 0:
            args = args[:cut]
        names = [nm.group(1) for nm in _NAME_RE.finditer(args)]
        upd = comp.shapes.get(names[1], 0) if len(names) > 1 else 0
        return 2.0 * upd
    if op == "fusion" and comps is not None:
        cm = _CALL_RE.search(line)
        callee = comps.get(cm.group(1)) if cm else None
        if callee is not None:
            # a fusion rooted at dynamic-update-slice updates in place:
            # charge the update sub-array, not the whole buffer
            for fl in callee.lines:
                fm = _OPCODE_RE.search(fl)
                if fm and fm.group(1) == "dynamic-update-slice" \
                        and _size_of_shapes(_result_part(fl)) >= result:
                    return _line_bytes(fl, "dynamic-update-slice", callee)
    return 2.0 * result


def _collective_bytes(line: str, op: str, n_devices: int) -> float:
    size = _size_of_shapes(_result_part(line))
    g = n_devices
    m = _GROUPS_EXPL.search(line)
    if m:
        g = len([x for x in m.group(1).split(",") if x.strip() != ""])
    else:
        m = _GROUPS_IOTA.search(line)
        if m:
            g = int(m.group(2))
    if op == "collective-permute":
        return float(size)
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if op == "all-reduce":
        return 2.0 * size * frac
    if op == "reduce-scatter":
        return float(size) * (g - 1)     # result is the scattered shard
    return float(size) * frac            # all-gather (big result), all-to-all


@dataclass
class HloCosts:
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = field(default_factory=dict)
    per_collective_bytes: dict = field(default_factory=dict)
    trip_counts: dict = field(default_factory=dict)


def analyze_hlo(hlo: str, n_devices: int) -> HloCosts:
    comps = parse_computations(hlo)
    entry = comps.get("__entry__") or (list(comps.values())[-1]
                                       if comps else None)
    out = HloCosts()

    def cond_trip_count(cond_name: str) -> int:
        c = comps.get(cond_name)
        if not c:
            return 1
        consts = [int(x) for line in c.lines for x in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    stack = []

    def visit(comp: Computation, mult: float, in_fusion: bool = False):
        if comp.name in stack:
            return
        stack.append(comp.name)
        for line in comp.lines:
            m = _OPCODE_RE.search(line)
            op = m.group(1) if m else ""
            if op == "dot":
                out.dot_flops += mult * _dot_flops(line, comp)
            if not in_fusion:
                # instructions inside fusion computations are not
                # materialized — only the fusion result moves bytes
                out.bytes_accessed += mult * _line_bytes(line, op, comp,
                                                          comps)
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                b = mult * _collective_bytes(line, base, n_devices)
                out.collective_bytes += b
                out.collective_counts[base] = \
                    out.collective_counts.get(base, 0) + mult
                out.per_collective_bytes[base] = \
                    out.per_collective_bytes.get(base, 0.0) + b
            if op == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trips = cond_trip_count(cond)
                    out.trip_counts[body] = trips
                    if body in comps:
                        visit(comps[body], mult * trips, in_fusion)
                continue
            for cm in _CALL_RE.finditer(line):
                callee = cm.group(1)
                if callee in comps and callee not in stack:
                    # fusion/reduce bodies: elementwise, nothing materialized
                    visit(comps[callee], mult,
                          in_fusion or op in ("fusion", "reduce", "scatter",
                                              "sort", "map", "reduce-window",
                                              "select-and-scatter",
                                              "all-reduce",
                                              "reduce-scatter"))
        stack.pop()

    if entry is not None:
        visit(entry, 1.0)
        # entry parameters are read (once) from HBM
        for line in entry.lines:
            m = _OPCODE_RE.search(line)
            if m and m.group(1) == "parameter":
                out.bytes_accessed += _size_of_shapes(_result_part(line))
    return out


# ----------------------------- roofline ---------------------------------------
TPU_V5E = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
}


def roofline_terms(dot_flops, bytes_accessed, collective_bytes,
                   hw=TPU_V5E) -> dict:
    """All inputs are PER-DEVICE totals for one step."""
    t_compute = dot_flops / hw["peak_flops_bf16"]
    t_memory = bytes_accessed / hw["hbm_bw"]
    t_collective = collective_bytes / hw["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(t_compute, t_memory, t_collective)
    terms["roofline_fraction"] = t_compute / total if total > 0 else 0.0
    return terms
