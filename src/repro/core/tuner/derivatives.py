"""Memory-tuner cost derivatives (§5.2, §5.3) — pure, jittable JAX.

All estimators work from runtime statistics collected over one tuning cycle;
no workload knowledge is required (the paper's "white-box" property).

write'(x):  Eq. 4/5 —
    write'_i(x) = - merge_i(x) / (x * ln(|L_Ni| / (a_i x)))
                  * flush_mem_i / (flush_mem_i + flush_log_i)

read'(x):   Eq. 6 —
    read'(x) = (saved_q + saved_m)/sim + write'(x) * read_m(x)/merge(x)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TunerStats:
    """Statistics from one tuning cycle (Table 2 of the paper).

    Per-tree arrays (length K): merge_pages_per_op, last_level_bytes, alloc
    (a_i), flush_mem_bytes, flush_log_bytes. Scalars: x (write memory),
    sim_bytes, saved_q/saved_m (pages/op from the ghost cache), read_m
    (merge disk reads per op), merge (merge disk writes per op, all trees).
    """

    x: float
    merge_pages_per_op: np.ndarray
    last_level_bytes: np.ndarray
    alloc: np.ndarray
    flush_mem_bytes: np.ndarray
    flush_log_bytes: np.ndarray
    sim_bytes: float
    saved_q_per_op: float
    saved_m_per_op: float
    read_m_per_op: float
    merge_per_op: float


@jax.jit
def write_derivative(x, merge_pages_per_op, last_level_bytes, alloc,
                     flush_mem_bytes, flush_log_bytes):
    """Equations 4+5 (pages/op per byte of write memory; negative)."""
    x = jnp.asarray(x, jnp.float64) if jax.config.read("jax_enable_x64") \
        else jnp.asarray(x, jnp.float32)
    merge = jnp.asarray(merge_pages_per_op, x.dtype)
    lN = jnp.asarray(last_level_bytes, x.dtype)
    a = jnp.asarray(alloc, x.dtype)
    fm = jnp.asarray(flush_mem_bytes, x.dtype)
    fl = jnp.asarray(flush_log_bytes, x.dtype)
    # ln(|L_N| / (a*x)); the paper assumes a*x < |L_N|. Clamp to keep the
    # estimate sane when a tree is still tiny.
    ratio = jnp.maximum(lN / jnp.maximum(a * x, 1.0), jnp.e)
    scale = jnp.where(fm + fl > 0, fm / jnp.maximum(fm + fl, 1e-30), 1.0)
    per_tree = -merge / (x * jnp.log(ratio)) * scale
    return jnp.sum(per_tree)


@jax.jit
def read_derivative(write_prime, saved_q_per_op, saved_m_per_op, sim_bytes,
                    read_m_per_op, merge_per_op):
    """Equation 6 (pages/op per byte of write memory)."""
    f32 = jnp.asarray(write_prime).dtype
    saved = (jnp.asarray(saved_q_per_op, f32)
             + jnp.asarray(saved_m_per_op, f32))
    ghost_term = saved / jnp.maximum(jnp.asarray(sim_bytes, f32), 1.0)
    merge_term = jnp.where(
        merge_per_op > 0,
        write_prime * read_m_per_op / jnp.maximum(merge_per_op, 1e-30), 0.0)
    return ghost_term + merge_term


def cost_derivative(stats: TunerStats, omega: float = 1.0,
                    gamma: float = 1.0) -> tuple:
    """cost'(x) = ω·write'(x) + γ·read'(x). Returns (cost', write', read')."""
    wp = write_derivative(stats.x, stats.merge_pages_per_op,
                          stats.last_level_bytes, stats.alloc,
                          stats.flush_mem_bytes, stats.flush_log_bytes)
    rp = read_derivative(wp, stats.saved_q_per_op, stats.saved_m_per_op,
                         stats.sim_bytes, stats.read_m_per_op,
                         stats.merge_per_op)
    return (float(omega * wp + gamma * rp), float(wp), float(rp))
