from .derivatives import TunerStats, cost_derivative  # noqa: F401
from .simcache import GhostCache  # noqa: F401
from .tuner import (AdaptiveMemoryController, MemoryTuner,  # noqa: F401
                    TuneRecord, TunerConfig)
