"""Simulated ("ghost") cache for the memory tuner (§5.3, after DB2 STMM).

Stores only page IDs. Page ids evicted from the real buffer cache are added
here; when a page is about to be read from disk, a hit in the ghost cache
means the read *would have been saved* had the buffer cache been bigger by
``sim`` bytes. Query and merge reads are attributed separately (saved_q /
saved_m).
"""
from __future__ import annotations

from collections import OrderedDict


class GhostCache:
    def __init__(self, capacity_pages: int):
        self.capacity = max(0, int(capacity_pages))
        self._pages: OrderedDict = OrderedDict()
        self.saved_q = 0
        self.saved_m = 0

    def __len__(self):
        return len(self._pages)

    def resize(self, capacity_pages: int) -> None:
        self.capacity = max(0, int(capacity_pages))
        while len(self._pages) > self.capacity:
            self._pages.popitem(last=False)

    def add_evicted(self, pid) -> None:
        if self.capacity == 0:
            return
        self._pages[pid] = True
        self._pages.move_to_end(pid)
        if len(self._pages) > self.capacity:
            self._pages.popitem(last=False)

    def on_disk_read(self, pid, *, merge: bool) -> None:
        """Called when the buffer cache missed and a real read happens."""
        if self._pages.pop(pid, None) is not None:
            if merge:
                self.saved_m += 1
            else:
                self.saved_q += 1

    def invalidate_many(self, pids) -> None:
        for pid in pids:
            self._pages.pop(pid, None)

    def take_counters(self):
        q, m = self.saved_q, self.saved_m
        self.saved_q = self.saved_m = 0
        return q, m
