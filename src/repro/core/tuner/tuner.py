"""The memory tuner (§5.4): Newton–Raphson on cost'(x) with stability
heuristics, plus the controller that wires it to an LSMStore.

The numeric step is a pure jittable function (``newton_step``); the
controller holds the (tiny) host-side sample history and applies the chosen
write-memory size to the store.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from .derivatives import TunerStats, cost_derivative

if TYPE_CHECKING:  # avoid a circular import (storage uses the ghost cache)
    from ..lsm.storage import LSMStore


@dataclass
class TunerConfig:
    omega: float = 1.0                 # write-cost weight
    gamma: float = 1.0                 # read-cost weight
    k_samples: int = 3                 # points for the linear cost'(x) fit
    fixed_step_frac: float = 0.05      # fallback step: 5% of total memory
    max_shrink_frac: float = 0.10      # max 10% shrink of either region
    min_step_bytes: int = 32 << 20     # stop: step smaller than this
    min_rel_gain: float = 0.001        # stop: expected gain < 0.1% of cost
    min_write_mem: int = 16 << 20
    ops_cycle: int = 20_000            # timer-equivalent cycle (read-heavy)


@jax.jit
def _linear_fit(xs, ys):
    """Least-squares fit ys ≈ A*xs + B. Returns (A, B)."""
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    xm, ym = xs.mean(), ys.mean()
    var = jnp.sum((xs - xm) ** 2)
    A = jnp.where(var > 0, jnp.sum((xs - xm) * (ys - ym))
                  / jnp.maximum(var, 1e-30), 0.0)
    return A, ym - A * xm


def newton_step(history_x, history_cp, x, cost_prime, total_mem, sim_bytes,
                cfg: TunerConfig):
    """Propose the next write-memory size (§5.4).

    Newton–Raphson on the fitted line cost'(x) = A x + B when the fit is
    usable (enough samples, A > 0 so the root is a minimum); otherwise a
    fixed 5% step against the sign of cost'(x).
    """
    total = float(total_mem)
    fixed = cfg.fixed_step_frac * total
    use_newton = False
    x_next = x
    if len(history_x) >= cfg.k_samples:
        A, B = _linear_fit(np.array(history_x[-cfg.k_samples:]),
                           np.array(history_cp[-cfg.k_samples:]))
        A, B = float(A), float(B)
        if A > 0:                       # locally convex: root is a minimum
            x_next = x - cost_prime / A
            use_newton = True
    if not use_newton:
        x_next = x - np.sign(cost_prime) * fixed
    # §5.4 heuristic 2: never shrink a region by more than 10% of itself.
    cache = total - x - sim_bytes
    lo = x - cfg.max_shrink_frac * x                 # write memory shrink cap
    hi = x + cfg.max_shrink_frac * max(cache, 0.0)   # buffer cache shrink cap
    x_next = float(np.clip(x_next, lo, hi))
    x_next = float(np.clip(x_next, cfg.min_write_mem,
                           total - sim_bytes - cfg.min_write_mem))
    return x_next


@dataclass
class TuneRecord:
    step: int
    x: float
    cost_prime: float
    write_prime: float
    read_prime: float
    cost_per_op: float
    x_next: float
    stopped: str = ""


class MemoryTuner:
    """Feedback-control loop of Figure 5."""

    def __init__(self, cfg: TunerConfig, total_mem_bytes: int,
                 sim_bytes: int):
        self.cfg = cfg
        self.total = total_mem_bytes
        self.sim = sim_bytes
        self.hist_x: deque = deque(maxlen=16)
        self.hist_cp: deque = deque(maxlen=16)
        self.records: list[TuneRecord] = []

    def propose(self, stats: TunerStats, cost_per_op: float) -> float:
        cfg = self.cfg
        cp, wp, rp = cost_derivative(stats, cfg.omega, cfg.gamma)
        self.hist_x.append(stats.x)
        self.hist_cp.append(cp)
        x_next = newton_step(list(self.hist_x), list(self.hist_cp), stats.x,
                             cp, self.total, self.sim, cfg)
        stopped = ""
        step = x_next - stats.x
        if abs(step) < cfg.min_step_bytes:
            stopped = "step_too_small"
            x_next = stats.x
        elif cost_per_op > 0 and \
                abs(cp * step) < cfg.min_rel_gain * cost_per_op:
            stopped = "gain_too_small"
            x_next = stats.x
        self.records.append(TuneRecord(len(self.records), stats.x, cp, wp,
                                       rp, cost_per_op, x_next, stopped))
        return x_next


class AdaptiveMemoryController:
    """Wires a MemoryTuner to an LSMStore: collects per-cycle statistics,
    computes the derivatives, and actuates the write-memory size.

    Tuning triggers when the log has accumulated ``max_log_bytes`` since the
    last tuning or after ``ops_cycle`` operations (the paper's timer cycle
    for read-heavy workloads).
    """

    def __init__(self, store: "LSMStore", cfg: TunerConfig | None = None):
        self.store = store
        self.cfg = cfg or TunerConfig()
        self.tuner = MemoryTuner(self.cfg, store.cfg.total_memory_bytes,
                                 store.cfg.sim_cache_bytes)
        self._cycle_start_stats = store.disk.stats.copy()
        self._cycle_start_tree = {n: (t.stats.merge_pages_written,
                                      t.stats.bytes_flushed_mem,
                                      t.stats.bytes_flushed_log)
                                  for n, t in store.trees.items()}
        self._cycle_log_pos = store.log_pos
        self._ghost_base = (0, 0)

    def maybe_tune(self) -> TuneRecord | None:
        s = self.store
        ops = s.disk.stats.ops - self._cycle_start_stats.ops
        log_grown = s.log_pos - self._cycle_log_pos
        if log_grown < s.cfg.max_log_bytes and ops < self.cfg.ops_cycle:
            return None
        return self.tune_now()

    def tune_now(self) -> TuneRecord | None:
        s = self.store
        delta = s.disk.stats.delta(self._cycle_start_stats)
        ops = max(delta.ops, 1)
        names = list(s.trees)
        base = self._cycle_start_tree
        merge_pp = np.array([
            (s.trees[n].stats.merge_pages_written - base.get(n, (0, 0, 0))[0])
            / ops for n in names], np.float64)
        lN = np.array([max(s.trees[n].last_level_bytes, 1.0)
                       for n in names], np.float64)
        used = np.array([max(s.trees[n].mem_bytes, 1.0) for n in names],
                        np.float64)
        alloc = used / used.sum()
        fmem = np.array([s.trees[n].stats.bytes_flushed_mem
                         - base.get(n, (0, 0, 0))[1] for n in names],
                        np.float64)
        flog = np.array([s.trees[n].stats.bytes_flushed_log
                         - base.get(n, (0, 0, 0))[2] for n in names],
                        np.float64)
        saved_q, saved_m = s.ghost.take_counters()
        stats = TunerStats(
            x=float(s.write_memory_bytes),
            merge_pages_per_op=merge_pp,
            last_level_bytes=lN,
            alloc=alloc,
            flush_mem_bytes=fmem,
            flush_log_bytes=flog,
            sim_bytes=float(s.cfg.sim_cache_bytes),
            saved_q_per_op=saved_q / ops,
            saved_m_per_op=saved_m / ops,
            read_m_per_op=delta.pages_merge_read / ops,
            merge_per_op=delta.pages_merge_written / ops,
        )
        cost_per_op = (self.cfg.omega * delta.pages_written
                       + self.cfg.gamma * delta.pages_read) / ops
        x_next = self.tuner.propose(stats, cost_per_op)
        if x_next != s.write_memory_bytes:
            s.set_write_memory(int(x_next))
        # reset cycle
        self._cycle_start_stats = s.disk.stats.copy()
        self._cycle_start_tree = {n: (t.stats.merge_pages_written,
                                      t.stats.bytes_flushed_mem,
                                      t.stats.bytes_flushed_log)
                                  for n, t in s.trees.items()}
        self._cycle_log_pos = s.log_pos
        return self.tuner.records[-1]
