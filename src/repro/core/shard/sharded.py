"""ShardedStore: the data plane as N StorageShards behind one memory arena.

``StorageService -> ShardRouter -> N StorageShard`` replaces the direct
``StorageService -> LSMStore`` plumbing at scale. Each shard is a full
``LSMStore`` (its own trees, L0s, levels, flush bookkeeping and
``MaintenanceScheduler``), but the *memory walls stay global*: every shard
draws from ONE ``MemoryArena`` -- one write-memory pool, one clock buffer
cache, one ghost cache, one transaction log, one ``Disk``/``IOStats`` --
and a single ``ShardedMaintenanceScheduler`` arbitrates flushes and merges
across all (shard, tree) pairs under the shared budgets. The governor and
tuner therefore keep adapting ONE boundary, exactly as in the paper, while
the keyspace scales out.

``ShardedStore`` exposes the exact batched ``LSMStore`` surface
(``write_batch`` / ``read_batch`` / ``delete_batch`` / ``scan`` /
``scan_batch``): each batch splits per shard through the deterministic
router, executes per-shard vectorized calls, and scatters results back in
input order. With ``shards=1`` the store is bit-identical -- state,
results, IOStats -- to a bare ``LSMStore`` (enforced differentially); with
``shards=N`` the per-shard key sets partition the input and the shared
counters conserve across shards.
"""
from __future__ import annotations

import numpy as np

from ..engine.scheduler import ShardedMaintenanceScheduler
from ..lsm.arena import MemoryArena
from ..lsm.storage import LSMStore, StoreConfig
from .router import ShardRouter


class StorageShard:
    """One shard of the data plane: an ``LSMStore`` whose memory, cache,
    log and I/O accounting live in the shared arena."""

    __slots__ = ("index", "store")

    def __init__(self, index: int, store: LSMStore):
        self.index = index
        self.store = store

    def __repr__(self):  # pragma: no cover
        return f"StorageShard({self.index}, trees={list(self.store.trees)})"


class ShardedStore:
    """N ``StorageShard``s sharing one ``MemoryArena``, driven by one
    global maintenance scheduler. Drop-in for ``LSMStore`` behind the
    ``StorageService`` front door."""

    def __init__(self, cfg: StoreConfig, *, shards: int | None = None,
                 router: ShardRouter | None = None,
                 wal=None, manifest=None):
        if router is None:
            router = ShardRouter(1 if shards is None else int(shards))
        elif shards is not None and router.n_shards != int(shards):
            raise ValueError(
                f"shards={shards} disagrees with router.n_shards="
                f"{router.n_shards}; pass one or make them match")
        self.cfg = cfg.validate()
        self.router = router
        # ``wal``/``manifest`` adopt an existing durability plane (crash
        # recovery); by default the arena creates a fresh one. The router
        # spec is recorded in the manifest: replaying the ONE shared log
        # re-partitions keys through the identical deterministic router.
        self.arena = MemoryArena(cfg, wal=wal, manifest=manifest)
        self.arena.manifest.set_router(
            (router.kind, router.n_shards, router.boundaries))
        # Every shard shares the SAME StoreConfig instance, so a governor
        # flipping cfg.flush_policy steers all shards at once.
        self.shards = [StorageShard(i, LSMStore(cfg, arena=self.arena))
                       for i in range(router.n_shards)]
        self.scheduler = ShardedMaintenanceScheduler(
            [sh.store for sh in self.shards], self.arena,
            merge_budget=cfg.merge_budget)
        self._trees_view: dict | None = None    # cached flat observer view
        self.recovery_info: dict | None = None  # set by durability.recover

    # -- geometry / shared-state views -----------------------------------------
    @property
    def n_shards(self) -> int:
        return self.router.n_shards

    @property
    def disk(self):
        return self.arena.disk

    @property
    def ghost(self):
        return self.arena.ghost

    @property
    def cache(self):
        return self.arena.cache

    @property
    def log_pos(self) -> int:
        return self.arena.log_pos

    @property
    def wal(self):
        """The ONE shared write-ahead log all shards append to."""
        return self.arena.wal

    @property
    def manifest(self):
        """The shared versioned manifest (SSTable edits + checkpoints)."""
        return self.arena.manifest

    def checkpoint(self):
        """Force a durable checkpoint now and truncate the WAL below the
        global min-LSN (the scheduler also checkpoints automatically)."""
        from ..durability.checkpoint import checkpoint_now
        return checkpoint_now(self.arena, self.scheduler)

    @property
    def write_memory_bytes(self) -> int:
        return self.arena.write_memory_bytes

    def set_write_memory(self, x: int) -> None:
        self.arena.set_write_memory(x)

    @property
    def device_pool(self):
        """The shared HBM page pool behind fused reads (one per arena)."""
        return self.arena.device_pool

    def set_device_pool_bytes(self, budget_bytes: int) -> None:
        self.arena.set_device_pool_bytes(budget_bytes)

    def write_memory_used(self) -> int:
        return sum(sh.store.write_memory_used() for sh in self.shards)

    def min_lsn(self) -> int:
        return self.scheduler._min_lsn()

    @property
    def log_length(self) -> int:
        return self.scheduler._log_length()

    @property
    def trees(self):
        """Flat observer view over every shard's trees, keyed
        ``name@shard`` -- what the tuner/governor iterates to see
        per-shard memory shares and flush/merge counters. Data-path
        callers address trees by bare name; keys route to shards. Cached:
        the view only changes on ``create_tree``."""
        if self._trees_view is None:
            self._trees_view = {f"{name}@{sh.index}": t
                                for sh in self.shards
                                for name, t in sh.store.trees.items()}
        return self._trees_view

    def tree_names(self) -> list[str]:
        return list(self.shards[0].store.trees)

    def shard_tree(self, shard: int, name: str):
        return self.shards[shard].store.trees[name]

    # -- schema ----------------------------------------------------------------
    def create_tree(self, name: str, *, dataset: str | None = None,
                    entry_bytes: int | None = None) -> list:
        """Create the tree in every shard; returns the per-shard trees."""
        self._trees_view = None
        return [sh.store.create_tree(name, dataset=dataset,
                                     entry_bytes=entry_bytes)
                for sh in self.shards]

    # -- write path -------------------------------------------------------------
    def write_batch(self, tree_name: str, keys, vals=None, *, op: bool = True,
                    tick: bool = True) -> None:
        """Batched writes, split per shard in routing order; ONE global
        scheduler tick amortized over all shards (no per-shard ticks)."""
        keys = np.asarray(keys, np.int64)
        if vals is None:
            vals = keys
        vals = np.asarray(vals, np.int64)
        for si, sel in self.router.split(keys):
            self.shards[si].store.write_batch(tree_name, keys[sel],
                                              vals[sel], op=op, tick=False)
        if tick:
            self.scheduler.tick()

    def delete_batch(self, tree_name: str, keys, *, op: bool = True,
                     tick: bool = True) -> None:
        keys = np.asarray(keys, np.int64)
        for si, sel in self.router.split(keys):
            self.shards[si].store.delete_batch(tree_name, keys[sel],
                                               op=op, tick=False)
        if tick:
            self.scheduler.tick()

    def write(self, tree_name: str, keys, vals=None, *, op: bool = True) -> None:
        """Legacy scalar-semantics entry point (ONE logical op per call)."""
        self.write_batch(tree_name, keys, vals, op=False)
        if op:
            self.arena.disk.stats.ops += 1

    def note_ops(self, n: int = 1) -> None:
        self.arena.disk.stats.ops += n

    # -- reads -----------------------------------------------------------------
    def read_batch(self, tree_name: str, keys, *, op: bool = True):
        """Batched point lookups: split per shard, per-shard vectorized
        probes, results scattered back in input order."""
        keys = np.asarray(keys, np.int64)
        found = np.zeros(len(keys), bool)
        vals = np.zeros(len(keys), np.int64)
        for si, sel in self.router.split(keys):
            f, v = self.shards[si].store.read_batch(tree_name, keys[sel],
                                                    op=op)
            found[sel] = f
            vals[sel] = v
        return found, vals

    def lookup(self, tree_name: str, key: int, *, op: bool = True):
        si = self.router.shard_of(int(key))
        return self.shards[si].store.lookup(tree_name, int(key), op=op)

    def scan(self, tree_name: str, lo: int, n: int, *, op: bool = True):
        """Range scan: every shard holds a disjoint key subset, so the
        global count is the sum of per-shard counts -- ONE logical op."""
        if op:
            self.arena.disk.stats.ops += 1
        return int(sum(sh.store.scan(tree_name, int(lo), int(n), op=False)
                       for sh in self.shards))

    def scan_batch(self, tree_name: str, los, ns, *, op: bool = True):
        """Batched range scans: ONE op per range (same contract as the
        unsharded store), counts summed across the shard partition."""
        los = np.asarray(los, np.int64)
        ns = np.asarray(ns, np.int64)
        if op:
            self.arena.disk.stats.ops += len(los)
        counts = np.zeros(len(los), np.int64)
        for sh in self.shards:
            counts += sh.store.scan_batch(tree_name, los, ns, op=False)
        return counts

    # -- reporting ----------------------------------------------------------------
    def sync_mem_stats(self) -> None:
        self.arena.disk.stats.entries_merged_mem = sum(
            t.mem.stats.entries_merged
            for sh in self.shards for t in sh.store.trees.values()
            if hasattr(t.mem, "stats"))

    def shard_tree_stats(self) -> list[dict]:
        """Per-shard sums of the per-tree counters. Because all shards
        write through ONE shared ``Disk``, these must conserve: summed
        over shards they equal the corresponding global ``IOStats``
        fields (tested in the cross-shard conservation suite)."""
        out = []
        for sh in self.shards:
            agg = dict(entries_written=0, bytes_flushed_mem=0,
                       bytes_flushed_log=0, merge_pages_written=0,
                       mem_bytes=0)
            for t in sh.store.trees.values():
                agg["entries_written"] += t.stats.entries_written
                agg["bytes_flushed_mem"] += t.stats.bytes_flushed_mem
                agg["bytes_flushed_log"] += t.stats.bytes_flushed_log
                agg["merge_pages_written"] += t.stats.merge_pages_written
                agg["mem_bytes"] += t.mem_bytes
            out.append(agg)
        return out

    def elapsed(self):
        return self.cfg.time_model.elapsed(self.arena.disk.stats,
                                           scheme=self.cfg.scheme)

    def throughput(self, prev_stats=None) -> float:
        stats = self.arena.disk.stats if prev_stats is None \
            else self.arena.disk.stats.delta(prev_stats)
        io, cpu = self.cfg.time_model.elapsed(stats, scheme=self.cfg.scheme)
        return stats.ops / max(io, cpu, 1e-9)
