# Sharded data plane: deterministic key routing + N StorageShards behind
# one shared MemoryArena, arbitrated by one global maintenance scheduler.
from .router import ShardRouter  # noqa: F401
from .sharded import ShardedStore, StorageShard  # noqa: F401
