"""ShardRouter: deterministic key -> shard placement.

Routing must be a pure function of (key, router config) -- NO process
state, no Python ``hash()`` (which is salted per process) -- so that any
client, worker or replica computes the same placement, and a persisted
store reopened by another process routes identically. Two disciplines:

  * ``hash``  -- Fibonacci multiplicative hashing on the 64-bit key
    (golden-ratio constant, top bits), then modulo ``n_shards``. Spreads
    hot *ranges* across shards; any single hot key still lands on one
    shard (its "hot shard").
  * ``range`` -- ``n_shards - 1`` sorted split points partition the key
    space into contiguous half-open buckets: shard i serves keys in
    ``[boundaries[i-1], boundaries[i])`` (a boundary key opens the next
    shard). Preserves locality, so skewed key ranges produce a hot shard
    by construction -- the adversarial case the shared memory arena must
    absorb.

``n_shards=1`` routes everything to shard 0 under either discipline (the
degenerate router of the single-store deployment).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# Golden-ratio (Fibonacci hashing) multiplier; fixed forever -- changing it
# would re-route every persisted key.
_FIB = np.uint64(0x9E3779B97F4A7C15)
_SHIFT = np.uint64(33)

KINDS = ("hash", "range")


@dataclass(frozen=True)
class ShardRouter:
    """Deterministic hash/range router over ``n_shards`` shards."""

    n_shards: int = 1
    kind: str = "hash"                       # "hash" | "range"
    boundaries: tuple[int, ...] | None = None  # range: n_shards-1 splits

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown router kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.kind == "range":
            b = self.boundaries
            if b is None or len(b) != self.n_shards - 1:
                raise ValueError(
                    f"range routing over {self.n_shards} shards needs "
                    f"exactly {self.n_shards - 1} boundaries, got "
                    f"{None if b is None else len(b)}")
            object.__setattr__(self, "boundaries", tuple(int(x) for x in b))
            if list(self.boundaries) != sorted(set(self.boundaries)):
                raise ValueError("range boundaries must be strictly "
                                 f"increasing, got {self.boundaries}")
        elif self.boundaries is not None:
            raise ValueError("boundaries are only valid with kind='range'")

    @classmethod
    def ranges(cls, n_shards: int, key_max: int) -> "ShardRouter":
        """Equal-width range router over the key space [0, key_max);
        ``n_shards=1`` builds the degenerate single-range router."""
        bounds = tuple(int(key_max * (i + 1) / n_shards)
                       for i in range(n_shards - 1))
        return cls(n_shards, kind="range", boundaries=bounds)

    # -- routing --------------------------------------------------------------
    def shard_of_batch(self, keys) -> np.ndarray:
        """Vectorized placement: int64 shard index per key."""
        keys = np.asarray(keys, np.int64)
        if self.n_shards == 1:
            return np.zeros(len(keys), np.int64)
        if self.kind == "hash":
            h = (keys.astype(np.uint64) * _FIB) >> _SHIFT
            return (h % np.uint64(self.n_shards)).astype(np.int64)
        return np.searchsorted(np.asarray(self.boundaries, np.int64),
                               keys, side="right").astype(np.int64)

    def shard_of(self, key: int) -> int:
        return int(self.shard_of_batch(np.array([key], np.int64))[0])

    def split(self, keys):
        """Partition a key batch per shard.

        Yields ``(shard_index, positions)`` for every shard that received
        at least one key; ``positions`` (int64, ascending) index into the
        input batch, so per-shard sub-batches preserve submission order --
        the property that keeps duplicate keys within one batch resolving
        last-wins exactly as in the unsharded store.
        """
        sid = self.shard_of_batch(keys)
        for si in range(self.n_shards):
            sel = np.flatnonzero(sid == si)
            if len(sel):
                yield si, sel
