"""Checkpoints: manifest-anchored snapshots bit-identical recovery resumes
from.

A checkpoint captures, at one maintenance-tick boundary:

  * the **manifest live set** at its current version (the on-disk SSTable
    payloads) plus each tree's *placement* (which tables sit in which L0
    group / disk level, ``deleting_l1``);
  * the **write-memory image** per (shard, tree) -- the paper's flush
    policies drain memory by key range, not LSN order, so the memory
    component's internal structure is history-dependent and must be
    captured, not re-derived (a fuzzy checkpoint with a memory image,
    rather than a sharp flush-everything checkpoint that would perturb
    the very flush behavior §4 studies);
  * the **flush-decision state** replay determinism depends on: per-tree
    OPT rate windows, share EWMAs, partial-flush windows, round-robin
    cursors, static-scheme LRU dataset state;
  * the durable counters (IOStats write-path fields) and the WAL
    sequence/LSN watermark replay resumes from.

Everything captured is either copied (mutable containers) or immutable
and shared (numpy run arrays -- the engine never mutates them in place),
so a checkpoint stays valid while the live store keeps running: exactly
what stable storage would hold at a crash.

``restore_checkpoint`` rebuilds a fresh store from a checkpoint; the WAL
tail replayed on top (see ``recovery.py``) then reproduces the crashed
store's structure bit-for-bit, because scheduler ticks are deterministic
functions of store state.

Volatile by design (NOT captured): the clock buffer cache and the ghost
cache. A recovered store starts cold, so cache-dependent read counters
(``pages_query_read`` / ``pages_merge_read``) and read-op counts are
observability, not durable state -- ``RECOVERY_EXACT_COUNTERS`` names the
IOStats fields the recovery contract guarantees bit-identical.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

from ..lsm.baselines import AccordionMemComponent, BTreeMemComponent
from ..lsm.grouped_l0 import GroupedL0
from ..lsm.memtable import PartitionedMemComponent
from ..lsm.sstable import sstable_from_run
from .manifest import LiveSSTable

# IOStats fields that are pure functions of the (replayed) write-path
# history: the recovery contract guarantees these match the uncrashed
# store exactly. Cache-dependent read-miss counters and read-op counts are
# excluded (reads are not logged; the page cache is volatile).
RECOVERY_EXACT_COUNTERS = (
    "entries_written", "pages_flushed", "pages_merge_written",
    "flushes_mem", "flushes_log", "bytes_flushed_mem", "bytes_flushed_log",
    "entries_merged_mem", "entries_merged_disk", "merge_pins",
)


@dataclass
class Checkpoint:
    """One recovery point. ``wal_seq``/``watermark`` anchor the replay
    tail; everything else is the state image at that boundary."""

    version: int                 # manifest version at capture
    wal_seq: int                 # last WAL record folded into this image
    watermark: int               # WAL head LSN at capture (replay start)
    man_watermark: int           # manifest's min-LSN watermark at capture
    write_memory_bytes: int
    iostats: dict
    schema: list                 # [(tree, dataset, entry_bytes), ...]
    shards: list                 # per-shard image dicts, shard order
    payloads: dict               # sst_id -> LiveSSTable at capture
    scheduler: dict              # ticks / carried_debt


# --------------------------- capture -----------------------------------------
def _mem_image(mem) -> dict:
    if isinstance(mem, PartitionedMemComponent):
        return {
            "kind": "partitioned",
            "active": list(mem.active.items()),
            "active_lsn_min": mem.active_lsn_min,
            "levels": [[(s.keys, s.vals, s.lsn_min, s.lsn_max)
                        for s in lvl] for lvl in mem.levels],
            "rr_key": mem.rr_key,
            "stats": vars(mem.stats).copy(),
        }
    if isinstance(mem, BTreeMemComponent):
        return {"kind": "btree", "data": list(mem.data.items()),
                "lsn_min": mem.lsn_min_, "lsn_max": mem.lsn_max_,
                "stats": vars(mem.stats).copy()}
    if isinstance(mem, AccordionMemComponent):
        return {"kind": "accordion", "active": list(mem.active.items()),
                "segments": list(mem.segments),
                "lsn_min": mem.lsn_min_, "lsn_max": mem.lsn_max_,
                "request_flush": mem.request_flush,
                "budget_hint": mem.budget_hint_bytes,
                "stats": vars(mem.stats).copy()}
    raise TypeError(f"unknown memory component {type(mem).__name__}")


def _payload_of(sst, manifest, shard: int, tree: str) -> LiveSSTable:
    """Durable payload of one on-disk table: from the manifest live set
    when the table arrived through a flush/merge edit, else captured
    directly (bulk-loaded fixtures bypass the edit path)."""
    p = manifest.live.get(sst.sst_id)
    if p is not None:
        return p
    return LiveSSTable(shard, tree, sst.keys, sst.vals, sst.lsn_min,
                       sst.lsn_max, sst.entry_bytes, sst.page_bytes,
                       "restored")


def _tree_image(tree, manifest, shard: int, payloads: dict) -> dict:
    def ref(sst):
        payloads[sst.sst_id] = _payload_of(sst, manifest, shard, tree.name)
        return sst.sst_id

    if isinstance(tree.l0, GroupedL0):
        l0 = {"groups": [[ref(s) for s in g] for g in tree.l0.groups]}
    else:
        l0 = {"runs": [ref(s) for s in tree.l0.runs]}
    return {
        "mem": _mem_image(tree.mem),
        "l0": l0,
        "levels": [[ref(s) for s in lvl] for lvl in tree.levels.levels],
        "deleting_l1": tree.levels.deleting_l1,
        "partial_flush_window": list(tree.partial_flush_window),
        "stats": vars(tree.stats).copy(),
    }


def capture_checkpoint(arena, scheduler) -> Checkpoint:
    """Snapshot the full recoverable state of every store drawing from
    ``arena`` (one member for a standalone store, one per shard for a
    sharded one; ``arena.members`` is shard order)."""
    members = arena.members
    wal, manifest = arena.wal, arena.manifest
    payloads: dict[int, LiveSSTable] = {}
    shards = []
    for si, s in enumerate(members):
        shards.append({
            "trees": {name: _tree_image(t, manifest, si, payloads)
                      for name, t in s.trees.items()},
            "rate_win": {n: list(w) for n, w in s._rate_win.items()},
            "share_ewma": dict(s._share_ewma),
            "active_ds": list(s._active_ds),
            "pending_evict": list(s._pending_evict),
        })
    first = members[0]
    schema = [(name, first.tree_dataset[name], t.entry_bytes)
              for name, t in first.trees.items()]
    return Checkpoint(
        version=manifest.version,
        wal_seq=wal.next_seq - 1,
        watermark=wal.head_lsn,
        man_watermark=manifest.watermark,
        write_memory_bytes=arena.write_memory_bytes,
        iostats=vars(arena.disk.stats).copy(),
        schema=schema,
        shards=shards,
        payloads=payloads,
        scheduler={"ticks": scheduler.ticks,
                   "segments": scheduler.segments,
                   "carried_debt": scheduler.carried_debt},
    )


def take_checkpoint(arena, scheduler) -> Checkpoint:
    """Capture and install a checkpoint in the arena's manifest.

    The WAL is synced first: the checkpoint anchors its replay tail to
    ``wal_seq``/``watermark``, so every record it references must be
    durable before the (fsynced) checkpoint frame can point at it --
    otherwise a crash in between leaves a durable checkpoint whose
    anchor records died in the group-commit buffer. No-op on the
    in-memory medium."""
    arena.wal.sync()
    ck = capture_checkpoint(arena, scheduler)
    arena.manifest.add_checkpoint(ck)
    return ck


def global_min_lsn(arena) -> int:
    """Arena-wide truncation point: the smallest LSN still buffered in
    any member's write memory, clamped to the log head when every memory
    component is empty."""
    m = min((s.min_lsn() for s in arena.members), default=2**62)
    return min(m, arena.wal.head_lsn)


def truncate_below_min_lsn(arena) -> int:
    """The ONE truncation path (scheduler phase 5 and explicit
    checkpoints both end here): record the min-LSN watermark in the
    manifest and physically truncate the WAL below it, never dropping
    records newer than the latest checkpoint -- they are the replay tail,
    including zero-span control records sitting exactly at the
    watermark. Returns records dropped."""
    wal, man = arena.wal, arena.manifest
    trunc = global_min_lsn(arena)
    ck = man.latest_checkpoint
    man.note_watermark(trunc)
    return wal.truncate(trunc,
                        keep_after_seq=-1 if ck is None else ck.wal_seq)


def checkpoint_now(arena, scheduler) -> Checkpoint:
    """Explicit checkpoint: capture, install, and physically truncate the
    WAL below the arena-global min-LSN."""
    ck = take_checkpoint(arena, scheduler)
    truncate_below_min_lsn(arena)
    return ck


# --------------------------- restore -----------------------------------------
def _restore_mem(mem, image: dict) -> None:
    kind = image["kind"]
    if kind == "partitioned":
        assert isinstance(mem, PartitionedMemComponent)
        mem.active = dict(image["active"])
        mem.active_lsn_min = image["active_lsn_min"]
        mem.levels = [
            [sstable_from_run(k, v, lmin, lmax, mem.entry_bytes,
                              mem.page_bytes)
             for k, v, lmin, lmax in lvl] for lvl in image["levels"]]
        mem.rr_key = image["rr_key"]
    elif kind == "btree":
        assert isinstance(mem, BTreeMemComponent)
        mem.data = dict(image["data"])
        mem.lsn_min_ = image["lsn_min"]
        mem.lsn_max_ = image["lsn_max"]
    else:
        assert isinstance(mem, AccordionMemComponent)
        mem.active = dict(image["active"])
        mem.segments = list(image["segments"])
        mem.lsn_min_ = image["lsn_min"]
        mem.lsn_max_ = image["lsn_max"]
        mem.request_flush = image["request_flush"]
        mem.budget_hint_bytes = image["budget_hint"]
    vars(mem.stats).update(image["stats"])


def _restore_tree(tree, image: dict, payloads: dict, shard: int,
                  live_out: dict) -> None:
    def build(sst_id):
        p = payloads[sst_id]
        sst = sstable_from_run(p.keys, p.vals, p.lsn_min, p.lsn_max,
                               p.entry_bytes, p.page_bytes)
        live_out[sst.sst_id] = LiveSSTable(
            shard, tree.name, p.keys, p.vals, p.lsn_min, p.lsn_max,
            p.entry_bytes, p.page_bytes, p.kind)
        # Files medium: the restored table gets a fresh sst_id, so its
        # pages must exist under that id for reads to have a file to hit
        # (counters untouched -- the original write was already accounted).
        tree.disk.ensure_sst(sst)
        return sst

    _restore_mem(tree.mem, image["mem"])
    if "groups" in image["l0"]:
        tree.l0.groups = [[build(i) for i in g]
                          for g in image["l0"]["groups"]]
    else:
        tree.l0.runs = [build(i) for i in image["l0"]["runs"]]
    tree.levels.levels = [[build(i) for i in lvl]
                          for lvl in image["levels"]]
    tree.levels.deleting_l1 = image["deleting_l1"]
    tree.partial_flush_window = list(image["partial_flush_window"])
    vars(tree.stats).update(image["stats"])


def restore_checkpoint(store, ck: Checkpoint) -> None:
    """Rebuild a fresh (empty) sharded store to the checkpoint image.
    Runs under WAL replay mode, so nothing here re-logs. The manifest is
    rebased to the checkpoint version with the restored live set; the
    subsequent tail replay re-emits the post-checkpoint edits."""
    if len(store.shards) != len(ck.shards):
        raise ValueError(
            f"checkpoint holds {len(ck.shards)} shard images but the "
            f"store has {len(store.shards)} shards; recover with the "
            f"original router")
    for name, ds, e in ck.schema:
        store.create_tree(name, dataset=ds, entry_bytes=e)
    live: dict[int, LiveSSTable] = {}
    for si, image in enumerate(ck.shards):
        s = store.shards[si].store
        for name, ti in image["trees"].items():
            _restore_tree(s.trees[name], ti, ck.payloads, si, live)
        s._rate_win = {n: deque(w) for n, w in image["rate_win"].items()}
        s._share_ewma = dict(image["share_ewma"])
        s._active_ds = list(image["active_ds"])
        s._pending_evict = list(image["pending_evict"])
    arena = store.arena
    arena.restore_write_memory(ck.write_memory_bytes)
    vars(arena.disk.stats).update(ck.iostats)
    store.scheduler.ticks = ck.scheduler["ticks"]
    store.scheduler.segments = ck.scheduler.get("segments", 0)
    store.scheduler.carried_debt = ck.scheduler["carried_debt"]
    arena.manifest.reset_to_checkpoint(ck, live)
