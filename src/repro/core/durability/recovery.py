"""Crash recovery: rebuild a sharded store from (WAL, manifest) alone.

``recover(cfg, wal, manifest)`` reconstructs a ``ShardedStore`` that is
*bit-identical* -- memory-component structure, L0 groups, disk levels,
``log_pos``, write-memory size, and the write-path IOStats counters
(``checkpoint.RECOVERY_EXACT_COUNTERS``) -- to the store that crashed:

  1. restore the manifest's latest checkpoint (disk placement + memory
     images + flush-decision state);
  2. replay the WAL tail above the checkpoint's sequence watermark, in
     order, **re-partitioning the one shared log through the
     deterministic ShardRouter**: each write/delete record's keys hash to
     exactly one shard (per-shard sub-batches were logged separately), and
     the replayed ingest flows through the same ``ingest_run`` batched
     path -- numpy or Pallas -- the original writes took;
  3. replayed ``TickRecord``s re-run the maintenance scheduler at the
     original trigger points. Ticks are pure functions of store state, so
     every flush, memory merge and compaction re-executes identically,
     and ``SetWriteMemoryRecord``s re-apply tuner decisions by value (no
     volatile ghost-cache state needed).

During replay the WAL is in *replay mode*: the ingest path receives the
original LSNs from the replay cursor (verified record-by-record, so any
divergence fails loudly) and nothing is re-logged. A recovered store is a
full citizen -- it keeps appending to the same WAL/manifest and can crash
and recover again.

The one thing recovery deliberately does NOT rebuild is volatile cache
state (buffer cache, ghost cache): a recovered store serves reads cold.
"""
from __future__ import annotations

from .checkpoint import restore_checkpoint
from .wal import (DeleteBatchRecord, SetWriteMemoryRecord, TickRecord,
                  TreeCreateRecord, WriteBatchRecord)


def router_from_spec(spec):
    """Rebuild the deterministic router a manifest was written under."""
    from ..shard.router import ShardRouter
    if spec is None:
        return ShardRouter(1)
    kind, n_shards, boundaries = spec
    return ShardRouter(n_shards, kind=kind, boundaries=boundaries)


def _apply(store, rec, wal) -> None:
    """Re-execute one WAL record against the recovering store."""
    if isinstance(rec, TreeCreateRecord):
        store.create_tree(rec.tree, dataset=rec.dataset,
                          entry_bytes=rec.entry_bytes)
    elif isinstance(rec, (WriteBatchRecord, DeleteBatchRecord)):
        sid = store.router.shard_of_batch(rec.keys)
        si = int(sid[0]) if len(sid) else 0
        if len(sid) and not (sid == si).all():
            raise RuntimeError(
                f"WAL record at lsn {rec.lsn0} spans shards "
                f"{sorted(set(sid.tolist()))}: the log was written under "
                f"a different router")
        wal.expect(rec)
        s = store.shards[si].store
        if isinstance(rec, WriteBatchRecord):
            s.write_batch(rec.tree, rec.keys, rec.vals, op=rec.op,
                          tick=False)
        else:
            s.delete_batch(rec.tree, rec.keys, op=rec.op, tick=False)
    elif isinstance(rec, TickRecord):
        b = rec.merge_budget
        kw = {} if b == "default" \
            else {"merge_budget": None if b == "drain" else int(b)}
        if rec.segment == "full":
            store.scheduler.tick(**kw)
        else:
            # Paced schedules log one record per tick segment; replay
            # re-runs exactly the logged segment at the logged point, so
            # interleaved maintenance recovers bit-identically.
            store.scheduler.run_segment(rec.segment, **kw)
    elif isinstance(rec, SetWriteMemoryRecord):
        store.arena.set_write_memory(rec.write_memory_bytes)
    else:                                         # pragma: no cover
        raise TypeError(f"unknown WAL record {rec!r}")


def recover(cfg, wal, manifest, *, router=None):
    """Rebuild a ``ShardedStore`` from the durable plane.

    ``cfg`` must be the ``StoreConfig`` the crashed store ran with (the
    manifest's identity guardrail verifies the load-bearing fields).
    ``router=None`` rebuilds the router recorded in the manifest; a bare
    (unsharded) ``LSMStore``'s log recovers as the bit-identical one-shard
    store. Returns a live store with replay statistics attached as
    ``store.recovery_info`` ({replayed_records, replayed_keys,
    tail_bytes, from_checkpoint})."""
    from ..shard.sharded import ShardedStore
    cfg = cfg.validate()
    if router is None:
        router = router_from_spec(manifest.router_spec)
    store = ShardedStore(cfg, router=router, wal=wal, manifest=manifest)
    ck = manifest.latest_checkpoint
    if ck is None and wal.truncated_to > 0:
        raise RuntimeError(
            "WAL was truncated but the manifest holds no checkpoint: the "
            "durable state cannot cover the dropped prefix")
    after_seq = -1 if ck is None else ck.wal_seq
    start_lsn = 0 if ck is None else ck.watermark
    tail = wal.tail_records(after_seq)
    tail_bytes = wal.tail_bytes
    replayed_bytes = wal.head_lsn - start_lsn
    wal.begin_replay(start_lsn)
    try:
        if ck is not None:
            restore_checkpoint(store, ck)
        for _, rec in tail:
            _apply(store, rec, wal)
    except BaseException:
        # keep the real divergence error as the diagnostic; end_replay's
        # completeness check would mask it with "replay incomplete"
        wal.abort_replay()
        raise
    wal.end_replay()
    store.recovery_info = {
        "replayed_records": len(tail),
        "replayed_keys": sum(len(r.keys) for _, r in tail
                             if hasattr(r, "keys")),
        # LSN-space log length at crash (the paper's quantity) vs the
        # span replay actually walked (head - checkpoint watermark; what
        # checkpoint_interval_bytes bounds)
        "tail_bytes": tail_bytes,
        "replayed_bytes": replayed_bytes,
        "from_checkpoint": ck is not None,
    }
    # Files medium: replayed flushes re-wrote their tables under fresh
    # sst_ids, so the crashed run's files are orphans now -- reconcile
    # the page directory against the converged live set (checkpoint-
    # pinned files are spared inside gc).
    page_store = getattr(store.arena.disk, "page_store", None)
    if page_store is not None:
        store.recovery_info["gc_ssts"] = len(page_store.gc(manifest.live))
        wal.sync()                # recovery effects are durable on return
    return store
