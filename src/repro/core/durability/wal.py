"""Typed write-ahead log: the physical transaction log behind ``log_pos``.

Before this module the shared "transaction log" was a bare byte counter
(``MemoryArena.log_pos``): min-LSN flush policies and log-triggered flushes
(§4) were enforced against a log that did not exist, so nothing could ever
be replayed or truncated. ``WriteAheadLog`` makes the log real while
preserving the LSN semantics the whole engine (and the differential test
suite) is built on **exactly**:

  * an LSN is a *payload byte offset*: a batch of ``n`` keys appended at
    log position ``L`` spans LSNs ``[L, L + n * entry_bytes)`` and entry
    ``i`` carries LSN ``L + i * entry_bytes`` -- bit-identical to the old
    counter, so a batch of n is still indistinguishable from n batches of
    one;
  * control records (scheduler ticks, tuner resizes, tree creation) have
    a zero LSN footprint: they order the replay without consuming log
    bytes, so ``log_pos`` advances only by ingested payload.

Records are encoded to flat byte buffers through numpy (``Record.encode``
/ ``decode_record`` are exact inverses -- property-tested round-trip), so
what the WAL retains is a genuine serialized log, not live object graphs.

Physical truncation: ``truncate(min_lsn)`` drops every whole record below
the global min-LSN watermark (the §4 invariant: log bytes below the
smallest LSN still buffered in write memory are dead weight) and the
retained *tail* is ``tail_bytes = log_pos - truncated_to`` -- equal to the
store's ``log_length`` whenever truncation is driven by the maintenance
scheduler's log-enforcement phase.

Replay mode: during recovery the log is *consumed*, not appended. The
engine's ingest path calls the same ``append_batch`` API; in replay mode
it hands back the next expected LSN (verified against the record being
replayed) instead of growing the log, so one code path serves both normal
operation and crash recovery.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_INF = 2**62

# Record kinds (wire tags -- fixed forever, a persisted log must decode).
K_WRITE = 1
K_DELETE = 2
K_TREE_CREATE = 3
K_TICK = 4
K_SET_WRITE_MEMORY = 5

_HEADER_FIELDS = 8          # int64 header words per record
_NONE = -(2**31)            # wire encoding of "None" for small int fields


# --------------------------- typed records -----------------------------------
@dataclass(frozen=True, eq=False)
class WriteBatchRecord:
    """One ingested write batch: ``keys[i] -> vals[i]`` at LSN
    ``lsn0 + i * entry_bytes``."""

    tree: str
    lsn0: int
    entry_bytes: int
    keys: np.ndarray
    vals: np.ndarray
    op: bool = True            # whether the batch was counted in IOStats.ops

    kind = K_WRITE

    @property
    def lsn_end(self) -> int:
        return self.lsn0 + len(self.keys) * self.entry_bytes


@dataclass(frozen=True, eq=False)
class DeleteBatchRecord:
    """One tombstone batch. Deletes carry *no* values on the wire -- the
    tombstone payload is an engine constant, reconstructed at replay."""

    tree: str
    lsn0: int
    entry_bytes: int
    keys: np.ndarray
    op: bool = True

    kind = K_DELETE

    @property
    def lsn_end(self) -> int:
        return self.lsn0 + len(self.keys) * self.entry_bytes


@dataclass(frozen=True, eq=False)
class TreeCreateRecord:
    """Schema record: a tree created with the given ``create_tree`` args
    (``None`` means the store-config default applied)."""

    tree: str
    dataset: str | None = None
    entry_bytes: int | None = None
    lsn0: int = 0

    kind = K_TREE_CREATE
    lsn_end = property(lambda self: self.lsn0)


@dataclass(frozen=True, eq=False)
class TickRecord:
    """Control record: one maintenance-scheduler tick -- or one resumable
    tick *segment* -- ran here, with the given merge-budget override
    (``"default"`` = the scheduler's own budget, ``"drain"`` = explicit
    None, or an int). ``segment`` is ``"full"`` for a one-shot tick or a
    ``scheduler.SEGMENTS`` name for a paced schedule's individual phase;
    segment-granular records are what keep interleaved maintenance
    replay-deterministic (recovery re-runs exactly the logged segment at
    exactly the logged point)."""

    lsn0: int = 0
    merge_budget: object = "default"     # "default" | "drain" | int
    segment: str = "full"                # "full" | a SEGMENTS name

    kind = K_TICK
    lsn_end = property(lambda self: self.lsn0)


@dataclass(frozen=True, eq=False)
class SetWriteMemoryRecord:
    """Control record: the tuner/governor resized the shared write memory.
    The *decision value* is durable so replay never needs the (volatile)
    ghost-cache statistics that produced it."""

    write_memory_bytes: int
    lsn0: int = 0

    kind = K_SET_WRITE_MEMORY
    lsn_end = property(lambda self: self.lsn0)


Record = (WriteBatchRecord | DeleteBatchRecord | TreeCreateRecord
          | TickRecord | SetWriteMemoryRecord)


# --------------------------- wire encoding -----------------------------------
def _pad8(n: int) -> int:
    return -(-n // 8) * 8


def encode_record(rec: Record) -> bytes:
    """Serialize one record to a flat byte buffer (numpy int64 header +
    utf-8 names + int64 key/val arrays). Exact inverse of
    ``decode_record``."""
    name = rec.tree.encode() if hasattr(rec, "tree") else b""
    ds = b""
    ds_len = _NONE
    flag = 0
    extra = 0
    entry_bytes = getattr(rec, "entry_bytes", None)
    if rec.kind in (K_WRITE, K_DELETE):
        n = len(rec.keys)
        flag = 1 if rec.op else 0
    elif rec.kind == K_TREE_CREATE:
        n = 0
        if rec.dataset is not None:
            ds = rec.dataset.encode()
            ds_len = len(ds)
    elif rec.kind == K_TICK:
        n = 0
        b = rec.merge_budget
        flag = {"default": -2, "drain": -1}.get(b, 1)
        extra = 0 if isinstance(b, str) else int(b)
        if rec.segment != "full":        # name slot carries the segment;
            name = rec.segment.encode()  # empty name decodes as "full"
    else:                                    # K_SET_WRITE_MEMORY
        n = 0
        extra = int(rec.write_memory_bytes)
    header = np.array(
        [rec.kind, rec.lsn0,
         _NONE if entry_bytes is None else int(entry_bytes),
         n, len(name), ds_len, flag, extra], np.int64)
    body = name + ds
    body += b"\x00" * (_pad8(len(body)) - len(body))
    parts = [header.tobytes(), body]
    if rec.kind in (K_WRITE, K_DELETE):
        parts.append(np.ascontiguousarray(rec.keys, np.int64).tobytes())
        if rec.kind == K_WRITE:
            parts.append(np.ascontiguousarray(rec.vals, np.int64).tobytes())
    return b"".join(parts)


def decode_record(buf: bytes) -> Record:
    """Deserialize one record (exact inverse of ``encode_record``)."""
    header = np.frombuffer(buf[:_HEADER_FIELDS * 8], np.int64)
    kind, lsn0, entry_bytes, n, name_len, ds_len, flag, extra = \
        (int(x) for x in header)
    off = _HEADER_FIELDS * 8
    name = buf[off:off + name_len].decode()
    ds = None if ds_len == _NONE \
        else buf[off + name_len:off + name_len + ds_len].decode()
    off += _pad8(name_len + max(ds_len, 0))
    if kind == K_WRITE:
        keys = np.frombuffer(buf[off:off + n * 8], np.int64).copy()
        vals = np.frombuffer(buf[off + n * 8:off + 2 * n * 8],
                             np.int64).copy()
        return WriteBatchRecord(name, lsn0, entry_bytes, keys, vals,
                                op=bool(flag))
    if kind == K_DELETE:
        keys = np.frombuffer(buf[off:off + n * 8], np.int64).copy()
        return DeleteBatchRecord(name, lsn0, entry_bytes, keys,
                                 op=bool(flag))
    if kind == K_TREE_CREATE:
        return TreeCreateRecord(
            name, dataset=ds,
            entry_bytes=None if entry_bytes == _NONE else entry_bytes,
            lsn0=lsn0)
    if kind == K_TICK:
        budget = {-2: "default", -1: "drain"}.get(flag, extra)
        return TickRecord(lsn0=lsn0, merge_budget=budget,
                          segment=name or "full")
    if kind == K_SET_WRITE_MEMORY:
        return SetWriteMemoryRecord(write_memory_bytes=extra, lsn0=lsn0)
    raise ValueError(f"unknown WAL record kind {kind}")


# --------------------------- the log ------------------------------------------
@dataclass
class _Stored:
    """One retained record: its sequence number, LSN span, and encoded
    bytes. ``seq`` orders records absolutely (control records share LSN
    boundaries, so LSNs alone cannot anchor a replay start)."""

    seq: int
    lsn0: int
    lsn_end: int
    buf: bytes


class _ReplayState:
    __slots__ = ("cursor", "expect")

    def __init__(self, cursor: int):
        self.cursor = cursor     # the LSN the next replayed append receives
        self.expect = None       # record being replayed (verified)


class WriteAheadLog:
    """Append-only typed log with LSN = payload byte offset, physical
    truncation below the min-LSN watermark, and a replay mode that feeds
    recovered ingests their original LSNs."""

    def __init__(self):
        self._records: list[_Stored] = []
        self._head = 0               # authoritative log_pos
        self.truncated_to = 0        # LSN watermark physically dropped below
        self.next_seq = 0
        self._trees_logged: set[str] = set()
        self._replay: _ReplayState | None = None

    # -- geometry -------------------------------------------------------------
    @property
    def head_lsn(self) -> int:
        """The current log position. During replay this is the *replay
        cursor*, so ``log_pos``-dependent engine decisions (flush windows,
        rate trimming) see exactly the values they saw originally."""
        if self._replay is not None:
            return self._replay.cursor
        return self._head

    @property
    def tail_bytes(self) -> int:
        """Retained log tail in LSN (payload byte) space. Under
        scheduler-driven truncation this equals the store's
        ``log_length`` after every tick."""
        return self._head - self.truncated_to

    @property
    def num_records(self) -> int:
        return len(self._records)

    @property
    def encoded_bytes(self) -> int:
        """Physical size of the retained encoded records (headers, names
        and padding included)."""
        return sum(len(r.buf) for r in self._records)

    @property
    def replaying(self) -> bool:
        return self._replay is not None

    # -- durability hooks -------------------------------------------------------
    # The in-memory medium is "durable" the instant it appends (clone()
    # models stable storage), so these are no-ops here; the file-backed
    # ``storage_io.FileWAL`` overrides them with real buffering + fsync.
    fsyncs = 0                   # physical fsync calls issued
    commit_hist = None           # LatencyHistogram of commit waits (files)

    def commit(self, n: int = 1) -> None:
        """A commit point: ``n`` logical ops want durability here (store
        batch end, scheduler tick/segment end). No-op in memory."""

    def sync(self) -> None:
        """Force everything durable now. No-op in memory."""

    def bind_stats(self, stats) -> None:
        """Mirror fsync counts into an ``IOStats``. No-op in memory."""

    @property
    def durable_lsn(self) -> int:
        """Highest LSN guaranteed to survive a process kill."""
        return self._head

    @property
    def all_durable(self) -> bool:
        """True when no appended record is still waiting for its fsync."""
        return True

    # -- appends ---------------------------------------------------------------
    def _push(self, rec: Record) -> None:
        self._records.append(_Stored(self.next_seq, rec.lsn0, rec.lsn_end,
                                     encode_record(rec)))
        self.next_seq += 1

    def append_batch(self, tree: str, keys, vals, *, entry_bytes: int,
                     op: bool, delete: bool = False) -> int:
        """Log one write/delete batch; returns the assigned ``lsn0``.
        In replay mode no record is written: the replay cursor supplies
        (and verifies) the original LSN instead."""
        n = len(keys)
        span = n * entry_bytes
        if self._replay is not None:
            lsn0 = self._replay.cursor
            exp = self._replay.expect
            if exp is not None:
                want_kind = K_DELETE if delete else K_WRITE
                if (exp.kind != want_kind or exp.tree != tree
                        or len(exp.keys) != n or exp.lsn0 != lsn0):
                    raise RuntimeError(
                        f"WAL replay diverged: expected {exp.kind}@"
                        f"{exp.lsn0} ({exp.tree}, {len(exp.keys)} keys), "
                        f"got {'delete' if delete else 'write'}@{lsn0} "
                        f"({tree}, {n} keys)")
                self._replay.expect = None
            self._replay.cursor += span
            return lsn0
        lsn0 = self._head
        if delete:
            rec = DeleteBatchRecord(tree, lsn0, entry_bytes,
                                    np.asarray(keys, np.int64), op=op)
        else:
            rec = WriteBatchRecord(tree, lsn0, entry_bytes,
                                   np.asarray(keys, np.int64),
                                   np.asarray(vals, np.int64), op=op)
        self._push(rec)
        self._head += span
        return lsn0

    def append_tree_create(self, tree: str, *, dataset: str | None,
                           entry_bytes: int | None) -> None:
        """Log a tree creation once per logical tree (a sharded store
        creates the tree in every shard; only the first create logs)."""
        if tree in self._trees_logged:
            return
        self._trees_logged.add(tree)
        if self._replay is not None:
            return
        self._push(TreeCreateRecord(tree, dataset=dataset,
                                    entry_bytes=entry_bytes,
                                    lsn0=self._head))

    def append_tick(self, merge_budget, *, segment: str = "full") -> None:
        """Log a maintenance tick (``merge_budget``: "default" | "drain" |
        int) or one resumable tick segment (``segment`` = a
        ``scheduler.SEGMENTS`` name). Ticks and segments are deterministic
        functions of store state, so logging the trigger point (not its
        effects) is enough to replay them."""
        if self._replay is not None:
            return
        self._push(TickRecord(lsn0=self._head, merge_budget=merge_budget,
                              segment=segment))

    def append_set_write_memory(self, x: int) -> None:
        if self._replay is not None:
            return
        self._push(SetWriteMemoryRecord(write_memory_bytes=int(x),
                                        lsn0=self._head))

    def set_head(self, v: int) -> None:
        """Compat shim for the legacy ``log_pos`` *setter* (the old bare
        counter could be assigned). Moves the head without a payload
        record -- observability-only; a log advanced this way carries no
        replayable data for the skipped span."""
        self._head = int(v)

    # -- truncation -------------------------------------------------------------
    def truncate(self, min_lsn: int, *, keep_after_seq: int = -1) -> int:
        """Physical truncation. Returns the number of records dropped.

        ``keep_after_seq`` is the replay-tail barrier -- the latest
        checkpoint's WAL sequence. Records at or below it are fully
        folded into that checkpoint's state image and recovery never
        replays them, so the whole covered prefix is dropped -- including
        records above ``min_lsn`` that a min-LSN-only rule would retain
        forever when flushes stall (the ``checkpoint_interval_bytes``
        knob bounds physical log size through exactly this path).
        Records *after* the barrier are NEVER dropped, whatever their
        LSN: zero-span control records (ticks, tuner resizes) logged at
        exactly the checkpoint watermark belong to the replay tail.

        ``truncated_to`` (and so ``tail_bytes = head - truncated_to``)
        advances in LSN space to ``min_lsn``, tracking the paper's
        ``log_length`` exactly whatever the physical drops."""
        keep = 0
        while keep < len(self._records) \
                and self._records[keep].seq <= keep_after_seq:
            keep += 1
        if keep:
            del self._records[:keep]
        if min_lsn > self.truncated_to:
            self.truncated_to = min_lsn
        return keep

    # -- reads / replay ----------------------------------------------------------
    def records(self):
        """Decoded retained records, oldest first."""
        return [decode_record(r.buf) for r in self._records]

    def tail_records(self, after_seq: int):
        """Decoded ``(seq, record)`` pairs with ``seq > after_seq`` --
        the replay tail above a checkpoint's sequence watermark."""
        return [(r.seq, decode_record(r.buf)) for r in self._records
                if r.seq > after_seq]

    def begin_replay(self, start_lsn: int) -> None:
        if self._replay is not None:
            raise RuntimeError("WAL already in replay mode")
        self._replay = _ReplayState(int(start_lsn))

    def expect(self, rec: Record) -> None:
        """Arm the replay-divergence check for the next ``append_batch``."""
        if self._replay is not None:
            self._replay.expect = rec

    def end_replay(self) -> None:
        """Leave replay mode after a *successful* replay; verifies the
        cursor consumed the tail exactly."""
        if self._replay is None:
            raise RuntimeError("WAL not in replay mode")
        cursor = self._replay.cursor
        self._replay = None
        if cursor != self._head:
            raise RuntimeError(
                f"WAL replay incomplete: cursor {cursor} != head "
                f"{self._head} (the tail was not fully replayed)")

    def abort_replay(self) -> None:
        """Leave replay mode after a failed replay without the
        completeness check, so the original error stays the diagnostic."""
        self._replay = None

    # -- crash simulation ---------------------------------------------------------
    def clone(self) -> "WriteAheadLog":
        """Snapshot of the durable log state -- what stable storage holds
        at a crash point. Encoded buffers are immutable and shared; all
        bookkeeping is copied."""
        w = WriteAheadLog()
        w._records = list(self._records)
        w._head = self._head
        w.truncated_to = self.truncated_to
        w.next_seq = self.next_seq
        w._trees_logged = set(self._trees_logged)
        return w
