"""Versioned manifest: the durable record of on-disk SSTable state.

Every flush and merge emits typed edits (``AddSSTable`` / ``RemoveSSTable``)
and the scheduler's log-enforcement phase records the advancing min-LSN
``Watermark``, so at any instant the manifest's *live set* is exactly the
SSTables reachable from the trees' L0s and levels -- maintained
incrementally by edits, never rebuilt by scanning the store (the
consistency the recovery tests assert).

A **checkpoint** is a snapshot anchored to a manifest version: the live
set at that version plus the write-memory image and the auxiliary
flush-decision state (see ``checkpoint.py``), stamped with the WAL
sequence/LSN watermark replay resumes from. ``latest_checkpoint`` is what
``recover`` restores before replaying the WAL tail; the scheduler keeps
``checkpoint_watermark >= truncated_to`` so the tail needed for replay is
never truncated away.

The edit log itself is bounded (old edits are observability, not recovery
state -- recovery needs only the latest checkpoint and the live set).
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["LiveSSTable", "ManifestEdit", "Manifest"]


@dataclass(frozen=True, eq=False)
class LiveSSTable:
    """Durable payload of one on-disk SSTable (arrays are immutable and
    shared with the live object -- the engine never mutates run arrays in
    place)."""

    shard: int
    tree: str
    keys: object
    vals: object
    lsn_min: int
    lsn_max: int
    entry_bytes: int
    page_bytes: int
    kind: str                 # "flush" | "merge" | "restored"


@dataclass(frozen=True)
class ManifestEdit:
    """One versioned manifest mutation. ``kind`` is one of
    ``add-flush`` / ``add-merge`` / ``remove`` / ``watermark``."""

    version: int
    kind: str
    shard: int = -1
    tree: str = ""
    sst_id: int = -1
    n_entries: int = 0
    lsn: int = 0              # lsn_min of the table, or the watermark LSN


class Manifest:
    """Edit-versioned live-SSTable set + checkpoints + store identity."""

    MAX_EDITS = 4096          # retained edit history (observability bound)
    MAX_CHECKPOINTS = 2       # latest is load-bearing; one spare for debug

    def __init__(self):
        self.version = 0
        self.edits: list[ManifestEdit] = []
        self.live: dict[int, LiveSSTable] = {}     # sst_id -> payload
        self.checkpoints: list = []                # Checkpoint, oldest first
        self.watermark = 0                         # last recorded min-LSN
        self.router_spec: tuple | None = None      # (kind, n, boundaries)
        self.store_meta: dict | None = None        # cfg guardrail fields

    # -- identity guardrails ----------------------------------------------------
    # Every StoreConfig field that shapes durable structure or replay
    # determinism. Deliberately absent: ``backend`` (numpy and pallas are
    # bit-identical by design -- recovering on the other backend is
    # supported) and ``time_model`` (reporting only).
    _META_FIELDS = (
        "scheme", "flush_policy", "entry_bytes", "page_bytes",
        "size_ratio", "active_sstable_bytes", "sstable_bytes",
        "total_memory_bytes", "write_memory_bytes", "sim_cache_bytes",
        "max_log_bytes", "checkpoint_interval_bytes",
        "mem_flush_threshold", "merge_budget", "beta",
        "l0_grouped", "l0_greedy", "l0_max_groups", "l0_target_groups",
        "dynamic_levels", "static_num_levels", "forced_flush_kind",
        "max_active_datasets", "accordion_pipeline",
    )

    @classmethod
    def _meta_of(cls, cfg) -> dict:
        return {k: getattr(cfg, k) for k in cls._META_FIELDS}

    def bind(self, cfg) -> None:
        """Record (or verify) the store identity this manifest belongs to:
        recovering with a mismatched config would silently re-route or
        re-partition persisted data."""
        meta = self._meta_of(cfg)
        if self.store_meta is None:
            self.store_meta = meta
        elif self.store_meta != meta:
            raise ValueError(
                f"manifest belongs to a store with {self.store_meta}, "
                f"but the config says {meta}; recover with the original "
                f"StoreConfig")

    def set_router(self, spec: tuple) -> None:
        if self.router_spec is None:
            self.router_spec = spec
        elif self.router_spec != spec:
            raise ValueError(
                f"manifest was written under router {self.router_spec}, "
                f"got {spec}; a persisted store must be recovered with "
                f"the router that placed its keys")

    # -- edits --------------------------------------------------------------------
    def _append(self, edit: ManifestEdit) -> None:
        self.edits.append(edit)
        if len(self.edits) > self.MAX_EDITS:
            del self.edits[:-self.MAX_EDITS]

    def add_sstable(self, shard: int, tree: str, sst, kind: str) -> None:
        """AddSSTable edit: a flush or merge wrote ``sst``."""
        self.version += 1
        self.live[sst.sst_id] = LiveSSTable(
            shard, tree, sst.keys, sst.vals, sst.lsn_min, sst.lsn_max,
            sst.entry_bytes, sst.page_bytes, kind)
        self._append(ManifestEdit(self.version, f"add-{kind}", shard, tree,
                                  sst.sst_id, sst.num_entries, sst.lsn_min))

    def remove_sstable(self, shard: int, tree: str, sst) -> None:
        """RemoveSSTable edit: a merge consumed ``sst``."""
        self.version += 1
        self.live.pop(sst.sst_id, None)
        self._append(ManifestEdit(self.version, "remove", shard, tree,
                                  sst.sst_id, sst.num_entries, sst.lsn_min))

    def note_watermark(self, lsn: int) -> None:
        """Record the advancing global min-LSN the log truncates below."""
        if lsn <= self.watermark:
            return
        self.version += 1
        self.watermark = lsn
        self._append(ManifestEdit(self.version, "watermark", lsn=lsn))

    # -- checkpoints ---------------------------------------------------------------
    @property
    def latest_checkpoint(self):
        return self.checkpoints[-1] if self.checkpoints else None

    @property
    def checkpoint_watermark(self) -> int:
        ck = self.latest_checkpoint
        return 0 if ck is None else ck.watermark

    def add_checkpoint(self, ck) -> None:
        self.checkpoints.append(ck)
        if len(self.checkpoints) > self.MAX_CHECKPOINTS:
            del self.checkpoints[:-self.MAX_CHECKPOINTS]

    def reset_to_checkpoint(self, ck, live: dict[int, LiveSSTable]) -> None:
        """Recovery rebase: drop edits past the checkpoint version and
        install the restored live set (re-keyed to the recovered store's
        SSTable ids). Replay then re-emits the tail's edits, converging
        the manifest to its pre-crash equivalent."""
        self.edits = [e for e in self.edits if e.version <= ck.version]
        self.version = ck.version
        self.live = dict(live)
        self.watermark = ck.man_watermark

    # -- crash simulation ------------------------------------------------------------
    def clone(self) -> "Manifest":
        """Durable-state snapshot at a crash point (payload arrays are
        immutable and shared; bookkeeping copied)."""
        m = Manifest()
        m.version = self.version
        m.edits = list(self.edits)
        m.live = dict(self.live)
        m.checkpoints = list(self.checkpoints)
        m.watermark = self.watermark
        m.router_spec = self.router_spec
        m.store_meta = None if self.store_meta is None \
            else dict(self.store_meta)
        return m
