# The durability plane: typed write-ahead log (LSN = byte offset,
# physically truncated below the global min-LSN), versioned manifests with
# checkpoints, and bit-identical crash recovery across the sharded store.
#
# ``recover`` is exported lazily: it pulls in the sharded data plane,
# which itself builds on this package (arena -> wal/manifest).
from .wal import (DeleteBatchRecord, Record,  # noqa: F401
                  SetWriteMemoryRecord, TickRecord, TreeCreateRecord,
                  WriteAheadLog, WriteBatchRecord, decode_record,
                  encode_record)
from .manifest import LiveSSTable, Manifest, ManifestEdit  # noqa: F401
from .checkpoint import (Checkpoint, RECOVERY_EXACT_COUNTERS,  # noqa: F401
                         capture_checkpoint, restore_checkpoint,
                         take_checkpoint)


def __getattr__(name):
    if name in ("recover", "router_from_spec"):
        from . import recovery
        return getattr(recovery, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
