"""File-backed manifest: an append-only frame log of edits + checkpoints.

One ``MANIFEST`` file holds four frame kinds (``format.py`` framing, tag
= frame kind):

  * ``META`` (json): the store-identity guardrail fields, written once
    at bind time -- reopening verifies the config matches.
  * ``ROUTER`` (pickle): the shard-router spec, written once.
  * ``EDIT`` (fixed int64 header + utf-8 names): one versioned
    ``ManifestEdit`` per flush/merge/watermark -- ``encode_edit`` /
    ``decode_edit`` are exact inverses (property-tested round-trip,
    mirroring the WAL record codec's contract).
  * ``CHECKPOINT`` (pickle): a full recovery point with SSTable payload
    arrays replaced by *references* into the page store (``sst_id`` ->
    geometry); reopening materializes the latest checkpoint frame by
    CRC-verified reads of the referenced ``sst-*.run`` files. The frame
    is fsynced before ``add_checkpoint`` returns, so the WAL-truncation
    that follows a checkpoint never outruns it; referenced page files
    are pinned against unlink until the checkpoint itself is retired.

Reopen tolerates (and physically truncates) a torn tail frame -- a
writer may die mid-append. Edits re-emitted by recovery replay append
duplicate frames with their original version numbers; the rebuild takes
``max`` over versions, so a re-recovered manifest converges.
"""
from __future__ import annotations

import json
import os
import pickle

import numpy as np

from ..durability.checkpoint import Checkpoint
from ..durability.manifest import LiveSSTable, Manifest, ManifestEdit
from ..durability.wal import _pad8
from .format import CorruptFrameError, build_frame, read_frames

__all__ = ["FileManifest", "encode_edit", "decode_edit"]

TAG_META = 1
TAG_ROUTER = 2
TAG_EDIT = 3
TAG_CHECKPOINT = 4

_EDIT_HEADER_WORDS = 8


# --------------------------- edit codec ---------------------------------------
def encode_edit(edit: ManifestEdit) -> bytes:
    """Serialize one manifest edit (int64 header + padded utf-8 names).
    Exact inverse of ``decode_edit`` for any string ``kind``/``tree``."""
    kind = edit.kind.encode()
    tree = edit.tree.encode()
    header = np.array([edit.version, edit.shard, edit.sst_id,
                       edit.n_entries, edit.lsn, len(kind), len(tree), 0],
                      np.int64)
    body = kind + tree
    body += b"\x00" * (_pad8(len(body)) - len(body))
    return header.tobytes() + body


def decode_edit(buf: bytes) -> ManifestEdit:
    """Deserialize one manifest edit (exact inverse of ``encode_edit``)."""
    header = np.frombuffer(buf[:_EDIT_HEADER_WORDS * 8], np.int64)
    version, shard, sst_id, n_entries, lsn, klen, tlen, _ = \
        (int(x) for x in header)
    off = _EDIT_HEADER_WORDS * 8
    kind = buf[off:off + klen].decode()
    tree = buf[off + klen:off + klen + tlen].decode()
    return ManifestEdit(version, kind, shard, tree, sst_id, n_entries, lsn)


# --------------------------- the file manifest --------------------------------
class FileManifest(Manifest):
    """``Manifest`` whose every mutation appends a durable frame."""

    def __init__(self, path: str, pages):
        super().__init__()
        self._path = path
        self.pages = pages                 # FilePageStore holding payloads
        self._f = None
        self._stats = None

    @classmethod
    def create(cls, path: str, pages) -> "FileManifest":
        if os.path.exists(path):
            raise FileExistsError(
                f"manifest {path!r} already exists; open the existing "
                f"plane with open_plane (then recover)")
        m = cls(path, pages)
        m._f = open(path, "ab", buffering=0)
        return m

    @classmethod
    def open(cls, path: str, pages) -> "FileManifest":
        """Rebuild from the frame log. Only the LATEST checkpoint frame
        is materialized (older frames may reference pages already
        retired); the live set stays empty -- ``recover()`` installs it
        from the checkpoint and the replayed tail, exactly as with the
        in-memory manifest."""
        m = cls(path, pages)
        ck_blob = None
        for tag, payload in read_frames(path, allow_torn_tail=True):
            if tag == TAG_META:
                m.store_meta = json.loads(payload.decode())
            elif tag == TAG_ROUTER:
                m.router_spec = pickle.loads(payload)
            elif tag == TAG_EDIT:
                e = decode_edit(payload)
                m.edits.append(e)
                m.version = max(m.version, e.version)
                if e.kind == "watermark" and e.lsn > m.watermark:
                    m.watermark = e.lsn
            elif tag == TAG_CHECKPOINT:
                ck_blob = payload
            else:
                raise CorruptFrameError(
                    f"{path}: unknown manifest frame tag {tag}")
        if len(m.edits) > m.MAX_EDITS:
            del m.edits[:-m.MAX_EDITS]
        if ck_blob is not None:
            ck = m._materialize_checkpoint(ck_blob)
            m.checkpoints = [ck]
            m.version = max(m.version, ck.version)
            pages.set_pinned(set(ck.payloads))
        m._f = open(path, "ab", buffering=0)
        return m

    def _materialize_checkpoint(self, blob: bytes) -> Checkpoint:
        d = pickle.loads(blob)
        payloads = {}
        for sid, (shard, tree, lsn_min, lsn_max, entry_bytes, page_bytes,
                  kind) in d["payload_refs"].items():
            run = self.pages.load(sid)
            payloads[sid] = LiveSSTable(
                shard, tree, run["keys"], run["vals"], lsn_min, lsn_max,
                entry_bytes, page_bytes, kind)
        return Checkpoint(
            version=d["version"], wal_seq=d["wal_seq"],
            watermark=d["watermark"], man_watermark=d["man_watermark"],
            write_memory_bytes=d["write_memory_bytes"],
            iostats=d["iostats"], schema=d["schema"], shards=d["shards"],
            payloads=payloads, scheduler=d["scheduler"])

    # -- frame appends ----------------------------------------------------------
    def bind_stats(self, stats) -> None:
        self._stats = stats
        self.pages.bind_stats(stats)

    def _frame(self, tag: int, payload: bytes, *, fsync: bool = False) -> None:
        self._f.write(build_frame(tag, payload))
        if fsync:
            os.fsync(self._f.fileno())
            self.fsyncs += 1
            if self._stats is not None:
                self._stats.fsyncs += 1

    fsyncs = 0

    # -- Manifest overrides: same state transitions, plus a durable frame -------
    def bind(self, cfg) -> None:
        first = self.store_meta is None
        super().bind(cfg)
        if first:
            self._frame(TAG_META,
                        json.dumps(self.store_meta, sort_keys=True).encode(),
                        fsync=True)

    def set_router(self, spec: tuple) -> None:
        first = self.router_spec is None
        super().set_router(spec)
        if first:
            self._frame(TAG_ROUTER, pickle.dumps(self.router_spec),
                        fsync=True)

    def _append(self, edit: ManifestEdit) -> None:
        super()._append(edit)
        self._frame(TAG_EDIT, encode_edit(edit))

    def add_checkpoint(self, ck: Checkpoint) -> None:
        # Every referenced payload must be a real file before the frame
        # that points at it is durable (bulk-loaded fixtures bypass the
        # flush path that normally writes them).
        for sid, p in ck.payloads.items():
            self.pages.ensure_payload(sid, p)
        super().add_checkpoint(ck)
        refs = {sid: (p.shard, p.tree, int(p.lsn_min), int(p.lsn_max),
                      int(p.entry_bytes), int(p.page_bytes), p.kind)
                for sid, p in ck.payloads.items()}
        blob = pickle.dumps({
            "version": ck.version, "wal_seq": ck.wal_seq,
            "watermark": ck.watermark, "man_watermark": ck.man_watermark,
            "write_memory_bytes": ck.write_memory_bytes,
            "iostats": ck.iostats, "schema": ck.schema,
            "shards": ck.shards, "scheduler": ck.scheduler,
            "payload_refs": refs,
        })
        self._frame(TAG_CHECKPOINT, blob, fsync=True)
        self.pages.set_pinned({sid for c in self.checkpoints
                               for sid in c.payloads})

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None
