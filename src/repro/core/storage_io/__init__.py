"""Physical storage plane: file-backed WAL segments, SSTable pages, and
the manifest frame log, behind the same ``StorageMedium`` seam the
in-memory durability plane uses (``StoreConfig.storage_medium``:
``"memory"`` keeps everything byte-accounted RAM; ``"files"`` moves real
bytes under ``storage_dir`` with CRC framing, group commit, and
process-kill crash safety)."""
from .format import CorruptFrameError, build_frame, scan_frames
from .manifest_files import FileManifest, decode_edit, encode_edit
from .pages import FilePageStore
from .plane import create_plane, open_plane, plane_paths
from .wal_files import FSYNC_POLICIES, FileWAL

__all__ = [
    "CorruptFrameError", "build_frame", "scan_frames",
    "FileManifest", "encode_edit", "decode_edit",
    "FilePageStore",
    "create_plane", "open_plane", "plane_paths",
    "FileWAL", "FSYNC_POLICIES",
]
