"""Assembly of the file-backed storage plane under one directory.

Layout of ``cfg.storage_dir``::

    <storage_dir>/
      wal/                segmented WAL (FileWAL: META + seg-*.wal)
      sst/                one sst-*.run file per SSTable (FilePageStore)
      MANIFEST            manifest frame log (FileManifest)

``create_plane`` starts a fresh store (refusing a directory that already
holds a manifest -- stale durable state must be recovered, not silently
shadowed); ``open_plane`` reopens existing state for ``recover()``.
``MemoryArena`` calls ``create_plane`` when ``storage_medium="files"``
and no adopted wal/manifest was passed in; ``recover()`` callers use
``open_plane`` and pass the result through the same adoption seam the
in-memory medium uses -- the media are interchangeable above this line.
"""
from __future__ import annotations

import os

from .manifest_files import FileManifest
from .pages import FilePageStore
from .wal_files import FileWAL

__all__ = ["plane_paths", "create_plane", "open_plane"]


def plane_paths(root: str) -> dict:
    return {"wal": os.path.join(root, "wal"),
            "sst": os.path.join(root, "sst"),
            "manifest": os.path.join(root, "MANIFEST")}


def _wal_kwargs(cfg) -> dict:
    return {"segment_bytes": cfg.wal_segment_bytes,
            "fsync_policy": cfg.fsync_policy,
            "group_bytes": cfg.group_commit_bytes,
            "group_max_wait_s": cfg.group_commit_max_wait_s,
            "async_fsync": getattr(cfg, "wal_async_fsync", False)}


def create_plane(cfg) -> tuple[FileWAL, FileManifest]:
    """Fresh physical plane under ``cfg.storage_dir``."""
    root = cfg.storage_dir
    if not root:
        raise ValueError(
            "storage_medium='files' requires storage_dir to be set")
    os.makedirs(root, exist_ok=True)
    p = plane_paths(root)
    if os.path.exists(p["manifest"]):
        raise FileExistsError(
            f"{p['manifest']} already exists: this directory holds a "
            f"persisted store; use open_plane + recover instead of "
            f"creating a new one over it")
    pages = FilePageStore(p["sst"])
    manifest = FileManifest.create(p["manifest"], pages)
    wal = FileWAL.create(p["wal"], **_wal_kwargs(cfg))
    return wal, manifest


def open_plane(cfg) -> tuple[FileWAL, FileManifest]:
    """Reopen a persisted plane (crash recovery / restart):
    ``recover(cfg, *open_plane(cfg))``."""
    root = cfg.storage_dir
    if not root:
        raise ValueError(
            "storage_medium='files' requires storage_dir to be set")
    p = plane_paths(root)
    pages = FilePageStore(p["sst"])
    manifest = FileManifest.open(p["manifest"], pages)
    wal = FileWAL.open(p["wal"], **_wal_kwargs(cfg))
    return wal, manifest
