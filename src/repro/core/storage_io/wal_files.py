"""Segmented file-backed WAL with group commit.

``FileWAL`` subclasses the in-memory ``WriteAheadLog`` -- same LSN
semantics, same replay machinery, same record wire encoding -- and makes
the log physical:

  * **Segments**: records append to fixed-size segment files
    (``seg-<index>.wal``), each a sequence of CRC frames whose tag is
    the record's absolute sequence number. A record never splits across
    segments; a segment seals (flush + fsync, file closed) when the next
    frame would overflow ``segment_bytes``. ``truncate(min_lsn)``
    unlinks whole sealed segments once every record they hold is below
    the retained minimum -- the durable twin of the base class's
    record-list truncation.
  * **META**: a tiny JSON file (rewritten atomically: tmp + fsync +
    rename, *before* any segment unlinks) pinning ``truncated_to``, the
    minimum retained sequence, and the head LSN at truncation time --
    what reopen needs to restart sequence/LSN counters when the log is
    empty or its oldest segment holds already-truncated frames.
  * **Group commit**: appended frames buffer in userspace (``_pending``)
    until the fsync policy releases them, so a SIGKILL loses exactly the
    un-fsynced suffix -- fsync is the real durability boundary, which is
    what the process-kill crash harness measures. ``per_record`` fsyncs
    every append; ``per_batch`` fsyncs at every commit point (store-level
    batch, scheduler tick/segment end); ``group`` defers until
    ``group_bytes`` of frames are pending or the oldest has waited
    ``group_max_wait_s``. Concurrent commit points queue leader-follower
    style: whichever commit trips the threshold issues ONE fsync for
    every queued commit, and each queued commit's wait is recorded in
    ``commit_hist`` (a ``LatencyHistogram``, microseconds) -- the
    ``commit_p99_us`` / ``fsyncs_per_kop`` BENCH columns read these.
  * **Async group commit** (``async_fsync=True``, ``group`` policy only):
    the leader no longer fsyncs on the foreground thread -- it hands the
    pending frames to a durability worker and returns, overlapping the
    next commit group's userspace buffering with the fsync in flight.
    Acks are unchanged: a commit's latency is recorded (and its ops
    counted durable) only when the fsync covering its head LSN completes,
    and ``all_durable`` stays False while a handoff is in flight. The
    worker additionally honors ``group_max_wait_s`` on its own timer, so
    a queued commit's durability no longer waits for the *next*
    foreground commit call to notice its age. ``IOStats.fsync_wait_us``
    counts foreground microseconds blocked on WAL durability in BOTH
    modes -- whole inline fsyncs when blocking, only the residual
    barrier waits (segment seal, ``sync()``, close) when async -- so at
    equal fsync rate the async mode's drop in that counter is the
    foreground time the handoff reclaimed.

Reopen (``FileWAL.open``) rescans the segments oldest-first, skipping
frames below the retained minimum; a torn tail is tolerated -- and
physically truncated -- on the LAST segment only (the one a crashed
writer was appending), while unreadable bytes in a sealed segment raise
``CorruptFrameError``. ``set_head`` (the legacy ``log_pos`` setter shim)
moves the in-memory head only; it logs no record, so like the base
class the skipped span is unreplayable -- observability-only.
"""
from __future__ import annotations

import json
import os
import threading
import time

from ...runtime.latency import LatencyHistogram
from ..durability.wal import TreeCreateRecord, WriteAheadLog, _Stored, \
    decode_record
from .format import build_frame, read_frames

__all__ = ["FileWAL", "FSYNC_POLICIES"]

FSYNC_POLICIES = ("per_record", "per_batch", "group")

_META = "META"
_SEG_FMT = "seg-%010d.wal"


class FileWAL(WriteAheadLog):
    """File-backed ``WriteAheadLog``: segment files + group commit."""

    def __init__(self, root: str, *, segment_bytes: int = 1 << 20,
                 fsync_policy: str = "per_batch",
                 group_bytes: int = 64 << 10,
                 group_max_wait_s: float = 1e-3,
                 async_fsync: bool = False):
        if fsync_policy not in FSYNC_POLICIES:
            raise ValueError(f"unknown fsync_policy {fsync_policy!r}; "
                             f"expected one of {FSYNC_POLICIES}")
        if async_fsync and fsync_policy != "group":
            raise ValueError(f"async_fsync requires fsync_policy='group', "
                             f"got {fsync_policy!r}")
        super().__init__()
        self.root = root
        self.segment_bytes = int(segment_bytes)
        self.fsync_policy = fsync_policy
        self.group_bytes = int(group_bytes)
        self.group_max_wait_s = float(group_max_wait_s)
        self.async_fsync = bool(async_fsync)
        self.fsyncs = 0
        self.commit_hist = LatencyHistogram()
        self._stats = None
        self._meta_path = os.path.join(root, _META)
        self._min_seq = 0              # oldest retained sequence number
        self._durable_lsn = 0
        self._pending: list[bytes] = []    # frames not yet written to the OS
        self._pending_bytes = 0
        self._pending_t0 = 0.0             # age of the oldest pending frame
        # (enqueue time, n ops, head LSN the commit needs durable)
        self._commit_q: list[tuple[float, int, int]] = []
        self._segments: list[tuple[str, int]] = []     # sealed: (path, last seq)
        self._f = None
        self._seg_index = -1
        self._seg_path = ""
        self._seg_bytes = 0
        self._seg_last_seq = -1
        # Async durability worker state. The condition guards _pending,
        # _commit_q, _handoff, _unfsynced and _durable_lsn whenever the
        # worker exists; with async_fsync off the lock is uncontended.
        self._dcv = threading.Condition()
        self._handoff: list[tuple[object, bytes, int]] = []  # (file, buf, head)
        self._unfsynced = 0            # handoffs not yet fsynced
        self._dclosed = False
        self._dthread = None
        if self.async_fsync:
            self._dthread = threading.Thread(
                target=self._durability_worker, daemon=True,
                name="wal-fsync")
            self._dthread.start()

    # -- construction ----------------------------------------------------------
    @classmethod
    def create(cls, root: str, **kw) -> "FileWAL":
        """Start a fresh log in an empty directory."""
        os.makedirs(root, exist_ok=True)
        if os.listdir(root):
            raise FileExistsError(
                f"WAL directory {root!r} is not empty; open the existing "
                f"log with FileWAL.open (then recover)")
        w = cls(root, **kw)
        w._write_meta()
        w._open_segment(0)
        return w

    @classmethod
    def open(cls, root: str, **kw) -> "FileWAL":
        """Reopen a persisted log: rescan segments, drop a torn tail on
        the last one, rebuild heads/sequences, keep appending in place."""
        w = cls(root, **kw)
        with open(w._meta_path) as f:
            meta = json.load(f)
        w.truncated_to = int(meta["truncated_to"])
        w._min_seq = int(meta["min_seq"])
        head = int(meta["head"])
        names = sorted(n for n in os.listdir(root)
                       if n.startswith("seg-") and n.endswith(".wal"))
        last_seq = None
        for i, name in enumerate(names):
            path = os.path.join(root, name)
            is_last = i == len(names) - 1
            frames = read_frames(path, allow_torn_tail=is_last)
            seg_last = -1
            for tag, payload in frames:
                seg_last = tag
                if tag < w._min_seq:      # truncated prefix of a segment
                    continue              # straddling the retention barrier
                rec = decode_record(payload)
                w._records.append(_Stored(tag, rec.lsn0, rec.lsn_end,
                                          payload))
                head = max(head, rec.lsn_end)
                last_seq = tag
                if isinstance(rec, TreeCreateRecord):
                    w._trees_logged.add(rec.tree)
            if not is_last:
                w._segments.append((path, seg_last))
        w._head = head
        w._durable_lsn = head             # everything scanned is on disk
        w.next_seq = w._min_seq if last_seq is None else last_seq + 1
        if names:
            w._seg_index = int(names[-1][4:-4])
            w._seg_path = os.path.join(root, names[-1])
            w._f = open(w._seg_path, "ab", buffering=0)
            w._seg_bytes = os.path.getsize(w._seg_path)
            w._seg_last_seq = -1 if last_seq is None else last_seq
        else:                             # crashed between META and segment 0
            w._open_segment(0)
        return w

    # -- plumbing ---------------------------------------------------------------
    def bind_stats(self, stats) -> None:
        """Mirror fsync counts into the store's ``IOStats``."""
        self._stats = stats

    def _open_segment(self, index: int) -> None:
        self._seg_index = index
        self._seg_path = os.path.join(self.root, _SEG_FMT % index)
        # buffering=0: bytes handed to write() are in the OS immediately,
        # so _pending is the ONLY kill-vulnerable buffer.
        self._f = open(self._seg_path, "ab", buffering=0)
        self._seg_bytes = os.path.getsize(self._seg_path)
        self._seg_last_seq = -1

    def _write_meta(self) -> None:
        tmp = self._meta_path + ".tmp"
        with open(tmp, "w") as f:
            # head = the DURABLE head, never self._head: under group
            # commit appended frames may still be buffered in userspace,
            # and a durable META claiming LSNs beyond the surviving
            # frames would make recovery's replay come up short.
            json.dump({"truncated_to": self.truncated_to,
                       "min_seq": self._min_seq,
                       "head": self._durable_lsn}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._meta_path)

    def _seal_segment(self) -> None:
        self._fsync_now()                  # a sealed file is never torn
        self._f.close()
        self._segments.append((self._seg_path, self._seg_last_seq))
        self._open_segment(self._seg_index + 1)

    def _fsync_now(self) -> None:
        """Write every pending frame and fsync; drain the commit queue
        into the latency histogram (ONE fsync serves all queued commits:
        leader-follower group commit). In async mode this is the
        *barrier* form: hand everything to the durability worker and
        block until it has fsynced (seal/sync/close call sites)."""
        if self._dthread is not None:
            self._wait_durable()
            return
        if self._pending:
            t0 = time.perf_counter()
            self._f.write(b"".join(self._pending))
            os.fsync(self._f.fileno())
            self.fsyncs += 1
            if self._stats is not None:
                self._stats.fsyncs += 1
                # foreground time blocked on WAL durability: the whole
                # inline fsync here; only the residual barrier waits in
                # async mode -- the same counter, so the two modes'
                # foreground durability cost compares directly.
                self._stats.fsync_wait_us += (time.perf_counter() - t0) * 1e6
            self._pending.clear()
            self._pending_bytes = 0
            self._durable_lsn = self._head
        if self._commit_q:
            t1 = time.perf_counter()
            for t0, n, _ in self._commit_q:
                self.commit_hist.record(max((t1 - t0) * 1e6, 1e-3), n=n)
            self._commit_q.clear()

    # -- async durability worker -------------------------------------------------
    def _handoff_locked(self) -> None:
        """Move the pending frames to the worker's queue (caller holds
        ``_dcv``). Captures the current segment file: a seal drains the
        worker first, so at most one file is ever in flight."""
        if not self._pending:
            return
        self._handoff.append((self._f, b"".join(self._pending), self._head))
        self._unfsynced += 1
        self._pending.clear()
        self._pending_bytes = 0
        self._dcv.notify_all()

    def _wait_durable(self) -> None:
        """Foreground barrier: hand off anything pending and block until
        the worker has fsynced every handoff. The blocked time is the
        async mode's residual foreground cost (``fsync_wait_us``)."""
        t0 = time.perf_counter()
        waited = False
        with self._dcv:
            self._handoff_locked()
            while self._unfsynced:
                waited = True
                self._dcv.wait()
        if waited and self._stats is not None:
            self._stats.fsync_wait_us += (time.perf_counter() - t0) * 1e6

    def _durability_worker(self) -> None:
        while True:
            with self._dcv:
                while not self._handoff:
                    if self._dclosed:
                        if not self._pending:
                            return
                        self._handoff_locked()
                        break
                    if self._pending:
                        # Honor group_max_wait_s on our own clock: a
                        # queued commit's durability must not wait for
                        # the next foreground commit to notice its age.
                        left = self.group_max_wait_s \
                            - (time.perf_counter() - self._pending_t0)
                        if left <= 0:
                            self._handoff_locked()
                            break
                        self._dcv.wait(timeout=left)
                    else:
                        self._dcv.wait()
                f, buf, head = self._handoff.pop(0)
            f.write(buf)
            os.fsync(f.fileno())
            t1 = time.perf_counter()
            with self._dcv:
                self.fsyncs += 1
                if self._stats is not None:
                    self._stats.fsyncs += 1
                if head > self._durable_lsn:
                    self._durable_lsn = head
                keep = []
                for t0, n, lsn in self._commit_q:
                    if lsn <= self._durable_lsn:
                        self.commit_hist.record(
                            max((t1 - t0) * 1e6, 1e-3), n=n)
                    else:
                        keep.append((t0, n, lsn))
                self._commit_q = keep
                self._unfsynced -= 1
                self._dcv.notify_all()

    # -- appends (one override: every record becomes a pending frame) -----------
    def _push(self, rec) -> None:
        seq = self.next_seq
        super()._push(rec)
        frame = build_frame(seq, self._records[-1].buf)
        if self._seg_bytes and self._seg_bytes + len(frame) > self.segment_bytes:
            self._seal_segment()
        with self._dcv:           # the async worker reads/steals _pending
            if not self._pending:
                self._pending_t0 = time.perf_counter()
            self._pending.append(frame)
            self._pending_bytes += len(frame)
        self._seg_bytes += len(frame)
        self._seg_last_seq = seq
        if self.fsync_policy == "per_record":
            self._commit_q.append((time.perf_counter(), 1, self._head))
            self._fsync_now()

    # -- durability -------------------------------------------------------------
    @property
    def durable_lsn(self) -> int:
        return self._durable_lsn

    @property
    def all_durable(self) -> bool:
        return not self._pending and self._unfsynced == 0

    def commit(self, n: int = 1) -> None:
        """A commit point: ``n`` logical ops want durability here. Under
        ``per_batch`` this fsyncs now; under ``group`` it queues behind
        the interval/age thresholds (the commit that trips one becomes
        the leader and fsyncs for the whole queue). With ``async_fsync``
        the leader hands the group to the durability worker instead of
        fsyncing inline -- the commit's ack (histogram entry, durable op
        count) still lands only when its covering fsync completes."""
        if self._replay is not None or self.fsync_policy == "per_record":
            return
        now = time.perf_counter()
        if self._dthread is not None:
            with self._dcv:
                if not self._pending and self._unfsynced == 0:
                    return
                self._commit_q.append((now, max(1, int(n)), self._head))
                # Same group-closing rule as the blocking leader (bytes
                # or age) -- just a handoff instead of an inline fsync.
                # The worker's own timer covers the case blocking mode
                # cannot: an aging group with no further commit calls.
                if self._pending_bytes >= self.group_bytes \
                        or now - self._pending_t0 >= self.group_max_wait_s:
                    self._handoff_locked()
            return
        if not self._pending:
            return
        self._commit_q.append((now, max(1, int(n)), self._head))
        if self.fsync_policy == "per_batch" \
                or self._pending_bytes >= self.group_bytes \
                or now - self._pending_t0 >= self.group_max_wait_s:
            self._fsync_now()

    def sync(self) -> None:
        """Force everything durable now (shutdown, tests, benchmarks)."""
        if self._pending or self._commit_q or self._unfsynced:
            self._fsync_now()

    def close(self) -> None:
        self.sync()
        if self._dthread is not None:
            with self._dcv:
                self._dclosed = True
                self._dcv.notify_all()
            self._dthread.join(timeout=5.0)
            self._dthread = None
        if self._f is not None:
            self._f.close()
            self._f = None

    # -- truncation --------------------------------------------------------------
    def truncate(self, min_lsn: int, *, keep_after_seq: int = -1) -> int:
        """Logical truncation (base class) + physical: rewrite META first
        (so a crash mid-unlink still reopens consistently), then unlink
        every sealed segment whose records are all below the retained
        minimum. The active segment is never unlinked; frames below the
        barrier inside a retained file are skipped at reopen."""
        dropped = super().truncate(min_lsn, keep_after_seq=keep_after_seq)
        self._min_seq = self._records[0].seq if self._records \
            else self.next_seq
        self._write_meta()
        keep = []
        for path, last_seq in self._segments:
            if last_seq < self._min_seq:
                try:
                    os.unlink(path)
                except FileNotFoundError:
                    pass
            else:
                keep.append((path, last_seq))
        self._segments = keep
        return dropped

    # -- observability ------------------------------------------------------------
    @property
    def segment_count(self) -> int:
        """Sealed segments + the active one."""
        return len(self._segments) + 1

    def segment_paths(self) -> list[str]:
        return [p for p, _ in self._segments] + [self._seg_path]
