"""Segmented file-backed WAL with group commit.

``FileWAL`` subclasses the in-memory ``WriteAheadLog`` -- same LSN
semantics, same replay machinery, same record wire encoding -- and makes
the log physical:

  * **Segments**: records append to fixed-size segment files
    (``seg-<index>.wal``), each a sequence of CRC frames whose tag is
    the record's absolute sequence number. A record never splits across
    segments; a segment seals (flush + fsync, file closed) when the next
    frame would overflow ``segment_bytes``. ``truncate(min_lsn)``
    unlinks whole sealed segments once every record they hold is below
    the retained minimum -- the durable twin of the base class's
    record-list truncation.
  * **META**: a tiny JSON file (rewritten atomically: tmp + fsync +
    rename, *before* any segment unlinks) pinning ``truncated_to``, the
    minimum retained sequence, and the head LSN at truncation time --
    what reopen needs to restart sequence/LSN counters when the log is
    empty or its oldest segment holds already-truncated frames.
  * **Group commit**: appended frames buffer in userspace (``_pending``)
    until the fsync policy releases them, so a SIGKILL loses exactly the
    un-fsynced suffix -- fsync is the real durability boundary, which is
    what the process-kill crash harness measures. ``per_record`` fsyncs
    every append; ``per_batch`` fsyncs at every commit point (store-level
    batch, scheduler tick/segment end); ``group`` defers until
    ``group_bytes`` of frames are pending or the oldest has waited
    ``group_max_wait_s``. Concurrent commit points queue leader-follower
    style: whichever commit trips the threshold issues ONE fsync for
    every queued commit, and each queued commit's wait is recorded in
    ``commit_hist`` (a ``LatencyHistogram``, microseconds) -- the
    ``commit_p99_us`` / ``fsyncs_per_kop`` BENCH columns read these.

Reopen (``FileWAL.open``) rescans the segments oldest-first, skipping
frames below the retained minimum; a torn tail is tolerated -- and
physically truncated -- on the LAST segment only (the one a crashed
writer was appending), while unreadable bytes in a sealed segment raise
``CorruptFrameError``. ``set_head`` (the legacy ``log_pos`` setter shim)
moves the in-memory head only; it logs no record, so like the base
class the skipped span is unreplayable -- observability-only.
"""
from __future__ import annotations

import json
import os
import time

from ...runtime.latency import LatencyHistogram
from ..durability.wal import TreeCreateRecord, WriteAheadLog, _Stored, \
    decode_record
from .format import build_frame, read_frames

__all__ = ["FileWAL", "FSYNC_POLICIES"]

FSYNC_POLICIES = ("per_record", "per_batch", "group")

_META = "META"
_SEG_FMT = "seg-%010d.wal"


class FileWAL(WriteAheadLog):
    """File-backed ``WriteAheadLog``: segment files + group commit."""

    def __init__(self, root: str, *, segment_bytes: int = 1 << 20,
                 fsync_policy: str = "per_batch",
                 group_bytes: int = 64 << 10,
                 group_max_wait_s: float = 1e-3):
        if fsync_policy not in FSYNC_POLICIES:
            raise ValueError(f"unknown fsync_policy {fsync_policy!r}; "
                             f"expected one of {FSYNC_POLICIES}")
        super().__init__()
        self.root = root
        self.segment_bytes = int(segment_bytes)
        self.fsync_policy = fsync_policy
        self.group_bytes = int(group_bytes)
        self.group_max_wait_s = float(group_max_wait_s)
        self.fsyncs = 0
        self.commit_hist = LatencyHistogram()
        self._stats = None
        self._meta_path = os.path.join(root, _META)
        self._min_seq = 0              # oldest retained sequence number
        self._durable_lsn = 0
        self._pending: list[bytes] = []    # frames not yet written to the OS
        self._pending_bytes = 0
        self._pending_t0 = 0.0             # age of the oldest pending frame
        self._commit_q: list[tuple[float, int]] = []   # (enqueue time, n ops)
        self._segments: list[tuple[str, int]] = []     # sealed: (path, last seq)
        self._f = None
        self._seg_index = -1
        self._seg_path = ""
        self._seg_bytes = 0
        self._seg_last_seq = -1

    # -- construction ----------------------------------------------------------
    @classmethod
    def create(cls, root: str, **kw) -> "FileWAL":
        """Start a fresh log in an empty directory."""
        os.makedirs(root, exist_ok=True)
        if os.listdir(root):
            raise FileExistsError(
                f"WAL directory {root!r} is not empty; open the existing "
                f"log with FileWAL.open (then recover)")
        w = cls(root, **kw)
        w._write_meta()
        w._open_segment(0)
        return w

    @classmethod
    def open(cls, root: str, **kw) -> "FileWAL":
        """Reopen a persisted log: rescan segments, drop a torn tail on
        the last one, rebuild heads/sequences, keep appending in place."""
        w = cls(root, **kw)
        with open(w._meta_path) as f:
            meta = json.load(f)
        w.truncated_to = int(meta["truncated_to"])
        w._min_seq = int(meta["min_seq"])
        head = int(meta["head"])
        names = sorted(n for n in os.listdir(root)
                       if n.startswith("seg-") and n.endswith(".wal"))
        last_seq = None
        for i, name in enumerate(names):
            path = os.path.join(root, name)
            is_last = i == len(names) - 1
            frames = read_frames(path, allow_torn_tail=is_last)
            seg_last = -1
            for tag, payload in frames:
                seg_last = tag
                if tag < w._min_seq:      # truncated prefix of a segment
                    continue              # straddling the retention barrier
                rec = decode_record(payload)
                w._records.append(_Stored(tag, rec.lsn0, rec.lsn_end,
                                          payload))
                head = max(head, rec.lsn_end)
                last_seq = tag
                if isinstance(rec, TreeCreateRecord):
                    w._trees_logged.add(rec.tree)
            if not is_last:
                w._segments.append((path, seg_last))
        w._head = head
        w._durable_lsn = head             # everything scanned is on disk
        w.next_seq = w._min_seq if last_seq is None else last_seq + 1
        if names:
            w._seg_index = int(names[-1][4:-4])
            w._seg_path = os.path.join(root, names[-1])
            w._f = open(w._seg_path, "ab", buffering=0)
            w._seg_bytes = os.path.getsize(w._seg_path)
            w._seg_last_seq = -1 if last_seq is None else last_seq
        else:                             # crashed between META and segment 0
            w._open_segment(0)
        return w

    # -- plumbing ---------------------------------------------------------------
    def bind_stats(self, stats) -> None:
        """Mirror fsync counts into the store's ``IOStats``."""
        self._stats = stats

    def _open_segment(self, index: int) -> None:
        self._seg_index = index
        self._seg_path = os.path.join(self.root, _SEG_FMT % index)
        # buffering=0: bytes handed to write() are in the OS immediately,
        # so _pending is the ONLY kill-vulnerable buffer.
        self._f = open(self._seg_path, "ab", buffering=0)
        self._seg_bytes = os.path.getsize(self._seg_path)
        self._seg_last_seq = -1

    def _write_meta(self) -> None:
        tmp = self._meta_path + ".tmp"
        with open(tmp, "w") as f:
            # head = the DURABLE head, never self._head: under group
            # commit appended frames may still be buffered in userspace,
            # and a durable META claiming LSNs beyond the surviving
            # frames would make recovery's replay come up short.
            json.dump({"truncated_to": self.truncated_to,
                       "min_seq": self._min_seq,
                       "head": self._durable_lsn}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._meta_path)

    def _seal_segment(self) -> None:
        self._fsync_now()                  # a sealed file is never torn
        self._f.close()
        self._segments.append((self._seg_path, self._seg_last_seq))
        self._open_segment(self._seg_index + 1)

    def _fsync_now(self) -> None:
        """Write every pending frame and fsync; drain the commit queue
        into the latency histogram (ONE fsync serves all queued commits:
        leader-follower group commit)."""
        if self._pending:
            self._f.write(b"".join(self._pending))
            os.fsync(self._f.fileno())
            self.fsyncs += 1
            if self._stats is not None:
                self._stats.fsyncs += 1
            self._pending.clear()
            self._pending_bytes = 0
            self._durable_lsn = self._head
        if self._commit_q:
            t1 = time.perf_counter()
            for t0, n in self._commit_q:
                self.commit_hist.record(max((t1 - t0) * 1e6, 1e-3), n=n)
            self._commit_q.clear()

    # -- appends (one override: every record becomes a pending frame) -----------
    def _push(self, rec) -> None:
        seq = self.next_seq
        super()._push(rec)
        frame = build_frame(seq, self._records[-1].buf)
        if self._seg_bytes and self._seg_bytes + len(frame) > self.segment_bytes:
            self._seal_segment()
        if not self._pending:
            self._pending_t0 = time.perf_counter()
        self._pending.append(frame)
        self._pending_bytes += len(frame)
        self._seg_bytes += len(frame)
        self._seg_last_seq = seq
        if self.fsync_policy == "per_record":
            self._commit_q.append((time.perf_counter(), 1))
            self._fsync_now()

    # -- durability -------------------------------------------------------------
    @property
    def durable_lsn(self) -> int:
        return self._durable_lsn

    @property
    def all_durable(self) -> bool:
        return not self._pending

    def commit(self, n: int = 1) -> None:
        """A commit point: ``n`` logical ops want durability here. Under
        ``per_batch`` this fsyncs now; under ``group`` it queues behind
        the interval/age thresholds (the commit that trips one becomes
        the leader and fsyncs for the whole queue)."""
        if self._replay is not None or self.fsync_policy == "per_record":
            return
        if not self._pending:
            return
        now = time.perf_counter()
        self._commit_q.append((now, max(1, int(n))))
        if self.fsync_policy == "per_batch" \
                or self._pending_bytes >= self.group_bytes \
                or now - self._pending_t0 >= self.group_max_wait_s:
            self._fsync_now()

    def sync(self) -> None:
        """Force everything durable now (shutdown, tests, benchmarks)."""
        if self._pending or self._commit_q:
            self._fsync_now()

    def close(self) -> None:
        self.sync()
        if self._f is not None:
            self._f.close()
            self._f = None

    # -- truncation --------------------------------------------------------------
    def truncate(self, min_lsn: int, *, keep_after_seq: int = -1) -> int:
        """Logical truncation (base class) + physical: rewrite META first
        (so a crash mid-unlink still reopens consistently), then unlink
        every sealed segment whose records are all below the retained
        minimum. The active segment is never unlinked; frames below the
        barrier inside a retained file are skipped at reopen."""
        dropped = super().truncate(min_lsn, keep_after_seq=keep_after_seq)
        self._min_seq = self._records[0].seq if self._records \
            else self.next_seq
        self._write_meta()
        keep = []
        for path, last_seq in self._segments:
            if last_seq < self._min_seq:
                try:
                    os.unlink(path)
                except FileNotFoundError:
                    pass
            else:
                keep.append((path, last_seq))
        self._segments = keep
        return dropped

    # -- observability ------------------------------------------------------------
    @property
    def segment_count(self) -> int:
        """Sealed segments + the active one."""
        return len(self._segments) + 1

    def segment_paths(self) -> list[str]:
        return [p for p, _ in self._segments] + [self._seg_path]
