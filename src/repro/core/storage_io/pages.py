"""File-backed SSTable pages: one file per table, page-aligned reads.

Layout of ``sst-<id>.run``::

    +----------------------------------------------+
    | header: magic u32, version u32, n i64,       |
    |         lsn_min i64, lsn_max i64,            |
    |         entry_bytes i64, page_bytes i64,     |
    |         crc32(keys+vals) u32                 |
    +----------------------------------------------+
    | keys: n * int64 LE                           |
    | vals: n * int64 LE                           |
    +----------------------------------------------+

Page ``p`` covers entries ``[p*epp, (p+1)*epp)`` with
``epp = max(1, page_bytes // entry_bytes)`` -- the exact geometry
``lsm/sstable.py`` accounts pins against, so ``Disk.query_pin_many``'s
counters stay bit-identical while every cache miss now issues a real
``pread`` of that page's key/value slices (page ``-1``, the Bloom unit,
reads the header). Files are written whole at flush/merge (tables are
immutable), fsynced, and unlinked at ``drop_sst`` -- except while a
retained checkpoint still references them (``set_pinned``): a
checkpoint frame must never point at an unlinked file, so drops defer
until the pin set moves on. ``gc`` reconciles the directory against the
manifest's live set after recovery (replayed flushes re-write tables
under fresh ids; the crashed run's orphans are removed).
"""
from __future__ import annotations

import os
import struct
import zlib

import numpy as np

__all__ = ["FilePageStore", "SST_MAGIC"]

SST_MAGIC = 0x4C534D53            # "LSMS"
SST_VERSION = 1
_HEADER = struct.Struct("<IIqqqqqI")


class FilePageStore:
    """Directory of immutable per-SSTable files keyed by ``sst_id``."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.stats = None                  # bound IOStats (fsync counter)
        self.fsyncs = 0
        self.bytes_written = 0
        self.bytes_read = 0
        self._pinned: set[int] = set()     # referenced by retained checkpoints
        self._deferred: set[int] = set()   # dropped while pinned

    def bind_stats(self, stats) -> None:
        self.stats = stats

    def path(self, sst_id: int) -> str:
        return os.path.join(self.root, f"sst-{int(sst_id):010d}.run")

    def ids(self) -> set[int]:
        out = set()
        for name in os.listdir(self.root):
            if name.startswith("sst-") and name.endswith(".run"):
                out.add(int(name[4:-4]))
        return out

    # -- writes -----------------------------------------------------------------
    def _write_file(self, sst_id: int, keys, vals, lsn_min: int,
                    lsn_max: int, entry_bytes: int, page_bytes: int) -> None:
        kb = np.ascontiguousarray(keys, np.int64).tobytes()
        vb = np.ascontiguousarray(vals, np.int64).tobytes()
        header = _HEADER.pack(SST_MAGIC, SST_VERSION, len(kb) // 8,
                              int(lsn_min), int(lsn_max), int(entry_bytes),
                              int(page_bytes),
                              zlib.crc32(vb, zlib.crc32(kb)) & 0xFFFFFFFF)
        with open(self.path(sst_id), "wb") as f:
            f.write(header)
            f.write(kb)
            f.write(vb)
            f.flush()
            os.fsync(f.fileno())
        self.fsyncs += 1
        self.bytes_written += _HEADER.size + len(kb) + len(vb)
        if self.stats is not None:
            self.stats.fsyncs += 1

    def write(self, sst) -> None:
        """Persist a freshly flushed/merged table (whole-file write)."""
        self._write_file(sst.sst_id, sst.keys, sst.vals, sst.lsn_min,
                         sst.lsn_max, sst.entry_bytes, sst.page_bytes)

    def ensure(self, sst) -> None:
        """Persist only if absent (checkpoint restore re-keys tables to
        fresh ids; their bytes may already live under the old id, but the
        recovered store must own files for the ids it actually uses)."""
        if not os.path.exists(self.path(sst.sst_id)):
            self._write_file(sst.sst_id, sst.keys, sst.vals, sst.lsn_min,
                             sst.lsn_max, sst.entry_bytes, sst.page_bytes)

    def ensure_payload(self, sst_id: int, p) -> None:
        """Persist a manifest ``LiveSSTable`` payload if absent (bulk-
        loaded fixtures bypass the flush path; a checkpoint frame must
        not reference a file that was never written)."""
        if not os.path.exists(self.path(sst_id)):
            self._write_file(sst_id, p.keys, p.vals, p.lsn_min, p.lsn_max,
                             p.entry_bytes, p.page_bytes)

    # -- reads ------------------------------------------------------------------
    def read_page(self, sst_id: int, page_index: int) -> int:
        """Physically read one page (both its key and value slices); page
        ``-1`` reads the header (the Bloom unit). Returns bytes read.
        Missing files read 0 bytes: the cache-miss accounting upstream is
        authoritative, and dropped-while-referenced windows (a merge
        dropping a table another thread still pins) must not crash."""
        path = self.path(sst_id)
        try:
            with open(path, "rb") as f:
                hdr = f.read(_HEADER.size)
                if len(hdr) < _HEADER.size:
                    return 0
                magic, _, n, _, _, entry_bytes, page_bytes, _ = \
                    _HEADER.unpack(hdr)
                if magic != SST_MAGIC:
                    raise RuntimeError(f"{path}: bad SSTable magic "
                                       f"{magic:#x}")
                if page_index < 0:
                    self.bytes_read += _HEADER.size
                    return _HEADER.size
                epp = max(1, page_bytes // max(1, entry_bytes))
                lo = page_index * epp
                count = max(0, min(epp, n - lo))
                if count == 0:
                    return 0
                f.seek(_HEADER.size + lo * 8)
                got = len(f.read(count * 8))
                f.seek(_HEADER.size + (n + lo) * 8)
                got += len(f.read(count * 8))
                self.bytes_read += got
                return got
        except FileNotFoundError:
            return 0

    def load(self, sst_id: int) -> dict:
        """Whole-table read with CRC verification (recovery path)."""
        path = self.path(sst_id)
        with open(path, "rb") as f:
            hdr = f.read(_HEADER.size)
            magic, version, n, lsn_min, lsn_max, entry_bytes, page_bytes, \
                crc = _HEADER.unpack(hdr)
            if magic != SST_MAGIC:
                raise RuntimeError(f"{path}: bad SSTable magic {magic:#x}")
            if version != SST_VERSION:
                raise RuntimeError(f"{path}: unsupported SSTable version "
                                   f"{version} (reader speaks "
                                   f"{SST_VERSION})")
            body = f.read(2 * n * 8)
        if len(body) != 2 * n * 8:
            raise RuntimeError(f"{path}: truncated SSTable body "
                               f"({len(body)} of {2 * n * 8} bytes)")
        if zlib.crc32(body) & 0xFFFFFFFF != crc:
            raise RuntimeError(f"{path}: SSTable payload CRC mismatch")
        self.bytes_read += _HEADER.size + len(body)
        return {
            "keys": np.frombuffer(body[:n * 8], np.int64).copy(),
            "vals": np.frombuffer(body[n * 8:], np.int64).copy(),
            "lsn_min": lsn_min, "lsn_max": lsn_max,
            "entry_bytes": entry_bytes, "page_bytes": page_bytes,
        }

    # -- lifecycle ---------------------------------------------------------------
    def mark_dropped(self, sst_id: int) -> None:
        """Unlink a merged-away table -- deferred while a retained
        checkpoint frame still references it."""
        if sst_id in self._pinned:
            self._deferred.add(sst_id)
            return
        try:
            os.unlink(self.path(sst_id))
        except FileNotFoundError:
            pass

    def set_pinned(self, ids) -> None:
        """Replace the checkpoint-referenced pin set; tables whose drop
        was deferred and are no longer pinned unlink now."""
        self._pinned = set(ids)
        for sid in sorted(self._deferred - self._pinned):
            self._deferred.discard(sid)
            try:
                os.unlink(self.path(sid))
            except FileNotFoundError:
                pass

    def gc(self, live_ids) -> list[int]:
        """Unlink files neither live in the manifest nor checkpoint-
        pinned (post-recovery orphan sweep). Returns removed ids."""
        keep = set(live_ids) | self._pinned
        removed = []
        for sid in sorted(self.ids() - keep):
            try:
                os.unlink(self.path(sid))
                removed.append(sid)
            except FileNotFoundError:
                pass
        self._deferred -= set(removed)
        return removed
