"""On-disk frame format shared by every file of the physical storage plane.

One self-delimiting frame wraps every durable unit (a WAL record, a
manifest edit, a checkpoint blob):

    +--------+-------------+-----------+-----------+-----------------+
    | magic  | payload_len | crc32     | tag       | payload bytes   |
    | u32 LE | u32 LE      | u32 LE    | i64 LE    | payload_len     |
    +--------+-------------+-----------+-----------+-----------------+

``tag`` is frame-type-specific: the WAL stores the record's sequence
number there (so a segment scan recovers absolute ordering without a
side index); the manifest stores a small frame-kind discriminant. The
CRC covers the payload only -- a header corrupted anywhere (bad magic,
impossible length) already fails the scan.

Torn-tail rule (the crash contract): a writer appends whole frames and
is allowed to die mid-append, so a scan accepts a file whose *suffix*
fails to parse -- incomplete header, payload running past EOF, or a CRC
mismatch -- and reports the byte offset where the valid prefix ends.
The *caller* decides whether a torn tail is legal: it is on the last
(actively appended) file only; sealed files and interior corruption
must fail loudly. Version bumps change MAGIC (a reader never guesses).
"""
from __future__ import annotations

import struct
import zlib

__all__ = ["FRAME", "MAGIC", "CorruptFrameError", "build_frame",
           "scan_frames", "read_frames"]

FRAME = struct.Struct("<IIIq")       # magic, payload_len, crc32, tag
MAGIC = 0x4C534D31                   # "LSM1" -- bump on format changes
MAX_PAYLOAD = 1 << 30                # sanity bound against garbage lengths


class CorruptFrameError(RuntimeError):
    """Interior (non-tail) frame corruption: the file cannot be trusted."""


def build_frame(tag: int, payload: bytes) -> bytes:
    """One encoded frame, ready to append."""
    return FRAME.pack(MAGIC, len(payload), zlib.crc32(payload) & 0xFFFFFFFF,
                      int(tag)) + payload


def scan_frames(data: bytes) -> tuple[list[tuple[int, bytes]], int]:
    """Parse ``data`` into frames. Returns ``(frames, good_end)`` where
    ``frames`` is the ``(tag, payload)`` list of the valid prefix and
    ``good_end`` is the byte offset it ends at. ``good_end < len(data)``
    means the tail is torn (or worse -- the caller applies the rule)."""
    frames: list[tuple[int, bytes]] = []
    off, n = 0, len(data)
    while off + FRAME.size <= n:
        magic, length, crc, tag = FRAME.unpack_from(data, off)
        if magic != MAGIC or length > MAX_PAYLOAD:
            break
        end = off + FRAME.size + length
        if end > n:
            break
        payload = data[off + FRAME.size:end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break
        frames.append((tag, payload))
        off = end
    return frames, off


def read_frames(path, *, allow_torn_tail: bool):
    """Scan one file. With ``allow_torn_tail`` a trailing unparseable
    suffix is *discarded* (physically truncated away, so the next append
    lands on a clean frame boundary); without it any trailing garbage
    raises ``CorruptFrameError``. Returns the ``(tag, payload)`` list."""
    with open(path, "rb") as f:
        data = f.read()
    frames, good_end = scan_frames(data)
    if good_end < len(data):
        if not allow_torn_tail:
            raise CorruptFrameError(
                f"{path}: unreadable frame at byte {good_end} of "
                f"{len(data)} in a sealed file (interior corruption, "
                f"not a torn tail)")
        with open(path, "r+b") as f:
            f.truncate(good_end)
    return frames
