"""MaintenanceWorkerPool: overlap maintenance compute with the foreground.

The segment API (``SegmentedScheduler.run_segment``) made every flush and
merge a WAL-logged, replay-deterministic unit -- but each unit still runs
*inline* on the submitting thread, so a merge slice's sort/dedup compute
lands squarely in the foreground stall histogram. This pool moves the
compute off-thread without giving up one bit of determinism, using a
**prepare/apply split**:

  * **prepare** (worker threads): the compute-heavy, side-effect-free part
    of a maintenance unit -- ``backend.merge_runs`` over the immutable
    key/value arrays of the SSTables a merge will read, or
    ``backend.bloom_build`` over a new table's keys -- runs speculatively
    against a snapshot. Prepares mutate NOTHING: no manifest edits, no
    level lists, no Disk accounting, no WAL records.
  * **apply** (foreground thread): the maintenance step executes exactly
    where it always did, inside its logged segment. At its compute point
    it calls ``take(key, fn)``: if a worker finished the same computation
    (identified by ``key`` -- the sst_ids of the inputs, which name
    immutable content), the prepared result is consumed; otherwise ``fn``
    runs inline. Both paths return *identical arrays*, because the
    computation is a pure function of inputs the key pins down. Every
    side effect then commits on the foreground path at the same
    deterministic segment boundaries as before.

Determinism contract: store state, query results and WAL contents are
bit-identical for any worker count (including 0) and any worker
completion order -- workers only change *when wall-clock time is spent*,
which the ``bg_segments`` / ``bg_overlap_us`` IOStats report. Replay
during recovery recomputes inline (the pool is never consulted with a
stale key, and a missed key is just an inline compute), so the SIGKILL
crash matrix holds with workers on.

``workers=0`` (the default) keeps the pool fully inert: no threads are
created and ``take`` simply calls ``fn`` -- byte-for-byte today's inline
behavior.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict

__all__ = ["MaintenanceWorkerPool"]


class _Job:
    __slots__ = ("fn", "result", "err", "dur_s", "done")

    def __init__(self, fn):
        self.fn = fn
        self.result = None
        self.err = None
        self.dur_s = 0.0
        self.done = False


class MaintenanceWorkerPool:
    """Bounded thread pool running speculative maintenance prepares.

    ``submit(key, fn)`` schedules ``fn`` (a pure thunk) under ``key``;
    ``take(key, fn)`` consumes the prepared result or computes inline.
    Threads start lazily on the first submit and are daemons -- an
    unclosed pool never blocks interpreter exit. ``stats`` (an
    ``IOStats``) receives ``bg_segments`` / ``bg_overlap_us`` for every
    consumed prepare.
    """

    def __init__(self, workers: int, *, stats=None, max_prepared: int = 64):
        if workers < 0:
            raise ValueError(f"workers must be >= 0, got {workers}")
        self.workers = int(workers)
        self.stats = stats
        self.max_prepared = int(max_prepared)
        self._cv = threading.Condition()
        self._queue: OrderedDict = OrderedDict()    # key -> _Job, not started
        self._running: dict = {}                    # key -> _Job, on a worker
        self._done: OrderedDict = OrderedDict()     # key -> _Job, unconsumed
        self._threads: list[threading.Thread] = []
        self._closed = False
        # observability (all monotonic; never part of replayed state)
        self.submitted = 0      # prepares accepted
        self.prepared = 0       # prepares completed on a worker
        self.hits = 0           # take() served from a prepared result
        self.misses = 0         # take() computed inline (never prepared,
                                # not started yet, or the prepare errored)
        self.wasted = 0         # prepared results evicted unconsumed

    @property
    def enabled(self) -> bool:
        return self.workers > 0 and not self._closed

    # -- worker side -----------------------------------------------------------
    def _spawn(self) -> None:
        while len(self._threads) < self.workers:
            t = threading.Thread(target=self._worker, daemon=True,
                                 name=f"maint-worker-{len(self._threads)}")
            self._threads.append(t)
            t.start()

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if self._closed and not self._queue:
                    return
                key, job = self._queue.popitem(last=False)
                self._running[key] = job
            t0 = time.perf_counter()
            try:
                job.result = job.fn()
            except BaseException as e:      # surfaces as a take() miss;
                job.err = e                 # the inline recompute re-raises
            job.dur_s = time.perf_counter() - t0
            with self._cv:
                del self._running[key]
                job.done = True
                job.fn = None               # drop the closure (holds arrays)
                self._done[key] = job
                self.prepared += 1
                while len(self._done) > self.max_prepared:
                    self._done.popitem(last=False)
                    self.wasted += 1
                self._cv.notify_all()

    # -- foreground side -------------------------------------------------------
    def submit(self, key, fn) -> bool:
        """Schedule a speculative prepare. Deduplicates by key; returns
        True iff the job was accepted. A no-op on a disabled pool."""
        if not self.enabled:
            return False
        with self._cv:
            if key in self._queue or key in self._running \
                    or key in self._done:
                return False
            self._spawn()
            self._queue[key] = _Job(fn)
            self.submitted += 1
            self._cv.notify()
        return True

    def take(self, key, fn):
        """Consume the prepared result for ``key``, or compute ``fn()``
        inline. The two are interchangeable by construction: ``fn`` is a
        pure function of inputs ``key`` identifies, so the returned value
        is bit-identical either way."""
        if not self.enabled:
            return fn()
        with self._cv:
            job = self._done.pop(key, None)
            if job is None:
                # Not finished: compute inline, whether the job is still
                # queued (cancel it) or mid-compute on a worker (let it
                # finish into _done -- a later take may still consume it,
                # else it counts wasted). Blocking on a running worker
                # would put scheduler latency on the foreground stall
                # path, which costs more than the duplicated pure compute.
                self._queue.pop(key, None)
        if job is not None and job.err is None:
            self.hits += 1
            if self.stats is not None:
                self.stats.bg_segments += 1
                self.stats.bg_overlap_us += job.dur_s * 1e6
            return job.result
        self.misses += 1
        return fn()

    # -- lifecycle -------------------------------------------------------------
    def drain(self) -> None:
        """Wait until no prepare is queued or running (tests)."""
        with self._cv:
            while self._queue or self._running:
                self._cv.wait()

    def close(self) -> None:
        """Stop the workers (idempotent). Unconsumed prepares are counted
        wasted; a closed pool computes everything inline."""
        with self._cv:
            self._closed = True
            self.wasted += len(self._queue)
            self._queue.clear()
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()
        with self._cv:
            self.wasted += len(self._done)
            self._done.clear()
