"""Pallas execution backend: routes the engine's primitives through the
TPU kernels in ``repro.kernels.{merge,bloom}``.

Runs in interpret mode on CPU (functional parity, no TPU required) and
compiled on TPU. All entry points bucket their operand sizes to powers of
two (sentinel padding) so the jitted kernels compile once per size bucket
instead of once per exact run length.

jax is imported lazily (on first instantiation), keeping the default numpy
path jax-free. Keys/values outside the kernels' int32 domain (negative
keys, magnitudes at/above 2**31 - 1) fall back to the numpy reference per
call; the engine never produces such keys in normal operation, but
correctness must not depend on that.
"""
from __future__ import annotations

import numpy as np

from .backend import (BLOOM_K_HASHES, ExecutionBackend, FusedLookup,
                      StoreLookup, StoreView, TierView, assign_bounds,
                      bloom_sizing, next_pow2, register_backend)
from .numpy_backend import NumpyBackend, ingest_order

_INT32_MAX = 2**31 - 1


def _int32_safe_keys(arrs) -> bool:
    return all(len(a) == 0 or (int(a.min()) >= 0
                               and int(a.max()) < _INT32_MAX)
               for a in arrs)


def _int32_safe_sorted(a) -> bool:
    """O(1) domain check for a sorted run: the endpoints bound the rest."""
    return len(a) == 0 or (int(a[0]) >= 0 and int(a[-1]) < _INT32_MAX)


def _int32_safe_vals(arrs) -> bool:
    return all(len(a) == 0 or (int(a.min()) > -_INT32_MAX - 1
                               and int(a.max()) <= _INT32_MAX)
               for a in arrs)


class PallasBackend(ExecutionBackend):
    name = "pallas"

    def __init__(self, *, interpret: bool | None = None,
                 merge_tile: int = 512, k_hashes: int = BLOOM_K_HASHES,
                 fused_wmax: int = 1024):
        super().__init__()
        import jax
        import jax.numpy as jnp

        from repro.kernels.bloom import ops as bloom_ops
        from repro.kernels.merge import ops as merge_ops
        self._bloom_ops = bloom_ops
        self._merge_ops = merge_ops
        self._jnp = jnp
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret
        self.merge_tile = merge_tile
        self.k_hashes = k_hashes
        # Widest per-table filter (columns) the fused probe will take
        # resident: bounds the kernel's one-hot working set to VMEM scale.
        self.fused_wmax = fused_wmax
        self._fallback = NumpyBackend(k_hashes=k_hashes)
        self._searchsorted = jax.jit(lambda a, v: jnp.searchsorted(a, v))
        self.fallback_calls = 0     # out-of-int32-domain merges/probes

    # -- merge ---------------------------------------------------------------
    def merge_runs(self, runs):
        runs = [(np.asarray(k), np.asarray(v)) for k, v in runs if len(k)]
        if len(runs) <= 1:
            return self._fallback.merge_runs(runs)
        if not (all(_int32_safe_sorted(k) for k, _ in runs)
                and _int32_safe_vals([v for _, v in runs])):
            self.fallback_calls += 1
            return self._fallback.merge_runs(runs)
        self._note_jit("merge",
                       tuple(next_pow2(len(k)) for k, _ in runs))
        keys, vals = self._merge_ops.merge_runs_device(
            runs, tile=self.merge_tile, interpret=self.interpret)
        return keys.astype(np.int64), vals.astype(np.int64)

    # -- write ingest --------------------------------------------------------
    def ingest_run(self, keys, vals):
        """Batch sort+dedup through the tile-merge kernel.

        The canonical ingest ordering (shared with the numpy reference) is
        computed on the host; the kernel then merges the two sorted halves
        of the ordered batch, carrying batch *positions* through its value
        channel -- values and LSNs are gathered host-side from the
        surviving positions, so arbitrarily wide payloads ride a fixed
        int32 kernel.
        """
        keys = np.asarray(keys, np.int64)
        vals = np.asarray(vals, np.int64)
        n = len(keys)
        if n < 2:
            return self._fallback.ingest_run(keys, vals)
        if not _int32_safe_keys([keys]):
            self.fallback_calls += 1
            return self._fallback.ingest_run(keys, vals)
        order = ingest_order(keys)
        h = n // 2
        self._note_jit("ingest", next_pow2(h), next_pow2(n - h))
        ks, src = self._merge_ops.ingest_run(
            keys[order].astype(np.int32), order.astype(np.int32),
            tile=self.merge_tile, interpret=self.interpret)
        src = src.astype(np.int64)
        return ks.astype(np.int64), vals[src], src

    # -- bloom ---------------------------------------------------------------
    def bloom_build(self, keys):
        keys = np.asarray(keys)          # an SSTable's keys: sorted run
        n_pad, n_slots = bloom_sizing(len(keys))
        if not _int32_safe_sorted(keys):
            self.fallback_calls += 1
            return ("numpy", self._fallback.bloom_build(keys))
        self._note_jit("bloom_build", n_pad, n_slots)
        filt = self._bloom_ops.bloom_build_run(
            keys, n_keys_padded=n_pad, n_slots=n_slots,
            k_hashes=self.k_hashes, interpret=self.interpret)
        # Cache membership bits on the host, not the kernel's int32 counts:
        # filters live as long as their SSTable, so resident size matters
        # (bool is 4x smaller; re-widened to int32 at probe time).
        return ("pallas", np.asarray(filt) != 0)

    def bloom_probe(self, filt, keys):
        keys = np.asarray(keys)
        kind, f = filt
        if kind == "numpy":
            return self._fallback.bloom_probe(f, keys)
        if len(keys) == 0:
            return np.zeros(0, bool)
        if not ((keys >= 0) & (keys < _INT32_MAX)).all():
            # Out-of-int32-domain queries: probe through the host hash path
            # on the flattened membership bits. The kernel's [128, W] layout
            # flattens to exactly the numpy backend's flat filter (slot =
            # row*W + col), and both hash via the same int32 wraparound, so
            # results -- including aliasing false positives -- stay
            # bit-identical across backends and false negatives remain
            # impossible for keys that were inserted via the same wrap.
            self.fallback_calls += 1
            return self._fallback.bloom_probe(f.reshape(-1), keys)
        self._note_jit("bloom_probe", f.shape,
                       next_pow2(len(keys), lo=256))
        return self._bloom_ops.bloom_probe_run(
            f, keys, k_hashes=self.k_hashes, interpret=self.interpret)

    # -- point lookups -------------------------------------------------------
    def lookup_batch(self, sorted_keys, queries):
        sorted_keys = np.asarray(sorted_keys)
        queries = np.asarray(queries)
        if len(queries) == 0:
            return np.zeros(0, np.int64), np.zeros(0, bool)
        if not (_int32_safe_sorted(sorted_keys)
                and _int32_safe_keys([queries])):
            self.fallback_calls += 1
            return self._fallback.lookup_batch(sorted_keys, queries)
        # Bucket both operands so the jitted searchsorted compiles once per
        # (run, batch) size bucket: the run pads with an INT_MAX sentinel
        # (never matched -- keys are int32-safe), queries pad by repeating
        # their last element (results discarded).
        n, q = len(sorted_keys), len(queries)
        self._note_jit("lookup", next_pow2(n), next_pow2(q))
        sk = np.pad(sorted_keys.astype(np.int32),
                    (0, next_pow2(n) - n), constant_values=_INT32_MAX)
        qk = np.pad(queries.astype(np.int32),
                    (0, next_pow2(q) - q), mode="edge")
        jnp = self._jnp
        pos = np.asarray(self._searchsorted(jnp.asarray(sk),
                                            jnp.asarray(qk)))[:q]
        pos = np.minimum(pos.astype(np.int64), n)
        inb = pos < n
        found = np.zeros(q, bool)
        safe = np.minimum(pos, n - 1)
        found[inb] = sorted_keys[safe[inb]] == queries[inb]
        return pos, found

    # -- fused tier probe ----------------------------------------------------
    def prepare_tier(self, tables, bloom_fn):
        """Device-resident tier view: the tier's key/val runs live on
        device as one INT_MAX-padded int32 concatenation, its Bloom
        filters as one stacked [T*128, Wmax] array (the HBM pages a
        ``DevicePagePool`` accounts for). Refuses (``None``) when any run
        is outside the int32 kernel domain, when a table's filter came
        from the numpy fallback, or when the widest filter would blow the
        fused kernel's VMEM working set."""
        keys_list = [t.keys for t in tables]
        if not (all(_int32_safe_sorted(k) for k in keys_list)
                and _int32_safe_vals([t.vals for t in tables])):
            self.fallback_calls += 1
            return None
        filts = []
        for t in tables:
            kind, f = bloom_fn(t)
            if kind != "pallas":
                self.fallback_calls += 1
                return None
            filts.append(f)                      # bool [128, W_t]
        wmax = max(f.shape[1] for f in filts)
        if wmax > self.fused_wmax:
            return None
        fstack = np.zeros((len(tables) * 128, wmax), bool)
        for i, f in enumerate(filts):
            fstack[i * 128:(i + 1) * 128, :f.shape[1]] = f
        lens = np.array([t.num_entries for t in tables], np.int64)
        offs = np.concatenate([[0], np.cumsum(lens)[:-1]])
        total = int(lens.sum())
        npad = next_pow2(max(1, total))
        ck = np.full(npad, _INT32_MAX, np.int32)
        cv = np.zeros(npad, np.int32)
        ck[:total] = np.concatenate(keys_list)
        cv[:total] = np.concatenate([t.vals for t in tables])
        jnp = self._jnp
        payload = {
            "keys": jnp.asarray(ck),
            "vals": jnp.asarray(cv),
            "fstack": jnp.asarray(fstack),
            "nslots_t": np.array([128 * f.shape[1] for f in filts],
                                 np.int32),
            "w_t": np.array([f.shape[1] for f in filts], np.int32),
            "npad": npad,
        }
        return TierView(
            backend=self.name,
            sst_ids=tuple(t.sst_id for t in tables),
            starts=np.array([t.min_key for t in tables], np.int64),
            ends=np.array([t.max_key for t in tables], np.int64),
            offs=offs, lens=lens, payload=payload)

    def lookup_fused(self, view, queries):
        """Two device invocations for the whole tier -- the fused Bloom
        multi-probe and the fused ranged sorted probe -- in place of the
        staged path's two invocations *per SSTable*."""
        q = np.asarray(queries)
        if not _int32_safe_keys([q]):
            self.fallback_calls += 1
            return None
        p = view.payload
        ti, ok = assign_bounds(view.starts, view.ends, q.astype(np.int64))
        kpad = next_pow2(max(1, len(q)), lo=256)
        self._note_jit("fused_bloom", view.num_tables,
                       int(p["fstack"].shape[1]), kpad)
        positive = self._bloom_ops.bloom_probe_multi(
            p["fstack"], q.astype(np.int32), ti.astype(np.int32),
            p["nslots_t"][ti], p["w_t"][ti],
            k_hashes=self.k_hashes, interpret=self.interpret)
        lo = view.offs[ti].astype(np.int32)
        hi = (view.offs[ti] + view.lens[ti]).astype(np.int32)
        self._note_jit("fused_lookup", p["npad"], kpad)
        abs_pos, hit, vals = self._merge_ops.lookup_runs_device(
            p["keys"], p["vals"], lo, hi, q.astype(np.int32))
        return FusedLookup(ti=ti, ok=ok, positive=positive,
                           pos=(abs_pos - view.offs[ti]).astype(np.int64),
                           hit=hit, vals=vals.astype(np.int64))

    # -- fused store (cross-tier) probe --------------------------------------
    def prepare_store(self, tiers, bloom_fn):
        """Device-resident view of EVERY lookup tier of one tree: all
        tables' key/val runs as one INT_MAX-padded int32 concatenation
        (tier-major), all Bloom filters as one stacked [Tg*128, Wmax]
        array, plus the static global-table -> tier-rank map the fused
        kernel grids over. Refusal conditions are the per-tier ones,
        applied across the whole stack."""
        tables = [t for tier in tiers for t in tier]
        if not (all(_int32_safe_sorted(t.keys) for t in tables)
                and _int32_safe_vals([t.vals for t in tables])):
            self.fallback_calls += 1
            return None
        filts = []
        for t in tables:
            kind, f = bloom_fn(t)
            if kind != "pallas":
                self.fallback_calls += 1
                return None
            filts.append(f)                      # bool [128, W_t]
        wmax = max((f.shape[1] for f in filts), default=1)
        if wmax > self.fused_wmax:
            return None
        fstack = np.zeros((len(tables) * 128, wmax), bool)
        for i, f in enumerate(filts):
            fstack[i * 128:(i + 1) * 128, :f.shape[1]] = f
        lens = np.array([t.num_entries for t in tables], np.int64)
        offs = (np.concatenate([[0], np.cumsum(lens)[:-1]])
                if len(tables) else np.zeros(0, np.int64))
        counts = np.array([len(tier) for tier in tiers], np.int64)
        t_off = (np.concatenate([[0], np.cumsum(counts)[:-1]])
                 if len(tiers) else np.zeros(0, np.int64))
        total = int(lens.sum())
        npad = next_pow2(max(1, total))
        ck = np.full(npad, _INT32_MAX, np.int32)
        cv = np.zeros(npad, np.int32)
        if total:
            ck[:total] = np.concatenate([t.keys for t in tables])
            cv[:total] = np.concatenate([t.vals for t in tables])
        jnp = self._jnp
        payload = {
            "keys": jnp.asarray(ck),
            "vals": jnp.asarray(cv),
            "fstack": jnp.asarray(fstack),
            "nslots_t": np.array([128 * f.shape[1] for f in filts],
                                 np.int32),
            "w_t": np.array([f.shape[1] for f in filts], np.int32),
            "t_off": t_off,
            "tier_of": tuple(r for r, tier in enumerate(tiers)
                             for _ in tier),
            "npad": npad,
        }
        return StoreView(
            backend=self.name,
            key=tuple(tuple(t.sst_id for t in tier) for tier in tiers),
            tier_starts=tuple(np.array([t.min_key for t in tier], np.int64)
                              for tier in tiers),
            tier_ends=tuple(np.array([t.max_key for t in tier], np.int64)
                            for tier in tiers),
            tier_offs=tuple(offs[t_off[r]:t_off[r] + counts[r]]
                            for r in range(len(tiers))),
            tier_lens=tuple(lens[t_off[r]:t_off[r] + counts[r]]
                            for r in range(len(tiers))),
            payload=payload)

    def lookup_store_fused(self, view, queries):
        """ONE device launch for the whole store: the composed
        ``lookup_store_device`` jit fuses the stacked Bloom probe, the
        cross-tier ranged sorted probe, and the newest-wins tier argmin,
        in place of the per-tier fused path's two launches *per tier*."""
        q = np.asarray(queries)
        if not _int32_safe_keys([q]):
            self.fallback_calls += 1
            return None
        p = view.payload
        R, K = view.num_tiers, len(q)
        if R == 0:
            return StoreLookup(
                ti=np.zeros((0, K), np.int64), ok=np.zeros((0, K), bool),
                positive=np.zeros((0, K), bool),
                pos=np.zeros((0, K), np.int64), hit=np.zeros((0, K), bool),
                vals=np.zeros((0, K), np.int64),
                win=np.full(K, -1, np.int64))
        q64 = q.astype(np.int64)
        ti = np.empty((R, K), np.int64)
        ok = np.empty((R, K), bool)
        lo = np.empty((R, K), np.int64)
        hi = np.empty((R, K), np.int64)
        for r in range(R):
            ti[r], ok[r] = assign_bounds(view.tier_starts[r],
                                         view.tier_ends[r], q64)
            lo[r] = view.tier_offs[r][ti[r]]
            hi[r] = lo[r] + view.tier_lens[r][ti[r]]
        gti = p["t_off"][:, None] + ti
        kpad = next_pow2(max(1, K), lo=256)
        self._note_jit("store_fused", p["tier_of"],
                       int(p["fstack"].shape[1]), p["npad"], kpad)
        member, abs_pos, hit, vals, win = self._merge_ops.lookup_store_device(
            p["fstack"], p["keys"], p["vals"], q.astype(np.int32),
            gti, p["nslots_t"][gti], p["w_t"][gti], lo, hi,
            tier_of=p["tier_of"], k_hashes=self.k_hashes,
            interpret=self.interpret)
        return StoreLookup(ti=ti, ok=ok, positive=member,
                           pos=(abs_pos - lo).astype(np.int64),
                           hit=hit, vals=vals, win=win)


register_backend("pallas", PallasBackend)
