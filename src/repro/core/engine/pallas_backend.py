"""Pallas execution backend: routes the engine's primitives through the
TPU kernels in ``repro.kernels.{merge,bloom}``.

Runs in interpret mode on CPU (functional parity, no TPU required) and
compiled on TPU. All entry points bucket their operand sizes to powers of
two (sentinel padding) so the jitted kernels compile once per size bucket
instead of once per exact run length.

jax is imported lazily (on first instantiation), keeping the default numpy
path jax-free. Keys/values outside the kernels' int32 domain (negative
keys, magnitudes at/above 2**31 - 1) fall back to the numpy reference per
call; the engine never produces such keys in normal operation, but
correctness must not depend on that.
"""
from __future__ import annotations

import numpy as np

from .backend import (BLOOM_K_HASHES, ExecutionBackend, bloom_sizing,
                      next_pow2, register_backend)
from .numpy_backend import NumpyBackend, ingest_order

_INT32_MAX = 2**31 - 1


def _int32_safe_keys(arrs) -> bool:
    return all(len(a) == 0 or (int(a.min()) >= 0
                               and int(a.max()) < _INT32_MAX)
               for a in arrs)


def _int32_safe_sorted(a) -> bool:
    """O(1) domain check for a sorted run: the endpoints bound the rest."""
    return len(a) == 0 or (int(a[0]) >= 0 and int(a[-1]) < _INT32_MAX)


def _int32_safe_vals(arrs) -> bool:
    return all(len(a) == 0 or (int(a.min()) > -_INT32_MAX - 1
                               and int(a.max()) <= _INT32_MAX)
               for a in arrs)


class PallasBackend(ExecutionBackend):
    name = "pallas"

    def __init__(self, *, interpret: bool | None = None,
                 merge_tile: int = 512, k_hashes: int = BLOOM_K_HASHES):
        import jax
        import jax.numpy as jnp

        from repro.kernels.bloom import ops as bloom_ops
        from repro.kernels.merge import ops as merge_ops
        self._bloom_ops = bloom_ops
        self._merge_ops = merge_ops
        self._jnp = jnp
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret
        self.merge_tile = merge_tile
        self.k_hashes = k_hashes
        self._fallback = NumpyBackend(k_hashes=k_hashes)
        self._searchsorted = jax.jit(lambda a, v: jnp.searchsorted(a, v))
        self.fallback_calls = 0     # out-of-int32-domain merges/probes

    # -- merge ---------------------------------------------------------------
    def merge_runs(self, runs):
        runs = [(np.asarray(k), np.asarray(v)) for k, v in runs if len(k)]
        if len(runs) <= 1:
            return self._fallback.merge_runs(runs)
        if not (all(_int32_safe_sorted(k) for k, _ in runs)
                and _int32_safe_vals([v for _, v in runs])):
            self.fallback_calls += 1
            return self._fallback.merge_runs(runs)
        keys, vals = self._merge_ops.merge_runs_device(
            runs, tile=self.merge_tile, interpret=self.interpret)
        return keys.astype(np.int64), vals.astype(np.int64)

    # -- write ingest --------------------------------------------------------
    def ingest_run(self, keys, vals):
        """Batch sort+dedup through the tile-merge kernel.

        The canonical ingest ordering (shared with the numpy reference) is
        computed on the host; the kernel then merges the two sorted halves
        of the ordered batch, carrying batch *positions* through its value
        channel -- values and LSNs are gathered host-side from the
        surviving positions, so arbitrarily wide payloads ride a fixed
        int32 kernel.
        """
        keys = np.asarray(keys, np.int64)
        vals = np.asarray(vals, np.int64)
        n = len(keys)
        if n < 2:
            return self._fallback.ingest_run(keys, vals)
        if not _int32_safe_keys([keys]):
            self.fallback_calls += 1
            return self._fallback.ingest_run(keys, vals)
        order = ingest_order(keys)
        ks, src = self._merge_ops.ingest_run(
            keys[order].astype(np.int32), order.astype(np.int32),
            tile=self.merge_tile, interpret=self.interpret)
        src = src.astype(np.int64)
        return ks.astype(np.int64), vals[src], src

    # -- bloom ---------------------------------------------------------------
    def bloom_build(self, keys):
        keys = np.asarray(keys)          # an SSTable's keys: sorted run
        n_pad, n_slots = bloom_sizing(len(keys))
        if not _int32_safe_sorted(keys):
            self.fallback_calls += 1
            return ("numpy", self._fallback.bloom_build(keys))
        filt = self._bloom_ops.bloom_build_run(
            keys, n_keys_padded=n_pad, n_slots=n_slots,
            k_hashes=self.k_hashes, interpret=self.interpret)
        # Cache membership bits on the host, not the kernel's int32 counts:
        # filters live as long as their SSTable, so resident size matters
        # (bool is 4x smaller; re-widened to int32 at probe time).
        return ("pallas", np.asarray(filt) != 0)

    def bloom_probe(self, filt, keys):
        keys = np.asarray(keys)
        kind, f = filt
        if kind == "numpy":
            return self._fallback.bloom_probe(f, keys)
        if len(keys) == 0:
            return np.zeros(0, bool)
        if not ((keys >= 0) & (keys < _INT32_MAX)).all():
            # Out-of-int32-domain queries: probe through the host hash path
            # on the flattened membership bits. The kernel's [128, W] layout
            # flattens to exactly the numpy backend's flat filter (slot =
            # row*W + col), and both hash via the same int32 wraparound, so
            # results -- including aliasing false positives -- stay
            # bit-identical across backends and false negatives remain
            # impossible for keys that were inserted via the same wrap.
            self.fallback_calls += 1
            return self._fallback.bloom_probe(f.reshape(-1), keys)
        return self._bloom_ops.bloom_probe_run(
            f, keys, k_hashes=self.k_hashes, interpret=self.interpret)

    # -- point lookups -------------------------------------------------------
    def lookup_batch(self, sorted_keys, queries):
        sorted_keys = np.asarray(sorted_keys)
        queries = np.asarray(queries)
        if len(queries) == 0:
            return np.zeros(0, np.int64), np.zeros(0, bool)
        if not (_int32_safe_sorted(sorted_keys)
                and _int32_safe_keys([queries])):
            self.fallback_calls += 1
            return self._fallback.lookup_batch(sorted_keys, queries)
        # Bucket both operands so the jitted searchsorted compiles once per
        # (run, batch) size bucket: the run pads with an INT_MAX sentinel
        # (never matched -- keys are int32-safe), queries pad by repeating
        # their last element (results discarded).
        n, q = len(sorted_keys), len(queries)
        sk = np.pad(sorted_keys.astype(np.int32),
                    (0, next_pow2(n) - n), constant_values=_INT32_MAX)
        qk = np.pad(queries.astype(np.int32),
                    (0, next_pow2(q) - q), mode="edge")
        jnp = self._jnp
        pos = np.asarray(self._searchsorted(jnp.asarray(sk),
                                            jnp.asarray(qk)))[:q]
        pos = np.minimum(pos.astype(np.int64), n)
        inb = pos < n
        found = np.zeros(q, bool)
        safe = np.minimum(pos, n - 1)
        found[inb] = sorted_keys[safe[inb]] == queries[inb]
        return pos, found


register_backend("pallas", PallasBackend)
