"""Reference execution backend: pure numpy, no jax imports.

Carries the engine's original semantics (the k-way merge extracted from
``sstable.merge_runs``) plus a real double-hashed Bloom filter whose hash
math mirrors ``kernels/bloom/ref.py`` exactly (same Knuth multipliers, same
int32 wraparound, same slot layout) so probe results match the Pallas
backend bit-for-bit.
"""
from __future__ import annotations

import numpy as np

from .backend import (BLOOM_K_HASHES, ExecutionBackend, FusedLookup,
                      StoreLookup, StoreView, TierView, assign_bounds,
                      bloom_sizing, register_backend)

# Same int32 constants as kernels/bloom/ref.py (golden-ratio multipliers).
C1 = np.int32(0x9E3779B1 - 2**32)
C2 = np.int32(0x85EBCA77 - 2**32)


def merge_runs_numpy(runs):
    """Merge sorted (keys, vals) runs with newest-wins reconciliation.

    ``runs`` is ordered newest-first. Returns a single sorted, unique run.
    """
    runs = [r for r in runs if len(r[0])]
    if not runs:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    if len(runs) == 1:
        return runs[0]
    keys = np.concatenate([r[0] for r in runs])
    vals = np.concatenate([r[1] for r in runs])
    # Stable sort by key keeps the newest occurrence first within equal keys
    # because runs are concatenated newest-first.
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    keep = np.ones(len(keys), bool)
    keep[1:] = keys[1:] != keys[:-1]
    return keys[keep], vals[keep]


def ingest_order(keys) -> np.ndarray:
    """Canonical ingest ordering of a write batch: positions sorted by key,
    newest (highest batch position) first among equal keys.

    Shared by both backends so the pre-kernel ordering -- and therefore
    which duplicate survives -- is identical everywhere.
    """
    n = len(keys)
    rev = np.argsort(keys[::-1], kind="stable")
    return (n - 1) - rev


def _bloom_slots(keys, n_slots: int, k_hashes: int) -> np.ndarray:
    """[K, k] slot indices; int32 wraparound arithmetic matches the jnp
    oracle in kernels/bloom/ref.py."""
    k32 = np.asarray(keys).astype(np.int32)
    h1 = (k32 * C1) % np.int32(n_slots)
    h2 = ((k32 * C2) | np.int32(1)) % np.int32(n_slots)
    j = np.arange(k_hashes, dtype=np.int64)
    return (h1.astype(np.int64)[:, None] + j[None, :]
            * h2.astype(np.int64)[:, None]) % n_slots


def lower_bound_ranged(concat_keys, lo, hi, queries):
    """Vectorized per-query lower-bound binary search of ``queries[i]``
    within ``concat_keys[lo[i]:hi[i]]`` (each slice sorted). Returns the
    *absolute* insertion positions -- exactly ``lo[i] +
    searchsorted(concat_keys[lo[i]:hi[i]], queries[i])``.

    The reference semantics of the fused sorted probe, shared with the
    device route (``kernels.merge.ops.lookup_runs_device``)."""
    lo = lo.astype(np.int64).copy()
    hi = hi.astype(np.int64).copy()
    n = len(concat_keys)
    while True:
        open_ = lo < hi
        if not open_.any():
            break
        mid = (lo + hi) >> 1
        less = np.zeros(len(queries), bool)
        idx = np.minimum(mid[open_], max(n - 1, 0))
        less[open_] = concat_keys[idx] < queries[open_]
        lo = np.where(open_ & less, mid + 1, lo)
        hi = np.where(open_ & ~less, mid, hi)
    return lo


class NumpyBackend(ExecutionBackend):
    name = "numpy"

    def __init__(self, *, k_hashes: int = BLOOM_K_HASHES):
        super().__init__()
        self.k_hashes = k_hashes

    def merge_runs(self, runs):
        return merge_runs_numpy(runs)

    def ingest_run(self, keys, vals):
        keys = np.asarray(keys, np.int64)
        vals = np.asarray(vals, np.int64)
        n = len(keys)
        if n == 0:
            return keys, vals, np.empty(0, np.int64)
        src = ingest_order(keys)
        ks = keys[src]
        keep = np.ones(n, bool)
        keep[1:] = ks[1:] != ks[:-1]        # newest-first: keep the first
        src = src[keep]
        return ks[keep], vals[src], src

    def bloom_build(self, keys):
        # Membership bits only (bool, not counts): filters are cached per
        # SSTable for the table's lifetime, so resident size matters.
        _, n_slots = bloom_sizing(len(keys))
        slots = _bloom_slots(keys, n_slots, self.k_hashes).reshape(-1)
        filt = np.zeros(n_slots, bool)
        filt[slots] = True
        return filt

    def bloom_probe(self, filt, keys):
        if len(keys) == 0:
            return np.zeros(0, bool)
        slots = _bloom_slots(keys, filt.shape[0], self.k_hashes)
        return filt[slots].all(axis=-1)

    def lookup_batch(self, sorted_keys, queries):
        pos = np.searchsorted(sorted_keys, queries)
        inb = pos < len(sorted_keys)
        found = np.zeros(len(queries), bool)
        safe = np.minimum(pos, len(sorted_keys) - 1)
        found[inb] = sorted_keys[safe[inb]] == np.asarray(queries)[inb]
        return pos.astype(np.int64), found

    # -- fused tier probe ----------------------------------------------------
    def prepare_tier(self, tables, bloom_fn):
        """Host-resident tier view: concatenated key/val runs plus the
        tier's flat Bloom bits. Never refuses (the reference path has no
        domain limits)."""
        filts = [np.asarray(bloom_fn(t)) for t in tables]
        f_lens = np.array([len(f) for f in filts], np.int64)
        f_offs = np.concatenate([[0], np.cumsum(f_lens)[:-1]])
        lens = np.array([t.num_entries for t in tables], np.int64)
        offs = np.concatenate([[0], np.cumsum(lens)[:-1]])
        payload = {
            "keys": np.concatenate([t.keys for t in tables]),
            "vals": np.concatenate([t.vals for t in tables]),
            "fbits": np.concatenate(filts),
            "f_offs": f_offs,
            "nslots": f_lens,
        }
        return TierView(
            backend=self.name,
            sst_ids=tuple(t.sst_id for t in tables),
            starts=np.array([t.min_key for t in tables], np.int64),
            ends=np.array([t.max_key for t in tables], np.int64),
            offs=offs, lens=lens, payload=payload)

    def lookup_fused(self, view, queries):
        """One vectorized pass over the whole tier: per-query table
        assignment, Bloom probe against each query's own table filter
        (bit-identical hash math to ``bloom_probe``, per-table slot
        counts applied element-wise), ranged lower-bound search in the
        concatenated runs, and payload gather."""
        q = np.asarray(queries, np.int64)
        p = view.payload
        ti, ok = assign_bounds(view.starts, view.ends, q)
        # Bloom: same double-hash int32 wraparound as _bloom_slots, with
        # each query's modulus taken from its assigned table's filter.
        n64 = p["nslots"][ti]
        n32 = n64.astype(np.int32)
        k32 = q.astype(np.int32)
        h1 = (k32 * C1) % n32
        h2 = ((k32 * C2) | np.int32(1)) % n32
        j = np.arange(self.k_hashes, dtype=np.int64)
        slots = (h1.astype(np.int64)[:, None]
                 + j[None, :] * h2.astype(np.int64)[:, None]) % n64[:, None]
        positive = p["fbits"][p["f_offs"][ti][:, None] + slots].all(axis=-1)
        # Sorted probe, confined to each query's table slice. The tier's
        # tables are disjoint and min_key-ordered, so the concatenation is
        # globally sorted: one C-level searchsorted clipped to the slice
        # is exactly ``lower_bound_ranged`` (inside the slice both agree;
        # outside, the ranged search clamps to the bound it clipped to).
        lo = view.offs[ti]
        lens = view.lens[ti]
        abs_pos = np.clip(np.searchsorted(p["keys"], q), lo, lo + lens)
        pos = abs_pos - lo
        inb = pos < lens
        safe = np.minimum(abs_pos, len(p["keys"]) - 1)
        hit = np.zeros(len(q), bool)
        hit[inb] = p["keys"][safe[inb]] == q[inb]
        vals = np.where(hit, p["vals"][safe], 0).astype(np.int64)
        return FusedLookup(ti=ti, ok=ok, positive=positive,
                           pos=pos.astype(np.int64), hit=hit, vals=vals)

    # -- fused store (cross-tier) probe --------------------------------------
    def prepare_store(self, tiers, bloom_fn):
        """Host-resident cross-tier view: the whole store's key/val runs
        and Bloom bits in one tier-major concatenation. Never refuses."""
        tables = [t for tier in tiers for t in tier]
        filts = [np.asarray(bloom_fn(t)) for t in tables]
        f_lens = np.array([len(f) for f in filts], np.int64)
        f_offs = np.cumsum(f_lens) - f_lens
        lens = np.array([t.num_entries for t in tables], np.int64)
        offs = np.cumsum(lens) - lens
        counts = np.array([len(tier) for tier in tiers], np.int64)
        t_off = np.cumsum(counts) - counts
        cat = lambda arrs, dt: (np.concatenate(arrs) if arrs  # noqa: E731
                                else np.zeros(0, dt))
        payload = {
            "keys": cat([t.keys for t in tables], np.int64),
            "vals": cat([t.vals for t in tables], np.int64),
            "fbits": cat(filts, bool),
            "f_offs": f_offs,
            "nslots": f_lens,
            "t_off": t_off,           # tier rank -> first global table index
        }
        return StoreView(
            backend=self.name,
            key=tuple(tuple(t.sst_id for t in tier) for tier in tiers),
            tier_starts=tuple(np.array([t.min_key for t in tier], np.int64)
                              for tier in tiers),
            tier_ends=tuple(np.array([t.max_key for t in tier], np.int64)
                            for tier in tiers),
            tier_offs=tuple(offs[t_off[r]:t_off[r] + counts[r]]
                            for r in range(len(tiers))),
            tier_lens=tuple(lens[t_off[r]:t_off[r] + counts[r]]
                            for r in range(len(tiers))),
            payload=payload)

    def lookup_store_fused(self, view, queries):
        """One vectorized pass over the whole store: per-tier table
        assignment (same ``assign_bounds`` as the per-tier path), one
        [R, K] Bloom gather, ONE ranged lower-bound search over the
        store-wide concatenation, and the newest-wins tier argmin --
        field-for-field identical to R independent ``lookup_fused``
        calls."""
        q = np.asarray(queries, np.int64)
        p = view.payload
        R, K = view.num_tiers, len(q)
        if R == 0:
            return StoreLookup(
                ti=np.zeros((0, K), np.int64), ok=np.zeros((0, K), bool),
                positive=np.zeros((0, K), bool),
                pos=np.zeros((0, K), np.int64), hit=np.zeros((0, K), bool),
                vals=np.zeros((0, K), np.int64),
                win=np.full(K, -1, np.int64))
        ti = np.empty((R, K), np.int64)
        ok = np.empty((R, K), bool)
        for r in range(R):
            ti[r], ok[r] = assign_bounds(view.tier_starts[r],
                                         view.tier_ends[r], q)
        gti = p["t_off"][:, None] + ti              # global table index [R,K]
        # Bloom: identical hash math to lookup_fused, flattened over (r, k).
        n64 = p["nslots"][gti]
        n32 = n64.astype(np.int32)
        k32 = np.broadcast_to(q.astype(np.int32), (R, K))
        h1 = (k32 * C1) % n32
        h2 = ((k32 * C2) | np.int32(1)) % n32
        j = np.arange(self.k_hashes, dtype=np.int64)
        slots = (h1.astype(np.int64)[..., None]
                 + j * h2.astype(np.int64)[..., None]) % n64[..., None]
        positive = p["fbits"][p["f_offs"][gti][..., None]
                              + slots].all(axis=-1)
        # Sorted probe per tier: each tier's segment of the store-wide
        # concatenation is itself globally sorted (disjoint,
        # min_key-ordered tables), so one C-level searchsorted per tier
        # clipped to each query's table slice is exactly the ranged lower
        # bound ``lower_bound_ranged`` computes (inside the slice both
        # agree; outside, the ranged search clamps to the clipped bound).
        abs_pos = np.empty((R, K), np.int64)
        for r in range(R):
            s0 = int(view.tier_offs[r][0])
            s1 = s0 + int(view.tier_lens[r].sum())
            abs_pos[r] = s0 + np.searchsorted(p["keys"][s0:s1], q)
        lo = np.stack([view.tier_offs[r][ti[r]] for r in range(R)])
        lens = np.stack([view.tier_lens[r][ti[r]] for r in range(R)])
        np.clip(abs_pos, lo, lo + lens, out=abs_pos)
        pos = abs_pos - lo
        inb = pos < lens
        safe = np.minimum(abs_pos, len(p["keys"]) - 1)
        hit = np.zeros((R, K), bool)
        qb = np.broadcast_to(q, (R, K))
        hit[inb] = p["keys"][safe[inb]] == qb[inb]
        vals = np.where(hit, p["vals"][safe], 0).astype(np.int64)
        # Newest-wins: first (lowest-rank) tier with a hit; a query can
        # match at most one table per tier (tiers are disjoint).
        win = np.where(hit.any(axis=0),
                       np.argmax(hit, axis=0), -1).astype(np.int64)
        return StoreLookup(ti=ti, ok=ok, positive=positive,
                           pos=pos.astype(np.int64), hit=hit, vals=vals,
                           win=win)


register_backend("numpy", NumpyBackend)
