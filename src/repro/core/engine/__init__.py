"""Pluggable execution backends for the LSM engine's hot loops.

``get_backend("numpy")`` is the reference; ``get_backend("pallas")`` runs
compaction merges, Bloom build/probe, and batched lookups through the
Pallas TPU kernels (interpret mode on CPU). The ``REPRO_LSM_BACKEND``
environment variable sets the backend for every store that does not pin
one explicitly.

Importing this package stays jax-free: the pallas backend module defers
its jax/kernel imports until first instantiation.
"""
from .backend import (ENV_VAR, ExecutionBackend,  # noqa: F401
                      FusedLookup, StoreLookup, StoreView, TierView,
                      available_backends, bloom_sizing, get_backend,
                      next_pow2, register_backend)
from .numpy_backend import (NumpyBackend, ingest_order,  # noqa: F401
                            merge_runs_numpy)
from .pallas_backend import PallasBackend  # noqa: F401
from .pacer import MaintenancePacer  # noqa: F401
from .scheduler import (SEGMENTS, MaintenanceScheduler,  # noqa: F401
                        TickReport)
