"""Execution-backend interface for the LSM engine's hot loops.

A backend supplies the engine's five data-parallel primitives:

  * ``merge_runs(runs)``     -- k-way newest-wins merge (compaction)
  * ``ingest_run(keys, vals)`` -- sort+dedup of one write batch (ingest)
  * ``bloom_build(keys)``    -- per-SSTable Bloom filter construction
  * ``bloom_probe(f, keys)`` -- batched membership probes
  * ``lookup_batch(sorted_keys, queries)`` -- batched binary search in a run

``NumpyBackend`` carries the reference semantics; ``PallasBackend`` routes
the same primitives through the Pallas TPU kernels (interpret mode on CPU,
compiled on TPU). Both backends use the *same* Bloom geometry (hash family,
slot count, size bucketing) so their probe results -- including false
positives -- are bit-identical, which the parity suite relies on.

Selection: ``get_backend(name)`` resolves, in order, the explicit
``name`` (``StoreConfig.backend``), the ``REPRO_LSM_BACKEND``
environment variable, then the ``"numpy"`` default — so the env var
flips every store that does not pin a backend (e.g. the stock
benchmarks) without silently overriding code that chose one.
"""
from __future__ import annotations

import os

from ...kernels.sizing import next_pow2, slots_for  # jax-free module

ENV_VAR = "REPRO_LSM_BACKEND"

# Shared Bloom geometry (matches kernels/bloom: 10 bits/key, 7 hashes).
BLOOM_BITS_PER_KEY = 10
BLOOM_K_HASHES = 7


def bloom_sizing(n_keys: int, bits_per_key: int = BLOOM_BITS_PER_KEY):
    """(padded_key_count, n_slots) for a filter over ``n_keys`` keys.

    Both backends size filters from the *bucketed* key count so a filter
    built by one backend has the same geometry (and false-positive set) as
    one built by the other.
    """
    n_pad = next_pow2(max(1, n_keys), lo=256)
    return n_pad, slots_for(n_pad, bits_per_key)


class ExecutionBackend:
    """Interface of the engine's batched primitives."""

    name: str = "abstract"

    def merge_runs(self, runs):
        """Merge sorted (keys, vals) runs, ordered newest-first, into one
        sorted unique run with newest-wins reconciliation.

        Returns (keys, vals) as int64 numpy arrays.
        """
        raise NotImplementedError

    def ingest_run(self, keys, vals):
        """Sort an *unsorted* write batch into one sorted unique run with
        last-occurrence-wins dedup (the write-ingest mirror of
        ``merge_runs``).

        Returns (keys, vals, src) as int64 numpy arrays: the sorted unique
        keys, the value of each key's newest occurrence, and ``src`` -- the
        original batch position of that occurrence (callers derive exact
        per-entry LSNs from it).
        """
        raise NotImplementedError

    def bloom_build(self, keys):
        """Build a Bloom filter over ``keys``; returns an opaque filter."""
        raise NotImplementedError

    def bloom_probe(self, filt, keys):
        """Probe ``filt`` for ``keys``; returns a bool membership mask
        (no false negatives)."""
        raise NotImplementedError

    def lookup_batch(self, sorted_keys, queries):
        """Batched binary search of ``queries`` in a sorted unique run.

        Returns (pos, found): the insertion position of each query (int64)
        and whether ``sorted_keys[pos] == query`` (bool).
        """
        raise NotImplementedError


_FACTORIES: dict = {}
_INSTANCES: dict = {}


def register_backend(name: str, factory) -> None:
    _FACTORIES[name] = factory


def available_backends() -> tuple:
    """Registered backend names (the registry is the source of truth)."""
    return tuple(sorted(_FACTORIES))


def get_backend(name: str | None = None) -> ExecutionBackend:
    """Resolve a backend by name: explicit name > env var > "numpy".

    Instances are cached (backends are stateless apart from jit caches).
    """
    resolved = name or os.environ.get(ENV_VAR) or "numpy"
    if resolved not in _FACTORIES:
        raise ValueError(
            f"unknown LSM backend {resolved!r}; expected one of "
            f"{sorted(_FACTORIES)}")
    if resolved not in _INSTANCES:
        _INSTANCES[resolved] = _FACTORIES[resolved]()
    return _INSTANCES[resolved]
