"""Execution-backend interface for the LSM engine's hot loops.

A backend supplies the engine's data-parallel primitives:

  * ``merge_runs(runs)``     -- k-way newest-wins merge (compaction)
  * ``ingest_run(keys, vals)`` -- sort+dedup of one write batch (ingest)
  * ``bloom_build(keys)``    -- per-SSTable Bloom filter construction
  * ``bloom_probe(f, keys)`` -- batched membership probes
  * ``lookup_batch(sorted_keys, queries)`` -- batched binary search in a run
  * ``prepare_tier(tables, bloom_fn)`` / ``lookup_fused(view, queries)``
    -- the device-resident read hot path: one fused Bloom-probe +
    sorted-probe pipeline over a whole disjoint tier of SSTables, replacing
    the per-SSTable ``bloom_probe`` + ``lookup_batch`` staging
  * ``prepare_store(tiers, bloom_fn)`` / ``lookup_store_fused(view,
    queries)`` -- the cross-tier extension: every lookup tier of a tree
    stacked into one ragged device layout, probed (Bloom + ranged search
    + newest-wins tier argmin) in ONE device launch per lookup batch

``NumpyBackend`` carries the reference semantics; ``PallasBackend`` routes
the same primitives through the Pallas TPU kernels (interpret mode on CPU,
compiled on TPU). Both backends use the *same* Bloom geometry (hash family,
slot count, size bucketing) so their probe results -- including false
positives -- are bit-identical, which the parity suite relies on.

Selection: ``get_backend(name)`` resolves, in order, the explicit
``name`` (``StoreConfig.backend``), the ``REPRO_LSM_BACKEND``
environment variable, then the ``"numpy"`` default — so the env var
flips every store that does not pin a backend (e.g. the stock
benchmarks) without silently overriding code that chose one.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import numpy as np

from ...kernels.sizing import next_pow2, slots_for  # jax-free module

ENV_VAR = "REPRO_LSM_BACKEND"

# Shared Bloom geometry (matches kernels/bloom: 10 bits/key, 7 hashes).
BLOOM_BITS_PER_KEY = 10
BLOOM_K_HASHES = 7


def bloom_sizing(n_keys: int, bits_per_key: int = BLOOM_BITS_PER_KEY):
    """(padded_key_count, n_slots) for a filter over ``n_keys`` keys.

    Both backends size filters from the *bucketed* key count so a filter
    built by one backend has the same geometry (and false-positive set) as
    one built by the other.
    """
    n_pad = next_pow2(max(1, n_keys), lo=256)
    return n_pad, slots_for(n_pad, bits_per_key)


@dataclass
class TierView:
    """One disjoint, min_key-sorted tier of SSTables prepared for fused
    probing (built by ``ExecutionBackend.prepare_tier``).

    The host-side metadata is backend-independent; ``payload`` carries the
    backend's resident representation of the tier's key/val/Bloom pages
    (numpy concatenations for the reference backend, device arrays for the
    Pallas backend -- the part a ``DevicePagePool`` keeps HBM-resident).
    """

    backend: str
    sst_ids: tuple                 # view identity (pool cache key)
    starts: np.ndarray             # int64 [T] per-table min_key
    ends: np.ndarray               # int64 [T] per-table max_key
    offs: np.ndarray               # int64 [T] entry offset of each table
    lens: np.ndarray               # int64 [T] entries per table
    payload: object                # backend-owned resident arrays

    @property
    def num_tables(self) -> int:
        return len(self.sst_ids)

    @property
    def num_entries(self) -> int:
        return int(self.offs[-1] + self.lens[-1]) if len(self.lens) else 0


@dataclass
class FusedLookup:
    """Per-query results of one fused tier probe, shaped so the caller can
    replicate the staged path's page-pin accounting exactly:

      ti/ok     -- table assignment (``assign_queries`` semantics);
      positive  -- Bloom membership of each query against its table's
                   filter (valid where ``ok``);
      pos/hit   -- binary-search insertion position *relative to the
                   table's run* and whether it is an exact match (valid
                   where ``ok & positive``);
      vals      -- the matched payload (valid where ``hit``).
    """

    ti: np.ndarray                 # int64 [K]
    ok: np.ndarray                 # bool  [K]
    positive: np.ndarray           # bool  [K]
    pos: np.ndarray                # int64 [K]
    hit: np.ndarray                # bool  [K]
    vals: np.ndarray               # int64 [K]


@dataclass
class StoreView:
    """Every lookup tier of one tree (newest-first: L0 groups, then disk
    levels top-down) prepared for a single fused probe (built by
    ``ExecutionBackend.prepare_store``).

    Per-tier metadata mirrors ``TierView`` -- tuples indexed by tier rank
    ``r`` -- except that ``tier_offs`` are offsets into the *store-wide*
    key/val concatenation (tier-major, table order within a tier).
    ``payload`` is the backend's resident representation of the whole
    stack; the ``DevicePagePool`` accounts its pages exactly like a
    per-tier view's.
    """

    backend: str
    key: tuple                     # tuple of per-tier sst_id tuples
    tier_starts: tuple             # per tier: int64 [T_r] min_key
    tier_ends: tuple               # per tier: int64 [T_r] max_key
    tier_offs: tuple               # per tier: int64 [T_r] GLOBAL offsets
    tier_lens: tuple               # per tier: int64 [T_r] entries/table
    payload: object                # backend-owned resident arrays

    @property
    def num_tiers(self) -> int:
        return len(self.key)

    @property
    def num_tables(self) -> int:
        return sum(len(k) for k in self.key)


@dataclass
class StoreLookup:
    """Per-(tier, query) results of one fused store probe. Every [R, K]
    field carries, for tier rank ``r``, exactly what a per-tier
    ``FusedLookup`` would have carried for that tier (``ti`` is
    tier-local), so the caller can replay the staged path's pin sequence
    tier by tier. ``win`` is the on-device newest-wins resolution: the
    first (newest) tier rank whose probe hit, -1 when no tier did."""

    ti: np.ndarray                 # int64 [R, K] tier-local table index
    ok: np.ndarray                 # bool  [R, K]
    positive: np.ndarray           # bool  [R, K]
    pos: np.ndarray                # int64 [R, K] relative to the table's run
    hit: np.ndarray                # bool  [R, K]
    vals: np.ndarray               # int64 [R, K]
    win: np.ndarray                # int64 [K] first tier rank with a hit


def assign_bounds(starts, ends, qkeys):
    """Array-level twin of ``sstable.assign_queries``: map each query to
    the covering table of a disjoint, min_key-sorted tier described by its
    bound arrays. Shared by both backends' fused paths so assignment is
    bit-identical to the staged probe."""
    ti = np.searchsorted(starts, qkeys, side="right") - 1
    ok = ti >= 0
    ti = np.clip(ti, 0, len(starts) - 1)
    ok &= qkeys <= ends[ti]
    return ti.astype(np.int64), ok


class ExecutionBackend:
    """Interface of the engine's batched primitives.

    Backends also keep jit-shape-bucket cache counters
    (``jit_compiles`` / ``jit_cache_hits``): every jitted entry point notes
    the pow2 shape bucket it is about to run under, counting a compile the
    first time a bucket is seen and a cache hit afterwards. The reference
    backend jits nothing, so its counters stay zero; benchmarks surface
    the deltas so recompile churn from new shape buckets (e.g. the fused
    read path's tier stacks) is observable in ``BENCH_*.json`` rows.
    """

    name: str = "abstract"

    def __init__(self):
        self._jit_shapes: set = set()
        self.jit_compiles = 0
        self.jit_cache_hits = 0

    def _note_jit(self, *key) -> None:
        """Record one jitted call under shape-bucket ``key``."""
        if key in self._jit_shapes:
            self.jit_cache_hits += 1
        else:
            self._jit_shapes.add(key)
            self.jit_compiles += 1

    def jit_stats(self) -> dict:
        return {"jit_compiles": self.jit_compiles,
                "jit_cache_hits": self.jit_cache_hits}

    def merge_runs(self, runs):
        """Merge sorted (keys, vals) runs, ordered newest-first, into one
        sorted unique run with newest-wins reconciliation.

        Returns (keys, vals) as int64 numpy arrays.
        """
        raise NotImplementedError

    def ingest_run(self, keys, vals):
        """Sort an *unsorted* write batch into one sorted unique run with
        last-occurrence-wins dedup (the write-ingest mirror of
        ``merge_runs``).

        Returns (keys, vals, src) as int64 numpy arrays: the sorted unique
        keys, the value of each key's newest occurrence, and ``src`` -- the
        original batch position of that occurrence (callers derive exact
        per-entry LSNs from it).
        """
        raise NotImplementedError

    def bloom_build(self, keys):
        """Build a Bloom filter over ``keys``; returns an opaque filter."""
        raise NotImplementedError

    def bloom_probe(self, filt, keys):
        """Probe ``filt`` for ``keys``; returns a bool membership mask
        (no false negatives)."""
        raise NotImplementedError

    def lookup_batch(self, sorted_keys, queries):
        """Batched binary search of ``queries`` in a sorted unique run.

        Returns (pos, found): the insertion position of each query (int64)
        and whether ``sorted_keys[pos] == query`` (bool).
        """
        raise NotImplementedError

    def prepare_tier(self, tables, bloom_fn):
        """Build a resident ``TierView`` over one disjoint, min_key-sorted
        tier of SSTables. ``bloom_fn(sst)`` returns the backend's (cached)
        Bloom filter of a table. Returns ``None`` when the tier cannot be
        made resident (e.g. keys/values outside the kernel domain); the
        caller then stays on the staged path."""
        raise NotImplementedError

    def lookup_fused(self, view: TierView, queries):
        """Fused tier probe: Bloom probe + per-table sorted probe of every
        query against the whole tier in one (or few) device invocations.

        Must be bit-identical -- assignment, Bloom membership (including
        false positives), insertion positions, matches, values -- to the
        staged loop of per-table ``bloom_probe`` + ``lookup_batch`` calls.
        Returns a ``FusedLookup``, or ``None`` when the queries fall
        outside the backend's domain (caller falls back to staged)."""
        raise NotImplementedError

    def prepare_store(self, tiers, bloom_fn):
        """Build a resident ``StoreView`` over every non-empty lookup tier
        of one tree, ordered newest-first. Each element of ``tiers`` is a
        disjoint, min_key-sorted table list (what ``prepare_tier`` takes).
        Returns ``None`` when the stack cannot be made resident (any tier
        outside the kernel domain); the caller then falls back to the
        per-tier fused path, and from there to staged."""
        raise NotImplementedError

    def lookup_store_fused(self, view: StoreView, queries):
        """Fused cross-tier probe: every query against every tier of the
        store in ONE device launch -- stacked Bloom probe, ranged sorted
        probe over the store-wide concatenation, and the newest-wins tier
        argmin, composed in a single jitted invocation.

        Field-for-field per tier, results must be bit-identical to R
        independent ``lookup_fused`` calls (which are themselves
        bit-identical to the staged loop). Returns a ``StoreLookup``, or
        ``None`` when the queries fall outside the backend's domain."""
        raise NotImplementedError


_FACTORIES: dict = {}
_INSTANCES: dict = {}


def register_backend(name: str, factory) -> None:
    _FACTORIES[name] = factory


def available_backends() -> tuple:
    """Registered backend names (the registry is the source of truth)."""
    return tuple(sorted(_FACTORIES))


def get_backend(name: str | None = None) -> ExecutionBackend:
    """Resolve a backend by name: explicit name > env var > "numpy".

    Instances are cached (backends are stateless apart from jit caches).
    """
    resolved = name or os.environ.get(ENV_VAR) or "numpy"
    if resolved not in _FACTORIES:
        raise ValueError(
            f"unknown LSM backend {resolved!r}; expected one of "
            f"{sorted(_FACTORIES)}")
    if resolved not in _INSTANCES:
        _INSTANCES[resolved] = _FACTORIES[resolved]()
    return _INSTANCES[resolved]
