"""Maintenance pacer: interleave tick segments with the foreground path.

A stop-the-world tick drains *all* merge debt before the next submit runs,
so a submit that lands after a burst of writes pays for every merge the
burst induced -- the classic LSM write-stall tail (Luo & Carey, "On
Performance Stability in LSM-based Storage Systems"). The pacer replaces
the one-shot tick on the service's write path with a *paced schedule*:

  * the mandatory segments (``upkeep`` -> ``mem`` -> ``log`` and the
    closing ``wal`` truncation) still run on every submit -- the memory
    and log bounds are correctness invariants, never deferred;
  * the discretionary merge pass is chopped into bounded **slices** of
    ``segment_budget`` maintenance steps each, released at a rate paced
    against the *observed write rate*: one slice per ``interval_bytes``
    of ingested payload. A write burst earns proportionally many slices
    spread over the submits that follow, instead of one monster pass;
  * slices are **flush-averse**: a pass whose mandatory segments already
    flushed has paid a write stall, so its slice is deferred (the banked
    intervals release on the next flush-free pass). Flush events and
    interval crossings are both driven by ingested bytes, so without
    this the worst-case pass stacks a flush AND a merge slice -- exactly
    the stop-the-world tail the pacer exists to remove. Deferral yields
    to backlog pressure: once ``carried_debt`` exceeds
    ``MAX_DEFER_DEBT_SLICES`` slices' worth of work, slices release on
    every pass, bounding starvation under sustained flush storms.

Between flushes the merge pass is largest-debt-first with stable ties and
maintenance of one tree never changes another tree's debt, so a run of
bounded slices serves exactly the step sequence one draining pass would:
pacing chops *when* merge steps run, never *what* a step does. Deferring
slices across later flushes can re-rank debts (that is the point -- a
burst's work spreads over the submits that follow), so a paced store is
logically equal to the stop-the-world store -- same keys, same answers,
same enforced memory/log bounds -- without being structurally
bit-identical to it. What IS bit-identical is the replay: every segment
is WAL-logged, so the paced schedule itself recovers bit-for-bit. The
deterministic-interleaving fuzzer enforces exactly these invariants.

Every segment the pacer runs is WAL-logged individually (see
``SegmentedScheduler.run_segment``), so a paced schedule replays
deterministically: recovery re-runs the logged segments at the logged
points. The pacer's own accumulator (``_pending``) is deliberately NOT
checkpointed -- pacing is a performance policy; replay follows the logged
records, so correctness never depends on pacer state, and a recovered
service simply resumes pacing from zero.

Knobs (``StoreConfig``): ``pacer_interval_bytes`` (None = pacing off,
the service ticks stop-the-world) and ``pacer_segment_budget`` (merge
steps per slice).
"""
from __future__ import annotations

from .scheduler import TickReport

# Backlog override for flush-averse deferral: once the carried merge debt
# exceeds this many slices' worth of steps, a slice is released even on a
# pass that flushed (latency shaping yields to keeping up with the debt).
MAX_DEFER_DEBT_SLICES = 4


class MaintenancePacer:
    """Releases maintenance in bounded slices paced by write rate."""

    def __init__(self, scheduler, *, segment_budget: int,
                 interval_bytes: int):
        if segment_budget <= 0:
            raise ValueError(
                f"segment_budget must be > 0, got {segment_budget}")
        if interval_bytes <= 0:
            raise ValueError(
                f"interval_bytes must be > 0, got {interval_bytes}")
        self.scheduler = scheduler
        self.segment_budget = int(segment_budget)
        self.interval_bytes = int(interval_bytes)
        self._pending = 0        # ingested bytes not yet paid for in slices
        self.slices = 0          # bounded merge slices released
        self.passes = 0          # on_submit() paced passes run
        self.deferrals = 0       # slices pushed past a pass that flushed

    def on_submit(self, wrote_bytes: int) -> TickReport:
        """One paced maintenance pass after a submit that ingested
        ``wrote_bytes`` of payload. Replaces ``scheduler.tick()`` on the
        service's write path; returns the aggregated ``TickReport``."""
        sched = self.scheduler
        self.passes += 1
        rep = TickReport()

        def add(r: TickReport) -> None:
            rep.flushes += r.flushes
            rep.upkeep_steps += r.upkeep_steps
            rep.merge_steps += r.merge_steps

        # Mandatory phases, canonical order: bounds are never deferred.
        add(sched.run_segment("upkeep"))
        add(sched.run_segment("mem"))
        add(sched.run_segment("log"))

        # Discretionary merges: one bounded slice per interval_bytes of
        # observed writes. Flush-induced debt with no further writes is
        # drained too (a slice per pass once debt exists), so an idle
        # tail still converges to the stop-the-world fixpoint. A pass
        # that flushed defers its slice (banked in _pending) unless the
        # backlog override says the debt is piling up.
        self._pending += int(wrote_bytes)
        due = (self._pending >= self.interval_bytes
               or sched.carried_debt > 0)
        defer = (due and rep.flushes > 0 and sched.carried_debt
                 <= MAX_DEFER_DEBT_SLICES * self.segment_budget)
        if defer:
            self.deferrals += 1
        elif due:
            budget = 0
            while self._pending >= self.interval_bytes:
                self._pending -= self.interval_bytes
                budget += self.segment_budget
            if budget == 0:
                budget = self.segment_budget    # idle drain of leftover debt
            r = sched.run_segment("merge", merge_budget=budget)
            add(r)
            self.slices += 1
            if r.carried_debt == 0:
                self._pending = 0       # debt drained: burst fully paid

        sched.run_segment("wal")
        # Cross-pass overlap: with background workers on, submit the next
        # merge computations now so they run while the foreground handles
        # the next write batches -- including the (flush-averse) passes
        # that release no slice. A pure hint: no store state changes and
        # nothing is WAL-logged, so paced replay is untouched.
        sched.prefetch_merges()
        rep.carried_debt = sched.carried_debt
        return rep
