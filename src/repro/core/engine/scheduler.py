"""Cross-tree maintenance scheduler: the single owner of flush/merge work.

The paper's architecture (§3-§4) requires flushes and merges to be
arbitrated *across* all LSM-trees sharing the write memory, not run inline
by whichever tree happened to receive a write. ``MaintenanceScheduler``
replaces the store's per-write inline enforcement: the write path only
appends to memory components and then calls ``tick()``, and every flush or
merge anywhere in the store flows through this class.

A tick runs four phases:

  1. **Memory-component upkeep** -- structures that do write-path-adjacent
     work (Accordion's seal + pipeline merges, which can set
     ``request_flush`` when a data merge's transient peak blows the
     budget) run their ``upkeep_step`` units.
  2. **Memory enforcement** (mandatory) -- static-scheme LRU dataset
     evictions queued by the write path are flushed first; then, while
     the shared write memory exceeds its threshold, pick a flush victim
     by the configured §4.2 flush policy (max-memory / min-LSN /
     write-rate-proportional OPT) and flush it. Runs to completion: the
     memory bound is a correctness invariant, not discretionary work.
  3. **Log enforcement** (mandatory) -- while the log exceeds its cap,
     flush the tree holding the minimum LSN (log-triggered flushes
     facilitate truncation, §4.1.1).
  4. **Merge pass** (discretionary, budgeted) -- rank all trees by their
     ``merge_debt`` (pending memory merges + L0 groups over target +
     over-full levels + L1 drains) and execute up to ``merge_budget``
     maintenance steps, always against the tree with the largest debt.
     Unspent debt carries to the next tick (``carried_debt``), modelling
     bounded background-merge bandwidth; ``merge_budget=None`` (default)
     drains all debt every tick.

The scheduler holds no tree state of its own -- it reads candidates from
the store each phase -- so ticks are a pure function of store state, which
the differential test suite exploits: any interleaving of writes producing
the same memory-component state followed by the same tick sequence yields
bit-identical trees.
"""
from __future__ import annotations

from dataclasses import dataclass

_INF = 2**62
_UNSET = object()      # tick(): "no override" vs an explicit None (=drain)


@dataclass
class TickReport:
    """What one scheduler tick did (returned by ``tick``)."""

    flushes: int = 0          # flush events executed (mem- or log-triggered)
    upkeep_steps: int = 0     # memory-component upkeep units
    merge_steps: int = 0      # discretionary maintenance units
    carried_debt: int = 0     # debt left unserved by the merge budget


class MaintenanceScheduler:
    """Arbitrates flush/merge work across every tree of one ``LSMStore``."""

    def __init__(self, store, *, merge_budget: int | None = None):
        self.store = store
        self.merge_budget = merge_budget
        self.ticks = 0
        self.carried_debt = 0

    # -- flush candidate ranking (§4.2) --------------------------------------
    def pick_flush_tree(self):
        """Rank non-empty trees by the configured flush policy and return
        the victim (None if all memory components are empty)."""
        s = self.store
        nonempty = [t for t in s.trees.values() if not t.mem.is_empty()]
        if not nonempty:
            return None
        pol = s.cfg.flush_policy
        if pol == "mem":
            return max(nonempty, key=lambda t: t.mem_bytes)
        if pol == "lsn":
            return min(nonempty, key=lambda t: t.min_lsn)
        # opt: flush the tree whose memory ratio most exceeds its optimal
        # write-rate-proportional ratio a_i_opt = r_i / sum_j r_j.
        rates = {t.name: sum(b for _, b in s._rate_win[t.name])
                 for t in nonempty}
        total_rate = sum(rates.values())
        used = {t.name: t.mem_bytes for t in nonempty}
        total_used = sum(used.values())
        if total_rate == 0 or total_used == 0:
            return min(nonempty, key=lambda t: t.min_lsn)
        best, best_gap = None, None
        for t in nonempty:
            a = used[t.name] / total_used
            a_opt = rates[t.name] / total_rate
            gap = a - a_opt
            if best_gap is None or gap > best_gap:
                best, best_gap = t, gap
        return best

    # -- flush execution ------------------------------------------------------
    def flush_tree(self, tree, *, trigger: str,
                   forced_kind: str | None = None) -> int:
        """Flush one tree. Returns bytes freed.

        Only the cheap level bookkeeping settles here; the merge work the
        flush induces (L0 merges, level merges) accrues as merge debt and
        is served by the budgeted merge pass."""
        s = self.store
        s._pre_flush_sample(tree)
        freed = tree.flush(trigger=trigger, log_pos=s.log_pos,
                           max_log_bytes=s.cfg.max_log_bytes,
                           total_write_mem=s.write_memory_bytes,
                           beta=s.cfg.beta, forced_kind=forced_kind)
        tree.levels.adjust(s._tree_share(tree))
        return freed

    def flush_dataset(self, ds: str, *, trigger: str) -> int:
        """Flush every tree of one dataset (static-scheme quota/eviction)."""
        freed = 0
        for name in self.store.datasets[ds]:
            t = self.store.trees[name]
            if not t.mem.is_empty():
                freed += self.flush_tree(t, trigger=trigger)
        return freed

    # -- tick phases ----------------------------------------------------------
    def _mem_upkeep(self) -> int:
        steps = 0
        for t in self.store.trees.values():
            while steps < 10_000 and t.mem.upkeep_step():
                steps += 1
        return steps

    def _enforce_memory(self) -> int:
        s, cfg = self.store, self.store.cfg
        flushes = 0
        if cfg.scheme.startswith("btree-static"):
            # per-dataset quota = write_mem / D; full flush at quota
            D = cfg.max_active_datasets
            quota = s.write_memory_bytes / max(1, D)
            for ds, names in s.datasets.items():
                used = sum(s.trees[n].mem_bytes for n in names)
                if used >= quota:
                    self.flush_dataset(ds, trigger="mem")
                    flushes += 1
            return flushes
        # shared-pool schemes
        budget = cfg.mem_flush_threshold * s.write_memory_bytes
        # Accordion-data: a big in-memory merge may blow the budget
        for t in s.trees.values():
            m = t.mem
            if hasattr(m, "budget_hint_bytes"):
                m.budget_hint_bytes = int(budget)
            if getattr(m, "request_flush", False):
                self.flush_tree(t, trigger="mem")
                m.request_flush = False
                flushes += 1
        guard = 0
        while s.write_memory_used() > budget and guard < 1000:
            guard += 1
            t = self.pick_flush_tree()
            if t is None:
                break
            freed = self.flush_tree(t, trigger="mem",
                                    forced_kind=cfg.forced_flush_kind)
            flushes += 1
            if freed == 0:
                break
        return flushes

    def _enforce_log(self) -> int:
        s, cfg = self.store, self.store.cfg
        flushes = 0
        guard = 0
        while s.log_length > cfg.mem_flush_threshold * cfg.max_log_bytes \
                and guard < 1000:
            guard += 1
            if s.min_lsn() >= _INF:
                break
            tree = min((t for t in s.trees.values()
                        if not t.mem.is_empty() or t.min_lsn < _INF),
                       key=lambda t: t.min_lsn, default=None)
            if tree is None or tree.mem.is_empty():
                break
            freed = self.flush_tree(tree, trigger="log",
                                    forced_kind=cfg.forced_flush_kind)
            flushes += 1
            if freed == 0:
                break
        return flushes

    def _run_merges(self, budget: int | None) -> int:
        """Serve maintenance units to the tree with the largest merge debt
        until the budget (or all debt) is exhausted.

        Debts are cached per tree and re-evaluated only for the tree just
        served: maintenance of one tree never changes another tree's
        structures or share, so the cached ranking stays exact."""
        s = self.store
        steps = 0
        debts = {t.name: t.merge_debt(s._tree_share(t))
                 for t in s.trees.values()}
        guard = 0
        while guard < 20_000 and (budget is None or steps < budget):
            guard += 1
            name = max(debts, key=debts.__getitem__, default=None)
            if name is None or debts[name] <= 0:
                break
            t = s.trees[name]
            if t.maintenance_step(s._tree_share(t)):
                steps += 1
                debts[name] = t.merge_debt(s._tree_share(t))
            else:
                # debt signal was stale (e.g. cleared by levels.adjust)
                debts[name] = 0
        self.carried_debt = sum(debts.values())
        return steps

    # -- the tick --------------------------------------------------------------
    def tick(self, *, merge_budget=_UNSET) -> TickReport:
        """One maintenance round over the whole store. ``merge_budget``
        overrides the scheduler's default for this tick only; pass an
        explicit ``None`` to drain all debt regardless of the default."""
        self.ticks += 1
        rep = TickReport()
        rep.upkeep_steps = self._mem_upkeep()
        while self.store._pending_evict:     # static-scheme LRU evictions
            self.flush_dataset(self.store._pending_evict.pop(0),
                               trigger="mem")
            rep.flushes += 1
        rep.flushes += self._enforce_memory()
        rep.flushes += self._enforce_log()
        budget = self.merge_budget if merge_budget is _UNSET else merge_budget
        rep.merge_steps = self._run_merges(budget)
        rep.carried_debt = self.carried_debt
        return rep
