"""Cross-tree maintenance scheduler: the single owner of flush/merge work.

The paper's architecture (§3-§4) requires flushes and merges to be
arbitrated *across* all LSM-trees sharing the write memory, not run inline
by whichever tree happened to receive a write. ``MaintenanceScheduler``
replaces the store's per-write inline enforcement: the write path only
appends to memory components and then calls ``tick()``, and every flush or
merge anywhere in the store flows through this class.

A tick runs five phases, each of which is also exposed as a *resumable
tick segment* (``run_segment``) so a ``MaintenancePacer`` can interleave
maintenance with foreground write batches instead of stopping the world:

  1. **Memory-component upkeep** (segment ``"upkeep"``) -- structures that
     do write-path-adjacent work (Accordion's seal + pipeline merges,
     which can set ``request_flush`` when a data merge's transient peak
     blows the budget) run their ``upkeep_step`` units, and static-scheme
     LRU dataset evictions queued by the write path are flushed.
  2. **Memory enforcement** (segment ``"mem"``, mandatory) -- while the
     shared write memory exceeds its threshold, pick a flush victim by
     the configured §4.2 flush policy (max-memory / min-LSN /
     write-rate-proportional OPT) and flush it. Runs to completion: the
     memory bound is a correctness invariant, not discretionary work.
  3. **Log enforcement** (segment ``"log"``, mandatory) -- while the log
     exceeds its cap, flush the tree holding the minimum LSN
     (log-triggered flushes facilitate truncation, §4.1.1).
  4. **Merge pass** (segment ``"merge"``, discretionary, budgeted) --
     rank all trees by their ``merge_debt`` (pending memory merges + L0
     groups over target + over-full levels + L1 drains) and execute up to
     ``merge_budget`` maintenance steps, always against the tree with the
     largest debt. Unspent debt carries to the next tick
     (``carried_debt``), modelling bounded background-merge bandwidth;
     ``merge_budget=None`` (default) drains all debt. A bounded merge
     segment is a *slice*: repeated slices serve exactly the same
     largest-debt-first step sequence a single draining pass would
     (maintenance of one tree never changes another tree's debt), which
     is what makes paced schedules bit-identical to stop-the-world ones
     once the debt is drained.
  5. **WAL enforcement** (segment ``"wal"``) -- the durable twin of
     phase 3: physically truncate the write-ahead log below the
     arena-global min-LSN (the bytes the min-LSN flushes just made dead),
     taking a durable checkpoint first whenever the watermark would pass
     the last checkpoint (or the ``checkpoint_interval_bytes`` knob
     demands one), so the retained tail always suffices for bit-identical
     replay. After every tick ``wal.tail_bytes == store.log_length``.

Every tick -- and every individually-run segment -- is WAL-logged as a
``TickRecord`` *before* its phases run (write-ahead): ticks and segments
are pure functions of store state, so recovery re-runs them at the
original trigger points and a crash mid-segment redoes the whole segment
from its logged start. A one-shot ``tick()`` logs ONE record with
``segment="full"``; a paced schedule logs one record per segment, so any
interleaving of segments and write batches replays deterministically.

The scheduler holds no tree state of its own -- it reads candidates from
the store each phase -- so ticks are a pure function of store state, which
the differential test suite exploits: any interleaving of writes producing
the same memory-component state followed by the same tick-segment sequence
yields bit-identical trees.
"""
from __future__ import annotations

from dataclasses import dataclass

_INF = 2**62
_UNSET = object()      # tick(): "no override" vs an explicit None (=drain)

# Resumable tick segments, in the canonical (one-shot tick) order.
SEGMENTS = ("upkeep", "mem", "log", "merge", "wal")


def _budget_tag(merge_budget):
    """WAL encoding of a tick's merge-budget override."""
    if merge_budget is _UNSET:
        return "default"
    if merge_budget is None:
        return "drain"
    return int(merge_budget)


def enforce_wal(arena, scheduler) -> None:
    """Phase 5 (shared by both schedulers): checkpoint if the min-LSN
    watermark passed the last checkpoint (or the interval knob fired),
    then truncate through the one shared path
    (``durability.checkpoint.truncate_below_min_lsn``)."""
    from ..durability.checkpoint import (global_min_lsn, take_checkpoint,
                                         truncate_below_min_lsn)
    wal, man, cfg = arena.wal, arena.manifest, arena.cfg
    trunc = global_min_lsn(arena)
    need = trunc > man.checkpoint_watermark
    interval = cfg.checkpoint_interval_bytes
    if interval is not None:
        need = need or wal.head_lsn - man.checkpoint_watermark >= interval
    if need:
        # Replay determinism: a tick re-run during recovery sees exactly
        # the state the original saw, and the original did not checkpoint
        # here (the restored checkpoint is the latest one).
        assert not wal.replaying, \
            "checkpoint triggered during WAL replay (determinism bug)"
        take_checkpoint(arena, scheduler)
    truncate_below_min_lsn(arena)


@dataclass
class TickReport:
    """What one scheduler tick (or tick segment) did."""

    flushes: int = 0          # flush events executed (mem- or log-triggered)
    upkeep_steps: int = 0     # memory-component upkeep units
    merge_steps: int = 0      # discretionary maintenance units
    carried_debt: int = 0     # debt left unserved by the merge budget


def rank_flush_victim(cands, policy):
    """§4.2 flush-victim ranking over ``(store, tree)`` candidates whose
    memory components are non-empty. The stores may all be one store
    (single-store scheduler) or the shards of one arena (global
    scheduler): the ranking is the same either way, which is what makes a
    one-shard deployment bit-identical to a bare ``LSMStore``.

    Returns the chosen ``(store, tree)`` pair, or None if no candidates.
    """
    if not cands:
        return None
    if policy == "mem":
        return max(cands, key=lambda st: st[1].mem_bytes)
    if policy == "lsn":
        return min(cands, key=lambda st: st[1].min_lsn)
    # opt: flush the tree whose memory ratio most exceeds its optimal
    # write-rate-proportional ratio a_i_opt = r_i / sum_j r_j.
    rates = [sum(b for _, b in s._rate_win[t.name]) for s, t in cands]
    total_rate = sum(rates)
    used = [t.mem_bytes for _, t in cands]
    total_used = sum(used)
    if total_rate == 0 or total_used == 0:
        return min(cands, key=lambda st: st[1].min_lsn)
    best, best_gap = None, None
    for st, r, u in zip(cands, rates, used):
        gap = u / total_used - r / total_rate
        if best_gap is None or gap > best_gap:
            best, best_gap = st, gap
    return best


class SegmentedScheduler:
    """Shared tick/segment machinery of both schedulers.

    Subclasses provide the five phase implementations (``_mem_upkeep`` /
    ``_flush_pending`` / ``_enforce_memory`` / ``_enforce_log`` /
    ``_run_merges``) plus ``_arena``; this base turns them into the
    one-shot ``tick()`` and the resumable ``run_segment()`` -- both
    WAL-logged write-ahead, so a one-shot tick and any interleaved segment
    schedule are equally replay-deterministic.
    """

    merge_budget: int | None

    def _init_counters(self, merge_budget: int | None) -> None:
        self.merge_budget = merge_budget
        self.ticks = 0          # one-shot (full) ticks executed
        self.segments = 0       # individually-run tick segments executed
        self.carried_debt = 0

    def run_segment(self, name: str, *, merge_budget=_UNSET) -> TickReport:
        """Run ONE tick segment. ``merge_budget`` applies to the
        ``"merge"`` segment only (same override contract as ``tick``: an
        explicit ``None`` drains all debt). Each segment is logged
        write-ahead as its own ``TickRecord``, so any interleaving of
        segments with write batches replays deterministically."""
        if name not in SEGMENTS:
            raise ValueError(f"unknown tick segment {name!r}; "
                             f"expected one of {SEGMENTS}")
        arena = self._arena()
        arena.wal.append_tick(
            _budget_tag(merge_budget) if name == "merge" else "default",
            segment=name)
        self.segments += 1
        rep = TickReport()
        if name == "upkeep":
            rep.upkeep_steps = self._mem_upkeep()
            rep.flushes = self._flush_pending()
        elif name == "mem":
            rep.flushes = self._enforce_memory()
        elif name == "log":
            rep.flushes = self._enforce_log()
        elif name == "merge":
            budget = self.merge_budget if merge_budget is _UNSET \
                else merge_budget
            rep.merge_steps = self._run_merges(budget)
        else:                                     # "wal"
            enforce_wal(arena, self)
        rep.carried_debt = self.carried_debt
        # Commit point: the segment's TickRecord (and any still-pending
        # writes) reach stable storage under the configured fsync policy.
        arena.wal.commit()
        return rep

    # -- background prepare (engine/workers.py) -------------------------------
    def _merge_candidates(self):
        """``(debt, store, tree)`` triples with positive merge debt --
        the prefetcher's ranking input (subclasses provide it)."""
        raise NotImplementedError

    def prefetch_merges(self, limit: int | None = None) -> int:
        """Speculatively submit the next merge computations to the
        arena's worker pool, largest debt first (up to ``limit`` jobs,
        default one per worker). Entirely side-effect-free with respect
        to store state: prepares are pure and consumed only when the
        apply step derives the identical input key, so replay -- which
        never prefetches -- recomputes inline bit-identically. Returns
        the number of jobs submitted (0 with workers off)."""
        pool = getattr(self._arena(), "workers", None)
        if pool is None or not pool.enabled:
            return 0
        if limit is None:
            limit = pool.workers
        n = 0
        for _, s, t in sorted(self._merge_candidates(),
                              key=lambda c: -c[0]):
            pv = t.preview_merge(s._tree_share(t))
            if pv is None:
                continue
            key, runs = pv
            if pool.submit(key, lambda b=t.backend, r=runs: b.merge_runs(r)):
                n += 1
            if n >= limit:
                break
        return n

    def tick(self, *, merge_budget=_UNSET) -> TickReport:
        """One stop-the-world maintenance round: all five segments in
        canonical order under ONE ``TickRecord``. ``merge_budget``
        overrides the scheduler's default for this tick only; pass an
        explicit ``None`` to drain all debt regardless of the default."""
        arena = self._arena()
        arena.wal.append_tick(_budget_tag(merge_budget), segment="full")
        self.ticks += 1
        rep = TickReport()
        rep.upkeep_steps = self._mem_upkeep()
        rep.flushes += self._flush_pending()
        rep.flushes += self._enforce_memory()
        rep.flushes += self._enforce_log()
        budget = self.merge_budget if merge_budget is _UNSET else merge_budget
        rep.merge_steps = self._run_merges(budget)
        rep.carried_debt = self.carried_debt
        enforce_wal(arena, self)
        arena.wal.commit()        # commit point (see run_segment)
        return rep


class MaintenanceScheduler(SegmentedScheduler):
    """Arbitrates flush/merge work across every tree of one ``LSMStore``."""

    def __init__(self, store, *, merge_budget: int | None = None):
        self.store = store
        self._init_counters(merge_budget)

    def _arena(self):
        return self.store.arena

    # -- flush candidate ranking (§4.2) --------------------------------------
    def pick_flush_tree(self):
        """Rank non-empty trees by the configured flush policy and return
        the victim (None if all memory components are empty)."""
        s = self.store
        pick = rank_flush_victim(
            [(s, t) for t in s.trees.values() if not t.mem.is_empty()],
            s.cfg.flush_policy)
        return None if pick is None else pick[1]

    # -- flush execution ------------------------------------------------------
    def flush_tree(self, tree, *, trigger: str,
                   forced_kind: str | None = None) -> int:
        """Flush one tree. Returns bytes freed.

        Only the cheap level bookkeeping settles here; the merge work the
        flush induces (L0 merges, level merges) accrues as merge debt and
        is served by the budgeted merge pass."""
        s = self.store
        s._pre_flush_sample(tree)
        freed = tree.flush(trigger=trigger, log_pos=s.log_pos,
                           max_log_bytes=s.cfg.max_log_bytes,
                           total_write_mem=s.write_memory_bytes,
                           beta=s.cfg.beta, forced_kind=forced_kind)
        tree.levels.adjust(s._tree_share(tree))
        return freed

    def flush_dataset(self, ds: str, *, trigger: str) -> int:
        """Flush every tree of one dataset (static-scheme quota/eviction)."""
        freed = 0
        for name in self.store.datasets[ds]:
            t = self.store.trees[name]
            if not t.mem.is_empty():
                freed += self.flush_tree(t, trigger=trigger)
        return freed

    # -- tick phases ----------------------------------------------------------
    def _mem_upkeep(self) -> int:
        steps = 0
        for t in self.store.trees.values():
            while steps < 10_000 and t.mem.upkeep_step():
                steps += 1
        return steps

    def _flush_pending(self) -> int:
        flushes = 0
        while self.store._pending_evict:     # static-scheme LRU evictions
            self.flush_dataset(self.store._pending_evict.pop(0),
                               trigger="mem")
            flushes += 1
        return flushes

    def _enforce_memory(self) -> int:
        s, cfg = self.store, self.store.cfg
        flushes = 0
        if cfg.scheme.startswith("btree-static"):
            # per-dataset quota = write_mem / D; full flush at quota
            D = cfg.max_active_datasets
            quota = s.write_memory_bytes / max(1, D)
            for ds, names in s.datasets.items():
                used = sum(s.trees[n].mem_bytes for n in names)
                if used >= quota:
                    self.flush_dataset(ds, trigger="mem")
                    flushes += 1
            return flushes
        # shared-pool schemes
        budget = cfg.mem_flush_threshold * s.write_memory_bytes
        # Accordion-data: a big in-memory merge may blow the budget
        for t in s.trees.values():
            m = t.mem
            if hasattr(m, "budget_hint_bytes"):
                m.budget_hint_bytes = int(budget)
            if getattr(m, "request_flush", False):
                self.flush_tree(t, trigger="mem")
                m.request_flush = False
                flushes += 1
        guard = 0
        while s.write_memory_used() > budget and guard < 1000:
            guard += 1
            t = self.pick_flush_tree()
            if t is None:
                break
            freed = self.flush_tree(t, trigger="mem",
                                    forced_kind=cfg.forced_flush_kind)
            flushes += 1
            if freed == 0:
                break
        # Paced flush slice: below the hard threshold but above the
        # proactive one, release ONE partial flush so memory pressure is
        # paid down in slices instead of a stop-the-world burst at the
        # threshold. Pure function of store state + config (never of
        # pacer state), so the logged "mem" segment replays it.
        thr = cfg.pacer_flush_threshold
        if thr is not None and flushes == 0 \
                and s.write_memory_used() > thr * s.write_memory_bytes:
            t = self.pick_flush_tree()
            if t is not None:
                self.flush_tree(t, trigger="mem",
                                forced_kind=cfg.forced_flush_kind)
                flushes += 1
                s.disk.stats.flush_slices += 1
        return flushes

    def _enforce_log(self) -> int:
        s, cfg = self.store, self.store.cfg
        flushes = 0
        guard = 0
        while s.log_length > cfg.mem_flush_threshold * cfg.max_log_bytes \
                and guard < 1000:
            guard += 1
            if s.min_lsn() >= _INF:
                break
            tree = min((t for t in s.trees.values()
                        if not t.mem.is_empty() or t.min_lsn < _INF),
                       key=lambda t: t.min_lsn, default=None)
            if tree is None or tree.mem.is_empty():
                break
            freed = self.flush_tree(tree, trigger="log",
                                    forced_kind=cfg.forced_flush_kind)
            flushes += 1
            if freed == 0:
                break
        return flushes

    def _run_merges(self, budget: int | None) -> int:
        """Serve maintenance units to the tree with the largest merge debt
        until the budget (or all debt) is exhausted.

        Debts are cached per tree and re-evaluated only for the tree just
        served: maintenance of one tree never changes another tree's
        structures or share, so the cached ranking stays exact -- and a
        sequence of bounded slices serves exactly the step sequence one
        draining pass would."""
        self.prefetch_merges()
        s = self.store
        steps = 0
        debts = {t.name: t.merge_debt(s._tree_share(t))
                 for t in s.trees.values()}
        guard = 0
        while guard < 20_000 and (budget is None or steps < budget):
            guard += 1
            name = max(debts, key=debts.__getitem__, default=None)
            if name is None or debts[name] <= 0:
                break
            t = s.trees[name]
            if t.maintenance_step(s._tree_share(t)):
                steps += 1
                debts[name] = t.merge_debt(s._tree_share(t))
            else:
                # debt signal was stale (e.g. cleared by levels.adjust)
                debts[name] = 0
        self.carried_debt = sum(debts.values())
        return steps

    def _merge_candidates(self):
        s = self.store
        out = []
        for t in s.trees.values():
            d = t.merge_debt(s._tree_share(t))
            if d > 0:
                out.append((d, s, t))
        return out


class ShardedMaintenanceScheduler(SegmentedScheduler):
    """Global maintenance arbiter of a sharded data plane.

    Each shard keeps its own ``MaintenanceScheduler`` (the flush/upkeep
    executor for that shard's trees), but nothing ticks them individually:
    this class runs the same tick phases *across all shards* under
    ONE write-memory budget, ONE log cap and ONE discretionary merge
    budget -- the paper's cross-tree arbitration lifted to cross-shard:

      * memory enforcement compares the arena-wide usage (every shard's
        trees) against the shared threshold and picks flush victims by
        the §4.2 policy ranked over all (shard, tree) pairs;
      * log enforcement flushes the globally minimal-LSN tree, since all
        shards append to the arena's single transaction log;
      * the merge pass serves ``merge_budget`` maintenance units to the
        (shard, tree) with the largest merge debt, wherever it lives --
        a hot shard therefore drains the whole store's merge bandwidth,
        which is exactly the backpressure the service's per-shard
        admission gate then surfaces as ``Deferred`` on that shard only.

    With one shard every phase degenerates to ``MaintenanceScheduler``'s
    behavior bit-for-bit (the differential suite enforces this).
    """

    def __init__(self, stores, arena, *, merge_budget: int | None = None):
        self.stores = list(stores)
        self.arena = arena
        self._init_counters(merge_budget)

    def _arena(self):
        return self.arena

    # -- global aggregates ----------------------------------------------------
    def _used(self) -> int:
        return sum(s.write_memory_used() for s in self.stores)

    def _min_lsn(self) -> int:
        return min((s.min_lsn() for s in self.stores), default=_INF)

    def _log_length(self) -> int:
        m = self._min_lsn()
        lp = self.arena.log_pos
        return lp - (m if m < _INF else lp)

    def pick_flush_victim(self):
        """Globally ranked §4.2 flush victim: (store, tree) or None."""
        return rank_flush_victim(
            [(s, t) for s in self.stores for t in s.trees.values()
             if not t.mem.is_empty()],
            self.arena.cfg.flush_policy)

    # -- tick phases (global twins of MaintenanceScheduler's) -----------------
    def _mem_upkeep(self) -> int:
        return sum(s.scheduler._mem_upkeep() for s in self.stores)

    def _flush_pending(self) -> int:
        flushes = 0
        for s in self.stores:
            while s._pending_evict:          # static-scheme LRU evictions
                s.scheduler.flush_dataset(s._pending_evict.pop(0),
                                          trigger="mem")
                flushes += 1
        return flushes

    def _enforce_memory(self) -> int:
        cfg = self.arena.cfg
        flushes = 0
        if cfg.scheme.startswith("btree-static"):
            # per-dataset quota against the *global* write memory: a
            # dataset's usage is summed over its per-shard slices and the
            # whole dataset flushes everywhere once it crosses quota.
            quota = self.arena.write_memory_bytes \
                / max(1, cfg.max_active_datasets)
            names: list[str] = []
            for s in self.stores:
                for ds in s.datasets:
                    if ds not in names:
                        names.append(ds)
            for ds in names:
                used = sum(s.trees[n].mem_bytes for s in self.stores
                           for n in s.datasets.get(ds, ()))
                if used >= quota:
                    for s in self.stores:
                        if ds in s.datasets:
                            s.scheduler.flush_dataset(ds, trigger="mem")
                    flushes += 1
            return flushes
        # shared-pool schemes
        budget = cfg.mem_flush_threshold * self.arena.write_memory_bytes
        for s in self.stores:
            for t in s.trees.values():
                m = t.mem
                if hasattr(m, "budget_hint_bytes"):
                    m.budget_hint_bytes = int(budget)
                if getattr(m, "request_flush", False):
                    s.scheduler.flush_tree(t, trigger="mem")
                    m.request_flush = False
                    flushes += 1
        guard = 0
        while self._used() > budget and guard < 1000:
            guard += 1
            pick = self.pick_flush_victim()
            if pick is None:
                break
            s, t = pick
            freed = s.scheduler.flush_tree(
                t, trigger="mem", forced_kind=cfg.forced_flush_kind)
            flushes += 1
            if freed == 0:
                break
        # Paced flush slice (global twin; see MaintenanceScheduler).
        thr = cfg.pacer_flush_threshold
        if thr is not None and flushes == 0 \
                and self._used() > thr * self.arena.write_memory_bytes:
            pick = self.pick_flush_victim()
            if pick is not None:
                s, t = pick
                s.scheduler.flush_tree(t, trigger="mem",
                                       forced_kind=cfg.forced_flush_kind)
                flushes += 1
                self.arena.disk.stats.flush_slices += 1
        return flushes

    def _enforce_log(self) -> int:
        cfg = self.arena.cfg
        flushes = 0
        guard = 0
        while self._log_length() > cfg.mem_flush_threshold * cfg.max_log_bytes \
                and guard < 1000:
            guard += 1
            if self._min_lsn() >= _INF:
                break
            pick = min(((s, t) for s in self.stores
                        for t in s.trees.values()
                        if not t.mem.is_empty() or t.min_lsn < _INF),
                       key=lambda st: st[1].min_lsn, default=None)
            if pick is None or pick[1].mem.is_empty():
                break
            freed = pick[0].scheduler.flush_tree(
                pick[1], trigger="log", forced_kind=cfg.forced_flush_kind)
            flushes += 1
            if freed == 0:
                break
        return flushes

    def _run_merges(self, budget: int | None) -> int:
        """Largest-debt-first allocation of maintenance units across every
        (shard, tree); unspent debt carries to the next tick."""
        self.prefetch_merges()
        steps = 0
        owners: dict = {}
        debts: dict = {}
        for si, s in enumerate(self.stores):
            for t in s.trees.values():
                k = (si, t.name)
                owners[k] = (s, t)
                debts[k] = t.merge_debt(s._tree_share(t))
        guard = 0
        while guard < 20_000 and (budget is None or steps < budget):
            guard += 1
            k = max(debts, key=debts.__getitem__, default=None)
            if k is None or debts[k] <= 0:
                break
            s, t = owners[k]
            if t.maintenance_step(s._tree_share(t)):
                steps += 1
                debts[k] = t.merge_debt(s._tree_share(t))
            else:
                debts[k] = 0
        self.carried_debt = sum(debts.values())
        return steps

    def _merge_candidates(self):
        out = []
        for s in self.stores:
            for t in s.trees.values():
                d = t.merge_debt(s._tree_share(t))
                if d > 0:
                    out.append((d, s, t))
        return out
