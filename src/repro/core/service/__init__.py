# The unified storage front door (§3 as an API): typed request plans,
# sessions with admission control, and the pluggable MemoryGovernor.
from .governor import (AdaptiveGovernor, DevicePoolGovernor,  # noqa: F401
                       MemoryGovernor, MemoryPlan, StaticGovernor)
from .planner import ExecutionPlan, PlanStep, build_plan  # noqa: F401
from .requests import (Deferred, Delete, Get, GetResult, Put,  # noqa: F401
                       Request, Result, Scan, ScanResult, WriteAck,
                       request_kind)
from .service import (ServiceConfig, Session, SessionStats,  # noqa: F401
                      StorageService)
