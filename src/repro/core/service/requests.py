"""Typed request/result vocabulary of the ``StorageService`` front door.

A request names a tree and carries a *batch* of keys (and values): the
service's unit of admission and planning is the request, the unit of
execution is the vectorized backend call the planner groups requests into.
Results mirror requests one-to-one, in submission order; a request the
service could not admit comes back as ``Deferred`` (explicit backpressure)
carrying the original request so the caller can retry after ``drain()``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _as_keys(keys) -> np.ndarray:
    arr = np.atleast_1d(np.asarray(keys, np.int64))
    if arr.ndim != 1:
        raise ValueError(f"keys must be a scalar or 1-D array, got shape "
                         f"{arr.shape}")
    return arr


@dataclass(frozen=True, eq=False)
class Put:
    """Upsert ``vals[i]`` under ``keys[i]``; ``vals=None`` defaults the
    payload to the key (checksum convention of ``LSMStore.write_batch``)."""

    tree: str
    keys: np.ndarray
    vals: np.ndarray | None = None

    def __post_init__(self):
        object.__setattr__(self, "keys", _as_keys(self.keys))
        if self.vals is not None:
            vals = np.atleast_1d(np.asarray(self.vals, np.int64))
            if vals.shape != self.keys.shape:
                raise ValueError("vals must match keys in shape")
            object.__setattr__(self, "vals", vals)


@dataclass(frozen=True, eq=False)
class Get:
    """Batched point lookup."""

    tree: str
    keys: np.ndarray

    def __post_init__(self):
        object.__setattr__(self, "keys", _as_keys(self.keys))


@dataclass(frozen=True, eq=False)
class Delete:
    """Batched delete (tombstone writes; reads and scans filter them)."""

    tree: str
    keys: np.ndarray

    def __post_init__(self):
        object.__setattr__(self, "keys", _as_keys(self.keys))


@dataclass(frozen=True)
class Scan:
    """Range scan of ``n`` key-space slots starting at ``lo``; resolves to
    the number of live entries in the range."""

    tree: str
    lo: int
    n: int


Request = Put | Get | Delete | Scan


# --------------------------------- results -----------------------------------
@dataclass(frozen=True)
class WriteAck:
    """A Put/Delete request fully ingested (``n`` keys). ``durable``
    reports whether the WAL records covering this submit had reached
    stable storage when the ack was built: always True on the in-memory
    medium and under per_record/per_batch fsync policies; under group
    commit an ack may return before its group's fsync (the deferred-
    durability window group commit trades for fewer fsyncs)."""

    tree: str
    n: int
    durable: bool = True


@dataclass(frozen=True, eq=False)
class GetResult:
    tree: str
    found: np.ndarray       # bool[n]
    vals: np.ndarray        # int64[n]


@dataclass(frozen=True)
class ScanResult:
    tree: str
    count: int


@dataclass(frozen=True, eq=False)
class Deferred:
    """Backpressure: the request was *not* executed. ``reason`` is one of
    ``"l0-stall"`` (too many L0 groups on the target tree),
    ``"memory-pressure"`` (shared write memory over its admission slack) or
    ``"session-quota"`` (the session's outstanding-work cap). Retry via
    ``StorageService.drain()`` + resubmit (or ``submit_all``).

    Over a sharded store the gate is per shard, so ``request`` may be
    *narrowed* to the keys routed to the stalled shard(s); keys on healthy
    shards executed and are not re-carried."""

    request: Request
    reason: str


Result = WriteAck | GetResult | ScanResult | Deferred


def request_kind(req: Request) -> str:
    """Stable op-kind tag used by the planner's (tree, kind) grouping."""
    if isinstance(req, Put):
        return "put"
    if isinstance(req, Delete):
        return "delete"
    if isinstance(req, Get):
        return "get"
    if isinstance(req, Scan):
        return "scan"
    raise TypeError(f"not a storage request: {req!r}")
