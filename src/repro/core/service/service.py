"""StorageService: the single typed front door over an ``LSMStore``.

The §3 architecture is one storage service mediating many LSM-trees behind
shared write memory and a buffer cache. ``StorageService`` is that front
door as an API:

  * ``submit(requests)`` plans a mixed-op batch into vectorized per-(tree,
    kind) steps -- per-(tree, shard, kind) write steps over a sharded
    store (see ``planner``) -- dispatches them through the store's batched
    backend paths (``write_batch`` / ``read_batch`` / ``scan_batch``),
    and returns per-request typed results in submission order;
  * maintenance is amortized: ONE ``MaintenanceScheduler.tick()`` per
    submit that executed writes, instead of one per write call -- or,
    with ``StoreConfig.pacer_interval_bytes`` set, a *paced* schedule
    (``engine/pacer.py``): mandatory segments every submit, merges in
    bounded slices paced against the observed write rate, every segment
    WAL-logged so interleavings replay deterministically. Submit wall
    time and maintenance stall durations stream into two
    ``LatencyHistogram``s (``service.latency`` / ``service.stall``);
  * admission control converts L0 write stalls and write-memory overload
    into explicit ``Deferred`` responses (counted in
    ``IOStats.write_stalls``) instead of silent inline stalls; per-tenant
    ``Session`` handles meter outstanding work on top;
  * memory adaptation is owned by one pluggable ``MemoryGovernor``
    observed once per submit (default: the §5.4 tuner).

Op accounting is bit-identical to direct store calls: a plan step performs
exactly the batched call a caller would have made on the concatenated keys.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ...runtime.latency import LatencyHistogram
from ..engine.pacer import MaintenancePacer
from ..lsm.storage import LSMStore, POLICIES, StoreConfig
from .governor import MemoryGovernor, MemoryPlan, StallGovernor, \
    StaticGovernor
from .planner import PlanStep, build_plan
from .requests import (Deferred, Delete, Get, GetResult, Put, Result,
                       ScanResult, WriteAck)

_UNSET = object()


@dataclass
class ServiceConfig:
    # Master switch for engine-side backpressure (L0 stall + memory slack).
    admission: bool = True
    # Defer writes to a tree holding >= this many L0 groups (None: the
    # store's l0_max_groups -- the point a real engine stalls flushes).
    l0_stall_groups: int | None = None
    # Defer writes that would push shared write memory past
    # slack * write_memory_bytes (a hard overload bound well above the
    # mem_flush_threshold the scheduler enforces each tick).
    memory_admit_slack: float | None = 2.0   # None disables the gate
    # Safety cap for drain() catch-up ticks.
    max_drain_ticks: int = 200


@dataclass
class SessionStats:
    submitted_keys: int = 0
    executed_keys: int = 0
    deferred_keys: int = 0
    deferred_events: int = 0
    submits: int = 0


class Session:
    """Per-tenant handle metering outstanding work.

    ``max_outstanding_keys`` caps the write keys one submit may admit for
    this tenant (the admission window); excess write steps come back as
    ``Deferred("session-quota")`` without touching the engine. Obtain via
    ``StorageService.session()``; ``session.submit`` is sugar for
    ``service.submit(..., session=session)``.
    """

    def __init__(self, service: "StorageService", tenant: str, *,
                 max_outstanding_keys: int | None = None):
        self.service = service
        self.tenant = tenant
        self.max_outstanding_keys = max_outstanding_keys
        self.stats = SessionStats()
        self._window = 0          # write keys admitted in the current submit

    def _begin_submit(self) -> None:
        self._window = 0
        self.stats.submits += 1

    def _admit(self, n_keys: int) -> bool:
        if self.max_outstanding_keys is not None \
                and self._window + n_keys > self.max_outstanding_keys:
            return False
        self._window += n_keys
        return True

    def submit(self, requests, **kw) -> list[Result]:
        return self.service.submit(requests, session=self, **kw)

    def submit_all(self, requests, **kw) -> list[Result]:
        return self.service.submit_all(requests, session=self, **kw)


class StorageService:
    """Front door over one ``LSMStore`` or ``ShardedStore`` (owned or
    adopted)."""

    def __init__(self, store, *,
                 governor: MemoryGovernor | None = None,
                 config: ServiceConfig | None = None):
        self.store = store
        self.cfg = config or ServiceConfig()
        self.governor = governor or StaticGovernor()
        self.governor.attach(store)
        self.plans: list[MemoryPlan] = []        # applied governor decisions
        self.sessions: dict[str, Session] = {}
        self.submits = 0
        # Tail latency is first-class: every submit records its wall time
        # (once per request) and the duration of its inline maintenance
        # (the foreground stall). Window deltas feed the BENCH_*.json
        # p99/p999/max_stall columns.
        self.latency = LatencyHistogram()        # submit wall time, us
        self.stall = LatencyHistogram()          # maintenance pauses, us
        # Paced maintenance replaces the per-submit stop-the-world tick
        # when the store opts in (StoreConfig.pacer_interval_bytes). The
        # pacer is rebuilt (accumulator zero) on recovery by design:
        # pacing is a performance policy, never replayed state.
        cfg = store.cfg
        self.pacer = None
        if cfg.pacer_interval_bytes is not None:
            self.pacer = MaintenancePacer(
                store.scheduler,
                segment_budget=cfg.pacer_segment_budget,
                interval_bytes=cfg.pacer_interval_bytes)
        # Pacer autotune rides beside the memory governor (it owns a
        # different actuator -- the live pacer's knobs -- so the two never
        # fight over a plan field).
        self.stall_governor = None
        if getattr(cfg, "pacer_autotune", False) and self.pacer is not None:
            self.stall_governor = StallGovernor()

    @classmethod
    def open(cls, store_cfg: StoreConfig, **kw) -> "StorageService":
        return cls(LSMStore(store_cfg), **kw)

    @classmethod
    def recover(cls, store_cfg: StoreConfig, wal, manifest, *,
                router=None, **kw) -> "StorageService":
        """Crash-recovery front door: rebuild the data plane from the
        durable (WAL, manifest) pair and open a fresh service over it.

        The recovered store is bit-identical to the crashed one (state and
        write-path counters; see ``repro.core.durability``). Requests the
        old service answered with ``Deferred`` were never executed and are
        therefore *provably absent* from the log -- admission control
        refuses a write before it reaches the WAL append, so a deferred
        key appears in no ``WriteBatchRecord`` and recovery cannot
        resurrect it. Replay statistics: ``service.store.recovery_info``.
        """
        from ..durability.recovery import recover as _recover
        return cls(_recover(store_cfg, wal, manifest, router=router), **kw)

    # -- schema / passthroughs ------------------------------------------------
    def create_tree(self, name: str, **kw):
        return self.store.create_tree(name, **kw)

    def note_ops(self, n: int = 1) -> None:
        self.store.note_ops(n)

    @property
    def stats(self):
        return self.store.disk.stats

    def session(self, tenant: str, *,
                max_outstanding_keys=_UNSET) -> Session:
        """Get-or-create the tenant's session. Passing
        ``max_outstanding_keys`` (including an explicit ``None`` for
        unlimited) sets the admission window on the session, new or
        existing; omitting it leaves an existing session's window alone."""
        s = self.sessions.get(tenant)
        if s is None:
            s = self.sessions[tenant] = Session(
                self, tenant,
                max_outstanding_keys=(None if max_outstanding_keys is _UNSET
                                      else max_outstanding_keys))
        elif max_outstanding_keys is not _UNSET:
            s.max_outstanding_keys = max_outstanding_keys
        return s

    # -- admission ------------------------------------------------------------
    def _stall_groups(self) -> int:
        return (self.cfg.l0_stall_groups
                if self.cfg.l0_stall_groups is not None
                else self.store.cfg.l0_max_groups)

    def _step_tree(self, step: PlanStep):
        """The one LSMTree a step targets: over a sharded store, a write
        step names a (tree, shard) pair, so admission inspects the hot
        shard's tree only."""
        if step.shard is not None:
            return self.store.shard_tree(step.shard, step.tree)
        return self.store.trees[step.tree]

    def _refuse_write(self, step: PlanStep,
                      session: Session | None) -> str | None:
        """Admission check for one write step, just before execution.
        Returns a Deferred reason, or None to admit.

        Engine-side gates run first: a step the engine refuses must not
        charge the session's admission window (the keys never execute, and
        charging them would spuriously defer later steps of the submit)."""
        if self.cfg.admission:
            tree = self._step_tree(step)
            if tree.l0.num_groups >= self._stall_groups():
                return "l0-stall"
            slack = self.cfg.memory_admit_slack
            if slack is not None:
                incoming = step.n_keys * tree.entry_bytes
                if self.store.write_memory_used() + incoming \
                        > slack * self.store.write_memory_bytes:
                    return "memory-pressure"
        if session is not None and not session._admit(step.n_keys):
            return "session-quota"
        return None

    def stalled_trees(self) -> list[str]:
        """Trees currently refused writes by the L0 admission gate. Over a
        sharded store, entries are per-shard (``name@shard``): only the
        stalled shard refuses writes, the rest keep serving."""
        g = self._stall_groups()
        return [n for n, t in self.store.trees.items()
                if t.l0.num_groups >= g]

    def drain(self, max_ticks: int | None = None) -> int:
        """Catch-up maintenance: tick with an unbounded merge budget until
        no tree is L0-stalled, write memory is back under its threshold
        and no merge debt is carried (paced schedules defer slices, so a
        drain must also pay whatever the pacer left outstanding), or the
        tick cap is hit. Returns ticks executed. The explicit pair to a
        ``Deferred`` response: drain, then resubmit."""
        cap = max_ticks if max_ticks is not None else self.cfg.max_drain_ticks
        s = self.store
        done = 0
        for _ in range(cap):
            over_mem = s.write_memory_used() \
                > s.cfg.mem_flush_threshold * s.write_memory_bytes
            if not over_mem and not self.stalled_trees() \
                    and s.scheduler.carried_debt == 0:
                break
            tm = time.perf_counter()
            s.scheduler.tick(merge_budget=None)   # drain all debt
            self.stall.record((time.perf_counter() - tm) * 1e6)
            done += 1
        return done

    def sync(self) -> None:
        """Make every previously acked write durable now (drains a
        pending group-commit window; no-op on the memory medium)."""
        self.store.wal.sync()

    # -- execution ------------------------------------------------------------
    def _execute_step(self, step: PlanStep, results: list,
                      count_ops: bool) -> None:
        """Dispatch one plan step as ONE batched store call. Write acks
        are assembled by ``submit`` (a request may span several per-shard
        write steps); read/scan steps set their results here."""
        s = self.store
        if step.shard is not None:
            # the planner already routed this write step's keys: dispatch
            # straight to the shard's store instead of re-routing through
            # ShardedStore (every key would be hashed a second time)
            s = self.store.shards[step.shard].store
        if step.kind == "put":
            s.write_batch(step.tree, step.concat_keys(), step.concat_vals(),
                          op=count_ops, tick=False)
        elif step.kind == "delete":
            s.delete_batch(step.tree, step.concat_keys(),
                           op=count_ops, tick=False)
        elif step.kind == "get":
            found, vals = s.read_batch(step.tree, step.concat_keys(),
                                       op=count_ops)
            for i, _, a, b in step.slices():
                results[i] = GetResult(step.tree, found[a:b].copy(),
                                       vals[a:b].copy())
        elif step.kind == "scan":
            los = np.array([r.lo for r in step.requests], np.int64)
            lens = np.array([r.n for r in step.requests], np.int64)
            counts = s.scan_batch(step.tree, los, lens, op=count_ops)
            for j, i in enumerate(step.indices):
                results[i] = ScanResult(step.tree, int(counts[j]))
        else:                                     # pragma: no cover
            raise AssertionError(step.kind)

    @staticmethod
    def _narrow(req, sel: np.ndarray):
        """The sub-request carrying only positions ``sel`` of the keys --
        what a partially-deferred sharded write hands back for retry."""
        if isinstance(req, Put):
            return Put(req.tree, req.keys[sel],
                       None if req.vals is None else req.vals[sel])
        return Delete(req.tree, req.keys[sel])

    def submit(self, requests, *, session: Session | None = None,
               count_ops: bool = True) -> list[Result]:
        """Plan and execute a mixed-op batch; one scheduler tick amortized
        over all writes; governor observed once. Returns per-request
        results in submission order (``Deferred`` for refused writes --
        over a sharded store, refusal is per shard, and a Deferred may
        carry a request narrowed to the keys that did not execute)."""
        t0 = time.perf_counter()
        requests = list(requests)
        plan = build_plan(requests,
                          router=getattr(self.store, "router", None))
        if plan.n_requests == 0:
            return []
        self.submits += 1
        if session is not None:
            session._begin_submit()
        results: list = [None] * plan.n_requests
        wrote = False
        wrote_bytes = 0          # ingested payload, drives the pacer
        # Per write-request bookkeeping: a sharded request spans one step
        # per shard, so acks/deferrals aggregate after all steps ran.
        w_req = {i: r for i, r in enumerate(requests)
                 if isinstance(r, (Put, Delete))}
        w_defer: dict[int, tuple[list, str]] = {}
        for step in plan.steps:
            if step.kind in ("put", "delete"):
                reason = self._refuse_write(step, session)
                if reason is not None:
                    if reason != "session-quota":
                        self.store.disk.stats.write_stalls += 1
                    if session is not None:
                        session.stats.deferred_keys += step.n_keys
                        session.stats.deferred_events += 1
                    sels = step.key_sel if step.key_sel is not None \
                        else [None] * len(step.requests)
                    for i, sel in zip(step.indices, sels):
                        w_defer.setdefault(i, ([], reason))[0].append(sel)
                    continue
                wrote = True
                wrote_bytes += step.n_keys * self._step_tree(step).entry_bytes
            self._execute_step(step, results, count_ops)
            if session is not None:
                session.stats.executed_keys += step.n_keys
        if session is not None:
            session.stats.submitted_keys += sum(s.n_keys for s in plan.steps)
        if wrote:
            tm = time.perf_counter()
            if self.pacer is not None:
                self.pacer.on_submit(wrote_bytes)
            else:
                self.store.scheduler.tick()
            self.stall.record((time.perf_counter() - tm) * 1e6)
        # Acks are built AFTER maintenance so their durability flag sees
        # the tick-end commit point: under group commit the records may
        # still be waiting for their group's fsync, and the ack says so.
        durable = self.store.wal.all_durable
        for i, r in w_req.items():
            d = w_defer.get(i)
            if d is None:
                results[i] = WriteAck(r.tree, len(r.keys), durable=durable)
                continue
            sels, reason = d
            if any(s is None for s in sels) \
                    or sum(len(s) for s in sels) == len(r.keys):
                results[i] = Deferred(r, reason)
            else:
                sel = np.sort(np.concatenate(sels))
                results[i] = Deferred(self._narrow(r, sel), reason)
        mem_plan = self.governor.observe(self)
        if mem_plan is not None:
            self._apply_plan(mem_plan)
        if self.stall_governor is not None:
            pace_plan = self.stall_governor.observe(self)
            if pace_plan is not None:
                self._apply_plan(pace_plan)
        self.latency.record((time.perf_counter() - t0) * 1e6,
                            n=plan.n_requests)
        return results

    def submit_all(self, requests, *, session: Session | None = None,
                   count_ops: bool = True, max_rounds: int = 8) -> list[Result]:
        """``submit`` + automatic retry of deferred requests until all
        complete (or no retry makes progress / ``max_rounds``; remaining
        ``Deferred`` results are then returned as-is). Results keep the
        original submission order.

        Engine-side deferrals (l0-stall, memory-pressure) are drained then
        resubmitted together; session-quota deferrals are resubmitted one
        request per submit (each gets a fresh admission window), so only a
        single request larger than the window itself stays deferred --
        and that terminates the loop rather than spinning."""
        requests = list(requests)
        results = self.submit(requests, session=session, count_ops=count_ops)

        def settle(i, out):
            # A retried Deferred may carry a request narrowed to the keys
            # that had not executed; once it completes, the ack must cover
            # the caller's ORIGINAL request, not just the remainder.
            if isinstance(out, WriteAck) and out.n != len(requests[i].keys):
                out = WriteAck(out.tree, len(requests[i].keys),
                               durable=out.durable)
            results[i] = out
            return not isinstance(out, Deferred)

        for _ in range(max_rounds):
            pending = [(i, r) for i, r in enumerate(results)
                       if isinstance(r, Deferred)]
            if not pending:
                break
            engine = [(i, r.request) for i, r in pending
                      if r.reason != "session-quota"]
            quota = [(i, r.request) for i, r in pending
                     if r.reason == "session-quota"]
            progressed = False
            if engine:
                self.drain()
                retry = self.submit([req for _, req in engine],
                                    session=session, count_ops=count_ops)
                for (i, _), out in zip(engine, retry):
                    progressed |= settle(i, out)
            for i, req in quota:
                out = self.submit([req], session=session,
                                  count_ops=count_ops)[0]
                progressed |= settle(i, out)
            if not progressed:
                break
        return results

    def submit_strict(self, requests, **kw) -> list[Result]:
        """``submit_all`` that raises instead of returning leftover
        ``Deferred`` results: for callers (benchmark drivers, bulk loads)
        where a write that never lands is a bug, not backpressure."""
        results = self.submit_all(requests, **kw)
        dropped = [r for r in results if isinstance(r, Deferred)]
        if dropped:
            reasons = sorted({d.reason for d in dropped})
            raise RuntimeError(
                f"{len(dropped)} request(s) still deferred after "
                f"drain+retry (reasons: {reasons}); writes would be lost. "
                f"Raise the admission limits (ServiceConfig / session "
                f"window) or submit smaller batches.")
        return results

    # -- governor actuation ---------------------------------------------------
    def _apply_plan(self, plan: MemoryPlan) -> None:
        s = self.store
        if plan.write_memory_bytes is not None \
                and plan.write_memory_bytes != s.write_memory_bytes:
            s.set_write_memory(plan.write_memory_bytes)
        if plan.flush_policy is not None \
                and plan.flush_policy != s.cfg.flush_policy:
            if plan.flush_policy not in POLICIES:
                raise ValueError(
                    f"governor proposed unknown flush policy "
                    f"{plan.flush_policy!r}; expected one of {POLICIES}")
            s.cfg.flush_policy = plan.flush_policy
        if plan.device_pool_bytes is not None \
                and s.device_pool is not None \
                and plan.device_pool_bytes != s.device_pool.budget_bytes:
            s.set_device_pool_bytes(plan.device_pool_bytes)
        if self.pacer is not None:
            # Live-pacer knobs only: StoreConfig keeps the configured
            # values, so recovery re-paces from configuration.
            if plan.pacer_interval_bytes is not None:
                self.pacer.interval_bytes = int(plan.pacer_interval_bytes)
            if plan.pacer_segment_budget is not None:
                self.pacer.segment_budget = int(plan.pacer_segment_budget)
        self.plans.append(plan)
        if len(self.plans) > 256:
            del self.plans[:-256]

    # -- convenience sugar (single-request fronts) ----------------------------
    def put(self, tree: str, keys, vals=None) -> Result:
        return self.submit([Put(tree, keys, vals)])[0]

    def get(self, tree: str, keys) -> GetResult:
        return self.submit([Get(tree, keys)])[0]
