"""Request planner: groups a mixed-op submit batch into vectorized steps.

``build_plan`` partitions the submitted requests into ``PlanStep``s keyed by
(tree, op-kind) -- and, over a sharded store, write steps further split per
(tree, shard, op-kind) through the router. Steps execute in order of each
group's *first appearance* in the request list; within a step, requests
keep submission order. One put/delete/get step dispatches as ONE batched
backend call (the per-request keys concatenated), so a plan step is
bit-identical to the equivalent direct ``LSMStore.write_batch`` /
``delete_batch`` / ``read_batch`` call on the concatenated keys; a scan
step dispatches as ONE ``scan_batch`` call (one logical op per range).

Sharded write splitting is what keeps backpressure *per shard*: admission
gates inspect the one (tree, shard) a step targets, so an L0 pile-up on the
hot shard defers only the keys routed there while the rest of the submit
proceeds. Read steps stay whole -- the sharded store scatters/gathers
internally -- because reads are never admission-gated.

The grouping defines the submit batch's intra-batch semantics: a Get
observes a Put from the same batch iff the Put's (tree, "put") group first
appears before the Get's (tree, "get") group. Callers needing strict
read-your-writes across kinds issue separate submits.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .requests import Request, Scan, request_kind


@dataclass
class PlanStep:
    """One vectorized execution unit: all same-kind requests for one tree
    (and, for write steps over a sharded store, one shard)."""

    tree: str
    kind: str                                  # put | delete | get | scan
    indices: list[int] = field(default_factory=list)   # submission positions
    requests: list[Request] = field(default_factory=list)
    shard: int | None = None                   # write steps on sharded stores
    # Per-request positions (into request.keys) routed to this step's
    # shard; None = the whole request belongs to this step (unsharded).
    key_sel: list[np.ndarray] | None = None

    def _sels(self):
        return self.key_sel if self.key_sel is not None \
            else [None] * len(self.requests)

    def _req_len(self, r, sel) -> int:
        if isinstance(r, Scan):
            return 1
        return len(r.keys) if sel is None else len(sel)

    @property
    def n_keys(self) -> int:
        return sum(self._req_len(r, sel)
                   for r, sel in zip(self.requests, self._sels()))

    def concat_keys(self) -> np.ndarray:
        return np.concatenate([r.keys if sel is None else r.keys[sel]
                               for r, sel in zip(self.requests, self._sels())])

    def concat_vals(self) -> np.ndarray:
        """Put payloads with the vals=None -> keys default applied."""
        out = []
        for r, sel in zip(self.requests, self._sels()):
            v = r.keys if r.vals is None else r.vals
            out.append(v if sel is None else v[sel])
        return np.concatenate(out)

    def slices(self):
        """(index, request, start, stop) views back into the concat arrays."""
        off = 0
        for i, r, sel in zip(self.indices, self.requests, self._sels()):
            n = self._req_len(r, sel)
            yield i, r, off, off + n
            off += n


@dataclass
class ExecutionPlan:
    steps: list[PlanStep]
    n_requests: int

    def describe(self) -> str:
        parts = [f"{s.kind}:{s.tree}"
                 + (f"#{s.shard}" if s.shard is not None else "")
                 + f"[{len(s.requests)}r/{s.n_keys}k]"
                 for s in self.steps]
        return " -> ".join(parts) if parts else "(empty)"


def build_plan(requests, *, router=None) -> ExecutionPlan:
    """Plan a submit batch. ``router`` (a ``ShardRouter``, from a sharded
    store) splits write steps per shard; reads and scans stay whole."""
    groups: dict[tuple, PlanStep] = {}
    n = 0
    for i, req in enumerate(requests):
        kind = request_kind(req)      # raises TypeError on foreign objects
        if router is not None and kind in ("put", "delete"):
            for si, sel in router.split(req.keys):
                key = (req.tree, kind, si)
                step = groups.get(key)
                if step is None:
                    step = groups[key] = PlanStep(
                        tree=req.tree, kind=kind, shard=si, key_sel=[])
                step.indices.append(i)
                step.requests.append(req)
                step.key_sel.append(sel)
        else:
            key = (req.tree, kind, None)
            step = groups.get(key)
            if step is None:
                step = groups[key] = PlanStep(tree=req.tree, kind=kind)
            step.indices.append(i)
            step.requests.append(req)
        n += 1
    return ExecutionPlan(steps=list(groups.values()), n_requests=n)
