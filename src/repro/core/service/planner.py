"""Request planner: groups a mixed-op submit batch into vectorized steps.

``build_plan`` partitions the submitted requests into ``PlanStep``s keyed by
(tree, op-kind). Steps execute in order of each group's *first appearance*
in the request list; within a step, requests keep submission order. One
put/delete/get step dispatches as ONE batched backend call (the per-request
keys concatenated), so a plan step is bit-identical to the equivalent
direct ``LSMStore.write_batch`` / ``delete_batch`` / ``read_batch`` call on
the concatenated keys; scan steps execute their requests sequentially
(scans are per-range operations).

The grouping defines the submit batch's intra-batch semantics: a Get
observes a Put from the same batch iff the Put's (tree, "put") group first
appears before the Get's (tree, "get") group. Callers needing strict
read-your-writes across kinds issue separate submits.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .requests import Request, Scan, request_kind


@dataclass
class PlanStep:
    """One vectorized execution unit: all same-kind requests for one tree."""

    tree: str
    kind: str                                  # put | delete | get | scan
    indices: list[int] = field(default_factory=list)   # submission positions
    requests: list[Request] = field(default_factory=list)

    @property
    def n_keys(self) -> int:
        return sum(1 if isinstance(r, Scan) else len(r.keys)
                   for r in self.requests)

    def concat_keys(self) -> np.ndarray:
        return np.concatenate([r.keys for r in self.requests])

    def concat_vals(self) -> np.ndarray:
        """Put payloads with the vals=None -> keys default applied."""
        return np.concatenate([r.keys if r.vals is None else r.vals
                               for r in self.requests])

    def slices(self):
        """(index, request, start, stop) views back into the concat arrays."""
        off = 0
        for i, r in zip(self.indices, self.requests):
            n = len(r.keys)
            yield i, r, off, off + n
            off += n


@dataclass
class ExecutionPlan:
    steps: list[PlanStep]
    n_requests: int

    def describe(self) -> str:
        parts = [f"{s.kind}:{s.tree}[{len(s.requests)}r/{s.n_keys}k]"
                 for s in self.steps]
        return " -> ".join(parts) if parts else "(empty)"


def build_plan(requests) -> ExecutionPlan:
    groups: dict[tuple[str, str], PlanStep] = {}
    n = 0
    for i, req in enumerate(requests):
        kind = request_kind(req)      # raises TypeError on foreign objects
        key = (req.tree, kind)
        step = groups.get(key)
        if step is None:
            step = groups[key] = PlanStep(tree=req.tree, kind=kind)
        step.indices.append(i)
        step.requests.append(req)
        n += 1
    return ExecutionPlan(steps=list(groups.values()), n_requests=n)
