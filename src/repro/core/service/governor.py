"""MemoryGovernor: memory adaptation as a first-class, pluggable policy.

The service owns exactly one governor and calls ``observe(service)`` once
per submit. The governor inspects whatever state it cares about (the
store's I/O stats, the ghost cache, the log) and returns a ``MemoryPlan``
describing any reallocation it decided on -- or ``None`` when it has
nothing to say. This unifies the two adaptation mechanisms the paper
scatters across layers:

  * the §5.4 memory tuner (write memory vs buffer cache boundary) --
    ``AdaptiveGovernor``, the default, wrapping the existing
    ``AdaptiveMemoryController`` unchanged in behavior;
  * the §4.2 flush-policy selection -- any governor may switch the store's
    flush policy through ``MemoryPlan.flush_policy``.

``StaticGovernor`` pins a fixed allocation (the baseline schemes). New
policies implement ``observe`` -- e.g. the serving runtime's
``repro.runtime.hbm_tuner.HBMGovernor`` drives the KV-pool / prefix-cache
HBM split through this same interface.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..tuner.tuner import AdaptiveMemoryController, TunerConfig


@dataclass(frozen=True)
class MemoryPlan:
    """One adaptation decision. ``None`` fields mean "leave unchanged"."""

    write_memory_bytes: int | None = None
    flush_policy: str | None = None
    # Byte budget of the device (HBM) page pool behind fused tier lookups;
    # actuated via MemoryArena.set_device_pool_bytes (0 disables the pool).
    device_pool_bytes: int | None = None
    # Pacing knobs (StallGovernor): actuated onto the live MaintenancePacer
    # only -- never written back to StoreConfig, so recovery re-paces from
    # the configured values, not the tuned ones.
    pacer_interval_bytes: int | None = None
    pacer_segment_budget: int | None = None
    note: str = ""


class MemoryGovernor:
    """Strategy interface: ``observe(service) -> MemoryPlan | None``.

    ``attach(store)`` is called once when the governor is handed to a
    service; governors needing per-cycle baselines snapshot them there.
    """

    def attach(self, store) -> None:
        pass

    def observe(self, service) -> MemoryPlan | None:
        raise NotImplementedError


class StaticGovernor(MemoryGovernor):
    """No adaptation: optionally pins an allocation/policy once at attach
    time, then never moves it (the static baseline schemes of §6)."""

    def __init__(self, *, write_memory_bytes: int | None = None,
                 flush_policy: str | None = None):
        self.write_memory_bytes = write_memory_bytes
        self.flush_policy = flush_policy
        self._pinned = False

    def observe(self, service) -> MemoryPlan | None:
        if self._pinned or (self.write_memory_bytes is None
                            and self.flush_policy is None):
            return None
        self._pinned = True
        return MemoryPlan(write_memory_bytes=self.write_memory_bytes,
                          flush_policy=self.flush_policy, note="static-pin")


class AdaptiveGovernor(MemoryGovernor):
    """The default governor: the §5.4 memory tuner, behavior-identical to
    driving ``AdaptiveMemoryController.maybe_tune()`` per batch by hand.

    The controller is built at ``attach`` (before any operations run), so
    its tuning cycle baselines match a hand-constructed controller; its
    records stay available at ``governor.controller.tuner.records``.
    """

    def __init__(self, cfg: TunerConfig | None = None):
        self.cfg = cfg or TunerConfig()
        self.controller: AdaptiveMemoryController | None = None

    def attach(self, store) -> None:
        self.controller = AdaptiveMemoryController(store, self.cfg)

    def observe(self, service) -> MemoryPlan | None:
        if self.controller is None:             # governor used store-less
            self.attach(service.store)
        rec = self.controller.maybe_tune()
        if rec is None or rec.x_next == rec.x:
            return None
        return MemoryPlan(write_memory_bytes=int(rec.x_next),
                          note=f"tuner:{rec.stopped or 'step'}")

    @property
    def records(self):
        return self.controller.tuner.records if self.controller else []


class DevicePoolGovernor(MemoryGovernor):
    """Adaptive sizing of the fused-read device page pool from its own
    hit/miss stream, through the standard ``MemoryPlan`` actuation.

    Every ``ops_cycle`` logical store operations it takes the pool's
    hit/miss deltas (tier- and store-level acquires combined): while
    residency keeps failing (cold pool or a budget too small for the
    working tiers) the budget doubles toward ``max_bytes``; when the
    fused path is serving and the clock holds fewer pages than half the
    capacity, the slack is returned (halved, floored at ``min_bytes``).
    Decisions are emitted, not self-actuated: ``StorageService
    ._apply_plan`` -> ``MemoryArena.set_device_pool_bytes`` is the single
    writer of the budget, same as the write-memory split.

    Two stabilizers keep the doubling/halving from oscillating on
    workloads that sit near the decision boundary:

      * deadband -- act only when the cycle's miss fraction leaves
        ``[0.5 - deadband, 0.5 + deadband]``; inside the band the budget
        holds (the raw ``d_miss > d_hit`` rule flapped on ~50/50 mixes);
      * min dwell -- a direction REVERSAL (grow->shrink or shrink->grow)
        needs ``min_dwell`` CONSECUTIVE cycles wanting the opposite
        direction, so neither one anomalous cycle nor a strictly
        alternating workload can bounce the budget back and forth. Held
        reversals are recorded with ``held=True``.
    """

    def __init__(self, *, min_bytes: int = 1 << 20,
                 max_bytes: int = 256 << 20, ops_cycle: int = 2048,
                 deadband: float = 0.15, min_dwell: int = 2):
        self.min_bytes = int(min_bytes)
        self.max_bytes = int(max_bytes)
        self.ops_cycle = int(ops_cycle)
        self.deadband = float(deadband)
        self.min_dwell = int(min_dwell)
        self._last_ops = 0
        self._last: dict | None = None
        self._dir = 0               # last actuated direction (+1/-1)
        self._rev = 0               # consecutive opposite-direction wants
        self.records: list = []

    def attach(self, store) -> None:
        self._last_ops = store.disk.stats.ops
        pool = store.device_pool
        self._last = dict(pool.stats()) if pool is not None else None

    def observe(self, service) -> MemoryPlan | None:
        store = service.store
        pool = store.device_pool
        if pool is None:
            return None
        ops = store.disk.stats.ops
        if ops - self._last_ops < self.ops_cycle:
            return None
        self._last_ops = ops
        st = pool.stats()
        prev = self._last or {k: 0 for k in st}
        self._last = dict(st)
        d_hit = (st["tier_hits"] - prev.get("tier_hits", 0)
                 + st["store_hits"] - prev.get("store_hits", 0))
        d_miss = (st["tier_misses"] - prev.get("tier_misses", 0)
                  + st["store_misses"] - prev.get("store_misses", 0))
        miss_frac = d_miss / (d_hit + d_miss) if d_hit + d_miss else 0.5
        budget = pool.budget_bytes
        if miss_frac > 0.5 + self.deadband:
            want, new = 1, min(self.max_bytes,
                               max(2 * budget, self.min_bytes))
        elif miss_frac < 0.5 - self.deadband \
                and st["resident_pages"] < st["capacity_pages"] // 2:
            want, new = -1, max(self.min_bytes, budget // 2)
        else:
            self._rev = 0           # in-band: the reversal streak breaks
            return None
        held = False
        if self._dir != 0 and want != self._dir:
            self._rev += 1
            held = self._rev < self.min_dwell
        else:
            self._rev = 0
        if not held and new != budget:
            self._dir, self._rev = want, 0
        rec = {"budget": budget, "budget_next": budget if held else new,
               "tier_hits": d_hit, "tier_misses": d_miss,
               "miss_frac": miss_frac, "held": held,
               "resident_pages": st["resident_pages"]}
        if held or new == budget:
            if held:
                self.records.append(rec)
            return None
        self.records.append(rec)
        return MemoryPlan(device_pool_bytes=new,
                          note=f"device-pool:{new}")


class StallGovernor(MemoryGovernor):
    """Auto-nudges the pacer's knobs from the observed stall tail
    (``StoreConfig.pacer_autotune``).

    Every ``ops_cycle`` logical store operations it takes a window of the
    service's maintenance-stall histogram and compares the window's exact
    ``max_value`` against ``target_stall_us``:

      * **over target** -- a pass stalled too long: halve the merge slice
        (``segment_budget``) toward 1; once slices are minimal, double
        ``interval_bytes`` so slices release less often;
      * **under target** -- headroom: undo in reverse order, halving the
        interval toward its floor first (paying debt down sooner), then
        doubling the slice back up.

    Decisions are emitted as ``MemoryPlan``s and actuated by the service
    onto the LIVE pacer only -- ``StoreConfig`` stays at its configured
    values, so a recovered service re-paces from configuration, never
    from a tuned transient. The deadband + min-dwell stabilizers are the
    ``DevicePoolGovernor`` idiom: hold inside
    ``target * [1 - deadband, 1 + deadband]``, and require ``min_dwell``
    consecutive cycles wanting a direction REVERSAL before acting on it
    (held reversals are recorded with ``held=True``).
    """

    def __init__(self, *, target_stall_us: float = 2_000.0,
                 ops_cycle: int = 1024, deadband: float = 0.25,
                 min_dwell: int = 2,
                 min_interval_bytes: int = 4 << 10,
                 max_interval_bytes: int = 4 << 20,
                 min_segment_budget: int = 1,
                 max_segment_budget: int = 64):
        self.target_stall_us = float(target_stall_us)
        self.ops_cycle = int(ops_cycle)
        self.deadband = float(deadband)
        self.min_dwell = int(min_dwell)
        self.min_interval_bytes = int(min_interval_bytes)
        self.max_interval_bytes = int(max_interval_bytes)
        self.min_segment_budget = int(min_segment_budget)
        self.max_segment_budget = int(max_segment_budget)
        self._snap = None           # stall-histogram snapshot (lazy: the
        self._last_ops = 0          # service exists only at observe time)
        self._dir = 0               # last actuated direction (+1 tighten)
        self._rev = 0               # consecutive opposite-direction wants
        self.records: list = []

    def observe(self, service) -> MemoryPlan | None:
        pacer = service.pacer
        if pacer is None:
            return None
        if self._snap is None:
            self._snap = service.stall.copy()
            self._last_ops = service.store.disk.stats.ops
            return None
        ops = service.store.disk.stats.ops
        if ops - self._last_ops < self.ops_cycle:
            return None
        self._last_ops = ops
        win = service.stall.delta(self._snap)
        self._snap = service.stall.copy()
        if win.count == 0:
            return None
        sig = win.max_value
        interval, budget = pacer.interval_bytes, pacer.segment_budget
        if sig > self.target_stall_us * (1.0 + self.deadband):
            want = 1
            if budget > self.min_segment_budget:
                new_i, new_b = interval, max(self.min_segment_budget,
                                             budget // 2)
            else:
                new_i, new_b = min(self.max_interval_bytes,
                                   interval * 2), budget
        elif sig < self.target_stall_us * (1.0 - self.deadband):
            want = -1
            if interval > self.min_interval_bytes:
                new_i, new_b = max(self.min_interval_bytes,
                                   interval // 2), budget
            else:
                new_i, new_b = interval, min(self.max_segment_budget,
                                             budget * 2)
        else:
            self._rev = 0           # in-band: the reversal streak breaks
            return None
        held = False
        if self._dir != 0 and want != self._dir:
            self._rev += 1
            held = self._rev < self.min_dwell
        else:
            self._rev = 0
        changed = (new_i, new_b) != (interval, budget)
        if not held and changed:
            self._dir, self._rev = want, 0
        rec = {"stall_max_us": sig, "window": win.count,
               "interval": interval, "budget": budget,
               "interval_next": interval if held else new_i,
               "budget_next": budget if held else new_b, "held": held}
        if held or not changed:
            if held:
                self.records.append(rec)
            return None
        self.records.append(rec)
        return MemoryPlan(pacer_interval_bytes=new_i,
                          pacer_segment_budget=new_b,
                          note=f"pacer:{'tighten' if want > 0 else 'relax'}")
