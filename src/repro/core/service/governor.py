"""MemoryGovernor: memory adaptation as a first-class, pluggable policy.

The service owns exactly one governor and calls ``observe(service)`` once
per submit. The governor inspects whatever state it cares about (the
store's I/O stats, the ghost cache, the log) and returns a ``MemoryPlan``
describing any reallocation it decided on -- or ``None`` when it has
nothing to say. This unifies the two adaptation mechanisms the paper
scatters across layers:

  * the §5.4 memory tuner (write memory vs buffer cache boundary) --
    ``AdaptiveGovernor``, the default, wrapping the existing
    ``AdaptiveMemoryController`` unchanged in behavior;
  * the §4.2 flush-policy selection -- any governor may switch the store's
    flush policy through ``MemoryPlan.flush_policy``.

``StaticGovernor`` pins a fixed allocation (the baseline schemes). New
policies implement ``observe`` -- e.g. the serving runtime's
``repro.runtime.hbm_tuner.HBMGovernor`` drives the KV-pool / prefix-cache
HBM split through this same interface.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..tuner.tuner import AdaptiveMemoryController, TunerConfig


@dataclass(frozen=True)
class MemoryPlan:
    """One adaptation decision. ``None`` fields mean "leave unchanged"."""

    write_memory_bytes: int | None = None
    flush_policy: str | None = None
    # Byte budget of the device (HBM) page pool behind fused tier lookups;
    # actuated via MemoryArena.set_device_pool_bytes (0 disables the pool).
    device_pool_bytes: int | None = None
    note: str = ""


class MemoryGovernor:
    """Strategy interface: ``observe(service) -> MemoryPlan | None``.

    ``attach(store)`` is called once when the governor is handed to a
    service; governors needing per-cycle baselines snapshot them there.
    """

    def attach(self, store) -> None:
        pass

    def observe(self, service) -> MemoryPlan | None:
        raise NotImplementedError


class StaticGovernor(MemoryGovernor):
    """No adaptation: optionally pins an allocation/policy once at attach
    time, then never moves it (the static baseline schemes of §6)."""

    def __init__(self, *, write_memory_bytes: int | None = None,
                 flush_policy: str | None = None):
        self.write_memory_bytes = write_memory_bytes
        self.flush_policy = flush_policy
        self._pinned = False

    def observe(self, service) -> MemoryPlan | None:
        if self._pinned or (self.write_memory_bytes is None
                            and self.flush_policy is None):
            return None
        self._pinned = True
        return MemoryPlan(write_memory_bytes=self.write_memory_bytes,
                          flush_policy=self.flush_policy, note="static-pin")


class AdaptiveGovernor(MemoryGovernor):
    """The default governor: the §5.4 memory tuner, behavior-identical to
    driving ``AdaptiveMemoryController.maybe_tune()`` per batch by hand.

    The controller is built at ``attach`` (before any operations run), so
    its tuning cycle baselines match a hand-constructed controller; its
    records stay available at ``governor.controller.tuner.records``.
    """

    def __init__(self, cfg: TunerConfig | None = None):
        self.cfg = cfg or TunerConfig()
        self.controller: AdaptiveMemoryController | None = None

    def attach(self, store) -> None:
        self.controller = AdaptiveMemoryController(store, self.cfg)

    def observe(self, service) -> MemoryPlan | None:
        if self.controller is None:             # governor used store-less
            self.attach(service.store)
        rec = self.controller.maybe_tune()
        if rec is None or rec.x_next == rec.x:
            return None
        return MemoryPlan(write_memory_bytes=int(rec.x_next),
                          note=f"tuner:{rec.stopped or 'step'}")

    @property
    def records(self):
        return self.controller.tuner.records if self.controller else []
