# The paper's primary contribution: adaptive memory management for
# LSM-based storage (partitioned memory components, flush policies, and the
# write-memory/buffer-cache memory tuner).
from .engine import ExecutionBackend, get_backend  # noqa: F401
from .lsm.storage import LSMStore, StoreConfig, TimeModel  # noqa: F401
from .lsm.tree import LSMTree  # noqa: F401
from .shard import ShardedStore, ShardRouter, StorageShard  # noqa: F401
from .service import (AdaptiveGovernor, Deferred, Delete, Get,  # noqa: F401
                      GetResult, MemoryGovernor, MemoryPlan, Put, Scan,
                      ScanResult, ServiceConfig, Session, StaticGovernor,
                      StorageService, WriteAck)
from .tuner.derivatives import TunerStats, cost_derivative  # noqa: F401
from .tuner.tuner import (AdaptiveMemoryController, MemoryTuner,  # noqa: F401
                          TunerConfig)
