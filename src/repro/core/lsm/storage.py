"""LSMStore: the adaptive memory-management architecture of §3.

One store = many LSM-trees (grouped into *datasets*: a primary tree plus its
secondary-index trees) sharing

  * a write-memory region ``x`` (shared pool, no per-component limits),
  * a buffer cache of ``total - x - sim`` bytes (clock replacement),
  * a transaction log (length-capped; log-triggered flushes),
  * a ghost cache of ``sim`` bytes feeding the memory tuner.

Flush policies (§4.2): ``mem`` (max-memory), ``lsn`` (min-LSN), ``opt``
(write-rate-proportional). Memory-management schemes (§6):
``partitioned`` (this paper), ``btree-dynamic``, ``btree-static``,
``btree-static-tuned``, ``accordion-index``, ``accordion-data``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..engine import available_backends, get_backend
from ..engine.scheduler import MaintenanceScheduler
from .arena import MemoryArena
from .baselines import AccordionMemComponent, BTreeMemComponent
from .memtable import PartitionedMemComponent
from .sstable import TOMBSTONE
from .tree import LSMTree

_INF = 2**62

SCHEMES = ("partitioned", "btree-dynamic", "btree-static",
           "btree-static-tuned", "accordion-index", "accordion-data")
POLICIES = ("mem", "lsn", "opt")
STORAGE_MEDIA = ("memory", "files")
FSYNC_POLICIES = ("per_record", "per_batch", "group")


@dataclass
class TimeModel:
    """Throughput proxy: simulated wall time from I/O bytes + CPU work.

    Bandwidths follow the paper's testbed (NVMe: 250 MB/s write, 500 MB/s
    read). CPU constants are calibrated so that the *relative* overheads
    match the paper's measurements (e.g. Fig. 8's 20-40% in-memory overhead
    of Partitioned vs B+-dynamic at ~11x memory write amplification).
    """

    write_bw: float = 250e6
    read_bw: float = 500e6
    cpu_insert_btree: float = 0.80e-6     # dict/B+-tree point insert
    cpu_insert_append: float = 0.30e-6    # append to the active SSTable
    cpu_seal_sort: float = 0.15e-6        # per entry, sort at seal
    cpu_merge_mem: float = 0.10e-6        # per entry per memory-merge pass
    cpu_merge_disk: float = 0.05e-6       # per entry per disk-merge pass
    cpu_lookup: float = 1.00e-6           # per point lookup / scan seek

    def elapsed(self, stats, *, scheme: str) -> tuple[float, float]:
        page = 16 * 1024
        io = ((stats.pages_flushed + stats.pages_merge_written) * page
              / self.write_bw
              + (stats.pages_merge_read + stats.pages_query_read) * page
              / self.read_bw)
        if scheme.startswith("partitioned"):
            ins = stats.entries_written * self.cpu_insert_append \
                + stats.entries_written * self.cpu_seal_sort
        else:
            ins = stats.entries_written * self.cpu_insert_btree
        cpu = (ins + stats.entries_merged_mem * self.cpu_merge_mem
               + stats.entries_merged_disk * self.cpu_merge_disk)
        return io, cpu


@dataclass
class StoreConfig:
    total_memory_bytes: int = 512 << 20
    write_memory_bytes: int = 128 << 20        # the tunable x
    sim_cache_bytes: int = 16 << 20
    page_bytes: int = 16 << 10
    entry_bytes: int = 1024
    size_ratio: int = 10
    active_sstable_bytes: int = 1 << 20        # scaled-down 32MB
    sstable_bytes: int = 2 << 20               # disk SSTable partition target
    max_log_bytes: int = 256 << 20
    # Force a durable checkpoint whenever the WAL head has advanced this
    # many bytes past the last checkpoint's watermark, bounding the replay
    # tail (and therefore recovery time) independently of flush activity.
    # None = checkpoint only when log truncation requires one (the min-LSN
    # watermark passing the last checkpoint).
    checkpoint_interval_bytes: int | None = None
    mem_flush_threshold: float = 0.95
    scheme: str = "partitioned"
    flush_policy: str = "opt"                  # mem | lsn | opt
    max_active_datasets: int = 8               # D for the static schemes
    beta: float = 0.5                          # §4.1.4 partial-vs-full
    l0_target_groups: int = 2
    l0_max_groups: int = 4
    l0_greedy: bool = True
    l0_grouped: bool = True
    dynamic_levels: bool = True
    static_num_levels: int | None = None
    forced_flush_kind: str | None = None       # for the Fig. 9 ablation
    accordion_pipeline: int = 4
    # Execution backend for merges/Bloom/batched lookups ("numpy" |
    # "pallas"); None defers to the REPRO_LSM_BACKEND env var, then "numpy".
    backend: str | None = None
    # Device (HBM) page-pool budget for the fused read hot path; 0 keeps
    # the pool disabled and every lookup on the staged per-SSTable path.
    # Governors resize it at runtime via MemoryPlan.device_pool_bytes.
    device_pool_bytes: int = 0
    # Fused-read launch scope once the pool holds a tier resident:
    # "store" collapses the whole lookup (every tier) into ONE device
    # launch per batch, falling back per-tier then staged; "tier" keeps
    # the PR-6 one-launch-per-tier pipeline. Results, page pins and
    # IOStats are bit-identical across all three paths.
    fused_scope: str = "store"
    # Max discretionary maintenance units per scheduler tick (None = drain
    # all merge debt every tick). Mandatory memory/log enforcement is never
    # budgeted.
    merge_budget: int | None = None
    # Paced maintenance (engine/pacer.py): with an interval set, the
    # service replaces the per-submit stop-the-world tick with a paced
    # schedule -- mandatory segments every submit, merges released in
    # bounded slices of ``pacer_segment_budget`` steps, one slice per
    # ``pacer_interval_bytes`` of ingested payload. None = pacing off.
    pacer_interval_bytes: int | None = None
    pacer_segment_budget: int = 8
    # Paced partial-flush slices: with a threshold set, every "mem"
    # segment releases at most ONE extra partial flush once shared write
    # memory crosses threshold * write_memory_bytes -- BELOW the hard
    # mem_flush_threshold -- so a paced schedule drains memory in bounded
    # chunks instead of a burst of flushes at the hard bound. The decision
    # reads only store state + config (never pacer state), so segments
    # stay replay-deterministic. None = off (bit-identical to before).
    pacer_flush_threshold: float | None = None
    # StallGovernor (core/service/governor.py): auto-nudge the pacer's
    # interval/budget knobs from the observed stall histogram (deadband +
    # dwell). Requires pacing to be on.
    pacer_autotune: bool = False
    # Background maintenance workers (engine/workers.py): threads running
    # the compute-heavy, side-effect-free part of merge slices (run
    # sort/dedup, Bloom builds) speculatively off the foreground path.
    # All side effects still commit inline at the logged segment
    # boundaries, so store state is bit-identical for ANY worker count;
    # 0 (default) creates no threads at all.
    maintenance_workers: int = 0
    # Physical storage plane (core/storage_io): "memory" keeps the WAL /
    # SSTables as byte-accounted RAM buffers (every existing trajectory
    # bit-identical); "files" backs them with real files under
    # storage_dir -- segmented WAL, one file per SSTable, manifest frame
    # log -- with process-kill crash safety.
    storage_medium: str = "memory"
    storage_dir: str | None = None
    # Commit durability policy on the files medium: "per_record" fsyncs
    # every WAL append, "per_batch" fsyncs at every commit point (store
    # batch / scheduler tick), "group" batches concurrent commits until
    # group_commit_bytes of frames are pending or the oldest commit has
    # waited group_commit_max_wait_s (leader-follower: one fsync serves
    # the whole queue). Ignored (no fsyncs at all) on the memory medium.
    fsync_policy: str = "per_batch"
    wal_segment_bytes: int = 1 << 20
    group_commit_bytes: int = 64 << 10
    group_commit_max_wait_s: float = 1e-3
    # Async group commit (files medium, fsync_policy="group" only): a
    # durability worker thread owns the physical write+fsync, the leader
    # hands the pending frames off and keeps buffering the next commit
    # group in userspace. Acks still flip durable only on a COMPLETED
    # fsync (WriteAck.durable / sync() semantics unchanged); the worker
    # additionally honors group_commit_max_wait_s on its own timer, so a
    # queued commit's durability no longer waits for the next foreground
    # commit call to notice its age.
    wal_async_fsync: bool = False
    time_model: TimeModel = field(default_factory=TimeModel)

    def validate(self):
        # ValueErrors, not asserts: config mistakes must fail loudly even
        # under ``python -O``, with a message saying how to fix them.
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}; "
                             f"expected one of {SCHEMES}")
        if self.flush_policy not in POLICIES:
            raise ValueError(f"unknown flush_policy {self.flush_policy!r}; "
                             f"expected one of {POLICIES}")
        if self.backend is not None \
                and self.backend not in available_backends():
            raise ValueError(
                f"unknown backend {self.backend!r}; registered backends: "
                f"{sorted(available_backends())} (or leave None to use "
                f"the REPRO_LSM_BACKEND env var)")
        if self.entry_bytes <= 0:
            raise ValueError(f"entry_bytes must be positive, got "
                             f"{self.entry_bytes}")
        if self.device_pool_bytes < 0:
            raise ValueError(
                f"device_pool_bytes must be >= 0 (0 disables the device "
                f"page pool), got {self.device_pool_bytes}")
        if self.fused_scope not in ("store", "tier"):
            raise ValueError(
                f"fused_scope must be 'store' (one launch per lookup "
                f"batch) or 'tier' (one per tier), got "
                f"{self.fused_scope!r}")
        if self.merge_budget is not None and self.merge_budget < 0:
            raise ValueError(
                f"merge_budget must be >= 0 (or None to drain all debt "
                f"every tick), got {self.merge_budget}")
        if self.max_log_bytes <= 0:
            raise ValueError(
                f"max_log_bytes must be positive (the transaction-log cap "
                f"that triggers min-LSN flushes), got {self.max_log_bytes}")
        if self.checkpoint_interval_bytes is not None \
                and self.checkpoint_interval_bytes <= 0:
            raise ValueError(
                f"checkpoint_interval_bytes must be positive (or None to "
                f"checkpoint only when log truncation requires it), got "
                f"{self.checkpoint_interval_bytes}")
        if self.pacer_interval_bytes is not None \
                and self.pacer_interval_bytes <= 0:
            raise ValueError(
                f"pacer_interval_bytes must be positive (or None to run "
                f"stop-the-world ticks instead of paced maintenance), got "
                f"{self.pacer_interval_bytes}")
        if self.pacer_segment_budget <= 0:
            raise ValueError(
                f"pacer_segment_budget must be positive (merge steps per "
                f"paced slice), got {self.pacer_segment_budget}")
        if self.pacer_flush_threshold is not None \
                and not 0.0 < self.pacer_flush_threshold < 1.0:
            raise ValueError(
                f"pacer_flush_threshold must be in (0, 1) -- the fraction "
                f"of write memory at which paced partial-flush slices "
                f"start, below mem_flush_threshold -- or None to disable "
                f"flush slices, got {self.pacer_flush_threshold}")
        if self.pacer_autotune and self.pacer_interval_bytes is None:
            raise ValueError(
                f"pacer_autotune requires paced maintenance: set "
                f"pacer_interval_bytes (got pacer_interval_bytes="
                f"{self.pacer_interval_bytes})")
        if self.maintenance_workers < 0:
            raise ValueError(
                f"maintenance_workers must be >= 0 (0 runs all maintenance "
                f"inline), got {self.maintenance_workers}")
        if self.wal_async_fsync and self.fsync_policy != "group":
            raise ValueError(
                f"wal_async_fsync requires fsync_policy='group' (the "
                f"durability worker batches group commits), got "
                f"fsync_policy={self.fsync_policy!r}")
        if self.storage_medium not in STORAGE_MEDIA:
            raise ValueError(
                f"unknown storage_medium {self.storage_medium!r}; "
                f"expected one of {STORAGE_MEDIA}")
        if self.storage_medium == "files" and not self.storage_dir:
            raise ValueError(
                f"storage_dir must name a directory when storage_medium="
                f"'files', got {self.storage_dir!r}")
        if self.fsync_policy not in FSYNC_POLICIES:
            raise ValueError(
                f"unknown fsync_policy {self.fsync_policy!r}; expected "
                f"one of {FSYNC_POLICIES}")
        if self.wal_segment_bytes <= 0:
            raise ValueError(
                f"wal_segment_bytes must be positive (the fixed WAL "
                f"segment-file size), got {self.wal_segment_bytes}")
        if self.group_commit_bytes <= 0:
            raise ValueError(
                f"group_commit_bytes must be positive (pending WAL bytes "
                f"that trigger a group fsync), got "
                f"{self.group_commit_bytes}")
        if self.group_commit_max_wait_s <= 0:
            raise ValueError(
                f"group_commit_max_wait_s must be positive (max age of a "
                f"queued commit before the group fsyncs), got "
                f"{self.group_commit_max_wait_s}")
        if self.write_memory_bytes + self.sim_cache_bytes \
                > self.total_memory_bytes:
            raise ValueError(
                f"write_memory_bytes ({self.write_memory_bytes}) + "
                f"sim_cache_bytes ({self.sim_cache_bytes}) exceed "
                f"total_memory_bytes ({self.total_memory_bytes}); shrink "
                f"the write memory or simulated cache")
        return self


class LSMStore:
    def __init__(self, cfg: StoreConfig, *, arena: MemoryArena | None = None):
        """``arena=None`` (standalone store) builds a private memory pool;
        a ``ShardedStore`` passes ONE shared arena to every shard so all
        shards compete for the same write memory, buffer cache and log."""
        self.cfg = cfg.validate()
        self.backend = get_backend(cfg.backend)
        self.arena = arena if arena is not None else MemoryArena(cfg)
        self.shard_id = self.arena.register(self)
        self.ghost = self.arena.ghost
        self.cache = self.arena.cache
        self.disk = self.arena.disk
        self.trees: dict[str, LSMTree] = {}
        self.datasets: dict[str, list[str]] = {}
        self.tree_dataset: dict[str, str] = {}
        # per-tree write-rate windows for the OPT policy (§4.2)
        self._rate_win: dict[str, deque] = {}
        # LRU order of active datasets for the static schemes; evicted
        # datasets queue here and are flushed by the scheduler tick
        self._active_ds: list[str] = []
        self._pending_evict: list[str] = []
        self._share_ewma: dict[str, float] = {}
        # Sole owner of flush/merge work: the write path appends and ticks.
        self.scheduler = MaintenanceScheduler(
            self, merge_budget=cfg.merge_budget)

    # -- schema ------------------------------------------------------------------
    def create_tree(self, name: str, *, dataset: str | None = None,
                    entry_bytes: int | None = None) -> LSMTree:
        cfg = self.cfg
        e = entry_bytes or cfg.entry_bytes
        if cfg.scheme == "partitioned":
            mem = PartitionedMemComponent(
                entry_bytes=e, page_bytes=cfg.page_bytes,
                active_bytes_max=cfg.active_sstable_bytes,
                size_ratio=cfg.size_ratio, backend=self.backend)
        elif cfg.scheme.startswith("btree"):
            mem = BTreeMemComponent(entry_bytes=e, backend=self.backend)
        else:
            mem = AccordionMemComponent(
                entry_bytes=e, active_bytes_max=cfg.active_sstable_bytes,
                merge_data=cfg.scheme == "accordion-data",
                pipeline_threshold=cfg.accordion_pipeline,
                backend=self.backend)
        tree = LSMTree(
            name, disk=self.disk, entry_bytes=e, mem_component=mem,
            sstable_bytes=cfg.sstable_bytes, size_ratio=cfg.size_ratio,
            l0_max_groups=cfg.l0_max_groups,
            l0_target_groups=cfg.l0_target_groups,
            l0_greedy=cfg.l0_greedy, l0_grouped=cfg.l0_grouped,
            dynamic_levels=cfg.dynamic_levels,
            static_num_levels=cfg.static_num_levels,
            backend=self.backend, fused_scope=cfg.fused_scope,
            manifest=self.arena.manifest, shard_id=self.shard_id,
            workers=self.arena.workers)
        self.trees[name] = tree
        # Schema record: one TreeCreate per logical tree (the WAL dedups
        # the per-shard creates of a sharded store).
        self.arena.wal.append_tree_create(name, dataset=dataset,
                                          entry_bytes=entry_bytes)
        ds = dataset or name
        self.datasets.setdefault(ds, []).append(name)
        self.tree_dataset[name] = ds
        self._rate_win[name] = deque()
        self._share_ewma[name] = 0.0
        return tree

    # -- memory accounting ----------------------------------------------------------
    def write_memory_used(self) -> int:
        return sum(t.mem_bytes for t in self.trees.values())

    def min_lsn(self) -> int:
        return min((t.min_lsn for t in self.trees.values()), default=_INF)

    @property
    def write_memory_bytes(self) -> int:
        """The tunable ``x``: lives in the (possibly shared) arena."""
        return self.arena.write_memory_bytes

    @property
    def log_pos(self) -> int:
        """Transaction-log byte offset (shared across a sharded store)."""
        return self.arena.log_pos

    @log_pos.setter
    def log_pos(self, v: int) -> None:
        self.arena.log_pos = v

    @property
    def log_length(self) -> int:
        m = self.min_lsn()
        return self.log_pos - (m if m < _INF else self.log_pos)

    def set_write_memory(self, x: int) -> None:
        """Apply a new write-memory size (tuner's actuator)."""
        self.arena.set_write_memory(x)

    @property
    def device_pool(self):
        """The (possibly shared) HBM page pool behind fused reads."""
        return self.arena.device_pool

    def set_device_pool_bytes(self, budget_bytes: int) -> None:
        """Resize the device page pool (governor's fused-read actuator)."""
        self.arena.set_device_pool_bytes(budget_bytes)

    # -- durability plane -------------------------------------------------------
    @property
    def wal(self):
        """The (possibly shared) typed write-ahead log."""
        return self.arena.wal

    @property
    def manifest(self):
        """The (possibly shared) versioned manifest."""
        return self.arena.manifest

    def checkpoint(self):
        """Force a durable checkpoint now and truncate the WAL below the
        global min-LSN. The scheduler also checkpoints automatically when
        truncation or ``checkpoint_interval_bytes`` requires one."""
        from ..durability.checkpoint import checkpoint_now
        return checkpoint_now(self.arena, self.scheduler)

    # -- write path ------------------------------------------------------------------
    def _ingest(self, tree_name: str, keys, vals, *, op: bool,
                tick: bool, delete: bool = False) -> None:
        tree = self.trees[tree_name]
        # Write-ahead: the batch is logged (assigning lsn0 = the current
        # log position and advancing the head by the payload bytes) before
        # it touches the memory component. During crash-recovery replay
        # the same call hands back the record's original LSN instead.
        lsn0 = self.arena.wal.append_batch(
            tree_name, keys, None if delete else vals,
            entry_bytes=tree.entry_bytes, op=op, delete=delete)
        tree.write_batch(keys, vals, lsn0)
        nbytes = len(keys) * tree.entry_bytes
        self.disk.stats.entries_written += len(keys)
        if op:
            self.disk.stats.ops += len(keys)
        win = self._rate_win[tree_name]
        win.append((lsn0, nbytes))
        self._trim_rate_windows()
        self._dataset_touch(tree_name)
        if tick:
            self.scheduler.tick()

    def write_batch(self, tree_name: str, keys, vals=None, *, op: bool = True,
                    tick: bool = True) -> None:
        """Batched writes: one logical op per key, ingested through the
        tree's execution backend (vectorized sort+dedup), then one
        maintenance-scheduler tick. No flush or merge runs inline here.

        ``tick=False`` defers all maintenance; callers then drive
        ``self.scheduler.tick()`` explicitly (differential tests, drivers
        that amortize one tick over several batches).
        """
        keys = np.asarray(keys, np.int64)
        if vals is None:
            vals = keys  # payload checksum defaults to the key
        vals = np.asarray(vals, np.int64)
        # the tombstone payload is reserved for delete_batch -- accepting
        # it here would make a legitimate write behave as a silent delete
        if (vals == TOMBSTONE).any():
            raise ValueError(
                f"payload {TOMBSTONE} is reserved for deletes; "
                f"use delete_batch")
        self._ingest(tree_name, keys, vals, op=op, tick=tick)
        # Commit point: the batch is durable when this returns (under the
        # configured fsync policy). With tick=True the scheduler already
        # committed; this is then a no-op.
        self.arena.wal.commit(len(keys))

    def write(self, tree_name: str, keys, vals=None, *, op: bool = True) -> None:
        """Legacy entry point: a write_batch counted as ONE logical op per
        call (scalar semantics), whatever the array length."""
        self.write_batch(tree_name, keys, vals, op=False)
        if op:
            self.disk.stats.ops += 1

    def delete_batch(self, tree_name: str, keys, *, op: bool = True,
                     tick: bool = True) -> None:
        """Batched deletes: tombstone writes (newest-wins reconciliation
        shadows older versions; reads and scans filter them)."""
        keys = np.asarray(keys, np.int64)
        self._ingest(tree_name, keys,
                     np.full(len(keys), TOMBSTONE, np.int64),
                     op=op, tick=tick, delete=True)
        self.arena.wal.commit(len(keys))    # commit point (see write_batch)

    def note_ops(self, n: int = 1) -> None:
        self.disk.stats.ops += n

    def _trim_rate_windows(self):
        lo = self.log_pos - self.cfg.max_log_bytes
        for win in self._rate_win.values():
            while win and win[0][0] < lo:
                win.popleft()

    # -- dataset activation (static schemes, §2.2) --------------------------------------
    def _dataset_touch(self, tree_name: str) -> None:
        if not self.cfg.scheme.startswith("btree-static"):
            return
        ds = self.tree_dataset[tree_name]
        if ds in self._pending_evict:
            # re-activated before the tick flushed it: never flush an
            # active dataset
            self._pending_evict.remove(ds)
        if ds in self._active_ds:
            self._active_ds.remove(ds)
            self._active_ds.append(ds)
            return
        D = self.cfg.max_active_datasets
        if len(self._active_ds) >= D:
            # evict LRU dataset: the scheduler tick flushes it (nothing
            # flushes inline in the write path, even under tick=False)
            self._pending_evict.append(self._active_ds.pop(0))
        self._active_ds.append(ds)

    # -- flush bookkeeping (read by the scheduler) --------------------------------------
    def _pre_flush_sample(self, tree: LSMTree) -> None:
        e = self._share_ewma[tree.name]
        self._share_ewma[tree.name] = 0.7 * e + 0.3 * tree.mem_bytes

    def _tree_share(self, tree: LSMTree) -> float:
        return max(self._share_ewma[tree.name], tree.mem_bytes,
                   self.cfg.active_sstable_bytes)

    def _pick_flush_tree(self) -> LSMTree | None:
        """§4.2 flush policies (delegates to the scheduler's ranking)."""
        return self.scheduler.pick_flush_tree()

    # -- reads -----------------------------------------------------------------------
    def lookup(self, tree_name: str, key: int, *, op: bool = True):
        if op:
            self.disk.stats.ops += 1
        return self.trees[tree_name].lookup(int(key))

    def read_batch(self, tree_name: str, keys, *, op: bool = True):
        """Batched point lookups: one logical op per key, probes vectorized
        end-to-end through the tree's execution backend.

        Returns (found bool[n], vals int64[n]).
        """
        keys = np.asarray(keys, np.int64)
        if op:
            self.disk.stats.ops += len(keys)
        return self.trees[tree_name].lookup_batch(keys)

    def scan(self, tree_name: str, lo: int, n: int, *, op: bool = True):
        if op:
            self.disk.stats.ops += 1
        return self.trees[tree_name].scan(int(lo), int(n))

    def scan_batch(self, tree_name: str, los, ns, *, op: bool = True):
        """Batched range scans: ONE op per range (the same contract as a
        loop of scalar ``scan`` calls), executed with a vectorized seek
        through the tree. Returns live-entry counts int64[n]."""
        los = np.asarray(los, np.int64)
        ns = np.asarray(ns, np.int64)
        if op:
            self.disk.stats.ops += len(los)
        return self.trees[tree_name].scan_batch(los, ns)

    # -- reporting ----------------------------------------------------------------------
    def sync_mem_stats(self) -> None:
        """Mirror per-component memory-merge work into the global counters
        (CPU cost of §4.1 memory merges — Fig. 8's overhead)."""
        self.disk.stats.entries_merged_mem = sum(
            t.mem.stats.entries_merged for t in self.trees.values()
            if hasattr(t.mem, "stats"))

    def elapsed(self):
        return self.cfg.time_model.elapsed(self.disk.stats,
                                           scheme=self.cfg.scheme)

    def throughput(self, prev_stats=None) -> float:
        stats = self.disk.stats if prev_stats is None \
            else self.disk.stats.delta(prev_stats)
        io, cpu = self.cfg.time_model.elapsed(stats, scheme=self.cfg.scheme)
        t = max(io, cpu, 1e-9)
        return stats.ops / t
