"""LSMStore: the adaptive memory-management architecture of §3.

One store = many LSM-trees (grouped into *datasets*: a primary tree plus its
secondary-index trees) sharing

  * a write-memory region ``x`` (shared pool, no per-component limits),
  * a buffer cache of ``total - x - sim`` bytes (clock replacement),
  * a transaction log (length-capped; log-triggered flushes),
  * a ghost cache of ``sim`` bytes feeding the memory tuner.

Flush policies (§4.2): ``mem`` (max-memory), ``lsn`` (min-LSN), ``opt``
(write-rate-proportional). Memory-management schemes (§6):
``partitioned`` (this paper), ``btree-dynamic``, ``btree-static``,
``btree-static-tuned``, ``accordion-index``, ``accordion-data``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..engine import available_backends, get_backend
from ..tuner.simcache import GhostCache
from .baselines import AccordionMemComponent, BTreeMemComponent
from .cache import ClockCache, Disk
from .memtable import PartitionedMemComponent
from .tree import LSMTree

_INF = 2**62

SCHEMES = ("partitioned", "btree-dynamic", "btree-static",
           "btree-static-tuned", "accordion-index", "accordion-data")
POLICIES = ("mem", "lsn", "opt")


@dataclass
class TimeModel:
    """Throughput proxy: simulated wall time from I/O bytes + CPU work.

    Bandwidths follow the paper's testbed (NVMe: 250 MB/s write, 500 MB/s
    read). CPU constants are calibrated so that the *relative* overheads
    match the paper's measurements (e.g. Fig. 8's 20-40% in-memory overhead
    of Partitioned vs B+-dynamic at ~11x memory write amplification).
    """

    write_bw: float = 250e6
    read_bw: float = 500e6
    cpu_insert_btree: float = 0.80e-6     # dict/B+-tree point insert
    cpu_insert_append: float = 0.30e-6    # append to the active SSTable
    cpu_seal_sort: float = 0.15e-6        # per entry, sort at seal
    cpu_merge_mem: float = 0.10e-6        # per entry per memory-merge pass
    cpu_merge_disk: float = 0.05e-6       # per entry per disk-merge pass
    cpu_lookup: float = 1.00e-6           # per point lookup / scan seek

    def elapsed(self, stats, *, scheme: str) -> tuple:
        page = 16 * 1024
        io = ((stats.pages_flushed + stats.pages_merge_written) * page
              / self.write_bw
              + (stats.pages_merge_read + stats.pages_query_read) * page
              / self.read_bw)
        if scheme.startswith("partitioned"):
            ins = stats.entries_written * self.cpu_insert_append \
                + stats.entries_written * self.cpu_seal_sort
        else:
            ins = stats.entries_written * self.cpu_insert_btree
        cpu = (ins + stats.entries_merged_mem * self.cpu_merge_mem
               + stats.entries_merged_disk * self.cpu_merge_disk)
        return io, cpu


@dataclass
class StoreConfig:
    total_memory_bytes: int = 512 << 20
    write_memory_bytes: int = 128 << 20        # the tunable x
    sim_cache_bytes: int = 16 << 20
    page_bytes: int = 16 << 10
    entry_bytes: int = 1024
    size_ratio: int = 10
    active_sstable_bytes: int = 1 << 20        # scaled-down 32MB
    sstable_bytes: int = 2 << 20               # disk SSTable partition target
    max_log_bytes: int = 256 << 20
    mem_flush_threshold: float = 0.95
    scheme: str = "partitioned"
    flush_policy: str = "opt"                  # mem | lsn | opt
    max_active_datasets: int = 8               # D for the static schemes
    beta: float = 0.5                          # §4.1.4 partial-vs-full
    l0_target_groups: int = 2
    l0_max_groups: int = 4
    l0_greedy: bool = True
    l0_grouped: bool = True
    dynamic_levels: bool = True
    static_num_levels: int | None = None
    forced_flush_kind: str | None = None       # for the Fig. 9 ablation
    accordion_pipeline: int = 4
    # Execution backend for merges/Bloom/batched lookups ("numpy" |
    # "pallas"); None defers to the REPRO_LSM_BACKEND env var, then "numpy".
    backend: str | None = None
    time_model: TimeModel = field(default_factory=TimeModel)

    def validate(self):
        assert self.scheme in SCHEMES, self.scheme
        assert self.flush_policy in POLICIES, self.flush_policy
        assert self.backend is None or self.backend in available_backends(), \
            self.backend
        assert self.write_memory_bytes + self.sim_cache_bytes \
            <= self.total_memory_bytes
        return self


class LSMStore:
    def __init__(self, cfg: StoreConfig):
        self.cfg = cfg.validate()
        self.backend = get_backend(cfg.backend)
        self.ghost = GhostCache(cfg.sim_cache_bytes // cfg.page_bytes)
        cache_pages = max(
            0, (cfg.total_memory_bytes - cfg.write_memory_bytes
                - cfg.sim_cache_bytes) // cfg.page_bytes)
        self.cache = ClockCache(cache_pages, on_evict=self.ghost.add_evicted)
        self.disk = Disk(cfg.page_bytes, self.cache, self.ghost)
        self.trees: dict[str, LSMTree] = {}
        self.datasets: dict[str, list[str]] = {}
        self.tree_dataset: dict[str, str] = {}
        self.write_memory_bytes = cfg.write_memory_bytes
        # transaction log
        self.log_pos = 0                        # byte offset
        # per-tree write-rate windows for the OPT policy (§4.2)
        self._rate_win: dict[str, deque] = {}
        # LRU order of active datasets for the static schemes
        self._active_ds: list[str] = []
        self._share_ewma: dict[str, float] = {}

    # -- schema ------------------------------------------------------------------
    def create_tree(self, name: str, *, dataset: str | None = None,
                    entry_bytes: int | None = None) -> LSMTree:
        cfg = self.cfg
        e = entry_bytes or cfg.entry_bytes
        if cfg.scheme == "partitioned":
            mem = PartitionedMemComponent(
                entry_bytes=e, page_bytes=cfg.page_bytes,
                active_bytes_max=cfg.active_sstable_bytes,
                size_ratio=cfg.size_ratio, backend=self.backend)
        elif cfg.scheme.startswith("btree"):
            mem = BTreeMemComponent(entry_bytes=e, backend=self.backend)
        else:
            mem = AccordionMemComponent(
                entry_bytes=e, active_bytes_max=cfg.active_sstable_bytes,
                merge_data=cfg.scheme == "accordion-data",
                pipeline_threshold=cfg.accordion_pipeline,
                backend=self.backend)
        tree = LSMTree(
            name, disk=self.disk, entry_bytes=e, mem_component=mem,
            sstable_bytes=cfg.sstable_bytes, size_ratio=cfg.size_ratio,
            l0_max_groups=cfg.l0_max_groups,
            l0_target_groups=cfg.l0_target_groups,
            l0_greedy=cfg.l0_greedy, l0_grouped=cfg.l0_grouped,
            dynamic_levels=cfg.dynamic_levels,
            static_num_levels=cfg.static_num_levels,
            backend=self.backend)
        self.trees[name] = tree
        ds = dataset or name
        self.datasets.setdefault(ds, []).append(name)
        self.tree_dataset[name] = ds
        self._rate_win[name] = deque()
        self._share_ewma[name] = 0.0
        return tree

    # -- memory accounting ----------------------------------------------------------
    def write_memory_used(self) -> int:
        return sum(t.mem_bytes for t in self.trees.values())

    def min_lsn(self) -> int:
        return min((t.min_lsn for t in self.trees.values()), default=_INF)

    @property
    def log_length(self) -> int:
        m = self.min_lsn()
        return self.log_pos - (m if m < _INF else self.log_pos)

    def set_write_memory(self, x: int) -> None:
        """Apply a new write-memory size (tuner's actuator)."""
        cfg = self.cfg
        x = int(min(max(x, 1 << 20), cfg.total_memory_bytes
                    - cfg.sim_cache_bytes - (1 << 20)))
        self.write_memory_bytes = x
        pages = max(0, (cfg.total_memory_bytes - x - cfg.sim_cache_bytes)
                    // cfg.page_bytes)
        self.cache.resize(pages)

    # -- write path ------------------------------------------------------------------
    def write(self, tree_name: str, keys, vals=None, *, op: bool = True) -> None:
        tree = self.trees[tree_name]
        keys = np.asarray(keys, np.int64)
        if vals is None:
            vals = keys  # payload checksum defaults to the key
        lsn0 = self.log_pos
        tree.write_batch(keys, np.asarray(vals, np.int64), lsn0)
        nbytes = len(keys) * tree.entry_bytes
        self.log_pos += nbytes
        self.disk.stats.entries_written += len(keys)
        if op:
            self.disk.stats.ops += 1
        win = self._rate_win[tree_name]
        win.append((lsn0, nbytes))
        self._trim_rate_windows()
        self._dataset_touch(tree_name)
        self._enforce_memory()
        self._enforce_log()
        self._maintain(tree)

    def note_ops(self, n: int = 1) -> None:
        self.disk.stats.ops += n

    def _trim_rate_windows(self):
        lo = self.log_pos - self.cfg.max_log_bytes
        for win in self._rate_win.values():
            while win and win[0][0] < lo:
                win.popleft()

    # -- dataset activation (static schemes, §2.2) --------------------------------------
    def _dataset_touch(self, tree_name: str) -> None:
        if not self.cfg.scheme.startswith("btree-static"):
            return
        ds = self.tree_dataset[tree_name]
        if ds in self._active_ds:
            self._active_ds.remove(ds)
            self._active_ds.append(ds)
            return
        D = self.cfg.max_active_datasets
        if len(self._active_ds) >= D:
            victim = self._active_ds.pop(0)     # evict LRU dataset: flush all
            self._flush_dataset(victim, trigger="mem")
        self._active_ds.append(ds)

    def _flush_dataset(self, ds: str, *, trigger: str) -> int:
        freed = 0
        for name in self.datasets[ds]:
            t = self.trees[name]
            if not t.mem.is_empty():
                self._pre_flush_sample(t)
                freed += t.flush(trigger=trigger, log_pos=self.log_pos,
                                 max_log_bytes=self.cfg.max_log_bytes,
                                 total_write_mem=self.write_memory_bytes,
                                 beta=self.cfg.beta)
                self._maintain(t)
        return freed

    # -- flush triggers -------------------------------------------------------------------
    def _pre_flush_sample(self, tree: LSMTree) -> None:
        e = self._share_ewma[tree.name]
        self._share_ewma[tree.name] = 0.7 * e + 0.3 * tree.mem_bytes

    def _tree_share(self, tree: LSMTree) -> float:
        return max(self._share_ewma[tree.name], tree.mem_bytes,
                   self.cfg.active_sstable_bytes)

    def _enforce_memory(self) -> None:
        cfg = self.cfg
        if cfg.scheme.startswith("btree-static"):
            # per-dataset quota = write_mem / D; full flush at quota
            D = cfg.max_active_datasets
            quota = self.write_memory_bytes / max(1, D)
            for ds, names in self.datasets.items():
                used = sum(self.trees[n].mem_bytes for n in names)
                if used >= quota:
                    self._flush_dataset(ds, trigger="mem")
            return
        # shared-pool schemes
        budget = cfg.mem_flush_threshold * self.write_memory_bytes
        # Accordion-data: a big in-memory merge may blow the budget
        for t in self.trees.values():
            m = t.mem
            if isinstance(m, AccordionMemComponent):
                m.budget_hint_bytes = int(budget)
                if m.request_flush:
                    self._pre_flush_sample(t)
                    t.flush(trigger="mem", log_pos=self.log_pos,
                            max_log_bytes=cfg.max_log_bytes,
                            total_write_mem=self.write_memory_bytes,
                            beta=cfg.beta)
                    m.request_flush = False
                    self._maintain(t)
        guard = 0
        while self.write_memory_used() > budget and guard < 1000:
            guard += 1
            t = self._pick_flush_tree()
            if t is None:
                break
            self._pre_flush_sample(t)
            freed = t.flush(trigger="mem", log_pos=self.log_pos,
                            max_log_bytes=cfg.max_log_bytes,
                            total_write_mem=self.write_memory_bytes,
                            beta=cfg.beta,
                            forced_kind=cfg.forced_flush_kind)
            self._maintain(t)
            if freed == 0:
                break

    def _pick_flush_tree(self) -> LSMTree | None:
        """§4.2 flush policies."""
        nonempty = [t for t in self.trees.values() if not t.mem.is_empty()]
        if not nonempty:
            return None
        pol = self.cfg.flush_policy
        if pol == "mem":
            return max(nonempty, key=lambda t: t.mem_bytes)
        if pol == "lsn":
            return min(nonempty, key=lambda t: t.min_lsn)
        # opt: flush the tree whose memory ratio most exceeds its optimal
        # write-rate-proportional ratio a_i_opt = r_i / sum_j r_j.
        rates = {t.name: sum(b for _, b in self._rate_win[t.name])
                 for t in nonempty}
        total_rate = sum(rates.values())
        used = {t.name: t.mem_bytes for t in nonempty}
        total_used = sum(used.values())
        if total_rate == 0 or total_used == 0:
            return min(nonempty, key=lambda t: t.min_lsn)
        best, best_gap = None, None
        for t in nonempty:
            a = used[t.name] / total_used
            a_opt = rates[t.name] / total_rate
            gap = a - a_opt
            if best_gap is None or gap > best_gap:
                best, best_gap = t, gap
        return best

    def _enforce_log(self) -> None:
        cfg = self.cfg
        guard = 0
        while self.log_length > cfg.mem_flush_threshold * cfg.max_log_bytes \
                and guard < 1000:
            guard += 1
            m = self.min_lsn()
            if m >= _INF:
                break
            tree = min((t for t in self.trees.values()
                        if not t.mem.is_empty() or t.min_lsn < _INF),
                       key=lambda t: t.min_lsn, default=None)
            if tree is None or tree.mem.is_empty():
                break
            self._pre_flush_sample(tree)
            freed = tree.flush(trigger="log", log_pos=self.log_pos,
                               max_log_bytes=cfg.max_log_bytes,
                               total_write_mem=self.write_memory_bytes,
                               beta=cfg.beta,
                               forced_kind=cfg.forced_flush_kind)
            self._maintain(tree)
            if freed == 0:
                break

    def _maintain(self, tree: LSMTree) -> None:
        tree.maintain(self._tree_share(tree))

    # -- reads -----------------------------------------------------------------------
    def lookup(self, tree_name: str, key: int, *, op: bool = True):
        if op:
            self.disk.stats.ops += 1
        return self.trees[tree_name].lookup(int(key))

    def read_batch(self, tree_name: str, keys, *, op: bool = True):
        """Batched point lookups: one logical op per key, probes vectorized
        end-to-end through the tree's execution backend.

        Returns (found bool[n], vals int64[n]).
        """
        keys = np.asarray(keys, np.int64)
        if op:
            self.disk.stats.ops += len(keys)
        return self.trees[tree_name].lookup_batch(keys)

    def scan(self, tree_name: str, lo: int, n: int, *, op: bool = True):
        if op:
            self.disk.stats.ops += 1
        return self.trees[tree_name].scan(int(lo), int(n))

    # -- reporting ----------------------------------------------------------------------
    def sync_mem_stats(self) -> None:
        """Mirror per-component memory-merge work into the global counters
        (CPU cost of §4.1 memory merges — Fig. 8's overhead)."""
        self.disk.stats.entries_merged_mem = sum(
            t.mem.stats.entries_merged for t in self.trees.values()
            if hasattr(t.mem, "stats"))

    def elapsed(self):
        return self.cfg.time_model.elapsed(self.disk.stats,
                                           scheme=self.cfg.scheme)

    def throughput(self, prev_stats=None) -> float:
        stats = self.disk.stats if prev_stats is None \
            else self.disk.stats.delta(prev_stats)
        io, cpu = self.cfg.time_model.elapsed(stats, scheme=self.cfg.scheme)
        t = max(io, cpu, 1e-9)
        return stats.ops / t
