"""Memory component structures (§4.1).

``PartitionedMemComponent`` is the paper's contribution: the write memory of
one LSM-tree is itself an in-memory partitioned-leveling LSM-tree — an active
SSTable M0 plus memory levels M1..Mk of immutable, range-partitioned
SSTables. It supports *partial* flushes (one last-level SSTable at a time,
round-robin), min-LSN flushes (the SSTable with the smallest LSN plus all
overlapping SSTables at newer levels, to facilitate log truncation), and
*full* flushes (merge-sort everything).

Baseline components (monolithic B+-tree, Accordion) live in
``repro.core.lsm.baselines``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..engine import get_backend
from .sstable import (SSTable, partition_run, probe_tier,
                      sstable_from_run)


@dataclass
class MemStats:
    entries_merged: int = 0       # memory-merge CPU proxy
    entries_sealed: int = 0
    merges: int = 0


class MemComponentBase:
    """Interface shared by all memory-component structures.

    LSNs are *log byte offsets*: entry ``i`` of a batch written at log
    position ``lsn0`` carries LSN ``lsn0 + i * entry_bytes``, so one batch
    of n entries is indistinguishable from n batches of one (the
    differential suite relies on this).
    """

    def write(self, keys, vals, lsn0):
        raise NotImplementedError

    def ingest_batch(self, keys, vals, lsn0):
        """Batched write: semantics identical to ``write`` entry-by-entry
        (last occurrence of a duplicated key wins, with its own LSN).
        Structures override this to vectorize; the default defers to the
        scalar path."""
        self.write(keys, vals, lsn0)

    def upkeep_step(self) -> bool:
        """One unit of write-path upkeep that the maintenance scheduler
        runs *before* flush enforcement (e.g. Accordion's seal + pipeline
        merge). Returns True if work was done."""
        return False

    @property
    def used_bytes(self) -> int:
        raise NotImplementedError

    @property
    def min_lsn(self) -> int:
        """Smallest LSN still buffered (inf if empty)."""
        raise NotImplementedError

    def lookup(self, key: int):
        raise NotImplementedError

    def scan_runs(self, lo: int, hi: int):
        """Sorted (keys, vals) runs sliced to [lo, hi] inclusive, newest
        first."""
        raise NotImplementedError

    def lookup_batch(self, keys):
        """Batched point lookups; returns (found bool[n], vals int64[n]).

        Default: scalar fallback loop (monolithic baselines override or
        inherit this; the partitioned component vectorizes it).
        """
        keys = np.asarray(keys, np.int64)
        found = np.zeros(len(keys), bool)
        vals = np.zeros(len(keys), np.int64)
        for i, k in enumerate(keys.tolist()):
            f, v = self.lookup(int(k))
            if f:
                found[i] = True
                vals[i] = v
        return found, vals

    def is_empty(self) -> bool:
        raise NotImplementedError


def _slice_run(keys, vals, lo, hi):
    """Slice a sorted (keys, vals) run to [lo, hi] inclusive; None if the
    slice is empty."""
    i = int(np.searchsorted(keys, lo))
    j = int(np.searchsorted(keys, hi, side="right"))
    return (keys[i:j], vals[i:j]) if j > i else None


def _insert_disjoint(level, ssts):
    """Insert disjoint SSTables into a partitioned level, keep sorted order."""
    level.extend(ssts)
    level.sort(key=lambda s: s.min_key)


def _overlap_slice(level, lo, hi):
    """Return (start, end) index range of SSTables overlapping [lo, hi]."""
    i = 0
    while i < len(level) and level[i].max_key < lo:
        i += 1
    j = i
    while j < len(level) and level[j].min_key <= hi:
        j += 1
    return i, j


class PartitionedMemComponent(MemComponentBase):
    """§4.1.1: in-memory partitioned-leveling LSM-tree."""

    def __init__(self, *, entry_bytes: int, page_bytes: int,
                 active_bytes_max: int, size_ratio: int = 10, backend=None):
        self.entry_bytes = entry_bytes
        self.page_bytes = page_bytes
        self.active_bytes_max = active_bytes_max
        self.T = size_ratio
        self.backend = backend or get_backend()
        self.active: dict = {}            # key -> (val, lsn)
        self.active_lsn_min: int | None = None
        self.levels: list[list[SSTable]] = []   # M1..Mk
        self.rr_key: int = -(2**62)       # round-robin flush cursor (by min_key)
        self.stats = MemStats()

    # -- bookkeeping ---------------------------------------------------------
    @property
    def active_bytes(self) -> int:
        return len(self.active) * self.entry_bytes

    @property
    def used_bytes(self) -> int:
        return self.active_bytes + sum(s.size_bytes
                                       for lvl in self.levels for s in lvl)

    @property
    def min_lsn(self) -> int:
        lsns = [s.lsn_min for lvl in self.levels for s in lvl]
        if self.active_lsn_min is not None:
            lsns.append(self.active_lsn_min)
        return min(lsns) if lsns else 2**62

    def is_empty(self) -> bool:
        return not self.active and not any(self.levels)

    def level_max_bytes(self, i: int) -> int:
        """Max size of memory level M_{i+1} (0-indexed)."""
        return self.active_bytes_max * (self.T ** (i + 1))

    # -- write path ----------------------------------------------------------
    def write(self, keys, vals, lsn0: int) -> None:
        if self.active_lsn_min is None:
            self.active_lsn_min = lsn0
        a = self.active
        e = self.entry_bytes
        for i, k in enumerate(keys):
            a[int(k)] = (int(vals[i]), lsn0 + i * e)

    def ingest_batch(self, keys, vals, lsn0: int) -> None:
        """Vectorized write: one backend sort+dedup call per batch, then a
        single bulk dict update -- bit-identical active state to the
        scalar loop."""
        n = len(keys)
        if n == 0:
            return
        if self.active_lsn_min is None:
            self.active_lsn_min = lsn0
        ks, vs, src = self.backend.ingest_run(
            np.asarray(keys, np.int64), np.asarray(vals, np.int64))
        lsns = lsn0 + src * self.entry_bytes
        self.active.update(
            zip(ks.tolist(), zip(vs.tolist(), lsns.tolist())))

    def over_active_limit(self) -> bool:
        return self.active_bytes >= self.active_bytes_max

    def seal_active(self) -> None:
        """Freeze M0 into an SSTable and merge it into M1 (memory merge)."""
        if not self.active:
            return
        keys = np.fromiter(self.active.keys(), np.int64, len(self.active))
        order = np.argsort(keys)
        keys = keys[order]
        vv = np.array([self.active[int(k)] for k in keys], np.int64)
        vals, lsns = vv[:, 0], vv[:, 1]
        self.stats.entries_sealed += len(keys)
        sst = sstable_from_run(keys, vals, int(lsns.min()), int(lsns.max()),
                               self.entry_bytes, self.page_bytes)
        self.active = {}
        self.active_lsn_min = None
        if not self.levels:
            self.levels.append([])
        self._merge_into_level(0, [sst])

    def _merge_into_level(self, li: int, newer: list[SSTable]) -> None:
        """Merge ``newer`` SSTables (newest-first precedence) into level li."""
        if li >= len(self.levels):
            self.levels.append([])
        lvl = self.levels[li]
        lo = min(s.min_key for s in newer)
        hi = max(s.max_key for s in newer)
        i, j = _overlap_slice(lvl, lo, hi)
        olds = lvl[i:j]
        del lvl[i:j]
        runs = [(s.keys, s.vals) for s in newer] + [(s.keys, s.vals) for s in olds]
        keys, vals = self.backend.merge_runs(runs)
        self.stats.entries_merged += sum(len(r[0]) for r in runs)
        self.stats.merges += 1
        lsn_min = min(s.lsn_min for s in newer + olds)
        lsn_max = max(s.lsn_max for s in newer + olds)
        outs = partition_run(keys, vals, lsn_min, lsn_max, self.entry_bytes,
                             self.page_bytes, self.active_bytes_max)
        _insert_disjoint(lvl, outs)

    def maintain_step(self) -> bool:
        """One memory-merge unit (§4.1.1: greedy min-overlap-ratio victim
        pushed down from the shallowest over-full level; a new last level
        grows when needed). Returns True if a merge ran; once every level
        respects its max size, drops empty trailing levels and returns
        False."""
        for li in range(len(self.levels)):
            lvl = self.levels[li]
            if sum(s.size_bytes for s in lvl) > self.level_max_bytes(li):
                victim = self._greedy_victim(li)
                lvl.remove(victim)
                self._merge_into_level(li + 1, [victim])
                return True
        # Drop empty trailing levels so flush targets the true last level.
        while self.levels and not self.levels[-1]:
            self.levels.pop()
        return False

    def maintain(self) -> None:
        """Run memory merges until every level respects its max size."""
        guard = 0
        while guard < 10_000 and self.maintain_step():
            guard += 1

    def merge_debt(self) -> int:
        """Pending memory-merge units (scheduler ranking signal)."""
        debt = 1 if self.over_active_limit() else 0
        return debt + sum(
            1 for li, lvl in enumerate(self.levels)
            if sum(s.size_bytes for s in lvl) > self.level_max_bytes(li))

    def _greedy_victim(self, li: int) -> SSTable:
        """Pick the SSTable at level li minimizing the overlapping ratio with
        level li+1 (size of overlapping SSTables / size of the victim)."""
        lvl = self.levels[li]
        nxt = self.levels[li + 1] if li + 1 < len(self.levels) else []
        best, best_ratio = None, None
        for s in lvl:
            i, j = _overlap_slice(nxt, s.min_key, s.max_key)
            ov = sum(t.size_bytes for t in nxt[i:j])
            ratio = ov / s.size_bytes
            if best_ratio is None or ratio < best_ratio:
                best, best_ratio = s, ratio
        return best

    # -- flush paths ---------------------------------------------------------
    def flush_partial(self):
        """§4.1.1 memory-triggered: round-robin one SSTable off the last level.

        Returns a list with one (keys, vals, lsn_min, lsn_max) run.
        """
        if not any(self.levels):
            self.seal_active()
            self.maintain()
        if not any(self.levels):
            return []
        last = max(i for i, lvl in enumerate(self.levels) if lvl)
        lvl = self.levels[last]
        # round-robin by key: first SSTable with min_key > cursor, else wrap
        pick = next((s for s in lvl if s.min_key > self.rr_key), lvl[0])
        self.rr_key = pick.min_key
        lvl.remove(pick)
        while self.levels and not self.levels[-1]:
            self.levels.pop()
        return [(pick.keys, pick.vals, pick.lsn_min, pick.lsn_max)]

    def flush_min_lsn(self):
        """§4.1.1 log-triggered: flush the min-LSN SSTable plus all
        overlapping SSTables at newer (higher) levels, merged as one run."""
        if not any(self.levels):
            self.seal_active()
            self.maintain()
        if not any(self.levels):
            return []
        best_li, best = None, None
        for li, lvl in enumerate(self.levels):
            for s in lvl:
                if best is None or s.lsn_min < best.lsn_min:
                    best_li, best = li, s
        group = [best]
        self.levels[best_li].remove(best)
        for li in range(best_li - 1, -1, -1):   # newer levels
            lvl = self.levels[li]
            i, j = _overlap_slice(lvl, best.min_key, best.max_key)
            group = lvl[i:j] + group            # newer first
            del lvl[i:j]
        while self.levels and not self.levels[-1]:
            self.levels.pop()
        keys, vals = self.backend.merge_runs([(s.keys, s.vals)
                                              for s in group])
        self.stats.entries_merged += sum(s.num_entries for s in group)
        return [(keys, vals, min(s.lsn_min for s in group),
                 max(s.lsn_max for s in group))]

    def flush_full(self):
        """§4.1.4: merge-sort the entire component into one sorted run."""
        self.seal_active()
        ssts = [s for lvl in self.levels for s in lvl]
        if not ssts:
            return []
        runs = []
        for lvl in self.levels:                  # newer levels first
            runs.extend((s.keys, s.vals) for s in lvl)
        keys, vals = self.backend.merge_runs(runs)
        self.stats.entries_merged += sum(s.num_entries for s in ssts)
        self.levels = []
        return [(keys, vals, min(s.lsn_min for s in ssts),
                 max(s.lsn_max for s in ssts))]

    # -- reads ----------------------------------------------------------------
    def lookup(self, key: int):
        hit = self.active.get(key)
        if hit is not None:
            return True, hit[0]
        for lvl in self.levels:                  # newest level first
            i, j = _overlap_slice(lvl, key, key)
            for s in lvl[i:j]:
                found, val, _ = s.lookup(key)
                if found:
                    return True, val
        return False, 0

    def lookup_batch(self, keys):
        keys = np.asarray(keys, np.int64)
        n = len(keys)
        found = np.zeros(n, bool)
        vals = np.zeros(n, np.int64)
        if self.active:
            a = self.active
            for i, k in enumerate(keys.tolist()):
                hit = a.get(k)
                if hit is not None:
                    found[i] = True
                    vals[i] = hit[0]
        unresolved = ~found
        for lvl in self.levels:                  # newest level first
            if not unresolved.any():
                break
            probe_tier(lvl, keys, found, vals, unresolved,
                       self.backend.lookup_batch)
        return found, vals

    def scan_runs(self, lo: int, hi: int):
        """All in-memory (keys, vals) runs *sliced to* [lo,hi], newest
        first."""
        out = []
        if self.active:
            ks = np.array([k for k in self.active if lo <= k <= hi], np.int64)
            if len(ks):
                ks.sort()
                vs = np.array([self.active[int(k)][0] for k in ks], np.int64)
                out.append((ks, vs))
        for lvl in self.levels:                  # newest level first
            i, j = _overlap_slice(lvl, lo, hi)
            for s in lvl[i:j]:
                r = _slice_run(s.keys, s.vals, lo, hi)
                if r is not None:
                    out.append(r)
        return out
