"""MemoryArena: the shared memory pool one or more LSM stores draw from.

The paper's architecture (§3) pools write memory and the buffer cache so
the tuner can move memory to where the workload needs it. ``MemoryArena``
is that pool as an object: it owns the tunable write-memory size ``x``,
the clock buffer cache of ``total - x - sim`` pages, the ghost (simulated)
cache feeding the tuner, the byte-accounted ``Disk`` (and therefore the
global ``IOStats``), and the *durability plane*: one typed
``WriteAheadLog`` (the transaction log whose byte offsets are the LSNs)
and one versioned ``Manifest`` (the durable record of on-disk SSTable
state, carrying checkpoints).

A standalone ``LSMStore`` creates a private arena; a ``ShardedStore``
creates ONE arena and hands it to every shard, which is exactly how the
paper's memory walls become *cross-shard* walls: all shards compete for
the same write memory and buffer cache, append to the same log, and the
governor/tuner arbitrates the boundary globally by resizing this arena.
Tuner/governor resizes are logged as control records so crash recovery
can re-apply them by value.

``log_pos`` remains the canonical name for the log's byte position --
kept as a compat property over ``wal.head_lsn`` (the setter moves the WAL
head without a payload record; observability-only, used by nothing in the
engine itself).
"""
from __future__ import annotations

from ..durability.manifest import Manifest
from ..durability.wal import WriteAheadLog
from ..tuner.simcache import GhostCache
from .cache import ClockCache, Disk


class MemoryArena:
    """Shared write-memory pool + buffer cache + WAL/manifest for member
    stores."""

    def __init__(self, cfg, *, wal: WriteAheadLog | None = None,
                 manifest: Manifest | None = None):
        self.cfg = cfg
        self.write_memory_bytes = cfg.write_memory_bytes
        self.ghost = GhostCache(cfg.sim_cache_bytes // cfg.page_bytes)
        cache_pages = max(
            0, (cfg.total_memory_bytes - cfg.write_memory_bytes
                - cfg.sim_cache_bytes) // cfg.page_bytes)
        self.cache = ClockCache(cache_pages, on_evict=self.ghost.add_evicted)
        self.disk = Disk(cfg.page_bytes, self.cache, self.ghost)
        # Device page pool (HBM residency for fused reads): created by the
        # first member store to register -- the pool needs the store's
        # execution backend -- and shared by every shard after that.
        # Residency is derived state, so it is never checkpointed.
        self.device_pool = None
        # Background maintenance workers (engine/workers.py), shared by
        # every member store: speculative prepares of merge/Bloom compute.
        # With maintenance_workers=0 (default) the pool is inert -- no
        # threads exist and every compute runs inline, bit-identically.
        from ..engine.workers import MaintenanceWorkerPool
        self.workers = MaintenanceWorkerPool(
            getattr(cfg, "maintenance_workers", 0), stats=self.disk.stats)
        # Durability plane: adopted (recovery) or fresh. The manifest's
        # identity guardrail rejects a config that contradicts the one the
        # durable state was written under. The StorageMedium seam lives
        # here: "memory" builds the RAM-backed plane (default, bit-
        # identical to every pre-files trajectory); "files" builds the
        # physical plane under cfg.storage_dir (core/storage_io), whose
        # wal/manifest subclass the in-memory ones -- everything above
        # this line is medium-agnostic.
        if wal is None and manifest is None \
                and getattr(cfg, "storage_medium", "memory") == "files":
            from ..storage_io import create_plane
            wal, manifest = create_plane(cfg)
        self.wal = wal if wal is not None else WriteAheadLog()
        self.manifest = manifest if manifest is not None else Manifest()
        self.manifest.bind(cfg)
        # Physical plumbing (no-ops on the memory medium): cache misses /
        # flush writes reach the page store, fsync counts reach IOStats.
        page_store = getattr(self.manifest, "pages", None)
        if page_store is not None:
            self.disk.page_store = page_store
        self.wal.bind_stats(self.disk.stats)
        if hasattr(self.manifest, "bind_stats"):
            self.manifest.bind_stats(self.disk.stats)
        self.members: list = []             # stores drawing from this arena

    def register(self, store) -> int:
        """Add a member store; returns its index (== shard index for a
        sharded store, 0 for a standalone one)."""
        self.members.append(store)
        if self.device_pool is None:
            from .device_pool import DevicePagePool
            self.device_pool = DevicePagePool(
                store.backend, self.cfg.page_bytes,
                getattr(self.cfg, "device_pool_bytes", 0))
            self.disk.device_pool = self.device_pool
        return len(self.members) - 1

    def set_device_pool_bytes(self, budget_bytes: int) -> None:
        """Resize the device page pool (the governor's fused-read knob).
        Unlike ``set_write_memory`` this is not WAL-logged: residency is
        reconstructible and lookup results never depend on it."""
        if self.device_pool is not None:
            self.device_pool.set_budget_bytes(budget_bytes)

    @property
    def stats(self):
        return self.disk.stats

    @property
    def log_pos(self) -> int:
        """Transaction-log byte offset (compat name for ``wal.head_lsn``)."""
        return self.wal.head_lsn

    @log_pos.setter
    def log_pos(self, v: int) -> None:
        # Compat shim for the pre-WAL bare counter; see WriteAheadLog.set_head.
        self.wal.set_head(v)

    def used_bytes(self) -> int:
        """Write memory held across every member store."""
        return sum(s.write_memory_used() for s in self.members)

    def set_write_memory(self, x: int) -> None:
        """Apply a new write-memory size (the tuner's actuator): the
        buffer cache gives up (or reclaims) the complementary pages. The
        applied value is WAL-logged so recovery replays the decision."""
        cfg = self.cfg
        x = int(min(max(x, 1 << 20), cfg.total_memory_bytes
                    - cfg.sim_cache_bytes - (1 << 20)))
        self.write_memory_bytes = x
        pages = max(0, (cfg.total_memory_bytes - x - cfg.sim_cache_bytes)
                    // cfg.page_bytes)
        self.cache.resize(pages)
        self.wal.append_set_write_memory(x)

    def restore_write_memory(self, x: int) -> None:
        """Checkpoint restore: re-apply a captured write-memory size
        verbatim (it was either the config value or a past
        ``set_write_memory`` result, so it is already clamped -- clamping
        again would move a below-floor config value)."""
        self.write_memory_bytes = int(x)
        pages = max(0, (self.cfg.total_memory_bytes - x
                        - self.cfg.sim_cache_bytes) // self.cfg.page_bytes)
        self.cache.resize(pages)
