"""MemoryArena: the shared memory pool one or more LSM stores draw from.

The paper's architecture (§3) pools write memory and the buffer cache so
the tuner can move memory to where the workload needs it. ``MemoryArena``
is that pool as an object: it owns the tunable write-memory size ``x``,
the clock buffer cache of ``total - x - sim`` pages, the ghost (simulated)
cache feeding the tuner, the byte-accounted ``Disk`` (and therefore the
global ``IOStats``), and the shared transaction log position.

A standalone ``LSMStore`` creates a private arena; a ``ShardedStore``
creates ONE arena and hands it to every shard, which is exactly how the
paper's memory walls become *cross-shard* walls: all shards compete for
the same write memory and buffer cache, and the governor/tuner arbitrates
the boundary globally by resizing this arena.
"""
from __future__ import annotations

from ..tuner.simcache import GhostCache
from .cache import ClockCache, Disk


class MemoryArena:
    """Shared write-memory pool + buffer cache + log for member stores."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.write_memory_bytes = cfg.write_memory_bytes
        self.ghost = GhostCache(cfg.sim_cache_bytes // cfg.page_bytes)
        cache_pages = max(
            0, (cfg.total_memory_bytes - cfg.write_memory_bytes
                - cfg.sim_cache_bytes) // cfg.page_bytes)
        self.cache = ClockCache(cache_pages, on_evict=self.ghost.add_evicted)
        self.disk = Disk(cfg.page_bytes, self.cache, self.ghost)
        self.log_pos = 0                    # shared transaction-log offset
        self.members: list = []             # stores drawing from this arena

    def register(self, store) -> None:
        self.members.append(store)

    @property
    def stats(self):
        return self.disk.stats

    def used_bytes(self) -> int:
        """Write memory held across every member store."""
        return sum(s.write_memory_used() for s in self.members)

    def set_write_memory(self, x: int) -> None:
        """Apply a new write-memory size (the tuner's actuator): the
        buffer cache gives up (or reclaims) the complementary pages."""
        cfg = self.cfg
        x = int(min(max(x, 1 << 20), cfg.total_memory_bytes
                    - cfg.sim_cache_bytes - (1 << 20)))
        self.write_memory_bytes = x
        pages = max(0, (cfg.total_memory_bytes - x - cfg.sim_cache_bytes)
                    // cfg.page_bytes)
        self.cache.resize(pages)
