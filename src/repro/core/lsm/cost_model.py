"""Analytical LSM write-cost model (§2.1, Equation 1) — pure JAX.

    C = e/P + e/P * (T+1) * log_T(|L_N| / (a * Mw))        [pages/entry]

and the §4.2 optimal write-memory allocation, the Lagrange-multiplier
solution of Eq. 2:  a_i_opt = r_i / sum_j r_j.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def write_cost_per_entry(entry_bytes, page_bytes, size_ratio, last_level_bytes,
                         write_mem_bytes):
    """Equation 1. All args are scalars (or broadcastable arrays)."""
    e = jnp.asarray(entry_bytes, jnp.float32)
    P = jnp.asarray(page_bytes, jnp.float32)
    T = jnp.asarray(size_ratio, jnp.float32)
    n_levels = jnp.log(jnp.maximum(last_level_bytes / write_mem_bytes, 1.0)) \
        / jnp.log(T)
    return e / P + e / P * (T + 1.0) * n_levels


@jax.jit
def optimal_allocation(write_rates):
    """§4.2: a_i_opt = r_i / sum_j r_j (0-safe)."""
    r = jnp.asarray(write_rates, jnp.float32)
    s = jnp.sum(r)
    safe = jnp.where(s > 0, s, 1.0)    # no epsilon floor: subnormal rates
    return jnp.where(s > 0, r / safe,  # must still normalize to 1
                     jnp.ones_like(r) / r.shape[0])


@jax.jit
def total_write_cost(write_rates, entry_bytes, page_bytes, size_ratio,
                     last_level_bytes, alloc, write_mem_bytes):
    """Objective of Eq. 2: sum_i (r_i / e_i) * C_i, for a given allocation."""
    r = jnp.asarray(write_rates, jnp.float32)
    e = jnp.asarray(entry_bytes, jnp.float32)
    c = write_cost_per_entry(e, page_bytes, size_ratio, last_level_bytes,
                             alloc * write_mem_bytes)
    return jnp.sum(r / e * c)
