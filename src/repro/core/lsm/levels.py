"""Disk levels with dynamic level add/delete (§4.1.3).

Partitioned leveling: each level L1..LN holds disjoint SSTables; the last
level is always treated as full, which fixes the max sizes of the other
levels downward (|L_{N-1}|max = |L_N|/T, ...). Levels are added/deleted at L1
as the tree's write memory share changes:

  add  L1  when  a*Mw*T <  |L1|max           (write memory became too small)
  drop L1  when  a*Mw*T >  f*|L2|max, f=1.5  (write memory became too big)

While L1 is being deleted, L0 merges go *directly* into L2 (together with all
overlapping L1 SSTables — Figure 4), and low-priority L1→L2 merges drain the
remainder; when L1 empties it is removed.
"""
from __future__ import annotations

from .memtable import _insert_disjoint, _overlap_slice
from .sstable import SSTable


class DiskLevels:
    def __init__(self, *, size_ratio: int = 10, shrink_factor: float = 1.5,
                 dynamic: bool = True, static_num_levels: int | None = None):
        self.T = size_ratio
        self.f = shrink_factor
        self.dynamic = dynamic
        self.levels: list[list[SSTable]] = []    # L1 .. LN
        self.deleting_l1 = False
        if not dynamic:
            assert static_num_levels is not None and static_num_levels >= 1
            self.levels = [[] for _ in range(static_num_levels)]

    # -- geometry -------------------------------------------------------------
    @property
    def num_levels(self) -> int:
        return len(self.levels)

    def level_bytes(self, i: int) -> int:
        return sum(s.size_bytes for s in self.levels[i])

    @property
    def total_bytes(self) -> int:
        return sum(self.level_bytes(i) for i in range(self.num_levels))

    def level_max_bytes(self, i: int) -> float:
        """Max size of levels[i], derived from a full last level (§2.1)."""
        if not self.levels:
            return 0.0
        last = self.level_bytes(self.num_levels - 1)
        # The last level is treated as full; walk max sizes upward from it.
        return max(last, 1.0) / (self.T ** (self.num_levels - 1 - i))

    # -- dynamic level count (§4.1.3) ------------------------------------------
    def adjust(self, write_mem_bytes: float) -> None:
        if not self.dynamic:
            return
        if not self.levels:
            self.levels.append([])
            return
        # Add an empty L1 while the write memory is too small for |L1|max.
        while (self.num_levels >= 1 and self.level_bytes(self.num_levels - 1) > 0
               and write_mem_bytes * self.T < self.level_max_bytes(0)
               and self.num_levels < 24):
            self.levels.insert(0, [])
            self.deleting_l1 = False
        # Mark L1 for deletion when the write memory grew past f*|L2|max.
        if self.num_levels >= 2:
            if write_mem_bytes * self.T > self.f * self.level_max_bytes(1):
                self.deleting_l1 = True
            elif write_mem_bytes * self.T < self.level_max_bytes(0):
                self.deleting_l1 = False
        if self.deleting_l1 and self.num_levels >= 2 and not self.levels[0]:
            self.levels.pop(0)                  # L1 drained: remove it
            self.deleting_l1 = False

    # -- merge bookkeeping -----------------------------------------------------
    def l0_target_level(self) -> int:
        """Level index that L0 merges should feed (L2 while deleting L1)."""
        return 1 if (self.deleting_l1 and self.num_levels >= 2) else 0

    def over_full(self):
        """Indices of levels above their max size (never the last level)."""
        out = []
        for i in range(self.num_levels - 1):
            if self.level_bytes(i) > self.level_max_bytes(i):
                out.append(i)
        return out

    def greedy_victim(self, i: int) -> SSTable:
        """Min overlap-ratio SSTable of levels[i] w.r.t. levels[i+1]."""
        nxt = self.levels[i + 1]
        best, best_ratio = None, None
        for s in self.levels[i]:
            a, b = _overlap_slice(nxt, s.min_key, s.max_key)
            ratio = sum(t.size_bytes for t in nxt[a:b]) / s.size_bytes
            if best_ratio is None or ratio < best_ratio:
                best, best_ratio = s, ratio
        return best

    def overlapping_in(self, i: int, lo: int, hi: int):
        a, b = _overlap_slice(self.levels[i], lo, hi)
        return self.levels[i][a:b]

    def replace(self, i: int, olds, news) -> None:
        """Swap ``olds`` for ``news`` (disjoint) in levels[i]."""
        ids = {id(t) for t in olds}
        self.levels[i][:] = [s for s in self.levels[i] if id(s) not in ids]
        _insert_disjoint(self.levels[i], news)

    def remove_from(self, i: int, olds) -> None:
        ids = {id(t) for t in olds}
        self.levels[i][:] = [s for s in self.levels[i] if id(s) not in ids]

    # -- reads ---------------------------------------------------------------
    def lookup_tiers(self):
        """Disjoint, sorted table lists in probe order (L1 .. LN); each
        tier holds at most one candidate per key. Used by the batched read
        path."""
        return list(self.levels)

    def tables_covering(self, key: int):
        """One candidate SSTable per level (levels are disjoint), top-down."""
        out = []
        for lvl in self.levels:
            a, b = _overlap_slice(lvl, key, key)
            out.extend(lvl[a:b])                 # at most one
        return out

    def tables_overlapping(self, lo: int, hi: int):
        out = []
        for lvl in self.levels:
            a, b = _overlap_slice(lvl, lo, hi)
            out.extend(lvl[a:b])
        return out
