from .arena import MemoryArena  # noqa: F401
from .baselines import AccordionMemComponent, BTreeMemComponent  # noqa: F401
from .cache import ClockCache, Disk, IOStats  # noqa: F401
from .grouped_l0 import FlatL0, GroupedL0  # noqa: F401
from .levels import DiskLevels  # noqa: F401
from .memtable import PartitionedMemComponent  # noqa: F401
from .sstable import SSTable, merge_runs, partition_run  # noqa: F401
from .storage import LSMStore, StoreConfig, TimeModel  # noqa: F401
from .tree import LSMTree  # noqa: F401
