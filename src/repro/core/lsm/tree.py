"""LSMTree: one tree = memory component + grouped L0 + disk levels (§4).

All disk I/O is accounted through the shared ``Disk`` (page pins via the
buffer cache, flush/merge writes). Point lookups are batched end-to-end:
``lookup_batch`` probes the memory component, L0 groups, and disk levels
with vectorized range assignment and issues one Bloom-probe kernel call
per (SSTable, batch) through the configured execution backend; compaction
merges dispatch through the same backend (``repro.core.engine``). Per-tree
statistics feed the flush policies (§4.2) and the memory tuner (§5).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..engine import get_backend
from .cache import Disk
from .grouped_l0 import FlatL0, GroupedL0
from .levels import DiskLevels
from .memtable import MemComponentBase, PartitionedMemComponent
from .sstable import TOMBSTONE, assign_ranges, partition_run, probe_tier


@dataclass
class TreeStats:
    """Per-tree counters over the lifetime (window deltas taken by callers)."""

    entries_written: int = 0
    bytes_written: int = 0
    merge_pages_written: int = 0
    merge_pages_read: int = 0
    bytes_flushed_mem: int = 0
    bytes_flushed_log: int = 0
    lookups: int = 0


class LSMTree:
    def __init__(self, name: str, *, disk: Disk, entry_bytes: int,
                 mem_component: MemComponentBase,
                 sstable_bytes: int,
                 size_ratio: int = 10,
                 l0_max_groups: int = 4,
                 l0_target_groups: int = 2,
                 l0_greedy: bool = True,
                 l0_grouped: bool = True,
                 dynamic_levels: bool = True,
                 static_num_levels: int | None = None,
                 backend=None,
                 fused_scope: str = "store",
                 manifest=None, shard_id: int = 0, workers=None):
        self.name = name
        self.backend = backend or get_backend()
        # Background prepare pool (engine/workers.py; None or disabled =
        # every merge/Bloom compute runs inline). Only pure computation is
        # ever offloaded: all side effects stay on the foreground path.
        self.workers = workers
        # "store": try the one-launch cross-tier probe first, falling back
        # to per-tier fused, then staged. "tier": per-tier fused only.
        self.fused_scope = fused_scope
        self.disk = disk
        # Durability: every on-disk SSTable this tree writes or retires is
        # recorded as a versioned manifest edit (None for bare fixtures).
        self.manifest = manifest
        self.shard_id = shard_id
        self.entry_bytes = entry_bytes
        self.mem = mem_component
        self.sstable_bytes = sstable_bytes
        self.l0 = GroupedL0() if l0_grouped else FlatL0()
        self.l0_max_groups = l0_max_groups
        self.l0_target_groups = l0_target_groups
        self.l0_greedy = l0_greedy
        self.levels = DiskLevels(size_ratio=size_ratio,
                                 dynamic=dynamic_levels,
                                 static_num_levels=static_num_levels)
        self.stats = TreeStats()
        # Memoized per-SSTable Bloom filters, keyed by sst_id: built once
        # per table lifetime (not per probed batch) and invalidated when
        # flush/merge retires the table through _manifest_remove.
        self._bloom_cache: dict = {}
        # §4.1.4 adaptive flush window: (log_pos, bytes) of recent partial flushes
        self.partial_flush_window: list = []

    # -- properties used by policies/tuner -------------------------------------
    @property
    def mem_bytes(self) -> int:
        return self.mem.used_bytes

    @property
    def min_lsn(self) -> int:
        # Log truncation only needs the *memory* component's min LSN: data in
        # L0/levels is already durable on disk.
        return self.mem.min_lsn

    @property
    def last_level_bytes(self) -> int:
        if self.levels.num_levels == 0:
            return 0
        return self.levels.level_bytes(self.levels.num_levels - 1)

    @property
    def disk_bytes(self) -> int:
        return self.levels.total_bytes + self.l0.total_bytes

    # -- durability hooks -------------------------------------------------------
    def _manifest_add(self, sst, kind: str) -> None:
        if self.manifest is not None:
            self.manifest.add_sstable(self.shard_id, self.name, sst, kind)

    def _manifest_remove(self, sst) -> None:
        # The manifest edit marks the table's retirement: its memoized
        # Bloom filter dies with it (the device pool learns through
        # Disk.drop_sst at the same call sites).
        self._bloom_cache.pop(sst.sst_id, None)
        if self.manifest is not None:
            self.manifest.remove_sstable(self.shard_id, self.name, sst)

    # -- write path -------------------------------------------------------------
    def write_batch(self, keys, vals, lsn0: int) -> None:
        """Batched ingest into the memory component (one backend sort+dedup
        call); entry i carries LSN lsn0 + i*entry_bytes, so a batch of n is
        indistinguishable from n scalar writes.

        Batches of one take the component's scalar ``write`` path: that
        keeps the reference loop alive, and the differential suite (which
        replays every batch both ways) pins it bit-identical to
        ``ingest_batch``."""
        n = len(keys)
        if n == 1:
            self.mem.write(keys, vals, lsn0)
        else:
            self.mem.ingest_batch(keys, vals, lsn0)
        self.stats.entries_written += n
        self.stats.bytes_written += n * self.entry_bytes

    # -- flushes (§4.1.1 / §4.1.4) -----------------------------------------------
    def _emit_flush(self, runs, *, trigger: str, log_pos: int) -> int:
        """Partition runs into disk SSTables, write them, insert into L0.

        Returns bytes flushed.
        """
        total = 0
        for keys, vals, lsn_min, lsn_max in runs:
            if len(keys) == 0:
                continue
            for sst in partition_run(keys, vals, lsn_min, lsn_max,
                                     self.entry_bytes, self.disk.page_bytes,
                                     self.sstable_bytes):
                self.disk.write_sst(sst, flush=True)
                self._manifest_add(sst, "flush")
                self.l0.insert(sst)
                self._prepare_bloom(sst)
                total += sst.size_bytes
        if trigger == "mem":
            self.stats.bytes_flushed_mem += total
            self.disk.stats.bytes_flushed_mem += total
            self.disk.stats.flushes_mem += 1
        else:
            self.stats.bytes_flushed_log += total
            self.disk.stats.bytes_flushed_log += total
            self.disk.stats.flushes_log += 1
        return total

    def flush(self, *, trigger: str, log_pos: int, max_log_bytes: int,
              total_write_mem: int, beta: float = 0.5,
              forced_kind: str | None = None) -> int:
        """Flush per §4.1: memory-triggered → partial round-robin; log-
        triggered → adaptive partial(min-LSN)/full via the β window."""
        if isinstance(self.mem, PartitionedMemComponent):
            if forced_kind is None:
                if trigger == "mem":
                    kind = "partial"
                else:
                    # §4.1.4: window of recently partially-flushed bytes
                    self.partial_flush_window = [
                        (p, b) for p, b in self.partial_flush_window
                        if p > log_pos - max_log_bytes]
                    recent = sum(b for _, b in self.partial_flush_window)
                    kind = ("partial" if recent > beta * total_write_mem
                            else "full")
            else:
                kind = forced_kind
            if kind == "partial":
                runs = (self.mem.flush_partial() if trigger == "mem"
                        else self.mem.flush_min_lsn())
            elif kind == "partial_rr":
                runs = self.mem.flush_partial()
            elif kind == "partial_oldest":
                runs = self.mem.flush_min_lsn()
            else:
                runs = self.mem.flush_full()
            flushed = self._emit_flush(runs, trigger=trigger, log_pos=log_pos)
            if kind != "full" and flushed:
                self.partial_flush_window.append((log_pos, flushed))
            return flushed
        # Monolithic components: always a full flush.
        runs = self.mem.flush_full()
        return self._emit_flush(runs, trigger=trigger, log_pos=log_pos)

    # -- merges (maintenance) -----------------------------------------------------
    def _merge_key(self, read):
        """Identity of one merge computation: the sst_ids of the tables it
        reads, in run order. SSTables are immutable and ids are never
        reused within a store, so equal keys imply identical inputs --
        which is what lets a worker-prepared ``merge_runs`` result stand
        in for the inline one bit-for-bit."""
        return ("merge", self.shard_id, self.name,
                tuple(t.sst_id for t in read))

    def _merge_compute(self, read, runs):
        """The merge's pure compute: consume a worker-prepared result for
        these exact inputs, or run ``merge_runs`` inline (always inline
        with workers off -- today's behavior, bit-identical)."""
        w = self.workers
        if w is None or not w.enabled:
            return self.backend.merge_runs(runs)
        return w.take(self._merge_key(read),
                      lambda: self.backend.merge_runs(runs))

    def _prepare_bloom(self, sst) -> None:
        """Speculatively build a fresh table's Bloom filter off-thread
        (the read path will want it; ``_bloom`` consumes it)."""
        w = self.workers
        if w is not None and w.enabled:
            w.submit(("bloom", self.backend.name, sst.sst_id),
                     lambda k=sst.keys, b=self.backend: b.bloom_build(k))

    def _merge_write_out(self, keys, vals, lsn_min, lsn_max):
        outs = partition_run(keys, vals, lsn_min, lsn_max, self.entry_bytes,
                             self.disk.page_bytes, self.sstable_bytes)
        for sst in outs:
            self.disk.write_sst(sst, flush=False)
            self._manifest_add(sst, "merge")
            self.stats.merge_pages_written += sst.num_pages + sst.bloom_pages()
            self._prepare_bloom(sst)
        return outs

    def _purge_tombstones_at_bottom(self, keys, vals, target: int):
        """Drop TOMBSTONE entries when the merge output lands in the
        bottommost level: no older version can exist below it, so the
        tombstone has nothing left to shadow. Keeps delete-heavy
        workloads from accumulating dead entries (and merge bandwidth)
        forever."""
        if target == self.levels.num_levels - 1:
            live = vals != TOMBSTONE
            if not live.all():
                return keys[live], vals[live]
        return keys, vals

    def merge_l0_once(self) -> bool:
        if self.l0.num_groups == 0:
            return False
        ti = self.levels.l0_target_level()
        if self.levels.num_levels == 0:
            self.levels.adjust(self.mem_bytes)
            ti = self.levels.l0_target_level()
        target = self.levels.levels[ti]
        l0_tables, (a, b) = self.l0.pick_merge(target, greedy=self.l0_greedy)
        if not l0_tables:
            return False
        runs = [(t.keys, t.vals) for t in l0_tables]
        read = list(l0_tables)
        lo = min(t.min_key for t in l0_tables)
        hi = max(t.max_key for t in l0_tables)
        # Figure 4: while deleting L1, pull overlapping L1 SSTables along.
        mid_tables = []
        if ti == 1:
            mid_tables = self.levels.overlapping_in(0, lo, hi)
            runs += [(t.keys, t.vals) for t in mid_tables]
            read += mid_tables
            lo = min([lo] + [t.min_key for t in mid_tables])
            hi = max([hi] + [t.max_key for t in mid_tables])
        olds = self.levels.overlapping_in(ti, lo, hi)
        runs += [(t.keys, t.vals) for t in olds]
        read += olds
        for t in read:
            self.disk.merge_read_sst(t)
        keys, vals = self._merge_compute(read, runs)
        keys, vals = self._purge_tombstones_at_bottom(keys, vals, ti)
        self.disk.stats.entries_merged_disk += sum(len(r[0]) for r in runs)
        lsn_min = min(t.lsn_min for t in read)
        lsn_max = max(t.lsn_max for t in read)
        outs = self._merge_write_out(keys, vals, lsn_min, lsn_max)
        self.levels.replace(ti, olds, outs)
        if mid_tables:
            self.levels.remove_from(0, mid_tables)
        self.l0.remove(l0_tables)
        for t in read:
            self.disk.drop_sst(t)
            self._manifest_remove(t)
        return True

    def merge_level_once(self, i: int) -> None:
        victim = self.levels.greedy_victim(i)
        olds = self.levels.overlapping_in(i + 1, victim.min_key, victim.max_key)
        for t in [victim] + olds:
            self.disk.merge_read_sst(t)
        runs = [(victim.keys, victim.vals)] + [(t.keys, t.vals) for t in olds]
        keys, vals = self._merge_compute([victim] + olds, runs)
        keys, vals = self._purge_tombstones_at_bottom(keys, vals, i + 1)
        self.disk.stats.entries_merged_disk += sum(len(r[0]) for r in runs)
        outs = self._merge_write_out(
            keys, vals, min(t.lsn_min for t in [victim] + olds),
            max(t.lsn_max for t in [victim] + olds))
        self.levels.replace(i + 1, olds, outs)
        self.levels.remove_from(i, [victim])
        for t in [victim] + olds:
            self.disk.drop_sst(t)
            self._manifest_remove(t)

    def _l0_needs_merge(self, write_mem_share: float) -> bool:
        l0_bytes_budget = max(write_mem_share, 4 * self.sstable_bytes)
        return (self.l0.num_groups >= max(2, self.l0_target_groups)
                or self.l0.total_bytes > l0_bytes_budget)

    def maintenance_step(self, write_mem_share: float) -> bool:
        """One unit of maintenance work (simulated background threads, in
        priority order: memory seal, memory merge, L0 merge, level merge,
        L1-drain merge). Returns True if work was done; the scheduler's
        per-tick budget counts these units."""
        if isinstance(self.mem, PartitionedMemComponent):
            if self.mem.over_active_limit():
                self.mem.seal_active()
                return True
            if self.mem.maintain_step():
                return True
        self.levels.adjust(write_mem_share)
        if self._l0_needs_merge(write_mem_share) and self.merge_l0_once():
            return True
        over = self.levels.over_full()
        if over:
            self.merge_level_once(over[0])
            return True
        # low-priority drain of L1 while it is being deleted (§4.1.3)
        if self.levels.deleting_l1 and self.levels.num_levels >= 2 \
                and self.levels.levels[0]:
            self.merge_level_once(0)
            self.levels.adjust(write_mem_share)
            return True
        return False

    def preview_merge(self, write_mem_share: float):
        """Best-effort pure preview of the disk merge the next
        ``maintenance_step`` would run: ``(key, runs)`` for the worker
        pool, or None when the next step is not a disk merge (memory
        work first, nothing to merge).

        Mirrors ``maintenance_step``'s selection WITHOUT mutating
        anything -- in particular it does not run ``levels.adjust``, so a
        step whose adjust changes the level structure simply yields a
        stale key. Pending *memory* work (seal, in-memory merges) does
        not block the preview: it never touches L0 or the levels, so the
        disk merge that follows it still reads the previewed tables.
        Staleness is safe by construction: a prepared result is only
        ever consumed when the apply step derives the *same* key from
        the tables it actually reads; a mismatch is just an inline
        compute plus wasted worker cycles."""
        if self.levels.num_levels == 0:
            return None
        if self._l0_needs_merge(write_mem_share) and self.l0.num_groups > 0:
            ti = self.levels.l0_target_level()
            target = self.levels.levels[ti]
            l0_tables, _ = self.l0.pick_merge(target, greedy=self.l0_greedy)
            if not l0_tables:
                return None
            runs = [(t.keys, t.vals) for t in l0_tables]
            read = list(l0_tables)
            lo = min(t.min_key for t in l0_tables)
            hi = max(t.max_key for t in l0_tables)
            if ti == 1:
                mid = self.levels.overlapping_in(0, lo, hi)
                runs += [(t.keys, t.vals) for t in mid]
                read += mid
                lo = min([lo] + [t.min_key for t in mid])
                hi = max([hi] + [t.max_key for t in mid])
            olds = self.levels.overlapping_in(ti, lo, hi)
            runs += [(t.keys, t.vals) for t in olds]
            read += olds
            return self._merge_key(read), runs
        over = self.levels.over_full()
        if over:
            i = over[0]
        elif self.levels.deleting_l1 and self.levels.num_levels >= 2 \
                and self.levels.levels[0]:
            i = 0                            # low-priority L1 drain
        else:
            return None
        victim = self.levels.greedy_victim(i)
        olds = self.levels.overlapping_in(i + 1, victim.min_key,
                                          victim.max_key)
        runs = [(victim.keys, victim.vals)] + [(t.keys, t.vals)
                                               for t in olds]
        return self._merge_key([victim] + olds), runs

    def merge_debt(self, write_mem_share: float) -> int:
        """Pending maintenance units -- the scheduler's cross-tree ranking
        signal. Zero iff ``maintenance_step`` would find no work (up to a
        ``levels.adjust`` the step itself applies)."""
        debt = 0
        if isinstance(self.mem, PartitionedMemComponent):
            debt += self.mem.merge_debt()
        if self._l0_needs_merge(write_mem_share):
            debt += self.l0.num_groups
        debt += len(self.levels.over_full())
        if self.levels.deleting_l1 and self.levels.num_levels >= 2 \
                and self.levels.levels[0]:
            debt += 1
        return debt

    # -- reads ---------------------------------------------------------------
    def _bloom(self, sst):
        """Backend-built Bloom filter of one SSTable, memoized per sst_id
        for the table's lifetime (rebuilt if a differently-named backend
        owns the cached one; invalidated at the manifest edit sites)."""
        ent = self._bloom_cache.get(sst.sst_id)
        if ent is None or ent[0] != self.backend.name:
            w = self.workers
            if w is not None and w.enabled:
                fil = w.take(("bloom", self.backend.name, sst.sst_id),
                             lambda: self.backend.bloom_build(sst.keys))
            else:
                fil = self.backend.bloom_build(sst.keys)
            ent = (self.backend.name, fil)
            self._bloom_cache[sst.sst_id] = ent
        return ent[1]

    def _bloom_gate(self, sst, qk):
        """pre_probe hook: pin Bloom pages (one pin per probed key, as in
        the scalar path) and issue the Bloom probe as one backend call."""
        self.disk.query_pin_many(sst.sst_id, [-1] * len(qk))
        return self.backend.bloom_probe(self._bloom(sst), qk)

    def _leaf_pins(self, sst, pos, hit):
        """post_lookup hook: touch the leaf page of every Bloom positive."""
        epp = sst.entries_per_page
        pages = np.where(hit, pos,
                         np.minimum(pos, sst.num_entries - 1)) // epp
        self.disk.query_pin_many(sst.sst_id, pages)

    @staticmethod
    def _pin_meta(view, rr, tier):
        """Per-table geometry vectors (sst_id, entries_per_page,
        num_entries) of one tier, memoized on the pooled view -- the view
        is dropped whenever the tier's membership changes, so the memo
        can never go stale."""
        memo = getattr(view, "_pin_meta", None)
        if memo is None:
            memo = view._pin_meta = {}
        m = memo.get(rr)
        if m is None:
            n = len(tier)
            m = (np.fromiter((s.sst_id for s in tier), np.int64, n),
                 np.fromiter((s.entries_per_page for s in tier),
                             np.int64, n),
                 np.fromiter((s.num_entries for s in tier), np.int64, n))
            memo[rr] = m
        return m

    def _replay_tier_pins(self, meta, tis, starts, positive, pos, hit):
        """Issue one tier's staged-order pin sequence -- per visited
        table: one Bloom-unit pin per probed query, then the leaf page of
        every Bloom positive -- built as flat arrays and executed through
        ``Disk.pin_run``, accounting-identical to the per-group
        ``query_pin_many``/``_leaf_pins`` loop. All inputs are in visit
        order (stable-sorted by table, query order within a table)."""
        sst_ids, epp, nent = meta
        bounds = np.append(starts, len(tis))
        nq = np.diff(bounds)                       # Bloom pins per group
        nl = np.add.reduceat(positive.astype(np.intp), starts)
        tot = nq + nl
        gs = np.concatenate(([0], np.cumsum(tot)[:-1]))
        S = np.empty(int(tot.sum()), np.int64)
        P = np.empty(len(S), np.int64)
        G = len(starts)
        grp_b = np.repeat(np.arange(G), nq)
        intra_b = np.arange(int(nq.sum())) - np.repeat(np.cumsum(nq) - nq,
                                                       nq)
        db = gs[grp_b] + intra_b
        S[db] = sst_ids[tis[starts]][grp_b]
        P[db] = -1
        psel = np.flatnonzero(positive)
        if len(psel):
            t_p = tis[psel]
            pp, hh = pos[psel], hit[psel]
            lp = np.where(hh, pp,
                          np.minimum(pp, nent[t_p] - 1)) // epp[t_p]
            grp_l = np.repeat(np.arange(G), nl)
            intra_l = np.arange(len(psel)) - np.repeat(np.cumsum(nl) - nl,
                                                       nl)
            dl = gs[grp_l] + nq[grp_l] + intra_l
            S[dl] = sst_ids[t_p]
            P[dl] = lp
        self.disk.pin_run(S.tolist(), P.tolist())

    def _probe_tier_fused(self, tier, keys, found, vals, unresolved) -> bool:
        """Fused twin of ``probe_tier``: one (or two) device invocations
        for the whole tier through the pooled ``TierView``, then a host
        replay of the staged path's exact per-table pin sequence -- so
        results, page pins and IOStats are bit-identical to the staged
        loop. Returns False when this tier must take the staged path for
        this call (pool disabled/cold, backend refused the tier/queries).
        """
        pool = self.disk.device_pool
        if pool is None or not pool.enabled:
            return False
        idx_un = np.flatnonzero(unresolved)
        if not len(idx_un) or not tier:
            return True                    # the staged loop would no-op too
        view = pool.acquire(tier, self._bloom)
        if view is None:
            return False
        r = self.backend.lookup_fused(view, keys[idx_un])
        if r is None:
            return False
        st = self.disk.stats
        st.fused_launches += 1
        st.fused_tiers += 1
        okidx = np.flatnonzero(r.ok)
        if not len(okidx):
            st.fused_tier_misses += 1
            return True
        # Group by table with ONE stable sort: ascending table order, and
        # ascending query order within a table -- exactly the staged loop's
        # (np.unique, flatnonzero) visit order without T full-batch scans.
        order = okidx[np.argsort(r.ti[okidx], kind="stable")]
        tis = r.ti[order]
        starts = np.flatnonzero(np.r_[True, tis[1:] != tis[:-1]])
        self._replay_tier_pins(self._pin_meta(view, 0, tier), tis, starts,
                               r.positive[order], r.pos[order],
                               r.hit[order])
        sel = np.flatnonzero(r.hit)            # hit implies ok & positive
        gidx = idx_un[sel]
        found[gidx] = True
        vals[gidx] = r.vals[sel]
        unresolved[gidx] = False
        if r.hit.any():
            st.fused_tier_hits += 1
        else:
            st.fused_tier_misses += 1
        return True

    def _probe_store_fused(self, tiers, keys, found, vals, unresolved):
        """One-launch twin of the whole tier loop: a single fused probe of
        every lookup tier through the pooled ``StoreView`` (Bloom stack +
        ranged search + on-device newest-wins argmin), then a host replay
        of the staged path's exact per-tier, per-table pin sequence. The
        replay visits tier r only for the queries the staged loop would
        still have had unresolved there (``win`` == -1 or >= r), so page
        pins and IOStats stay bit-identical. Returns False when the batch
        must fall back to the per-tier (and from there staged) path."""
        pool = self.disk.device_pool
        if pool is None or not pool.enabled:
            return False
        idx_un = np.flatnonzero(unresolved)
        tiers = [t for t in tiers if t]
        if not len(idx_un) or not tiers:
            return True                    # the tier loop would no-op too
        view = pool.acquire_store(tiers, self._bloom)
        if view is None:
            return False
        r = self.backend.lookup_store_fused(view, keys[idx_un])
        if r is None:
            return False
        st = self.disk.stats
        st.fused_launches += 1
        st.fused_tiers += len(tiers)
        win = r.win
        for rr, tier in enumerate(tiers):
            # Staged-order activity: a query reaches tier rr iff no newer
            # tier resolved it.
            active = (win == -1) | (win >= rr)
            sel0 = np.flatnonzero(r.ok[rr] & active)
            if len(sel0):
                order = sel0[np.argsort(r.ti[rr][sel0], kind="stable")]
                tis = r.ti[rr][order]
                starts = np.flatnonzero(np.r_[True, tis[1:] != tis[:-1]])
                self._replay_tier_pins(self._pin_meta(view, rr, tier),
                                       tis, starts, r.positive[rr][order],
                                       r.pos[rr][order], r.hit[rr][order])
            if (win == rr).any():
                st.fused_tier_hits += 1
            else:
                st.fused_tier_misses += 1
        res = np.flatnonzero(win >= 0)
        gidx = idx_un[res]
        found[gidx] = True
        vals[gidx] = r.vals[win[res], res]
        unresolved[gidx] = False
        return True

    def lookup_batch(self, keys):
        """Batched point lookups; returns (found bool[n], vals int64[n]).

        Probe order matches the scalar semantics: memory component, then L0
        newest-group-first, then disk levels top-down; a key stops probing
        once resolved. Bloom probes are one backend call per (SSTable,
        batch)."""
        keys = np.asarray(keys, np.int64)
        self.stats.lookups += len(keys)
        found, vals = self.mem.lookup_batch(keys)
        unresolved = ~found
        tiers = self.l0.lookup_tiers() + self.levels.lookup_tiers()
        # Whole-store hot path first: ONE device launch for every tier.
        # Any miss (cold pool, refused stack) falls back to the per-tier
        # fused loop -- whose own cold ``acquire`` calls admit pages, so
        # the store stack is typically resident by the next batch.
        if unresolved.any() and self.fused_scope == "store" \
                and self._probe_store_fused(tiers, keys, found, vals,
                                            unresolved):
            tiers = []
        for tier in tiers:
            if not unresolved.any():
                break
            # Device-resident hot path first: one fused probe per tier.
            # Any miss (cold pool, refused tier) stays on the staged loop
            # for this call with identical results and pin accounting.
            if self._probe_tier_fused(tier, keys, found, vals, unresolved):
                continue
            probe_tier(tier, keys, found, vals, unresolved,
                       self.backend.lookup_batch,
                       pre_probe=self._bloom_gate,
                       post_lookup=self._leaf_pins)
        # A tombstone *resolves* its key (it shadows older versions, so
        # probing stopped at it) but reads back as absent.
        dead = found & (vals == TOMBSTONE)
        found[dead] = False
        vals[dead] = 0
        return found, vals

    def lookup(self, key: int):
        """Scalar lookup: a batch of one (same probe path and accounting)."""
        found, vals = self.lookup_batch(np.array([key], np.int64))
        return bool(found[0]), int(vals[0])

    def scan_batch(self, los, ns):
        """Batched range scans with reconciliation; returns live-entry
        counts int64[q].

        The *seek* is vectorized: for every disjoint tier (L0 groups, disk
        levels), the overlapping-table span of all ranges comes from one
        ``assign_ranges`` call (two searchsorted passes over the tier
        bounds) instead of a per-range sweep of the table lists. Per range,
        page pins, run slicing and the newest-first reconciliation merge
        then run exactly as the scalar ``scan`` did, so a batch of q scans
        is bit-identical -- counts, pins, IOStats -- to q scalar calls."""
        los = np.asarray(los, np.int64)
        ns = np.asarray(ns, np.int64)
        nq = len(los)
        self.stats.lookups += nq
        counts = np.zeros(nq, np.int64)
        if nq == 0:
            return counts
        his = los + ns       # key-space width proxy (uniform key density)
        tiers = self.l0.lookup_tiers() + self.levels.lookup_tiers()
        spans = [assign_ranges(tier, los, his - 1) for tier in tiers]
        for q in range(nq):
            lo, hi = int(los[q]), int(his[q])
            # every memory-component structure provides sliced scan runs
            runs = list(self.mem.scan_runs(lo, hi - 1))
            for tier, (a, b) in zip(tiers, spans):
                for sst in tier[a[q]:b[q]]:
                    i = int(np.searchsorted(sst.keys, lo))
                    j = int(np.searchsorted(sst.keys, hi))
                    if j <= i:
                        continue
                    epp = sst.entries_per_page
                    self.disk.query_pin_many(
                        sst.sst_id, np.arange(i // epp, (j - 1) // epp + 1))
                    runs.append((sst.keys[i:j], sst.vals[i:j]))
            if runs:
                keys, vals = self.backend.merge_runs(runs)
                counts[q] = np.count_nonzero(vals != TOMBSTONE)
        return counts

    def scan(self, lo: int, n_entries: int):
        """Scalar range scan: a batch of one (same seek path, pins and
        accounting as ``scan_batch``)."""
        return int(self.scan_batch(np.array([lo], np.int64),
                                   np.array([n_entries], np.int64))[0])
