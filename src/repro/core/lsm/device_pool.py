"""DevicePagePool: the HBM-resident half of the buffer cache.

The paper's buffer cache is an accounting model (``ClockCache`` pins over
simulated disk pages). This pool makes residency *physical* for the read
hot path: the same clock replacement, over the same (sst_id, page) ids --
key/val pages ``0..num_pages-1`` plus the Bloom unit at ``-1`` -- decides
which SSTables' pages stay device-resident, and a tier whose pages are all
resident is probed through the backend's fused ``lookup_fused`` pipeline
(one/two device invocations per tier) instead of the per-SSTable staged
calls.

Lifecycle per lookup tier:

  * tier fully resident  -> pin (refresh) its pages, hand back the cached
    ``TierView``; the tree runs the fused probe.
  * any page absent      -> count a tier miss, *admit* the pages (clock
    installs, possibly evicting another tier's pages), and return None;
    this call is served by the staged path with its usual pin accounting,
    the next one finds the tier resident.
  * tier wider than pool -> miss, nothing admitted (it could never fit).

Evicting any page of an SSTable drops the prepared views containing that
table (a view is only valid while the whole tier is resident); SSTables
retired by flush/merge are invalidated through ``Disk.drop_sst`` exactly
like their buffer-cache pages. The pool's byte budget is set through
``MemoryArena.set_device_pool_bytes`` -- the governor's ``MemoryPlan``
actuator -- and a budget of 0 disables the pool entirely (every store
behaves bit-identically to the staged-only engine).

Residency is derived state: nothing here is checkpointed, recovery starts
with a cold pool and identical lookup results.
"""
from __future__ import annotations

from .cache import ClockCache

_ABSENT = object()


class DevicePagePool:
    """Clock-managed HBM page pool backing fused tier lookups."""

    def __init__(self, backend, page_bytes: int, budget_bytes: int = 0):
        self.backend = backend
        self.page_bytes = max(1, int(page_bytes))
        self.cache = ClockCache(0, on_evict=self._on_evict)
        self._views: dict = {}        # view key -> Tier/StoreView | None
        self._views_of: dict = {}     # sst_id -> set of view keys
        self.tier_hits = 0            # tiers served fused
        self.tier_misses = 0          # tiers that fell back to staged
        self.store_hits = 0           # whole stores served one-launch
        self.store_misses = 0         # stores that fell back per-tier
        self._gen = 0                 # budget generation: bumped by every
                                      # set_budget_bytes so an in-flight
                                      # prepare races a shrink safely
        self.set_budget_bytes(budget_bytes)

    # -- budget (the governor's knob) ---------------------------------------
    @property
    def enabled(self) -> bool:
        return self.cache.capacity > 0

    @property
    def budget_bytes(self) -> int:
        return self._budget_bytes

    def set_budget_bytes(self, budget_bytes: int) -> None:
        self._gen += 1
        self._budget_bytes = max(0, int(budget_bytes))
        self.cache.resize(self._budget_bytes // self.page_bytes)
        if not self.enabled:
            self._views.clear()
            self._views_of.clear()

    # -- invalidation -------------------------------------------------------
    @staticmethod
    def _key_ssts(key):
        """sst_ids of a view key: a flat tuple (tier view) or a tuple of
        per-tier tuples (store view)."""
        for s in key:
            if isinstance(s, tuple):
                yield from s
            else:
                yield s

    def _on_evict(self, pid) -> None:
        self._drop_views(pid[0])

    def _drop_views(self, sst_id) -> None:
        for key in self._views_of.pop(sst_id, ()):
            self._views.pop(key, None)
            for s in self._key_ssts(key):
                if s != sst_id and s in self._views_of:
                    self._views_of[s].discard(key)

    def drop_sst(self, sst) -> None:
        """Retire an SSTable (flush/merge replaced it): its pages leave the
        pool and every view over it dies."""
        self.cache.invalidate_many(
            (sst.sst_id, p) for p in range(-1, sst.num_pages))
        self._drop_views(sst.sst_id)

    # -- the read hot path --------------------------------------------------
    def acquire(self, tables, bloom_fn):
        """Return a resident ``TierView`` over ``tables`` (a disjoint,
        min_key-sorted lookup tier) or None when the caller must stay on
        the staged path this call."""
        if not self.enabled or not tables:
            return None
        key = tuple(t.sst_id for t in tables)
        view = self._views.get(key, _ABSENT)
        if view is not _ABSENT:
            # A live view PROVES residency: it was built with every member
            # page in the pool, and every removal path (clock eviction,
            # budget shrink, drop_sst) drops the views over the departed
            # SSTable first. So the hot path is one dict probe -- no
            # per-page walk. Reference bits are not refreshed here; a hot
            # tier the clock nonetheless evicts re-admits on its next miss.
            if view is None:
                # Cached refusal: the backend cannot prepare this tier
                # (e.g. outside the kernel domain); stays staged without
                # re-attempting preparation per batch.
                self.tier_misses += 1
                return None
            self.tier_hits += 1
            return view
        pids = [(t.sst_id, p) for t in tables
                for p in range(-1, t.num_pages)]
        if len(pids) > self.cache.capacity:
            self.tier_misses += 1
            return None
        if not all(pid in self.cache for pid in pids):
            # Cold: admit (clock decides what yields) and serve staged.
            self.tier_misses += 1
            for pid in pids:
                self.cache.pin(pid)
            return None
        for pid in pids:          # resident: refresh every reference bit
            self.cache.pin(pid)
        gen = self._gen
        view = self.backend.prepare_tier(tables, bloom_fn)
        if self._gen != gen:
            # A budget change (e.g. governor shrink) raced the prepare:
            # residency may no longer hold, so do not cache or serve the
            # view -- this call stays staged and re-evaluates next batch.
            self.tier_misses += 1
            return None
        self._views[key] = view
        for s in key:
            self._views_of.setdefault(s, set()).add(key)
        if view is None:
            self.tier_misses += 1
            return None
        self.tier_hits += 1
        return view

    def acquire_store(self, tiers, bloom_fn):
        """Return a resident ``StoreView`` over every lookup tier of one
        tree (newest-first), or None when the caller must fall back to
        the per-tier path this batch. Same lifecycle as ``acquire``, with
        residency judged over the union of every tier's pages: fully
        resident -> refresh + serve (preparing and caching the stacked
        view on first touch); anything absent -> admit and fall back."""
        if not self.enabled or not tiers:
            return None
        key = tuple(tuple(t.sst_id for t in tier) for tier in tiers)
        view = self._views.get(key, _ABSENT)
        if view is not _ABSENT:
            if view is None:      # cached refusal (kernel-domain etc.)
                self.store_misses += 1
                return None
            self.store_hits += 1
            return view
        pids = [(t.sst_id, p) for tier in tiers for t in tier
                for p in range(-1, t.num_pages)]
        if len(pids) > self.cache.capacity:
            self.store_misses += 1
            return None
        if not all(pid in self.cache for pid in pids):
            self.store_misses += 1
            for pid in pids:
                self.cache.pin(pid)
            return None
        for pid in pids:
            self.cache.pin(pid)
        gen = self._gen
        view = self.backend.prepare_store(tiers, bloom_fn)
        if self._gen != gen:      # budget shrink raced the prepare
            self.store_misses += 1
            return None
        self._views[key] = view
        for s in self._key_ssts(key):
            self._views_of.setdefault(s, set()).add(key)
        if view is None:
            self.store_misses += 1
            return None
        self.store_hits += 1
        return view

    # -- reporting ----------------------------------------------------------
    def stats(self) -> dict:
        return {
            "tier_hits": self.tier_hits,
            "tier_misses": self.tier_misses,
            "store_hits": self.store_hits,
            "store_misses": self.store_misses,
            "page_hits": self.cache.hits,
            "page_misses": self.cache.misses,
            "resident_pages": len(self.cache),
            "capacity_pages": self.cache.capacity,
            "budget_bytes": self._budget_bytes,
        }
