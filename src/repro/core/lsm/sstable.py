"""SSTable: immutable sorted run + page/Bloom accounting.

An SSTable stores a sorted, de-duplicated run of (key, value) pairs. In the
real system the value payload lives in disk pages; here we carry values as an
int64 "payload checksum" array so correctness (newest-wins reconciliation) is
fully testable, while I/O is accounted at page granularity exactly as
AsterixDB does (entry_bytes per entry, page_bytes per page, one Bloom filter
per SSTable at ~10 bits/key for a 1% false-positive rate).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

_SST_IDS = itertools.count()


def reset_sst_ids() -> None:
    """Reset the global SSTable id counter (tests/benchmarks isolation)."""
    global _SST_IDS
    _SST_IDS = itertools.count()


def merge_runs(runs):
    """Merge sorted (keys, vals) runs with newest-wins reconciliation.

    ``runs`` is ordered newest-first. Returns a single sorted, unique run.
    """
    runs = [r for r in runs if len(r[0])]
    if not runs:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    if len(runs) == 1:
        return runs[0]
    keys = np.concatenate([r[0] for r in runs])
    vals = np.concatenate([r[1] for r in runs])
    # Stable sort by key keeps the newest occurrence first within equal keys
    # because runs are concatenated newest-first.
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    keep = np.ones(len(keys), bool)
    keep[1:] = keys[1:] != keys[:-1]
    return keys[keep], vals[keep]


@dataclass(eq=False)  # identity equality: SSTables live in Python lists
class SSTable:
    """Immutable sorted run with LSN bookkeeping."""

    keys: np.ndarray
    vals: np.ndarray
    lsn_min: int
    lsn_max: int
    entry_bytes: int
    page_bytes: int
    sst_id: int = field(default_factory=lambda: next(_SST_IDS))

    def __post_init__(self):
        assert len(self.keys) == len(self.vals)
        assert len(self.keys) > 0, "empty SSTable"

    # -- geometry -----------------------------------------------------------
    @property
    def num_entries(self) -> int:
        return int(len(self.keys))

    @property
    def size_bytes(self) -> int:
        return self.num_entries * self.entry_bytes

    @property
    def min_key(self) -> int:
        return int(self.keys[0])

    @property
    def max_key(self) -> int:
        return int(self.keys[-1])

    @property
    def entries_per_page(self) -> int:
        return max(1, self.page_bytes // max(1, self.entry_bytes))

    @property
    def num_pages(self) -> int:
        return -(-self.num_entries // self.entries_per_page)

    def bloom_pages(self, bits_per_key: int = 10) -> int:
        return max(1, -(-(self.num_entries * bits_per_key // 8) // self.page_bytes))

    # -- key ops ------------------------------------------------------------
    def overlaps(self, lo: int, hi: int) -> bool:
        return self.min_key <= hi and lo <= self.max_key

    def covers(self, key: int) -> bool:
        return self.min_key <= key <= self.max_key

    def lookup(self, key: int):
        """Return (found, value, page_index)."""
        i = int(np.searchsorted(self.keys, key))
        if i < len(self.keys) and int(self.keys[i]) == key:
            return True, int(self.vals[i]), i // self.entries_per_page
        return False, 0, min(i, self.num_entries - 1) // self.entries_per_page


def sstable_from_run(keys, vals, lsn_min, lsn_max, entry_bytes, page_bytes):
    return SSTable(np.asarray(keys, np.int64), np.asarray(vals, np.int64),
                   int(lsn_min), int(lsn_max), int(entry_bytes), int(page_bytes))


def partition_run(keys, vals, lsn_min, lsn_max, entry_bytes, page_bytes,
                  target_bytes):
    """Split a big sorted run into SSTables of ~target_bytes each."""
    n = len(keys)
    if n == 0:
        return []
    per = max(1, target_bytes // max(1, entry_bytes))
    return [sstable_from_run(keys[s:min(n, s + per)], vals[s:min(n, s + per)],
                             lsn_min, lsn_max, entry_bytes, page_bytes)
            for s in range(0, n, per)]


def total_bytes(tables) -> int:
    return sum(t.size_bytes for t in tables)


def overlapping(tables, lo: int, hi: int):
    """Subset of ``tables`` whose key range intersects [lo, hi]."""
    return [t for t in tables if t.overlaps(lo, hi)]
