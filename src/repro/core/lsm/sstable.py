"""SSTable: immutable sorted run + page/Bloom accounting.

An SSTable stores a sorted, de-duplicated run of (key, value) pairs. In the
real system the value payload lives in disk pages; here we carry values as an
int64 "payload checksum" array so correctness (newest-wins reconciliation) is
fully testable, while I/O is accounted at page granularity exactly as
AsterixDB does (entry_bytes per entry, page_bytes per page, one Bloom filter
per SSTable at ~10 bits/key for a 1% false-positive rate).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

_SST_IDS = itertools.count()

# Reserved payload marking a deleted key. Deletes are writes of this value:
# newest-wins reconciliation carries the tombstone down the tree shadowing
# older versions; reads and scans filter it out. Chosen inside the Pallas
# kernels' int32 value domain so deletes never force a numpy fallback.
TOMBSTONE = -(2**31) + 1


def reset_sst_ids() -> None:
    """Reset the global SSTable id counter (tests/benchmarks isolation)."""
    global _SST_IDS
    _SST_IDS = itertools.count()


# Reference k-way merge now lives with the execution backends (the engine
# dispatches merges through repro.core.engine); re-exported here for
# back-compat with existing callers/tests.
from ..engine.numpy_backend import merge_runs_numpy as merge_runs  # noqa: E402


def assign_queries(tables, qkeys):
    """Map each query key to the table covering it within a *disjoint,
    min_key-sorted* table list (one memory/disk level, or one L0 group).

    Returns (table_idx, covered): per-query table index (clipped) and a
    bool mask of queries that fall inside some table's key range.
    """
    if not tables:
        return (np.zeros(len(qkeys), np.int64),
                np.zeros(len(qkeys), bool))
    starts = np.fromiter((t.min_key for t in tables), np.int64, len(tables))
    ends = np.fromiter((t.max_key for t in tables), np.int64, len(tables))
    ti = np.searchsorted(starts, qkeys, side="right") - 1
    ok = ti >= 0
    ti = np.clip(ti, 0, len(tables) - 1)
    ok &= qkeys <= ends[ti]
    return ti, ok


def assign_ranges(tables, los, his):
    """Vectorized seek for *range* queries over a disjoint, min_key-sorted
    table list: the tables overlapping range q -- [los[q], his[q]] both
    inclusive -- are exactly ``tables[a[q]:b[q]]``.

    The batched companion of ``assign_queries``: two searchsorted calls
    over sorted table bounds serve the whole batch instead of a per-range
    Python sweep of the table list.
    """
    n = len(los)
    if not tables:
        z = np.zeros(n, np.int64)
        return z, z.copy()
    starts = np.fromiter((t.min_key for t in tables), np.int64, len(tables))
    ends = np.fromiter((t.max_key for t in tables), np.int64, len(tables))
    a = np.searchsorted(ends, los, side="left")      # first table ending >= lo
    b = np.searchsorted(starts, his, side="right")   # tables starting <= hi
    return a.astype(np.int64), np.maximum(a, b).astype(np.int64)


def probe_tier(tables, keys, found, vals, unresolved, lookup_batch, *,
               pre_probe=None, post_lookup=None):
    """Probe one disjoint, sorted tier with every still-unresolved key,
    scattering hits into ``found``/``vals``/``unresolved`` in place.

    The single home of the batched probe-and-scatter dance (vectorized
    table assignment, per-table backend lookup, double-indexed hit
    scatter) shared by the tree's disk tiers and the partitioned memory
    component's levels. Hooks carry the disk-only concerns:

      pre_probe(sst, qk) -> bool mask of probes worth a binary search
        (the tree pins Bloom pages and probes the filter here);
      post_lookup(sst, pos, hit) (the tree pins leaf pages here).
    """
    idx_un = np.flatnonzero(unresolved)
    if not len(idx_un) or not tables:
        return
    q = keys[idx_un]
    ti, ok = assign_queries(tables, q)
    for t_i in np.unique(ti[ok]):
        sst = tables[t_i]
        sel = np.flatnonzero(ok & (ti == t_i))
        if pre_probe is not None:
            positive = pre_probe(sst, q[sel])
            if not positive.any():
                continue
            sel = sel[positive]
        pos, hit = lookup_batch(sst.keys, q[sel])
        if post_lookup is not None:
            post_lookup(sst, pos, hit)
        gidx = idx_un[sel[hit]]
        found[gidx] = True
        vals[gidx] = sst.vals[pos[hit]]
        unresolved[gidx] = False


@dataclass(eq=False)  # identity equality: SSTables live in Python lists
class SSTable:
    """Immutable sorted run with LSN bookkeeping."""

    keys: np.ndarray
    vals: np.ndarray
    lsn_min: int
    lsn_max: int
    entry_bytes: int
    page_bytes: int
    sst_id: int = field(default_factory=lambda: next(_SST_IDS))
    # Lazily built, backend-owned Bloom filter: (backend_name, filter).
    bloom: tuple | None = field(default=None, repr=False)

    def __post_init__(self):
        assert len(self.keys) == len(self.vals)
        assert len(self.keys) > 0, "empty SSTable"

    # -- geometry -----------------------------------------------------------
    @property
    def num_entries(self) -> int:
        return int(len(self.keys))

    @property
    def size_bytes(self) -> int:
        return self.num_entries * self.entry_bytes

    @property
    def min_key(self) -> int:
        return int(self.keys[0])

    @property
    def max_key(self) -> int:
        return int(self.keys[-1])

    @property
    def entries_per_page(self) -> int:
        return max(1, self.page_bytes // max(1, self.entry_bytes))

    @property
    def num_pages(self) -> int:
        return -(-self.num_entries // self.entries_per_page)

    def bloom_pages(self, bits_per_key: int = 10) -> int:
        return max(1, -(-(self.num_entries * bits_per_key // 8) // self.page_bytes))

    # -- key ops ------------------------------------------------------------
    def overlaps(self, lo: int, hi: int) -> bool:
        return self.min_key <= hi and lo <= self.max_key

    def covers(self, key: int) -> bool:
        return self.min_key <= key <= self.max_key

    def lookup(self, key: int):
        """Return (found, value, page_index)."""
        i = int(np.searchsorted(self.keys, key))
        if i < len(self.keys) and int(self.keys[i]) == key:
            return True, int(self.vals[i]), i // self.entries_per_page
        return False, 0, min(i, self.num_entries - 1) // self.entries_per_page


def sstable_from_run(keys, vals, lsn_min, lsn_max, entry_bytes, page_bytes):
    return SSTable(np.asarray(keys, np.int64), np.asarray(vals, np.int64),
                   int(lsn_min), int(lsn_max), int(entry_bytes), int(page_bytes))


def partition_run(keys, vals, lsn_min, lsn_max, entry_bytes, page_bytes,
                  target_bytes):
    """Split a big sorted run into SSTables of ~target_bytes each."""
    n = len(keys)
    if n == 0:
        return []
    per = max(1, target_bytes // max(1, entry_bytes))
    return [sstable_from_run(keys[s:min(n, s + per)], vals[s:min(n, s + per)],
                             lsn_min, lsn_max, entry_bytes, page_bytes)
            for s in range(0, n, per)]


def total_bytes(tables) -> int:
    return sum(t.size_bytes for t in tables)


def overlapping(tables, lo: int, hi: int):
    """Subset of ``tables`` whose key range intersects [lo, hi]."""
    return [t for t in tables if t.overlaps(lo, hi)]
