"""Buffer cache (clock replacement) + disk I/O accounting.

The buffer cache stores immutable disk pages of SSTables and their Bloom
filters for *all* LSM-trees, exactly as in AsterixDB (§3 of the paper). Pages
are identified by (sst_id, page_index); Bloom pages use page_index -1.
Evicted page ids are forwarded to the tuner's simulated (ghost) cache so the
memory tuner can estimate the marginal utility of a bigger cache (§5.3).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class IOStats:
    """Page-granularity disk I/O counters (the paper's measured quantities)."""

    pages_flushed: int = 0          # flush writes
    pages_merge_written: int = 0    # merge (compaction) writes
    pages_merge_read: int = 0       # merge reads that missed the cache
    pages_query_read: int = 0       # query reads that missed the cache
    merge_pins: int = 0             # merge page requests (hit or miss)
    query_pins: int = 0             # query page requests (hit or miss)
    flushes_mem: int = 0            # memory-triggered flush events
    flushes_log: int = 0            # log-triggered flush events
    bytes_flushed_mem: int = 0      # write memory flushed by high memory usage
    bytes_flushed_log: int = 0      # write memory flushed by log truncation
    entries_merged_mem: int = 0     # in-memory merge CPU proxy (entries)
    entries_merged_disk: int = 0    # disk merge CPU proxy (entries)
    entries_written: int = 0
    ops: int = 0                    # logical operations observed
    write_stalls: int = 0           # write admission deferrals (service
                                    # backpressure: L0 stall / mem pressure)
    fsyncs: int = 0                 # physical fsync calls (files medium:
                                    # WAL commits + SSTable/manifest writes;
                                    # always 0 on the in-memory medium)
    fused_launches: int = 0         # fused read-path device launches
                                    # (one per store probe, or one per
                                    # tier on the per-tier fused path)
    fused_tiers: int = 0            # lookup tiers covered by those
                                    # launches: tiers/launches is the
                                    # launch-collapse factor BENCH rows
                                    # report as fused_tiers_per_launch
    fused_tier_hits: int = 0        # covered tiers that resolved >= 1
    fused_tier_misses: int = 0      # query vs. those that resolved none
    jit_compiles: int = 0           # backend jit shape-bucket compiles
    jit_cache_hits: int = 0         # backend jit shape-bucket cache hits
                                    # (both 0 on store paths; benchmark
                                    # windows populate them from
                                    # ExecutionBackend.jit_stats deltas)
    lat_p50_us: float = 0.0         # request-latency tail of a measurement
    lat_p99_us: float = 0.0         # window -- 0.0 on store paths;
    lat_p999_us: float = 0.0        # benchmark windows populate them from
    max_stall_us: float = 0.0       # the service's LatencyHistogram deltas
                                    # (max_stall = longest maintenance
                                    # pause inside one submit/drain call)
    bg_segments: int = 0            # maintenance prepare units (merge
                                    # sort/dedup, Bloom builds) consumed
                                    # from a background worker instead of
                                    # computed inline (0 with workers off)
    bg_overlap_us: float = 0.0      # worker compute time those consumed
                                    # units took off the foreground path
    fsync_wait_us: float = 0.0      # foreground time blocked on WAL
                                    # durability: inline fsyncs when
                                    # blocking, only the seal/sync
                                    # barrier waits when async
    flush_slices: int = 0           # proactive paced partial flushes
                                    # released below the hard memory
                                    # threshold (pacer_flush_threshold)

    def copy(self) -> "IOStats":
        return IOStats(**vars(self))

    def delta(self, prev: "IOStats") -> "IOStats":
        return IOStats(**{k: getattr(self, k) - getattr(prev, k)
                          for k in vars(self)})

    @property
    def pages_written(self) -> int:
        return self.pages_flushed + self.pages_merge_written

    @property
    def pages_read(self) -> int:
        return self.pages_merge_read + self.pages_query_read


class ClockCache:
    """Clock (second-chance) page cache with O(1) amortized eviction.

    Slots form a circular buffer; a dict maps page-id -> slot. The hand
    sweeps slots clearing reference bits until it finds a victim.
    """

    _TOMB = None

    def __init__(self, capacity_pages: int, on_evict=None):
        self.capacity = max(0, int(capacity_pages))
        self._slot_of: dict = {}    # pid -> slot index
        self._pids: list = []       # slot -> pid (or _TOMB)
        self._ref: list = []        # slot -> referenced bit
        self._free: list = []       # tombstone slots available for reuse
        self._hand = 0
        self.on_evict = on_evict
        self.hits = 0
        self.misses = 0

    def __len__(self):
        return len(self._slot_of)

    def __contains__(self, pid):
        return pid in self._slot_of

    def resize(self, capacity_pages: int) -> None:
        self.capacity = max(0, int(capacity_pages))
        while len(self._slot_of) > self.capacity:
            self._evict_one()

    def _evict_one(self) -> None:
        n = len(self._pids)
        while True:
            self._hand = (self._hand + 1) % n
            pid = self._pids[self._hand]
            if pid is self._TOMB:
                continue
            if self._ref[self._hand]:
                self._ref[self._hand] = 0
            else:
                del self._slot_of[pid]
                self._pids[self._hand] = self._TOMB
                self._free.append(self._hand)
                if self.on_evict is not None:
                    self.on_evict(pid)
                return

    def _install(self, pid) -> None:
        if self._free:
            s = self._free.pop()
            self._pids[s] = pid
            self._ref[s] = 1
        else:
            s = len(self._pids)
            self._pids.append(pid)
            self._ref.append(1)
        self._slot_of[pid] = s
        if len(self._slot_of) > self.capacity:
            self._evict_one()

    def pin(self, pid) -> bool:
        """Request a page. Returns True on hit, False on (simulated) disk read."""
        s = self._slot_of.get(pid)
        if s is not None:
            self._ref[s] = 1
            self.hits += 1
            return True
        self.misses += 1
        if self.capacity > 0:
            self._install(pid)
        return False

    def insert(self, pid) -> None:
        """Install a freshly written page (e.g. merge output) without a miss."""
        if self.capacity > 0 and pid not in self._slot_of:
            self._install(pid)

    def invalidate_many(self, pids) -> None:
        for pid in pids:
            s = self._slot_of.pop(pid, None)
            if s is not None:
                self._pids[s] = self._TOMB
                self._free.append(s)


@dataclass
class Disk:
    """Byte-accounted 'device': tracks I/O through the buffer cache."""

    page_bytes: int
    cache: ClockCache
    ghost: object = None                # tuner's GhostCache (optional)
    device_pool: object = None          # DevicePagePool (optional): HBM
                                        # residency for fused tier lookups
    page_store: object = None           # storage_io.FilePageStore (optional):
                                        # cache misses become real preads,
                                        # flush/merge writes become real files
    stats: IOStats = field(default_factory=IOStats)

    def query_pin(self, sst_id: int, page_index: int) -> None:
        self.stats.query_pins += 1
        if not self.cache.pin((sst_id, page_index)):
            self.stats.pages_query_read += 1
            if self.ghost is not None:
                self.ghost.on_disk_read((sst_id, page_index), merge=False)
            if self.page_store is not None:
                self.page_store.read_page(sst_id, page_index)

    def query_pin_many(self, sst_id: int, page_indices) -> None:
        """Batched query pins: one pin (hit-or-miss accounted) per entry.

        Accounting is identical to issuing ``query_pin`` per page in order,
        so batched reads and the scalar loop produce the same I/O counters;
        repeated pins of one page within a batch hit the cache after the
        first miss, exactly as in the scalar path.

        Fast path: a *consecutive* repeat pin is always a hit (nothing can
        evict the page between two adjacent pins of it, and the re-pin
        leaves the reference bit set exactly as the first did), so runs of
        repeats collapse to one real pin plus counter bumps. Duplicate-free
        batches pay one vectorized comparison; Bloom-page batches (all the
        same page) skip the Python loop almost entirely. Requires a real
        cache: with capacity 0 every pin misses, including repeats.
        """
        pages = np.asarray(page_indices, np.int64)
        n = len(pages)
        if n > 1 and self.cache.capacity > 0:
            keep = np.empty(n, bool)
            keep[0] = True
            np.not_equal(pages[1:], pages[:-1], out=keep[1:])
            reps = n - int(keep.sum())
            if reps:
                for p in pages[keep]:
                    self.query_pin(sst_id, int(p))
                self.stats.query_pins += reps
                self.cache.hits += reps
                return
        for p in pages:
            self.query_pin(sst_id, int(p))

    def pin_run(self, sst_ids, pages) -> None:
        """Ordered bulk query pins across possibly many tables -- the
        fused replay's hot path. Accounting is identical to calling
        ``query_pin(sst_ids[i], pages[i])`` for every i in sequence; the
        loop just binds the cache's hit path locally so a replay of a few
        hundred pins does not pay four attribute lookups and two call
        frames per page. Callers pass plain int sequences (``.tolist()``)
        so installed pids stay python-int keyed like the scalar path's.
        """
        cache = self.cache
        slot_of = cache._slot_of
        ref = cache._ref
        self.stats.query_pins += len(sst_ids)
        hits = 0
        for pid in zip(sst_ids, pages):
            s = slot_of.get(pid)
            if s is not None:
                ref[s] = 1
                hits += 1
                continue
            cache.misses += 1
            if cache.capacity > 0:
                cache._install(pid)
            self.stats.pages_query_read += 1
            if self.ghost is not None:
                self.ghost.on_disk_read(pid, merge=False)
            if self.page_store is not None:
                self.page_store.read_page(pid[0], pid[1])
        cache.hits += hits

    def merge_pin(self, sst_id: int, page_index: int) -> None:
        self.stats.merge_pins += 1
        if not self.cache.pin((sst_id, page_index)):
            self.stats.pages_merge_read += 1
            if self.ghost is not None:
                self.ghost.on_disk_read((sst_id, page_index), merge=True)
            if self.page_store is not None:
                self.page_store.read_page(sst_id, page_index)

    def merge_read_sst(self, sst) -> None:
        for p in range(sst.num_pages):
            self.merge_pin(sst.sst_id, p)

    def write_sst(self, sst, *, flush: bool) -> None:
        n = sst.num_pages + sst.bloom_pages()
        if flush:
            self.stats.pages_flushed += n
        else:
            self.stats.pages_merge_written += n
        for p in range(sst.num_pages):
            self.cache.insert((sst.sst_id, p))
        self.cache.insert((sst.sst_id, -1))  # bloom pages pinned as one unit
        if self.page_store is not None:
            self.page_store.write(sst)

    def ensure_sst(self, sst) -> None:
        """Make a restored table's file exist without touching counters
        (checkpoint restore re-keys tables to fresh sst_ids; the write
        was already accounted when the original id flushed)."""
        if self.page_store is not None:
            self.page_store.ensure(sst)

    def drop_sst(self, sst) -> None:
        pids = [(sst.sst_id, p) for p in range(-1, sst.num_pages)]
        self.cache.invalidate_many(pids)
        if self.ghost is not None:
            self.ghost.invalidate_many(pids)
        if self.device_pool is not None:
            self.device_pool.drop_sst(sst)
        if self.page_store is not None:
            self.page_store.mark_dropped(sst.sst_id)
