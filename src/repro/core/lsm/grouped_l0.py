"""Grouped L0 structure (§4.1.2).

L0 SSTables are organized into *groups* of mutually disjoint SSTables.
Groups are recency-ordered (``groups[0]`` is the oldest); keys in newer
groups override keys in older groups.

Insertion rule (paper): a flushed SSTable goes into the oldest group such
that no *newer* group contains an overlapping SSTable (and the target group
stays disjoint); otherwise a new (newest) group is created. Equivalently:
find the newest group with an overlap at index ``m`` and insert at ``m+1``.

Merge selection (paper): take the group with the fewest SSTables; among its
SSTables choose the one minimizing |overlapping L1 bytes| / |merged L0
bytes|, where the merged L0 set is the recency-downward closure (every
overlapping SSTable in *older* groups, transitively) — this closure is what
keeps reconciliation correct when the merge output lands in L1.
"""
from __future__ import annotations

from .memtable import _overlap_slice
from .sstable import SSTable


class GroupedL0:
    def __init__(self):
        self.groups: list[list[SSTable]] = []   # oldest .. newest

    # -- bookkeeping ----------------------------------------------------------
    @property
    def num_groups(self) -> int:
        return len(self.groups)

    @property
    def num_tables(self) -> int:
        return sum(len(g) for g in self.groups)

    @property
    def total_bytes(self) -> int:
        return sum(s.size_bytes for g in self.groups for s in g)

    @property
    def min_lsn(self) -> int:
        lsns = [s.lsn_min for g in self.groups for s in g]
        return min(lsns) if lsns else 2**62

    def all_tables(self):
        return [s for g in self.groups for s in g]

    # -- insertion (flush arrival) ---------------------------------------------
    def insert(self, sst: SSTable) -> None:
        m = -1
        for gi, g in enumerate(self.groups):
            i, j = _overlap_slice(g, sst.min_key, sst.max_key)
            if j > i:
                m = gi
        target = m + 1
        if target >= len(self.groups):
            self.groups.append([])
        g = self.groups[target]
        g.append(sst)
        g.sort(key=lambda s: s.min_key)

    # -- merge selection ---------------------------------------------------------
    def _closure_down(self, s: SSTable, gi: int):
        """Recency-downward transitive closure of overlapping SSTables.

        Returns a list of (group_index, sst) including (gi, s).
        """
        chosen = {(gi, id(s)): (gi, s)}
        work = [(gi, s)]
        while work:
            g, t = work.pop()
            for g2 in range(g):                  # strictly older groups
                i, j = _overlap_slice(self.groups[g2], t.min_key, t.max_key)
                for t2 in self.groups[g2][i:j]:
                    k = (g2, id(t2))
                    if k not in chosen:
                        chosen[k] = (g2, t2)
                        work.append((g2, t2))
        return list(chosen.values())

    def pick_merge(self, l1: list[SSTable], *, greedy: bool = True):
        """Choose the L0 merge set.

        Returns (l0_tables_newest_group_first, overlapping_l1_slice_bounds).
        ``greedy=False`` reproduces the paper's 'Grouped' baseline: leftmost
        SSTable of the oldest group, closure over *all* groups.
        """
        if not self.groups:
            return [], (0, 0)
        if greedy:
            # fewest-SSTables group; tie broken towards the oldest group
            counts = [(len(g), gi) for gi, g in enumerate(self.groups) if g]
            _, gi = min(counts)
            best_set, best_ratio = None, None
            for s in self.groups[gi]:
                group_set = self._closure_down(s, gi)
                lo = min(t.min_key for _, t in group_set)
                hi = max(t.max_key for _, t in group_set)
                i, j = _overlap_slice(l1, lo, hi)
                l1_bytes = sum(t.size_bytes for t in l1[i:j])
                l0_bytes = sum(t.size_bytes for _, t in group_set)
                ratio = l1_bytes / l0_bytes
                if best_ratio is None or ratio < best_ratio:
                    best_set, best_ratio = group_set, ratio
            chosen = best_set
        else:
            oldest = next(g for g in self.groups if g)
            s = oldest[0]
            gi = self.groups.index(oldest)
            # closure over all groups (always reconciliation-safe)
            chosen = [(gi, s)]
            seen = {id(s)}
            changed = True
            while changed:
                changed = False
                lo = min(t.min_key for _, t in chosen)
                hi = max(t.max_key for _, t in chosen)
                for g2, g in enumerate(self.groups):
                    i, j = _overlap_slice(g, lo, hi)
                    for t in g[i:j]:
                        if id(t) not in seen:
                            seen.add(id(t))
                            chosen.append((g2, t))
                            changed = True
        # newest group first for reconciliation precedence
        chosen.sort(key=lambda gt: -gt[0])
        tables = [t for _, t in chosen]
        lo = min(t.min_key for t in tables)
        hi = max(t.max_key for t in tables)
        return tables, _overlap_slice(l1, lo, hi)

    def remove(self, tables) -> None:
        ids = {id(t) for t in tables}
        for g in self.groups:
            g[:] = [s for s in g if id(s) not in ids]
        self.groups = [g for g in self.groups if g]

    # -- reads ---------------------------------------------------------------
    def lookup_tiers(self):
        """Disjoint, sorted table lists in probe order (newest group
        first); each tier holds at most one candidate per key. Used by the
        batched read path."""
        return list(reversed(self.groups))

    def tables_covering(self, key: int):
        """SSTables possibly containing ``key``, newest group first."""
        out = []
        for g in reversed(self.groups):
            i, j = _overlap_slice(g, key, key)
            out.extend(g[i:j])
        return out

    def tables_overlapping(self, lo: int, hi: int):
        out = []
        for g in reversed(self.groups):
            i, j = _overlap_slice(g, lo, hi)
            out.extend(g[i:j])
        return out


class FlatL0:
    """The original LSM-tree L0 (recency list of possibly-overlapping runs).

    Used by the 'Original' baseline in the grouped-L0 experiment and by the
    monolithic (B+-tree) memory-component baselines, whose full flushes emit
    one run at a time.
    """

    def __init__(self):
        self.runs: list[SSTable] = []            # oldest .. newest

    @property
    def num_groups(self) -> int:                 # each run behaves as a group
        return len(self.runs)

    num_tables = num_groups

    @property
    def total_bytes(self) -> int:
        return sum(s.size_bytes for s in self.runs)

    @property
    def min_lsn(self) -> int:
        return min((s.lsn_min for s in self.runs), default=2**62)

    def all_tables(self):
        return list(self.runs)

    def insert(self, sst: SSTable) -> None:
        self.runs.append(sst)

    def pick_merge(self, l1: list[SSTable], **_):
        """Merge all L0 runs at once (newest first)."""
        if not self.runs:
            return [], (0, 0)
        tables = list(reversed(self.runs))
        lo = min(t.min_key for t in tables)
        hi = max(t.max_key for t in tables)
        return tables, _overlap_slice(l1, lo, hi)

    def remove(self, tables) -> None:
        ids = {id(t) for t in tables}
        self.runs = [s for s in self.runs if id(s) not in ids]

    def lookup_tiers(self):
        """Each run is its own tier (runs may overlap each other), newest
        first -- matching the scalar probe order."""
        return [[s] for s in reversed(self.runs)]

    def tables_covering(self, key: int):
        return [s for s in reversed(self.runs) if s.covers(key)]

    def tables_overlapping(self, lo: int, hi: int):
        return [s for s in reversed(self.runs) if s.overlaps(lo, hi)]
