"""Baseline memory-component structures evaluated in §6.

- ``BTreeMemComponent``: monolithic updatable B+-tree memory component, as in
  RocksDB/HBase/AsterixDB. ~2/3 page utilization (internal fragmentation,
  Yao 1978), always flushed in full.
- ``AccordionMemComponent``: Accordion's multi-level memory structure
  (pipeline of immutable flat segments + in-memory compactions). The
  *index* variant merges only the key index (the value log keeps obsolete
  versions, so memory is not reclaimed); the *data* variant rewrites the
  data too, but a big merge temporarily doubles that component's footprint,
  which can force flushes (§6.2.1).
"""
from __future__ import annotations

import numpy as np

from ..engine import get_backend
from .memtable import MemComponentBase, MemStats, _slice_run

_INF = 2**62


class BTreeMemComponent(MemComponentBase):
    B_TREE_UTILIZATION = 2.0 / 3.0

    def __init__(self, *, entry_bytes: int, backend=None, **_):
        self.entry_bytes = entry_bytes
        self.backend = backend or get_backend()
        self.data: dict = {}
        self.lsn_min_: int = _INF
        self.lsn_max_: int = 0
        self.stats = MemStats()

    def write(self, keys, vals, lsn0: int) -> None:
        d = self.data
        for i, k in enumerate(keys):
            d[int(k)] = int(vals[i])
        self.lsn_min_ = min(self.lsn_min_, lsn0)
        self.lsn_max_ = max(self.lsn_max_, lsn0 + len(keys) * self.entry_bytes)

    def ingest_batch(self, keys, vals, lsn0: int) -> None:
        # A dict is already last-occurrence-wins and _seal sorts at flush
        # time, so a bulk update of the raw batch is bit-identical to a
        # backend sort+dedup -- no kernel call needed here.
        n = len(keys)
        if n == 0:
            return
        self.data.update(zip(np.asarray(keys, np.int64).tolist(),
                             np.asarray(vals, np.int64).tolist()))
        self.lsn_min_ = min(self.lsn_min_, lsn0)
        self.lsn_max_ = max(self.lsn_max_, lsn0 + n * self.entry_bytes)

    @property
    def used_bytes(self) -> int:
        # fragmentation: pages are ~2/3 full in an updatable B+-tree
        return int(len(self.data) * self.entry_bytes / self.B_TREE_UTILIZATION)

    @property
    def min_lsn(self) -> int:
        return self.lsn_min_ if self.data else _INF

    def is_empty(self) -> bool:
        return not self.data

    def lookup(self, key: int):
        v = self.data.get(key)
        return (True, v) if v is not None else (False, 0)

    def flush_full(self):
        if not self.data:
            return []
        keys = np.fromiter(self.data.keys(), np.int64, len(self.data))
        order = np.argsort(keys)
        keys = keys[order]
        vals = np.array([self.data[int(k)] for k in keys], np.int64)
        out = [(keys, vals, self.lsn_min_, self.lsn_max_)]
        self.data = {}
        self.lsn_min_, self.lsn_max_ = _INF, 0
        return out

    # monolithic structures only support full flushes
    flush_partial = flush_full
    flush_min_lsn = flush_full

    def scan_runs(self, lo: int, hi: int):
        ks = np.array([k for k in self.data if lo <= k <= hi], np.int64)
        if not len(ks):
            return []
        ks.sort()
        vs = np.array([self.data[int(k)] for k in ks], np.int64)
        return [(ks, vs)]


class AccordionMemComponent(MemComponentBase):
    INDEX_ENTRY_BYTES = 16           # key + offset in the value log

    def __init__(self, *, entry_bytes: int, active_bytes_max: int,
                 merge_data: bool, pipeline_threshold: int = 4,
                 backend=None, **_):
        self.backend = backend or get_backend()
        self.entry_bytes = entry_bytes
        self.active_bytes_max = active_bytes_max
        self.merge_data = merge_data            # Accordion-data vs -index
        self.pipeline_threshold = pipeline_threshold
        self.active: dict = {}
        self.segments: list = []                # newest last: (keys, vals, raw_bytes, lsn_min, lsn_max)
        self.lsn_min_: int = _INF
        self.lsn_max_: int = 0
        self.stats = MemStats()
        self.request_flush = False              # set when a data-merge peak blows the budget
        self.budget_hint_bytes: int = _INF      # set by the store before maintenance

    # -- write path ------------------------------------------------------------
    def write(self, keys, vals, lsn0: int) -> None:
        # Seal + pipeline merges are *not* inline: the maintenance
        # scheduler drives them through ``upkeep_step`` at tick time.
        a = self.active
        for i, k in enumerate(keys):
            a[int(k)] = int(vals[i])
        self.lsn_min_ = min(self.lsn_min_, lsn0)
        self.lsn_max_ = max(self.lsn_max_, lsn0 + len(keys) * self.entry_bytes)

    def ingest_batch(self, keys, vals, lsn0: int) -> None:
        # As in BTreeMemComponent: the active dict is last-wins and _seal
        # sorts, so a bulk update beats a backend sort+dedup round-trip.
        n = len(keys)
        if n == 0:
            return
        self.active.update(zip(np.asarray(keys, np.int64).tolist(),
                               np.asarray(vals, np.int64).tolist()))
        self.lsn_min_ = min(self.lsn_min_, lsn0)
        self.lsn_max_ = max(self.lsn_max_, lsn0 + n * self.entry_bytes)

    def over_active_limit(self) -> bool:
        return len(self.active) * self.entry_bytes >= self.active_bytes_max

    def upkeep_step(self) -> bool:
        if self.over_active_limit():
            self._seal()
            return True
        if len(self.segments) > self.pipeline_threshold:
            self._merge_pipeline()
            return True
        return False

    def _seal(self) -> None:
        if not self.active:
            return
        keys = np.fromiter(self.active.keys(), np.int64, len(self.active))
        order = np.argsort(keys)
        keys = keys[order]
        vals = np.array([self.active[int(k)] for k in keys], np.int64)
        raw = len(keys) * self.entry_bytes
        self.segments.append((keys, vals, raw, self.lsn_min_, self.lsn_max_))
        self.stats.entries_sealed += len(keys)
        self.active = {}

    def _merge_pipeline(self) -> None:
        runs = [(s[0], s[1]) for s in reversed(self.segments)]  # newest first
        keys, vals = self.backend.merge_runs(runs)
        self.stats.entries_merged += sum(len(r[0]) for r in runs)
        self.stats.merges += 1
        lsn_min = min(s[3] for s in self.segments)
        lsn_max = max(s[4] for s in self.segments)
        if self.merge_data:
            # data rewrite: obsolete values reclaimed, but the merge itself
            # transiently holds both old and new copies.
            peak = (sum(s[2] for s in self.segments)
                    + len(keys) * self.entry_bytes)
            if peak > self.budget_hint_bytes:
                self.request_flush = True
            raw = len(keys) * self.entry_bytes
        else:
            # index-only merge: the value log keeps obsolete versions
            raw = sum(s[2] for s in self.segments)
        self.segments = [(keys, vals, raw, lsn_min, lsn_max)]

    # -- bookkeeping -------------------------------------------------------------
    @property
    def used_bytes(self) -> int:
        seg = sum(s[2] + len(s[0]) * self.INDEX_ENTRY_BYTES
                  for s in self.segments)
        return seg + len(self.active) * self.entry_bytes

    @property
    def min_lsn(self) -> int:
        lsns = [s[3] for s in self.segments]
        if self.active:
            lsns.append(self.lsn_min_)
        return min(lsns) if lsns else _INF

    def is_empty(self) -> bool:
        return not self.active and not self.segments

    def lookup(self, key: int):
        v = self.active.get(key)
        if v is not None:
            return True, v
        for keys, vals, *_ in reversed(self.segments):
            i = int(np.searchsorted(keys, key))
            if i < len(keys) and int(keys[i]) == key:
                return True, int(vals[i])
        return False, 0

    def lookup_batch(self, qkeys):
        qkeys = np.asarray(qkeys, np.int64)
        n = len(qkeys)
        found = np.zeros(n, bool)
        vals = np.zeros(n, np.int64)
        a = self.active
        for i, k in enumerate(qkeys.tolist()):
            v = a.get(k)
            if v is not None:
                found[i] = True
                vals[i] = v
        for keys, segvals, *_ in reversed(self.segments):
            unresolved = np.flatnonzero(~found)
            if not len(unresolved):
                break
            if not len(keys):
                continue
            pos, hit = self.backend.lookup_batch(keys, qkeys[unresolved])
            gidx = unresolved[hit]
            found[gidx] = True
            vals[gidx] = segvals[pos[hit]]
        return found, vals

    def scan_runs(self, lo: int, hi: int):
        out = []
        ks = np.array([k for k in self.active if lo <= k <= hi], np.int64)
        if len(ks):
            ks.sort()
            out.append((ks, np.array([self.active[int(k)] for k in ks],
                                     np.int64)))
        for keys, vals, *_ in reversed(self.segments):
            r = _slice_run(keys, vals, lo, hi)
            if r is not None:
                out.append(r)
        return out

    # -- flush (whole component, HBase-style) --------------------------------------
    def flush_full(self):
        self._seal()
        if not self.segments:
            return []
        runs = [(s[0], s[1]) for s in reversed(self.segments)]
        keys, vals = self.backend.merge_runs(runs)
        if len(runs) > 1:
            self.stats.entries_merged += sum(len(r[0]) for r in runs)
        lsn_min = min(s[3] for s in self.segments)
        lsn_max = max(s[4] for s in self.segments)
        self.segments = []
        self.request_flush = False
        self.lsn_min_, self.lsn_max_ = _INF, 0
        return [(keys, vals, lsn_min, lsn_max)]

    flush_partial = flush_full
    flush_min_lsn = flush_full
