"""Multi-tenant LSM store behind the StorageService front door: per-tenant
sessions with admission quotas, the three §4.2 flush policies under a
skewed 10-tree workload, a workload shift with the AdaptiveGovernor
(the memory tuner as the service's pluggable governor) reallocating between
write memory and buffer cache, and the sharded data plane absorbing a
hot-shard skew through the shared memory arena.

Run:  PYTHONPATH=src python examples/multi_tenant_store.py
"""
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.common import (MB, Workload, bulk_load,  # noqa: E402
                               make_service, make_sharded_service, measure)

from repro.core import (AdaptiveGovernor, Deferred, Put,  # noqa: E402
                        ShardRouter, TunerConfig)

N = 10
probs = np.full(N, 0.2 / 8)
probs[:2] = 0.8 / 2                       # 80-20 hotspot

print("=== flush policies under an 80-20 hotspot (write-only) ===")
for policy in ["mem", "lsn", "opt"]:
    svc = make_service(scheme="partitioned", flush_policy=policy,
                       write_memory_bytes=2 * MB, max_log_bytes=8 * MB)
    names = [f"t{i}" for i in range(N)]
    for n in names:
        svc.create_tree(n)
        bulk_load(svc.store, n, 40_000)
    w = Workload(svc, names, 40_000, tree_probs=probs)
    m = measure(svc, lambda: w.run(80_000, write_frac=1.0))
    hot_mem = sum(svc.store.trees[f"t{i}"].mem_bytes for i in range(2))
    cold_mem = sum(svc.store.trees[f"t{i}"].mem_bytes for i in range(2, N))
    print(f"  {policy:4s}: throughput={m['throughput']:9.0f} "
          f"write_amp={m['write_amp']:.2f} stalls={m['stalls']} "
          f"hot/cold mem={hot_mem / max(cold_mem, 1):.1f}x")

print("=== per-tenant sessions: admission quota defers, drain clears ===")
svc = make_service(scheme="partitioned", flush_policy="opt",
                   write_memory_bytes=2 * MB, max_log_bytes=8 * MB)
svc.create_tree("tenant")
metered = svc.session("metered", max_outstanding_keys=512)
keys = np.arange(2048)
res = metered.submit([Put("tenant", keys)])          # over the 512-key quota
assert isinstance(res[0], Deferred) and res[0].reason == "session-quota"
ok = metered.submit([Put("tenant", keys[:256])])     # within quota
print(f"  2048-key Put -> {res[0].reason}; 256-key Put -> "
      f"{type(ok[0]).__name__}; session deferred_events="
      f"{metered.stats.deferred_events}")

print("=== workload shift: write-heavy -> read-heavy (governed tuner) ===")
governor = AdaptiveGovernor(TunerConfig(
    min_step_bytes=256 << 10, ops_cycle=15_000, min_write_mem=1 * MB,
    min_rel_gain=0.0002))
svc = make_service(scheme="partitioned", flush_policy="opt",
                   write_memory_bytes=8 * MB, total_memory_bytes=48 * MB,
                   max_log_bytes=6 * MB, governor=governor)
names = [f"t{i}" for i in range(N)]
for n in names:
    svc.create_tree(n)
    bulk_load(svc.store, n, 40_000)
w = Workload(svc, names, 40_000, tree_probs=probs)
for phase, wf in [("write-heavy", 0.9), ("read-heavy", 0.05)]:
    w.run(120_000, write_frac=wf)
    print(f"  after {phase:11s}: write memory = "
          f"{svc.store.write_memory_bytes / MB:5.1f} MB "
          f"(governor plans so far: {len(svc.plans)})")

print("=== sharded data plane: one arena absorbs a hot shard ===")
SHARDS, RECORDS = 4, 60_000
svc = make_sharded_service(router=ShardRouter.ranges(SHARDS, RECORDS),
                           flush_policy="opt", write_memory_bytes=1 * MB,
                           max_log_bytes=8 * MB)
svc.create_tree("kv")
bulk_load(svc.store, "kv", RECORDS)
rng = np.random.default_rng(0)
hot_hi = RECORDS // SHARDS                      # shard 0's key range
for _ in range(120):
    lo, hi = (0, hot_hi) if rng.random() < 0.85 else (hot_hi, RECORDS)
    ks = rng.integers(lo, hi, size=256)
    svc.submit_strict([Put("kv", ks, ks)])
per = svc.store.shard_tree_stats()
total = max(1, sum(a["mem_bytes"] for a in per))
shares = " ".join(f"s{i}={a['mem_bytes'] / total:.2f}"
                  for i, a in enumerate(per))
print(f"  write-memory shares across {SHARDS} shards (85% traffic -> s0): "
      f"{shares}")
print("OK")
