"""Multi-tenant LSM store: compares the three §4.2 flush policies under a
skewed 10-tree workload, then shifts the workload and shows the memory
tuner reallocating between write memory and buffer cache.

Run:  PYTHONPATH=src python examples/multi_tenant_store.py
"""
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.common import MB, Workload, bulk_load, make_store, measure  # noqa: E402

from repro.core import AdaptiveMemoryController, TunerConfig  # noqa: E402

N = 10
probs = np.full(N, 0.2 / 8)
probs[:2] = 0.8 / 2                       # 80-20 hotspot

print("=== flush policies under an 80-20 hotspot (write-only) ===")
for policy in ["mem", "lsn", "opt"]:
    store = make_store(scheme="partitioned", flush_policy=policy,
                       write_memory_bytes=2 * MB, max_log_bytes=8 * MB)
    names = [f"t{i}" for i in range(N)]
    for n in names:
        store.create_tree(n)
        bulk_load(store, n, 40_000)
    w = Workload(store, names, 40_000, tree_probs=probs)
    m = measure(store, lambda: w.run(80_000, write_frac=1.0))
    hot_mem = sum(store.trees[f"t{i}"].mem_bytes for i in range(2))
    cold_mem = sum(store.trees[f"t{i}"].mem_bytes for i in range(2, N))
    print(f"  {policy:4s}: throughput={m['throughput']:9.0f} "
          f"write_amp={m['write_amp']:.2f} "
          f"hot/cold mem={hot_mem / max(cold_mem, 1):.1f}x")

print("=== workload shift: write-heavy -> read-heavy (memory tuner) ===")
store = make_store(scheme="partitioned", flush_policy="opt",
                   write_memory_bytes=8 * MB, total_memory_bytes=48 * MB,
                   max_log_bytes=6 * MB)
names = [f"t{i}" for i in range(N)]
for n in names:
    store.create_tree(n)
    bulk_load(store, n, 40_000)
ctrl = AdaptiveMemoryController(store, TunerConfig(
    min_step_bytes=256 << 10, ops_cycle=15_000, min_write_mem=1 * MB,
    min_rel_gain=0.0002))
w = Workload(store, names, 40_000, tree_probs=probs)
for phase, wf in [("write-heavy", 0.9), ("read-heavy", 0.05)]:
    w.run(120_000, write_frac=wf, on_batch=lambda s: ctrl.maybe_tune())
    print(f"  after {phase:11s}: write memory = "
          f"{store.write_memory_bytes / MB:5.1f} MB "
          f"(tuning steps so far: {len(ctrl.tuner.records)})")
print("OK")
