"""End-to-end training driver: a ~15M-param minicpm-family model for a few
hundred steps on CPU with checkpoint/restart (kill-safe) and the WSD
schedule, via the production launch path (repro.launch.train).

Run:  PYTHONPATH=src python examples/train_lm.py
"""
import shutil

from repro.configs import get_config
from repro.launch.train import main as train_main

CKPT = "/tmp/repro_train_lm_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

# a ~15M-param member of the minicpm family (WSD schedule)
cfg = get_config("minicpm-2b")
small = cfg.with_(name="minicpm-15m", num_layers=4, d_model=256,
                  num_heads=4, num_kv_heads=4, d_ff=1024, head_dim=64,
                  vocab_size=8192, compute_dtype="float32",
                  param_dtype="float32")
from repro.configs.base import register  # noqa: E402
register(small)

losses = train_main([
    "--arch", "minicpm-15m", "--steps", "200", "--batch", "8",
    "--seq", "128", "--lr", "3e-3", "--ckpt", CKPT, "--ckpt-every", "50",
])
assert losses[-1] < losses[0] * 0.5, "loss should fall substantially"
print(f"trained 200 steps: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

# restart from the latest checkpoint and continue (fault-tolerance demo)
more = train_main([
    "--arch", "minicpm-15m", "--steps", "220", "--batch", "8",
    "--seq", "128", "--lr", "3e-3", "--ckpt", CKPT,
])
print(f"resumed from step 200 and reached step 220; "
      f"final loss {more[-1]:.3f}")
