"""Quickstart: the paper's adaptive memory management behind one front door.

Opens a ``StorageService`` over an LSM store with a partitioned memory
component, submits typed mixed-op request plans (Put + Get in one batch)
for a skewed two-tree workload, and lets the default ``AdaptiveGovernor``
(the §5.4 memory tuner) move the write-memory/buffer-cache boundary while
the §4.2 optimal flush policy allocates write memory by write rate.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (AdaptiveGovernor, Get, Put, StorageService,
                        StoreConfig, TunerConfig)

KB, MB = 1 << 10, 1 << 20

service = StorageService.open(
    StoreConfig(
        total_memory_bytes=64 * MB,
        write_memory_bytes=4 * MB,          # the governor will adjust this
        sim_cache_bytes=1 * MB,
        page_bytes=4 * KB, entry_bytes=256,
        active_sstable_bytes=256 * KB, sstable_bytes=512 * KB,
        max_log_bytes=8 * MB,
        scheme="partitioned",               # §4.1 partitioned memory component
        flush_policy="opt",                 # §4.2 write-rate-proportional
    ),
    governor=AdaptiveGovernor(TunerConfig(
        min_step_bytes=256 * KB, ops_cycle=20_000, min_write_mem=1 * MB)))
hot = service.create_tree("hot")
cold = service.create_tree("cold")

rng = np.random.default_rng(0)
for step in range(400):
    # 90% of writes go to 'hot'; one submit = one typed mixed-op plan
    # (vectorized write + read steps, one scheduler tick, governor observed)
    tree = "hot" if step % 10 else "cold"
    keys = rng.integers(0, 200_000, size=256)
    ack, reads = service.submit([Put(tree, keys, keys),
                                 Get(tree, keys[:32])])
    assert reads.found.all() and (reads.vals == keys[:32]).all()

store = service.store
st = service.stats
print(f"execution backend: {store.backend.name} "
      f"(select with StoreConfig.backend or REPRO_LSM_BACKEND)")
print(f"write memory (governed): {store.write_memory_bytes / MB:.1f} MB")
print(f"hot tree memory:  {hot.mem_bytes / KB:8.0f} KB  "
      f"(write-rate-proportional share)")
print(f"cold tree memory: {cold.mem_bytes / KB:8.0f} KB")
print(f"disk pages written={st.pages_written} read={st.pages_read} "
      f"over {st.ops} ops; write stalls deferred={st.write_stalls}")
print(f"governor plans applied: {len(service.plans)}")
for r in service.governor.records[:5]:
    print(f"  x={r.x / MB:6.1f}MB cost'={r.cost_prime:+.2e} "
          f"-> x_next={r.x_next / MB:6.1f}MB {r.stopped}")
assert hot.mem_bytes > cold.mem_bytes, "OPT policy favors the hot tree"
print("OK")
