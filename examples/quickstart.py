"""Quickstart: the paper's adaptive memory management in ~60 lines.

Creates an LSM store with a partitioned memory component, writes a skewed
multi-tree workload, watches the optimal flush policy allocate write memory
by write rate, and lets the memory tuner move the write-memory/buffer-cache
boundary to cut I/O per operation.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import AdaptiveMemoryController, TunerConfig
from repro.core.lsm.storage import LSMStore, StoreConfig

KB, MB = 1 << 10, 1 << 20

store = LSMStore(StoreConfig(
    total_memory_bytes=64 * MB,
    write_memory_bytes=4 * MB,          # the tuner will adjust this
    sim_cache_bytes=1 * MB,
    page_bytes=4 * KB, entry_bytes=256,
    active_sstable_bytes=256 * KB, sstable_bytes=512 * KB,
    max_log_bytes=8 * MB,
    scheme="partitioned",               # §4.1 partitioned memory component
    flush_policy="opt",                 # §4.2 write-rate-proportional
))
hot = store.create_tree("hot")
cold = store.create_tree("cold")
ctrl = AdaptiveMemoryController(store, TunerConfig(
    min_step_bytes=256 * KB, ops_cycle=20_000, min_write_mem=1 * MB))

rng = np.random.default_rng(0)
for step in range(400):
    # 90% of writes go to 'hot'; reads are zipf-ish point lookups
    tree = "hot" if step % 10 else "cold"
    keys = rng.integers(0, 200_000, size=256)
    store.write(tree, keys, keys)
    found, vals = store.read_batch(tree, keys[:32])  # batched point reads
    assert found.all() and (vals == keys[:32]).all()
    ctrl.maybe_tune()

st = store.disk.stats
print(f"execution backend: {store.backend.name} "
      f"(select with StoreConfig.backend or REPRO_LSM_BACKEND)")
print(f"write memory (tuned): {store.write_memory_bytes / MB:.1f} MB")
print(f"hot tree memory:  {hot.mem_bytes / KB:8.0f} KB  "
      f"(write-rate-proportional share)")
print(f"cold tree memory: {cold.mem_bytes / KB:8.0f} KB")
print(f"disk pages written={st.pages_written} read={st.pages_read} "
      f"over {st.ops} ops")
print(f"tuning steps taken: {len(ctrl.tuner.records)}")
for r in ctrl.tuner.records[:5]:
    print(f"  x={r.x / MB:6.1f}MB cost'={r.cost_prime:+.2e} "
          f"-> x_next={r.x_next / MB:6.1f}MB {r.stopped}")
assert hot.mem_bytes > cold.mem_bytes, "OPT policy favors the hot tree"
print("OK")
