"""End-to-end serving driver: a reduced yi-6b-family model serving batched
requests with the paged KV pool + prefix cache governed by ``HBMGovernor``
-- the paper's memory tuner behind the same pluggable ``MemoryGovernor``
interface the LSM ``StorageService`` uses, here splitting HBM between the
KV pool and the prefix cache instead of write memory and buffer cache.

Run:  PYTHONPATH=src python examples/serve_adaptive_kv.py
"""
from repro.launch.serve import main as serve_main

stats = serve_main([
    "--arch", "yi-6b", "--reduced", "--requests", "48", "--batch", "4",
    "--prompt-len", "48", "--gen", "12", "--shared-prefix-frac", "0.7",
])
hits = stats["prefix_hits"]
assert hits > 0, "shared prefixes should hit the prefix cache"
print("OK — served with governor-managed adaptive HBM split")
