"""Unit tests for the physical storage plane (``core.storage_io``).

Covers the frame codec and its torn-tail contract, the segmented
``FileWAL`` (rollover, reopen, truncation unlinks, fsync policies and the
group-commit accounting), the per-SSTable ``FilePageStore`` (real page
reads, CRC-verified loads, pin/defer/gc lifecycle), the manifest edit
codec round-trip (hypothesis-driven when available), and the files-vs-
memory differential: the storage medium must never change engine state,
only make it durable.
"""
import os
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import LSMStore, StoreConfig
from repro.core.durability.manifest import ManifestEdit
from repro.core.service import Put, StorageService
from repro.core.shard.sharded import ShardedStore
from repro.core.storage_io import (CorruptFrameError, FileManifest,
                                   FilePageStore, FileWAL, build_frame,
                                   decode_edit, encode_edit, open_plane,
                                   scan_frames)

from kill_workload import drive, kill_config
from test_differential import fingerprint

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KB = 1024


# ------------------------------ frame codec -----------------------------------
def test_frame_roundtrip_and_tail_offsets():
    frames = [(7, b"hello"), (0, b""), (2**40, b"x" * 1000)]
    blob = b"".join(build_frame(t, p) for t, p in frames)
    out, end = scan_frames(blob)
    assert out == frames and end == len(blob)


@pytest.mark.parametrize("junk", [
    b"\x00" * 5,                         # partial header
    build_frame(1, b"abc")[:-2],         # payload cut short
    b"\xff" * 40,                        # bad magic
], ids=["short-header", "cut-payload", "bad-magic"])
def test_scan_stops_at_torn_tail(junk):
    good = build_frame(3, b"keep me")
    out, end = scan_frames(good + junk)
    assert out == [(3, b"keep me")] and end == len(good)


def test_scan_stops_at_crc_mismatch():
    f = bytearray(build_frame(1, b"payload"))
    f[-3] ^= 0xFF                        # flip a payload bit
    out, end = scan_frames(bytes(f))
    assert out == [] and end == 0


# ------------------------------ FileWAL ---------------------------------------
def _fill(wal, n=12, tree="t", entry_bytes=256, keys_per=16):
    rng = np.random.default_rng(0)
    wal.append_tree_create(tree, dataset=None, entry_bytes=None)
    for _ in range(n):
        keys = rng.integers(0, 1000, size=keys_per)
        wal.append_batch(tree, keys, keys * 2, entry_bytes=entry_bytes,
                         op=True)
        wal.commit(keys_per)


def test_filewal_create_refuses_nonempty(tmp_path):
    (tmp_path / "stray").write_text("x")
    with pytest.raises(FileExistsError, match="not empty"):
        FileWAL.create(str(tmp_path))


def test_filewal_segments_roll_and_reopen(tmp_path):
    wal = FileWAL.create(str(tmp_path), segment_bytes=2 * KB)
    _fill(wal, n=12)
    assert wal.segment_count > 1, "workload must roll segments"
    wal.close()
    re = FileWAL.open(str(tmp_path), segment_bytes=2 * KB)
    assert re.head_lsn == wal.head_lsn
    assert re.next_seq == wal.next_seq
    assert re.num_records == wal.num_records
    assert [r.seq for r in re._records] == [r.seq for r in wal._records]
    assert [r.buf for r in re._records] == [r.buf for r in wal._records]
    assert re.all_durable and re.durable_lsn == re.head_lsn


def test_filewal_truncate_unlinks_sealed_segments(tmp_path):
    wal = FileWAL.create(str(tmp_path), segment_bytes=2 * KB)
    _fill(wal, n=12)
    n_before = len(os.listdir(tmp_path))
    # drop the checkpoint-covered prefix, as enforce_wal does after a
    # checkpoint: physical drops key off the sequence barrier
    mid_rec = wal._records[len(wal._records) // 2]
    mid = mid_rec.lsn0
    wal.truncate(mid, keep_after_seq=mid_rec.seq - 1)
    assert len(os.listdir(tmp_path)) < n_before, \
        "truncation must unlink whole dead segments"
    assert wal.truncated_to == mid
    wal.close()
    re = FileWAL.open(str(tmp_path), segment_bytes=2 * KB)
    assert re.truncated_to == mid
    assert re.head_lsn == wal.head_lsn and re.next_seq == wal.next_seq
    assert [r.seq for r in re._records] == [r.seq for r in wal._records]


def test_filewal_truncate_all_preserves_head_via_meta(tmp_path):
    wal = FileWAL.create(str(tmp_path))
    _fill(wal, n=4)
    head = wal.head_lsn
    # every record at/below the barrier: log empties physically
    wal.truncate(head, keep_after_seq=wal.next_seq - 1)
    assert wal.num_records == 0
    wal.close()
    re = FileWAL.open(str(tmp_path))
    assert re.head_lsn == head and re.next_seq == wal.next_seq
    assert re.num_records == len(wal._records)


def test_filewal_torn_tail_last_segment_only(tmp_path):
    wal = FileWAL.create(str(tmp_path), segment_bytes=2 * KB)
    _fill(wal, n=12)
    wal.close()
    paths = sorted(p for p in os.listdir(tmp_path) if p.startswith("seg-"))
    with open(tmp_path / paths[-1], "ab") as f:
        f.write(b"\xfftorn!")
    re = FileWAL.open(str(tmp_path), segment_bytes=2 * KB)
    assert re.num_records == wal.num_records     # tail dropped, then healed
    re.close()
    with open(tmp_path / paths[0], "ab") as f:   # sealed file: corruption
        f.write(b"\xffbad")
    with pytest.raises(CorruptFrameError, match="interior corruption"):
        FileWAL.open(str(tmp_path), segment_bytes=2 * KB)


def test_fsync_policy_counts(tmp_path):
    n = 10
    counts = {}
    for policy in ("per_record", "per_batch", "group"):
        root = tmp_path / policy
        wal = FileWAL.create(str(root), fsync_policy=policy,
                             group_bytes=16 * KB, group_max_wait_s=3600.0)
        _fill(wal, n=n)
        counts[policy] = wal.fsyncs
        if policy == "group":
            assert not wal.all_durable           # tail still buffered
            assert wal.durable_lsn < wal.head_lsn
            wal.sync()
            assert wal.all_durable and wal.durable_lsn == wal.head_lsn
        else:
            assert wal.all_durable
        assert wal.commit_hist.count > 0
        wal.close()
    # per_record also fsyncs the tree-create record; group batches many
    # commits behind one fsync
    assert counts["per_record"] == n + 1
    assert counts["per_batch"] == n
    assert counts["group"] < counts["per_batch"] / 2


def test_group_commit_latency_accounting(tmp_path):
    wal = FileWAL.create(str(tmp_path), fsync_policy="group",
                         group_bytes=1, group_max_wait_s=3600.0)
    keys = np.arange(8)
    wal.append_batch("t", keys, keys, entry_bytes=64, op=True)
    wal.commit(8)                         # group_bytes=1: fsyncs instantly
    assert wal.fsyncs == 1
    assert wal.commit_hist.count == 8     # one histogram entry per op
    assert wal.commit_hist.quantile(0.99) >= 0


# ---------------------------- FilePageStore -----------------------------------
def _sst(sst_id, n=64, entry_bytes=256, page_bytes=4 * KB):
    keys = np.arange(n, dtype=np.int64)
    return SimpleNamespace(sst_id=sst_id, keys=keys, vals=keys * 3,
                           lsn_min=10, lsn_max=99, entry_bytes=entry_bytes,
                           page_bytes=page_bytes)


def test_page_store_write_load_roundtrip(tmp_path):
    ps = FilePageStore(str(tmp_path))
    sst = _sst(7)
    ps.write(sst)
    run = ps.load(7)
    np.testing.assert_array_equal(run["keys"], sst.keys)
    np.testing.assert_array_equal(run["vals"], sst.vals)
    assert (run["lsn_min"], run["lsn_max"]) == (10, 99)
    assert (run["entry_bytes"], run["page_bytes"]) == (256, 4 * KB)
    assert ps.fsyncs == 1 and ps.ids() == {7}


def test_page_store_read_page_geometry(tmp_path):
    ps = FilePageStore(str(tmp_path))
    ps.write(_sst(1, n=20, entry_bytes=256, page_bytes=4 * KB))
    epp = 4 * KB // 256                   # 16 entries per page
    assert ps.read_page(1, 0) == 2 * epp * 8          # full page
    assert ps.read_page(1, 1) == 2 * (20 - epp) * 8   # ragged last page
    assert ps.read_page(1, 2) == 0                     # past the end
    assert ps.read_page(1, -1) > 0                     # header (Bloom unit)
    assert ps.read_page(999, 0) == 0                   # missing file


def test_page_store_load_detects_corruption(tmp_path):
    ps = FilePageStore(str(tmp_path))
    ps.write(_sst(3))
    with open(ps.path(3), "r+b") as f:
        f.seek(60)
        f.write(b"\xff")
    with pytest.raises(RuntimeError, match="CRC mismatch"):
        ps.load(3)


def test_page_store_pin_defers_unlink(tmp_path):
    ps = FilePageStore(str(tmp_path))
    for i in (1, 2, 3):
        ps.write(_sst(i))
    ps.set_pinned({1, 2})
    ps.mark_dropped(1)                    # pinned: defer
    ps.mark_dropped(3)                    # unpinned: immediate
    assert ps.ids() == {1, 2}
    ps.set_pinned({2})                    # pin moves on -> deferred unlink
    assert ps.ids() == {2}
    assert ps.gc(live_ids=set()) == []    # 2 still pinned: gc spares it
    ps.set_pinned(set())
    assert ps.gc(live_ids=set()) == [2]
    assert ps.ids() == set()


# --------------------------- manifest edit codec ------------------------------
def test_edit_codec_fixed_cases():
    for e in (ManifestEdit(1, "add", 0, "orders", 17, 4096, 1 << 40),
              ManifestEdit(9, "watermark", 3, "", -1, 0, 0),
              ManifestEdit(0, "drop", 2, "tree/with-punct", 2**50, 1, -5)):
        out = decode_edit(encode_edit(e))
        assert out == e
        assert len(encode_edit(e)) % 8 == 0


if HAVE_HYPOTHESIS:
    names = st.text(max_size=32).map(lambda s: s.replace("\x00", ""))

    @settings(max_examples=80, deadline=None)
    @given(st.integers(0, 2**60), names, st.integers(0, 255), names,
           st.integers(-1, 2**60), st.integers(0, 2**31),
           st.integers(-2**60, 2**60))
    def test_hypothesis_edit_roundtrip(version, kind, shard, tree, sst_id,
                                       n_entries, lsn):
        """encode_edit/decode_edit is identity for arbitrary edits --
        unicode names, empty strings, negative sentinel ids."""
        e = ManifestEdit(version, kind, shard, tree, sst_id, n_entries, lsn)
        assert decode_edit(encode_edit(e)) == e


# ------------------------- files-vs-memory differential -----------------------
def test_files_medium_is_state_transparent(tmp_path):
    """Same workload, both media: identical fingerprints, identical WAL
    record streams, identical IOStats except the fsync counter and the
    foreground durability-blocking clock (which the in-memory medium
    never moves)."""
    reset_sst_ids()
    sf = ShardedStore(kill_config(2, medium="files", root=str(tmp_path)),
                      shards=2)
    drive(sf)
    sf.wal.sync()
    reset_sst_ids()
    sm = ShardedStore(kill_config(2, medium="memory"), shards=2)
    drive(sm)
    assert [fingerprint(sh.store) for sh in sf.shards] \
        == [fingerprint(sh.store) for sh in sm.shards]
    assert [r.seq for r in sf.wal._records] == [r.seq for r in sm.wal._records]
    vf, vm = dict(vars(sf.arena.disk.stats)), dict(vars(sm.arena.disk.stats))
    assert vf.pop("fsyncs") > 0 and vm.pop("fsyncs") == 0
    assert vf.pop("fsync_wait_us") > 0 and vm.pop("fsync_wait_us") == 0
    assert vf == vm
    assert sf.arena.disk.page_store is not None
    assert sm.arena.disk.page_store is None


def test_page_files_track_live_set(tmp_path):
    """Every on-disk sst file is either live in the manifest or pinned by
    a retained checkpoint; merged-away unpinned tables are unlinked."""
    reset_sst_ids()
    s = ShardedStore(kill_config(1, medium="files", root=str(tmp_path)),
                     shards=1)
    drive(s)
    ps = s.arena.disk.page_store
    live = set(s.arena.manifest.live)
    assert ps.ids() == live | (ps.ids() & ps._pinned)
    assert live <= ps.ids()


def test_open_plane_refuses_fresh_create_over_existing(tmp_path):
    reset_sst_ids()
    cfg = kill_config(1, medium="files", root=str(tmp_path))
    s = ShardedStore(cfg, shards=1)
    drive(s)
    s.wal.sync()
    from repro.core.storage_io import create_plane
    with pytest.raises(FileExistsError):
        create_plane(cfg)
    wal, man = open_plane(cfg)            # reopen path works
    assert wal.head_lsn == s.arena.wal.head_lsn
    assert man.latest_checkpoint is not None


def test_manifest_create_refuses_existing(tmp_path):
    p = str(tmp_path / "MANIFEST")
    ps = FilePageStore(str(tmp_path / "sst"))
    m = FileManifest.create(p, ps)
    m.close()
    with pytest.raises(FileExistsError, match="already exists"):
        FileManifest.create(p, ps)


def test_group_pending_never_leaks_into_meta_or_checkpoint(tmp_path):
    """Regression: under group commit, maintenance (truncation META
    rewrites, checkpoint frames) must anchor only to *durable* WAL
    state. A durable META/checkpoint claiming LSNs whose frames still
    sit in the userspace group buffer would make post-kill recovery
    fail with an incomplete replay."""
    from repro.core.durability import recover
    reset_sst_ids()
    cfg = kill_config(1, medium="files", root=str(tmp_path),
                      fsync_policy="group")
    # group thresholds that keep frames pending across maintenance
    cfg = StoreConfig(**{**vars(cfg), "group_commit_bytes": 1 << 20,
                         "group_commit_max_wait_s": 3600.0})
    s = LSMStore(cfg)
    s.create_tree("t")
    keys = np.arange(512)
    s.write_batch("t", keys, keys * 5, tick=False)
    s.wal.sync()
    durable = s.arena.wal.durable_lsn
    s.write_batch("t", np.arange(512, 600), np.arange(512, 600),
                  tick=False)
    s.scheduler.tick()                    # truncation + maybe checkpoint
    assert not s.arena.wal.all_durable    # tail still buffered
    # simulate the kill: abandon the in-process store (its pending
    # frames are userspace-only, so on-disk state == post-SIGKILL state)
    reset_sst_ids()
    wal, man = open_plane(cfg)
    rec = recover(cfg, wal, man)          # must not raise
    assert rec.arena.wal.head_lsn >= durable
    assert rec.arena.wal.head_lsn <= s.arena.wal.head_lsn
    found, _ = rec.read_batch("t", keys)
    assert found.all(), "synced records must survive"


# ---------------------------- WriteAck.durable --------------------------------
def _files_cfg(tmp_path, policy):
    return kill_config(1, medium="files", root=str(tmp_path),
                       fsync_policy=policy, mode="group")


def test_writeack_durable_per_batch(tmp_path):
    reset_sst_ids()
    svc = StorageService(LSMStore(_files_cfg(tmp_path, "per_batch")))
    svc.store.create_tree("t")
    (ack,) = svc.submit([Put("t", np.arange(32))])
    assert ack.durable is True


def test_writeack_durable_group_then_sync(tmp_path):
    reset_sst_ids()
    svc = StorageService(LSMStore(_files_cfg(tmp_path, "group")))
    svc.store.create_tree("t")
    svc.sync()                            # tree-create frame out of the way
    (ack,) = svc.submit([Put("t", np.arange(32))])
    assert ack.durable is False, \
        "group commit: ack precedes the group's fsync"
    svc.sync()
    assert svc.store.wal.all_durable
    (ack2,) = svc.submit([Put("t", np.arange(32, 64))])
    assert ack2.durable is False
    svc.sync()


def test_memory_medium_acks_always_durable():
    reset_sst_ids()
    svc = StorageService(LSMStore(kill_config(1, medium="memory",
                                              mode="group")))
    svc.store.create_tree("t")
    (ack,) = svc.submit([Put("t", np.arange(8))])
    assert ack.durable is True
