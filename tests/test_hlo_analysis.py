"""Unit tests for the roofline HLO analyzer: trip-count-aware flop
accounting (XLA's cost_analysis counts while bodies once) and
collective-byte math."""
import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo import analyze_hlo, roofline_terms


def lowered_hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplies_dot_flops():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    n, L = 128, 12
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    w = jax.ShapeDtypeStruct((L, n, n), jnp.float32)
    costs = analyze_hlo(lowered_hlo(f, x, w), 1)
    expect = 2.0 * n * n * n * L
    assert costs.dot_flops == pytest.approx(expect, rel=0.01), \
        (costs.dot_flops, expect, costs.trip_counts)
    assert L in costs.trip_counts.values()


def test_unrolled_matmul_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    costs = analyze_hlo(lowered_hlo(f, a, b), 1)
    assert costs.dot_flops == pytest.approx(2 * 64 * 256 * 32, rel=1e-6)


def test_roofline_terms_bottleneck_selection():
    t = roofline_terms(dot_flops=197e12, bytes_accessed=1.0,
                       collective_bytes=1.0)
    assert t["bottleneck"] == "compute"
    assert t["roofline_fraction"] == pytest.approx(1.0)
    t = roofline_terms(dot_flops=1.0, bytes_accessed=819e9,
                       collective_bytes=1.0)
    assert t["bottleneck"] == "memory"
    t = roofline_terms(dot_flops=1.0, bytes_accessed=1.0,
                       collective_bytes=100e9)
    assert t["bottleneck"] == "collective"


def test_collective_bytes_counted_with_group_size():
    """8-way psum of N floats ~ 2*N*4*(7/8) bytes per device."""
    import subprocess, sys
    from pathlib import Path
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS
from repro.compat import shard_map
from repro.utils.hlo import analyze_hlo
mesh = jax.make_mesh((8,), ("d",))
def f(x):
    return shard_map(lambda v: jax.lax.psum(v, "d"), mesh=mesh,
                     in_specs=PS("d"), out_specs=PS(),
                     check_vma=False)(x)
x = jax.ShapeDtypeStruct((1024, 128), jnp.float32,
                         sharding=NamedSharding(mesh, PS("d")))
hlo = jax.jit(f).lower(x).compile().as_text()
c = analyze_hlo(hlo, 8)
expect = 2 * (1024 // 8) * 128 * 4 * (7 / 8)
assert abs(c.collective_bytes - expect) / expect < 0.05, \\
    (c.collective_bytes, expect)
print("OK")
"""
    import os
    repo = Path(__file__).resolve().parents[1]
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             # see tests/test_distributed.py: keep libtpu images on CPU
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin"},
        timeout=300)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
