"""The chunked decayed-outer-product scan (shared by Mamba2's SSD and
mLSTM) must equal the naive step-by-step recurrence for any chunk size."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.ssm import chunked_decay_scan, decay_scan_step


def naive_scan(log_a, u, w, q, h0):
    b, h, s = log_a.shape
    hcur = np.array(h0, np.float64)
    ys = []
    la, u_, w_, q_ = (np.array(x, np.float64) for x in (log_a, u, w, q))
    for t in range(s):
        a = np.exp(la[..., t])[..., None, None]
        hcur = a * hcur + np.einsum("bhv,bhk->bhvk", u_[:, :, t], w_[:, :, t])
        ys.append(np.einsum("bhvk,bhk->bhv", hcur, q_[:, :, t]))
    return np.stack(ys, axis=2), hcur


def rand_inputs(rng, b, h, s, dv, dk):
    log_a = -np.abs(rng.normal(size=(b, h, s))).astype(np.float32) * 0.5
    u = rng.normal(size=(b, h, s, dv)).astype(np.float32)
    w = rng.normal(size=(b, h, s, dk)).astype(np.float32)
    q = rng.normal(size=(b, h, s, dk)).astype(np.float32)
    h0 = rng.normal(size=(b, h, dv, dk)).astype(np.float32)
    return log_a, u, w, q, h0


@pytest.mark.parametrize("s,chunk", [(16, 4), (17, 4), (32, 32), (8, 16),
                                     (64, 8)])
def test_chunked_scan_matches_naive(s, chunk):
    rng = np.random.default_rng(s * 31 + chunk)
    args = rand_inputs(rng, 2, 3, s, 5, 4)
    y, hf = chunked_decay_scan(*(jnp.asarray(a) for a in args), chunk)
    y_ref, hf_ref = naive_scan(*args)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), hf_ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.sampled_from([2, 8, 64]),
       st.integers(0, 2**31 - 1))
def test_chunked_scan_chunk_size_invariance(s, chunk, seed):
    """The result must not depend on the chunk size (pure re-bracketing)."""
    rng = np.random.default_rng(seed)
    args = [jnp.asarray(a) for a in rand_inputs(rng, 1, 2, s, 3, 3)]
    y1, h1 = chunked_decay_scan(*args, 1)
    y2, h2 = chunked_decay_scan(*args, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4,
                               atol=2e-4)


def test_decode_step_continues_the_scan():
    """Running the chunked scan over s-1 steps then one decode step equals
    the full-s scan (prefill -> decode state handoff)."""
    rng = np.random.default_rng(0)
    args = rand_inputs(rng, 2, 2, 12, 4, 4)
    log_a, u, w, q, h0 = (jnp.asarray(a) for a in args)
    y_full, h_full = chunked_decay_scan(log_a, u, w, q, h0, 4)
    y_pre, h_pre = chunked_decay_scan(log_a[..., :11], u[:, :, :11],
                                      w[:, :, :11], q[:, :, :11], h0, 4)
    y_last, h_last = decay_scan_step(log_a[..., 11], u[:, :, 11],
                                     w[:, :, 11], q[:, :, 11], h_pre)
    np.testing.assert_allclose(np.asarray(y_last),
                               np.asarray(y_full[:, :, 11]), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
