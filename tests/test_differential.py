"""Differential model-based tests for the batched write path + scheduler.

Random op sequences (write / write_batch / delete / lookup / lookup_batch /
scan / forced flush / scheduler tick / tuner tick) are replayed against a
plain-dict oracle under controlled scheduler ticks. The same sequence is
replayed

  * batched vs scalar (every batch as n batches of one) -- the final store
    state must be *bit-identical* (LSNs are log byte offsets, so a batch
    of n is indistinguishable from n scalar writes), and
  * numpy vs pallas backend -- also bit-identical (merges, ingest dedup
    and Bloom geometry agree exactly across backends),

while every lookup/scan output must be value-identical to the oracle.

Fixed-seed sequences always run; when hypothesis is installed the same
replay machinery is additionally driven property-style.
"""
import numpy as np
import pytest

from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import LSMStore, StoreConfig
from repro.core.service import (Deferred, Delete, Get, Put, Scan,
                                ServiceConfig, StorageService)
from repro.core.tuner.tuner import AdaptiveMemoryController, TunerConfig

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KB, MB = 1 << 10, 1 << 20
TREES = ("a", "b")
KEY_SPACE = 2000          # small keyspace: lots of overwrites/tombstones


def small_config(backend="numpy", scheme="partitioned", policy="lsn"):
    # Tiny write memory / active SSTable so short sequences exercise
    # seals, memory merges, flushes and L0/level merges.
    return StoreConfig(
        total_memory_bytes=32 * MB, write_memory_bytes=256 * KB,
        sim_cache_bytes=1 * MB, page_bytes=4 * KB, entry_bytes=256,
        active_sstable_bytes=32 * KB, sstable_bytes=64 * KB,
        max_log_bytes=8 * MB, scheme=scheme, flush_policy=policy,
        backend=backend)


# --------------------------- op generation ----------------------------------
def gen_ops(rng, n_ops=None):
    n = int(n_ops or rng.integers(8, 16))
    ops = []
    for _ in range(n):
        r = rng.random()
        tree = TREES[int(rng.integers(0, len(TREES)))]
        seed = int(rng.integers(0, 2**31))
        if r < 0.40:
            ops.append(("write", tree, seed, int(rng.integers(50, 400))))
        elif r < 0.55:
            ops.append(("delete", tree, seed, int(rng.integers(10, 120))))
        elif r < 0.70:
            ops.append(("lookup", tree, seed, int(rng.integers(20, 200))))
        elif r < 0.82:
            ops.append(("scan", tree, int(rng.integers(0, KEY_SPACE)),
                        int(rng.integers(10, 400))))
        elif r < 0.92:
            ops.append(("flush", tree))
        elif r < 0.96:
            ops.append(("tick",))
        else:
            ops.append(("tune",))
    return ops


# --------------------------- replay ------------------------------------------
def _batch_keys(seed, size, hi=KEY_SPACE):
    rng = np.random.default_rng(seed)
    return rng.integers(0, hi, size=size), rng.integers(0, 2**31, size=size)


def replay(ops, *, backend="numpy", batched=True, scheme="partitioned",
           policy="lsn"):
    """Apply ``ops``; returns (store, outputs, oracle). Asserts every read
    against the oracle as it goes."""
    reset_sst_ids()
    store = LSMStore(small_config(backend, scheme, policy))
    for t in TREES:
        store.create_tree(t)
    ctrl = AdaptiveMemoryController(store, TunerConfig(
        min_step_bytes=64 * KB, min_write_mem=1 * MB, ops_cycle=10**9))
    oracle = {t: {} for t in TREES}
    outputs = []
    for op in ops:
        kind = op[0]
        if kind == "write":
            _, t, seed, size = op
            ks, vs = _batch_keys(seed, size)
            if batched:
                store.write_batch(t, ks, vs, tick=False)
            else:
                for k, v in zip(ks.tolist(), vs.tolist()):
                    store.write_batch(t, [k], [v], tick=False)
            store.scheduler.tick()
            oracle[t].update(zip(ks.tolist(), vs.tolist()))
        elif kind == "delete":
            _, t, seed, size = op
            ks, _ = _batch_keys(seed, size)
            if batched:
                store.delete_batch(t, ks, tick=False)
            else:
                for k in ks.tolist():
                    store.delete_batch(t, [k], tick=False)
            store.scheduler.tick()
            for k in ks.tolist():
                oracle[t][k] = None
        elif kind == "lookup":
            _, t, seed, size = op
            rng = np.random.default_rng(seed)
            ks = rng.integers(0, KEY_SPACE + 500, size=size)  # some absent
            if batched:
                found, vals = store.read_batch(t, ks)
            else:
                found = np.zeros(size, bool)
                vals = np.zeros(size, np.int64)
                for i, k in enumerate(ks.tolist()):
                    f, v = store.lookup(t, k)
                    found[i], vals[i] = f, v
            for i, k in enumerate(ks.tolist()):
                want = oracle[t].get(k)
                assert bool(found[i]) == (want is not None), (t, k)
                if want is not None:
                    assert int(vals[i]) == want, (t, k)
            outputs.append(("lookup", found.tolist(), vals.tolist()))
        elif kind == "scan":
            _, t, lo, width = op
            n = store.scan(t, lo, width)
            want = sum(1 for k, v in oracle[t].items()
                       if lo <= k < lo + width and v is not None)
            assert n == want, (t, lo, width)
            outputs.append(("scan", n))
        elif kind == "flush":
            tree = store.trees[op[1]]
            if not tree.mem.is_empty():
                store.scheduler.flush_tree(tree, trigger="mem")
        elif kind == "tick":
            store.scheduler.tick()
        elif kind == "tune":
            ctrl.tune_now()
    return store, outputs, oracle


# --------------------------- state fingerprint --------------------------------
def _sst_bits(s):
    return (s.keys.tobytes(), s.vals.tobytes(), s.lsn_min, s.lsn_max)


def fingerprint(store):
    """Bit-exact structural state: memory component, L0, disk levels,
    log position, write-memory size (Bloom caches and sst ids excluded)."""
    out = {"log_pos": store.log_pos,
           "write_mem": store.write_memory_bytes}
    for name in sorted(store.trees):
        t = store.trees[name]
        mem, f = t.mem, {}
        if hasattr(mem, "active"):
            f["active"] = sorted(mem.active.items())
        if hasattr(mem, "levels"):
            f["mem_levels"] = [[_sst_bits(s) for s in lvl]
                               for lvl in mem.levels]
        if hasattr(mem, "data"):
            f["data"] = sorted(mem.data.items())
        if hasattr(mem, "segments"):
            f["segments"] = [(s[0].tobytes(), s[1].tobytes(), s[2], s[3],
                              s[4]) for s in mem.segments]
        if hasattr(t.l0, "groups"):
            f["l0"] = [[_sst_bits(s) for s in g] for g in t.l0.groups]
        else:
            f["l0"] = [[_sst_bits(s)] for s in t.l0.runs]
        f["levels"] = [[_sst_bits(s) for s in lvl]
                       for lvl in t.levels.levels]
        out[name] = f
    return out


# --------------------------- fixed-seed suite ---------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("scheme", ["partitioned", "btree-dynamic",
                                    "accordion-data"])
def test_batched_vs_scalar_bit_identical(seed, scheme):
    ops = gen_ops(np.random.default_rng(seed))
    s_b, out_b, _ = replay(ops, batched=True, scheme=scheme)
    s_s, out_s, _ = replay(ops, batched=False, scheme=scheme)
    assert out_b == out_s
    assert fingerprint(s_b) == fingerprint(s_s)
    # identical structure => identical I/O accounting
    assert vars(s_b.disk.stats) == vars(s_s.disk.stats)


@pytest.mark.parametrize("policy", ["mem", "opt"])
def test_batched_vs_scalar_across_policies(policy):
    ops = gen_ops(np.random.default_rng(7), n_ops=12)
    s_b, out_b, _ = replay(ops, batched=True, policy=policy)
    s_s, out_s, _ = replay(ops, batched=False, policy=policy)
    assert out_b == out_s
    assert fingerprint(s_b) == fingerprint(s_s)


@pytest.mark.parametrize("batched", [True, False])
def test_numpy_vs_pallas_bit_identical(batched):
    ops = gen_ops(np.random.default_rng(5), n_ops=8)
    s_n, out_n, _ = replay(ops, backend="numpy", batched=batched)
    s_p, out_p, _ = replay(ops, backend="pallas", batched=batched)
    assert out_n == out_p
    assert fingerprint(s_n) == fingerprint(s_p)
    assert vars(s_n.disk.stats) == vars(s_p.disk.stats)


def test_delete_shadows_across_flush_and_merge():
    """A tombstone must shadow older versions wherever they sit (memory,
    L0, levels), including after forced flush + merges."""
    reset_sst_ids()
    store = LSMStore(small_config())
    store.create_tree("a")
    ks = np.arange(0, 600, dtype=np.int64)
    store.write_batch("a", ks, ks + 1)
    tree = store.trees["a"]
    store.scheduler.flush_tree(tree, trigger="mem")   # victims to disk
    store.delete_batch("a", ks[::2])                  # delete every other
    store.scheduler.flush_tree(tree, trigger="mem")   # tombstones to disk
    found, vals = store.read_batch("a", ks)
    assert not found[::2].any()
    assert found[1::2].all()
    np.testing.assert_array_equal(vals[1::2], ks[1::2] + 1)
    assert store.scan("a", 0, 600) == 300


def test_tombstones_purged_at_bottom_level():
    """Merges whose output lands in the bottommost level drop tombstones:
    delete-heavy workloads must not accumulate dead entries forever."""
    from repro.core.lsm.sstable import TOMBSTONE
    reset_sst_ids()
    store = LSMStore(small_config())
    store.create_tree("a")
    ks = np.arange(0, 2000, dtype=np.int64)
    store.write_batch("a", ks, ks + 1)
    store.delete_batch("a", ks)
    tree = store.trees["a"]
    for _ in range(200):                       # drain memory to disk
        if tree.mem.is_empty():
            break
        store.scheduler.flush_tree(tree, trigger="mem")
    store.scheduler.tick()
    while tree.merge_l0_once():                # drain L0 into the levels
        pass
    assert tree.mem.is_empty() and tree.l0.num_groups == 0
    for lvl in tree.levels.levels:
        for s in lvl:
            assert not (s.vals == TOMBSTONE).any()
    assert store.scan("a", 0, 2000) == 0
    found, _ = store.read_batch("a", ks[:100])
    assert not found.any()


def test_scan_batch_matches_scalar_scans():
    """A batch of q scans must be bit-identical -- counts, page pins,
    IOStats, cache state -- to q scalar ``scan`` calls (the service's
    grouped-scan step relies on this, including the one-op-per-range
    accounting contract)."""
    def build():
        reset_sst_ids()
        store = LSMStore(small_config())
        store.create_tree("a")
        rng = np.random.default_rng(42)
        for _ in range(6):
            ks = rng.integers(0, KEY_SPACE, 400)
            store.write_batch("a", ks, ks + 1)
        store.delete_batch("a", rng.integers(0, KEY_SPACE, 100))
        return store

    ranges = [(0, 300), (250, 500), (1500, 600), (1999, 50), (700, 1)]
    los = np.array([lo for lo, _ in ranges], np.int64)
    ns = np.array([n for _, n in ranges], np.int64)

    s_scalar = build()
    scalar = [s_scalar.scan("a", lo, n) for lo, n in ranges]
    s_batch = build()
    batched = s_batch.scan_batch("a", los, ns)
    assert batched.tolist() == scalar
    assert vars(s_scalar.disk.stats) == vars(s_batch.disk.stats)
    assert fingerprint(s_scalar) == fingerprint(s_batch)
    # one logical op per range on both paths
    before = s_batch.disk.stats.ops
    s_batch.scan_batch("a", los, ns)
    assert s_batch.disk.stats.ops - before == len(ranges)


def test_write_batch_rejects_reserved_tombstone_payload():
    reset_sst_ids()
    store = LSMStore(small_config())
    store.create_tree("a")
    from repro.core.lsm.sstable import TOMBSTONE
    with pytest.raises(ValueError):
        store.write_batch("a", [1], [TOMBSTONE])


# --------------------------- service front door -------------------------------
def gen_request_batches(rng, n_batches=10):
    """Shuffled mixed-op submit batches (typed requests across both trees)."""
    batches = []
    for _ in range(n_batches):
        reqs = []
        for _ in range(int(rng.integers(2, 7))):
            tree = TREES[int(rng.integers(0, len(TREES)))]
            r = rng.random()
            krng = np.random.default_rng(int(rng.integers(0, 2**31)))
            size = int(rng.integers(10, 200))
            if r < 0.40:
                reqs.append(Put(tree, krng.integers(0, KEY_SPACE, size),
                                krng.integers(0, 2**31, size)))
            elif r < 0.55:
                reqs.append(Delete(tree, krng.integers(0, KEY_SPACE, size)))
            elif r < 0.85:
                reqs.append(Get(tree,
                                krng.integers(0, KEY_SPACE + 500, size)))
            else:
                reqs.append(Scan(tree, int(krng.integers(0, KEY_SPACE)),
                                 int(krng.integers(10, 400))))
        order = rng.permutation(len(reqs))
        batches.append([reqs[i] for i in order])
    return batches


def _kind(req):
    return {Put: "put", Delete: "delete", Get: "get",
            Scan: "scan"}[type(req)]


def direct_apply(store, reqs):
    """The equivalent direct per-tree batched calls: the service's
    documented grouping contract -- (tree, kind) groups in first-appearance
    order, each dispatched as ONE batched store call on the concatenated
    keys, one scheduler tick iff any writes -- hand-rolled against the bare
    ``LSMStore``. Returns per-request read outputs in submission order."""
    groups: dict = {}
    for i, req in enumerate(reqs):
        groups.setdefault((req.tree, _kind(req)), []).append((i, req))
    outputs = {}
    wrote = False
    for (tree, kind), members in groups.items():
        if kind in ("put", "delete"):
            keys = np.concatenate([r.keys for _, r in members])
            if kind == "put":
                vals = np.concatenate(
                    [r.keys if r.vals is None else r.vals
                     for _, r in members])
                store.write_batch(tree, keys, vals, tick=False)
            else:
                store.delete_batch(tree, keys, tick=False)
            wrote = True
        elif kind == "get":
            found, vals = store.read_batch(
                tree, np.concatenate([r.keys for _, r in members]))
            off = 0
            for i, r in members:
                n = len(r.keys)
                outputs[i] = ("get", found[off:off + n].tolist(),
                              vals[off:off + n].tolist())
                off += n
        else:
            for i, r in members:
                outputs[i] = ("scan", store.scan(tree, r.lo, r.n))
    if wrote:
        store.scheduler.tick()
    return [outputs[i] for i in sorted(outputs)]


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
@pytest.mark.parametrize("seed", [21, 22])
def test_service_submit_matches_direct_calls(backend, seed):
    """StorageService.submit of shuffled mixed-op batches must leave store
    state AND IOStats bit-identical to the equivalent direct per-tree
    batched calls, with identical per-request read results."""
    batches = gen_request_batches(np.random.default_rng(seed))

    reset_sst_ids()
    svc = StorageService(LSMStore(small_config(backend)),
                         config=ServiceConfig(admission=False))
    for t in TREES:
        svc.create_tree(t)
    out_svc = []
    for reqs in batches:
        for res in svc.submit(reqs):
            assert not isinstance(res, Deferred)
            if hasattr(res, "found"):
                out_svc.append(("get", res.found.tolist(),
                                res.vals.tolist()))
            elif hasattr(res, "count"):
                out_svc.append(("scan", res.count))

    reset_sst_ids()
    store = LSMStore(small_config(backend))
    for t in TREES:
        store.create_tree(t)
    out_direct = []
    for reqs in batches:
        out_direct.extend(direct_apply(store, reqs))

    assert out_svc == out_direct
    assert fingerprint(svc.store) == fingerprint(store)
    assert vars(svc.store.disk.stats) == vars(store.disk.stats)


@pytest.mark.parametrize("scheme", ["btree-dynamic", "accordion-data"])
def test_service_submit_matches_direct_calls_schemes(scheme):
    batches = gen_request_batches(np.random.default_rng(23), n_batches=6)
    reset_sst_ids()
    svc = StorageService(LSMStore(small_config(scheme=scheme)),
                         config=ServiceConfig(admission=False))
    for t in TREES:
        svc.create_tree(t)
    for reqs in batches:
        svc.submit(reqs)
    reset_sst_ids()
    store = LSMStore(small_config(scheme=scheme))
    for t in TREES:
        store.create_tree(t)
    for reqs in batches:
        direct_apply(store, reqs)
    assert fingerprint(svc.store) == fingerprint(store)
    assert vars(svc.store.disk.stats) == vars(store.disk.stats)


# --------------------------- hypothesis suite ---------------------------------
if HAVE_HYPOTHESIS:
    @st.composite
    def op_sequences(draw):
        n = draw(st.integers(4, 12))
        ops = []
        for _ in range(n):
            kind = draw(st.sampled_from(
                ["write", "write", "write", "delete", "lookup", "scan",
                 "flush", "tick", "tune"]))
            tree = draw(st.sampled_from(list(TREES)))
            if kind in ("write", "delete", "lookup"):
                ops.append((kind, tree, draw(st.integers(0, 2**31 - 1)),
                            draw(st.integers(10, 300))))
            elif kind == "scan":
                ops.append((kind, tree, draw(st.integers(0, KEY_SPACE)),
                            draw(st.integers(10, 300))))
            elif kind == "flush":
                ops.append((kind, tree))
            else:
                ops.append((kind,))
        return ops

    @settings(max_examples=15, deadline=None)
    @given(op_sequences(),
           st.sampled_from(["partitioned", "btree-dynamic",
                            "accordion-data"]))
    def test_hypothesis_batched_vs_scalar(ops, scheme):
        s_b, out_b, _ = replay(ops, batched=True, scheme=scheme)
        s_s, out_s, _ = replay(ops, batched=False, scheme=scheme)
        assert out_b == out_s
        assert fingerprint(s_b) == fingerprint(s_s)
