"""Property tests for the streaming latency histogram (runtime/latency.py).

The BENCH tail columns (p99_us / p999_us / max_stall_us) are only as
trustworthy as this structure, so the error contract is tested directly:
for any sample set and any quantile, ``true <= estimate <= true * gamma``
(log-bucketed bound), merges are exact (associative + commutative), the
window max from ``delta()`` is exact or gamma-bounded, and the edge cases
(empty, one sample) behave.
"""
import math

import numpy as np
import pytest

from repro.runtime.latency import LatencyHistogram

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def hist_of(values, **kw):
    h = LatencyHistogram(**kw)
    for v in values:
        h.record(v)
    return h


def check_quantile_bounds(h, values, qs=(0.0, 0.1, 0.5, 0.9, 0.99,
                                         0.999, 1.0)):
    """The log-bucket error contract against the exact order statistic."""
    vs = np.sort(np.asarray(values, float))
    for q in qs:
        # the estimator targets the ceil(q*n)-th order statistic
        true = vs[max(1, math.ceil(q * len(vs))) - 1]
        est = h.quantile(q)
        assert est >= true * (1 - 1e-12), (q, true, est)
        assert est <= max(true * h.gamma, h.v0) * (1 + 1e-12), (q, true, est)


# --------------------------- edges ---------------------------------------------
def test_empty_histogram():
    h = LatencyHistogram()
    assert h.count == 0
    assert h.p50 == h.p99 == h.p999 == 0.0
    assert h.max_value == 0.0 and h.min_value == 0.0


def test_one_sample_is_exact():
    """Clamping estimates into [min, max] makes a single sample exact at
    every quantile -- whatever bucket it landed in."""
    for v in (0.0, 1e-6, 0.4, 1.0, 137.2, 9e9):
        h = hist_of([v])
        for q in (0.0, 0.5, 0.99, 1.0):
            assert h.quantile(q) == v, (v, q)
        assert h.max_value == v and h.min_value == v


def test_zero_and_subresolution_values():
    """Values at or below v0 share bucket 0 but min/max stay exact."""
    h = hist_of([0.0, 1e-9, 5e-4])
    assert h.count == 3
    assert h.min_value == 0.0 and h.max_value == 5e-4
    assert h.quantile(1.0) == 5e-4


def test_rejects_invalid_input():
    h = LatencyHistogram()
    with pytest.raises(ValueError, match=">= 0"):
        h.record(-1.0)
    with pytest.raises(ValueError, match="quantile"):
        h.quantile(1.5)
    with pytest.raises(ValueError, match="gamma"):
        LatencyHistogram(gamma=1.0)
    with pytest.raises(ValueError, match="v0"):
        LatencyHistogram(v0=0.0)
    h.record(1.0, n=0)               # no-op, not an error
    assert h.count == 0


# --------------------------- quantile error bound ------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_quantile_error_bound_random_samples(seed):
    rng = np.random.default_rng(seed)
    # heavy-tailed: spans ~6 decades, like microsecond latencies do
    values = np.exp(rng.normal(3.0, 2.5, 4000))
    h = hist_of(values)
    check_quantile_bounds(h, values)
    assert h.max_value == values.max()
    assert h.min_value == values.min()


def test_weighted_record_equals_repeats():
    a = LatencyHistogram()
    b = LatencyHistogram()
    a.record(17.0, n=500)
    for _ in range(500):
        b.record(17.0)
    assert a.count == b.count == 500
    assert a._counts == b._counts
    assert a.p999 == b.p999 == 17.0


# --------------------------- merge algebra -------------------------------------
def test_merge_is_exact_and_associative():
    rng = np.random.default_rng(9)
    parts = [np.exp(rng.normal(2, 2, n)) for n in (400, 50, 1300)]
    hs = [hist_of(p) for p in parts]
    whole = hist_of(np.concatenate(parts))
    merged_lr = hs[0].merge(hs[1]).merge(hs[2])
    merged_rl = hs[0].merge(hs[1].merge(hs[2]))
    for m in (merged_lr, merged_rl):
        assert m._counts == whole._counts       # exact counts
        assert m.count == whole.count
        assert m.max_value == whole.max_value
        assert m.min_value == whole.min_value
    # commutative
    ab, ba = hs[0].merge(hs[1]), hs[1].merge(hs[0])
    assert ab._counts == ba._counts and ab.count == ba.count


def test_merge_rejects_geometry_mismatch():
    with pytest.raises(ValueError, match="geometry"):
        LatencyHistogram(gamma=2.0).merge(LatencyHistogram())


def test_merge_with_empty_is_identity():
    h = hist_of([1.0, 2.0, 300.0])
    m = h.merge(LatencyHistogram())
    assert m._counts == h._counts
    assert m.max_value == h.max_value and m.count == h.count


# --------------------------- snapshot / delta ----------------------------------
def test_delta_recovers_the_window():
    rng = np.random.default_rng(4)
    h = LatencyHistogram()
    for v in np.exp(rng.normal(2, 1, 500)):
        h.record(v)
    before = h.copy()
    window = np.exp(rng.normal(5, 1, 300))       # hotter than the prefix
    for v in window:
        h.record(v)
    d = h.delta(before)
    assert d.count == 300
    # the window grew the global max, so the window max is EXACT
    assert d.max_value == window.max()
    check_quantile_bounds(d, window, qs=(0.5, 0.9, 0.99))


def test_delta_window_max_bounded_when_not_global_max():
    h = LatencyHistogram()
    h.record(1000.0)                  # global max lives in the prefix
    before = h.copy()
    h.record(3.0)
    h.record(7.0)
    d = h.delta(before)
    assert d.count == 2
    # max not recoverable exactly -- bounded by the top delta bucket edge
    assert 7.0 <= d.max_value <= 7.0 * d.gamma
    assert d.quantile(1.0) <= 7.0 * d.gamma


def test_delta_of_identical_snapshots_is_empty():
    h = hist_of([1.0, 2.0])
    d = h.delta(h.copy())
    assert d.count == 0 and d.max_value == 0.0


def test_delta_rejects_non_prefix():
    h = hist_of([5.0])
    other = hist_of([5.0, 5.0])
    with pytest.raises(ValueError, match="snapshot"):
        h.delta(other)


# --------------------------- hypothesis ----------------------------------------
if HAVE_HYPOTHESIS:
    sample_lists = st.lists(
        st.floats(min_value=0.0, max_value=1e12, allow_nan=False,
                  allow_infinity=False),
        min_size=1, max_size=300)

    @settings(max_examples=80, deadline=None)
    @given(sample_lists)
    def test_hypothesis_quantile_bounds(values):
        check_quantile_bounds(hist_of(values), values)

    @settings(max_examples=50, deadline=None)
    @given(sample_lists, sample_lists)
    def test_hypothesis_merge_equals_concat(a, b):
        m = hist_of(a).merge(hist_of(b))
        whole = hist_of(a + b)
        assert m._counts == whole._counts
        assert m.count == whole.count
        assert m.max_value == whole.max_value
        check_quantile_bounds(m, a + b, qs=(0.5, 0.99))

    @settings(max_examples=50, deadline=None)
    @given(sample_lists, sample_lists)
    def test_hypothesis_delta_equals_window(prefix, window):
        h = hist_of(prefix)
        before = h.copy()
        for v in window:
            h.record(v)
        d = h.delta(before)
        assert d.count == len(window)
        assert d._counts == hist_of(window)._counts
