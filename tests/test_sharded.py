"""Sharded data plane: differential + property tests.

The load-bearing invariant: ``ShardedStore(shards=1)`` is *bit-identical*
-- final structural state, per-read results, IOStats -- to a direct
``LSMStore`` on random mixed workloads (both backends), because routing is
then the identity and the global maintenance scheduler degenerates to the
single-store tick phase-for-phase. ``shards=N`` must match the dict oracle
with conserved global IOStats: every shard writes through ONE shared
``Disk``, so per-shard counter sums equal the global counters exactly.

Router properties: every key routes to exactly one shard, routing is a
pure function (deterministic across processes -- no ``hash()`` salt), and
per-shard key selections partition the input batch in order.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import LSMStore
from repro.core.service import (Deferred, Put, ServiceConfig,
                                StorageService, WriteAck)
from repro.core.shard import ShardedStore, ShardRouter
from repro.core.tuner.tuner import AdaptiveMemoryController, TunerConfig

from test_differential import (KB, KEY_SPACE, MB, TREES, _batch_keys,
                               fingerprint, gen_ops, gen_request_batches,
                               small_config)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# --------------------------- sharded replay -----------------------------------
def replay_sharded(ops, *, backend="numpy", shards=1, router=None,
                   scheme="partitioned", policy="lsn"):
    """The sharded twin of ``test_differential.replay``: applies the same
    op vocabulary to a ``ShardedStore``, asserting every read against the
    dict oracle. Returns (store, outputs, oracle)."""
    reset_sst_ids()
    store = ShardedStore(small_config(backend, scheme, policy),
                         shards=shards, router=router)
    for t in TREES:
        store.create_tree(t)
    ctrl = AdaptiveMemoryController(store, TunerConfig(
        min_step_bytes=64 * KB, min_write_mem=1 * MB, ops_cycle=10**9))
    oracle = {t: {} for t in TREES}
    outputs = []
    for op in ops:
        kind = op[0]
        if kind == "write":
            _, t, seed, size = op
            ks, vs = _batch_keys(seed, size)
            store.write_batch(t, ks, vs, tick=False)
            store.scheduler.tick()
            oracle[t].update(zip(ks.tolist(), vs.tolist()))
        elif kind == "delete":
            _, t, seed, size = op
            ks, _ = _batch_keys(seed, size)
            store.delete_batch(t, ks, tick=False)
            store.scheduler.tick()
            for k in ks.tolist():
                oracle[t][k] = None
        elif kind == "lookup":
            _, t, seed, size = op
            rng = np.random.default_rng(seed)
            ks = rng.integers(0, KEY_SPACE + 500, size=size)
            found, vals = store.read_batch(t, ks)
            for i, k in enumerate(ks.tolist()):
                want = oracle[t].get(k)
                assert bool(found[i]) == (want is not None), (t, k)
                if want is not None:
                    assert int(vals[i]) == want, (t, k)
            outputs.append(("lookup", found.tolist(), vals.tolist()))
        elif kind == "scan":
            _, t, lo, width = op
            n = store.scan(t, lo, width)
            want = sum(1 for k, v in oracle[t].items()
                       if lo <= k < lo + width and v is not None)
            assert n == want, (t, lo, width)
            outputs.append(("scan", n))
        elif kind == "flush":
            # per-shard twin of the forced single-tree flush
            for sh in store.shards:
                tree = sh.store.trees[op[1]]
                if not tree.mem.is_empty():
                    sh.store.scheduler.flush_tree(tree, trigger="mem")
        elif kind == "tick":
            store.scheduler.tick()
        elif kind == "tune":
            ctrl.tune_now()
    return store, outputs, oracle


def assert_conserved(store: ShardedStore):
    """Cross-shard IOStats conservation: all shards account through ONE
    shared Disk, so per-shard (per-tree) counter sums equal the global
    counters bit-exactly."""
    agg = store.shard_tree_stats()
    st = store.disk.stats
    assert sum(a["entries_written"] for a in agg) == st.entries_written
    assert sum(a["bytes_flushed_mem"] for a in agg) == st.bytes_flushed_mem
    assert sum(a["bytes_flushed_log"] for a in agg) == st.bytes_flushed_log
    assert sum(a["merge_pages_written"] for a in agg) \
        == st.pages_merge_written
    assert sum(a["mem_bytes"] for a in agg) == store.write_memory_used()


# --------------------------- shards=1 bit-identity ----------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("scheme", ["partitioned", "btree-dynamic",
                                    "accordion-data"])
def test_one_shard_bit_identical_to_lsmstore(seed, scheme):
    from test_differential import replay
    ops = gen_ops(np.random.default_rng(seed))
    direct, out_d, _ = replay(ops, scheme=scheme)
    sharded, out_s, _ = replay_sharded(ops, shards=1, scheme=scheme)
    assert out_d == out_s
    assert fingerprint(direct) == fingerprint(sharded.shards[0].store)
    assert vars(direct.disk.stats) == vars(sharded.disk.stats)


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_one_shard_bit_identical_both_backends(backend):
    from test_differential import replay
    ops = gen_ops(np.random.default_rng(9), n_ops=10)
    direct, out_d, _ = replay(ops, backend=backend)
    sharded, out_s, _ = replay_sharded(ops, shards=1, backend=backend)
    assert out_d == out_s
    assert fingerprint(direct) == fingerprint(sharded.shards[0].store)
    assert vars(direct.disk.stats) == vars(sharded.disk.stats)


@pytest.mark.parametrize("policy", ["mem", "opt"])
def test_one_shard_bit_identical_across_policies(policy):
    from test_differential import replay
    ops = gen_ops(np.random.default_rng(17), n_ops=12)
    direct, out_d, _ = replay(ops, policy=policy)
    sharded, out_s, _ = replay_sharded(ops, shards=1, policy=policy)
    assert out_d == out_s
    assert fingerprint(direct) == fingerprint(sharded.shards[0].store)
    assert vars(direct.disk.stats) == vars(sharded.disk.stats)


# --------------------------- shards=N vs dict oracle --------------------------
@pytest.mark.parametrize("shards", [2, 4])
@pytest.mark.parametrize("seed", [3, 4])
def test_sharded_matches_oracle_hash(shards, seed):
    ops = gen_ops(np.random.default_rng(seed), n_ops=14)
    store, _, _ = replay_sharded(ops, shards=shards)
    assert_conserved(store)


def test_sharded_matches_oracle_range_router():
    ops = gen_ops(np.random.default_rng(6), n_ops=14)
    router = ShardRouter.ranges(4, KEY_SPACE + 500)
    store, _, _ = replay_sharded(ops, shards=4, router=router)
    assert_conserved(store)


def test_sharded_log_and_memory_enforced_globally():
    """The arena's budgets are global: after any tick, total write memory
    respects the shared threshold and the shared log respects its cap,
    whichever shards the data landed on."""
    ops = gen_ops(np.random.default_rng(12), n_ops=20)
    store, _, _ = replay_sharded(ops, shards=4)
    cfg = store.cfg
    assert store.write_memory_used() \
        <= cfg.mem_flush_threshold * store.write_memory_bytes + \
        cfg.active_sstable_bytes * store.n_shards * len(TREES)
    assert store.log_length <= cfg.max_log_bytes
    assert store.log_pos == store.disk.stats.entries_written * cfg.entry_bytes


# --------------------------- service over shards ------------------------------
@pytest.mark.parametrize("shards", [1, 3])
def test_service_over_sharded_store_matches_oracle(shards):
    batches = gen_request_batches(np.random.default_rng(31), n_batches=8)
    reset_sst_ids()
    svc = StorageService(ShardedStore(small_config(), shards=shards),
                         config=ServiceConfig(admission=False))
    for t in TREES:
        svc.create_tree(t)
    oracle = {t: {} for t in TREES}
    for reqs in batches:
        results = svc.submit(reqs)
        for req, res in zip(reqs, results):
            assert not isinstance(res, Deferred)
            kind = type(req).__name__
            if kind == "Put":
                vals = req.keys if req.vals is None else req.vals
                oracle[req.tree].update(
                    zip(req.keys.tolist(), vals.tolist()))
            elif kind == "Delete":
                for k in req.keys.tolist():
                    oracle[req.tree][k] = None
        # verify reads of this batch against the pre-batch+writes oracle
        # indirectly: a full sweep after each batch keeps it simple
    for t in TREES:
        ks = np.arange(0, KEY_SPACE + 500)
        found, vals = svc.store.read_batch(t, ks)
        for k in ks.tolist():
            want = oracle[t].get(k)
            assert bool(found[k]) == (want is not None), (t, k)
            if want is not None:
                assert int(vals[k]) == want, (t, k)
    if shards > 1:
        assert_conserved(svc.store)


def test_one_shard_service_bit_identical_to_direct_service():
    batches = gen_request_batches(np.random.default_rng(33), n_batches=6)

    def drive(store):
        svc = StorageService(store, config=ServiceConfig(admission=False))
        for t in TREES:
            svc.create_tree(t)
        out = []
        for reqs in batches:
            for res in svc.submit(reqs):
                if hasattr(res, "found"):
                    out.append((res.found.tolist(), res.vals.tolist()))
                elif hasattr(res, "count"):
                    out.append(res.count)
        return svc, out

    reset_sst_ids()
    svc_d, out_d = drive(LSMStore(small_config()))
    reset_sst_ids()
    svc_s, out_s = drive(ShardedStore(small_config(), shards=1))
    assert out_d == out_s
    assert fingerprint(svc_d.store) == fingerprint(svc_s.store.shards[0].store)
    assert vars(svc_d.store.disk.stats) == vars(svc_s.store.disk.stats)


def test_hot_shard_stall_defers_only_hot_keys():
    """Admission gates per (tree, shard): an L0 pile-up on the hot shard
    defers exactly the keys routed there -- the Deferred carries the
    narrowed request -- while the cold shard's keys execute."""
    reset_sst_ids()
    cfg = small_config()
    store = ShardedStore(cfg, router=ShardRouter.ranges(2, KEY_SPACE))
    svc = StorageService(store, config=ServiceConfig(admission=True))
    svc.create_tree("a")
    hot = store.shard_tree(0, "a")
    for _ in range(cfg.l0_max_groups):    # overlapping full flushes: one
        ks = np.arange(0, 900)            # new L0 group each round
        store.shards[0].store.write_batch("a", ks, ks + 1, tick=False)
        store.shards[0].store.scheduler.flush_tree(
            hot, trigger="mem", forced_kind="full")
    assert hot.l0.num_groups >= cfg.l0_max_groups
    assert svc.stalled_trees() == ["a@0"]
    keys = np.array([10, 1500, 20, 1600])          # 2 hot, 2 cold
    res = svc.submit([Put("a", keys, keys + 5)])
    assert isinstance(res[0], Deferred) and res[0].reason == "l0-stall"
    assert sorted(res[0].request.keys.tolist()) == [10, 20]
    found, vals = store.read_batch("a", np.array([1500, 1600]))
    assert found.all() and vals.tolist() == [1505, 1605]
    # drain + retry of the narrowed request completes the write
    out = svc.submit_all([res[0].request])
    assert isinstance(out[0], WriteAck)
    found, vals = store.read_batch("a", keys)
    assert found.all() and vals.tolist() == (keys + 5).tolist()

    # submit_all of a FULL request that partially defers mid-flight must
    # ack the original key count, not the retried remainder
    for _ in range(cfg.l0_max_groups):        # rebuild the hot-shard stall
        ks = np.arange(0, 900)
        store.shards[0].store.write_batch("a", ks, ks + 1, tick=False)
        store.shards[0].store.scheduler.flush_tree(
            hot, trigger="mem", forced_kind="full")
    assert svc.stalled_trees() == ["a@0"]
    out = svc.submit_all([Put("a", keys, keys + 9)])
    assert isinstance(out[0], WriteAck) and out[0].n == len(keys)
    found, vals = store.read_batch("a", keys)
    assert found.all() and vals.tolist() == (keys + 9).tolist()


# --------------------------- router properties --------------------------------
@pytest.mark.parametrize("router", [
    ShardRouter(1),
    ShardRouter(4),
    ShardRouter(7),
    ShardRouter.ranges(4, KEY_SPACE),
    ShardRouter(3, kind="range", boundaries=(-50, 1000)),
])
def test_router_partitions_every_key(router):
    rng = np.random.default_rng(0)
    keys = rng.integers(-(2**40), 2**40, size=5000)
    sid = router.shard_of_batch(keys)
    assert sid.shape == keys.shape
    assert ((sid >= 0) & (sid < router.n_shards)).all()
    # split() yields ascending, disjoint position sets covering the batch
    pieces = list(router.split(keys))
    all_pos = np.concatenate([sel for _, sel in pieces])
    assert len(all_pos) == len(keys)
    assert np.array_equal(np.sort(all_pos), np.arange(len(keys)))
    for si, sel in pieces:
        assert (np.diff(sel) > 0).all() or len(sel) == 1
        assert (sid[sel] == si).all()
    # scalar routing agrees with the batch
    for k in keys[:64].tolist():
        assert router.shard_of(k) == sid[np.flatnonzero(keys == k)[0]]


def test_router_degenerate_single_shard():
    """Both disciplines, including ``ranges(1, ...)``, route everything
    to shard 0 when n_shards == 1."""
    keys = np.array([-10, 0, 999, 10**12])
    for r in (ShardRouter(1), ShardRouter.ranges(1, 1000)):
        assert r.shard_of_batch(keys).tolist() == [0, 0, 0, 0]


def test_router_range_boundaries():
    r = ShardRouter(3, kind="range", boundaries=(100, 200))
    # half-open [b_{i-1}, b_i) buckets: a boundary key opens the next shard
    assert r.shard_of(-5) == 0 and r.shard_of(99) == 0
    assert r.shard_of(100) == 1 and r.shard_of(199) == 1
    assert r.shard_of(200) == 2 and r.shard_of(10**9) == 2
    with pytest.raises(ValueError):
        ShardRouter(3, kind="range", boundaries=(5,))
    with pytest.raises(ValueError):
        ShardRouter(3, kind="range", boundaries=(200, 100))
    with pytest.raises(ValueError):
        ShardRouter(2, kind="hash", boundaries=(1,))
    with pytest.raises(ValueError):
        ShardRouter(0)
    with pytest.raises(ValueError):
        ShardRouter(2, kind="modulo")


def test_router_deterministic_across_processes():
    """Routing must not depend on process state (e.g. hash() salting): a
    fresh interpreter computes the identical placement."""
    keys = (np.arange(-3000, 3000, dtype=np.int64) * 2654435761) % (2**50)
    local = ShardRouter(5).shard_of_batch(keys)
    digest = int(np.sum(local * np.arange(len(keys), dtype=np.int64)))
    code = (
        "import numpy as np\n"
        "from repro.core.shard import ShardRouter\n"
        "keys = (np.arange(-3000, 3000, dtype=np.int64) * 2654435761)"
        " % (2**50)\n"
        "sid = ShardRouter(5).shard_of_batch(keys)\n"
        "print(int(np.sum(sid * np.arange(len(keys), dtype=np.int64))))\n")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) \
        + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, check=True)
    assert int(out.stdout.strip()) == digest


# --------------------------- hypothesis suite ---------------------------------
if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(-2**62, 2**62 - 1), min_size=1,
                    max_size=200),
           st.integers(1, 9))
    def test_hypothesis_router_partition(keys, n_shards):
        router = ShardRouter(n_shards)
        keys = np.array(keys, np.int64)
        sid = router.shard_of_batch(keys)
        assert ((sid >= 0) & (sid < n_shards)).all()
        pieces = list(router.split(keys))
        got = np.concatenate([sel for _, sel in pieces]) if pieces else []
        assert np.array_equal(np.sort(got), np.arange(len(keys)))
        # same key -> same shard, wherever it appears in the batch
        for si, sel in pieces:
            assert (sid[sel] == si).all()

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 4))
    def test_hypothesis_sharded_oracle(seed, shards):
        ops = gen_ops(np.random.default_rng(seed), n_ops=8)
        store, _, _ = replay_sharded(ops, shards=shards)
        assert_conserved(store)
