"""Per-architecture smoke tests: reduced config, one forward (+ decode) on
CPU, asserting output shapes and no NaNs. The FULL configs are exercised
only by the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config, reduced
from repro.models import abstract_params, build_model, init_params
from repro.models.params import P

ARCHS = all_archs()


def make(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0),
                        cfg.param_dtype)
    return cfg, model, params


def inputs_for(cfg, batch=2, seq=32):
    rng = np.random.default_rng(0)
    f = cfg.frontend_tokens
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq - f
                                                          if cfg.family != "encdec" else seq)))
    fe = None
    if cfg.frontend:
        fe = jnp.asarray(rng.normal(size=(batch, f, cfg.d_model)),
                         jnp.float32)
    return tokens, fe


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, model, params = make(arch)
    batch, seq = 2, 32
    tokens, fe = inputs_for(cfg, batch, seq)
    logits = model.apply(params, tokens, frontend_embeds=fe)
    exp_seq = seq if cfg.family != "encdec" else seq
    assert logits.shape == (batch, exp_seq, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_apply(arch):
    """Decode with a KV cache must agree with teacher-forcing logits."""
    cfg, model, params = make(arch)
    batch, seq = 2, 16
    tokens, fe = inputs_for(cfg, batch, seq)
    full = model.apply(params, tokens, frontend_embeds=fe)

    cache = init_params(model.cache_specs(batch, max_len=32),
                        jax.random.key(1), cfg.param_dtype)
    t = tokens.shape[1]
    logits_pre, cache = model.prefill(params, tokens[:, : t - 1], cache,
                                      frontend_embeds=fe)
    pos = full.shape[1] - 1  # position of the last token in the full stream
    logits_dec, _ = model.decode_step(params, tokens[:, t - 1:t], cache,
                                      jnp.int32(pos))
    ref = full[:, -1, :]
    got = logits_dec[:, -1, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_abstract_params_match_init(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    spec = model.param_specs()
    ab = abstract_params(spec, cfg.param_dtype)
    real = init_params(spec, jax.random.key(0), cfg.param_dtype)
    jax.tree.map(lambda a, r: (a.shape == r.shape) or (_ for _ in ()).throw(
        AssertionError((a.shape, r.shape))), ab, real)


def test_full_configs_registered():
    assert len(ARCHS) == 10
    for a in ARCHS:
        cfg = get_config(a)
        assert cfg.num_layers >= 12
