"""Overlapped maintenance & durability: the prepare/apply determinism
contract, paced flush slices, pacer autotune, and async group commit.

The tentpole claim of ``engine/workers.py``: background workers change
*when wall-clock time is spent*, never what the store contains. Concretely:

  1. ``maintenance_workers=0`` is bit-identical to not having the pool at
     all (no threads, ``take`` computes inline);
  2. any worker count and any worker completion order yields a store equal
     to the inline one in everything except the timing-only IOStats
     (``bg_segments`` / ``bg_overlap_us`` / ``fsync_wait_us``), and its
     WAL replays bit-identically -- the PR-7 interleaving fuzzer's
     invariants with workers on;
  3. paced flush slices (``pacer_flush_threshold``) are a pure function
     of store state + config, so they replay and they are identical with
     workers on or off (``flush_slices`` is deliberately NOT masked);
  4. ``StallGovernor`` converges onto the pacer's knobs without touching
     ``StoreConfig`` (recovery re-paces from configuration);
  5. async group commit preserves ack/sync semantics (``all_durable``,
     barrier ``sync()``) while moving the fsync off the foreground.

CI runs this file on numpy and pallas-interpret via the overlap-parity
job; the SIGKILL side of the contract lives in ``test_crash_kill.py``.
"""
import numpy as np
import pytest

from repro.core.durability import recover
from repro.core.engine.workers import MaintenanceWorkerPool
from repro.core.lsm.storage import StoreConfig
from repro.core.service import Put, ServiceConfig, StorageService
from repro.core.service.governor import MemoryPlan, StallGovernor
from repro.core.shard import ShardedStore
from repro.runtime.latency import LatencyHistogram

from test_differential import KB, MB
from test_recovery import exact_counters, sharded_fingerprint
from test_scheduler_interleave import (TREES, build, gen_schedule,
                                       run_schedule, small_config, state_of)

# IOStats fields that legitimately differ with workers on: they report
# where wall-clock time went, which thread scheduling decides. Everything
# else -- including flush_slices -- must be bit-identical.
TIMING_FIELDS = ("bg_segments", "bg_overlap_us", "fsync_wait_us")


def masked_state(store):
    fp, stats, log_pos, debt = state_of(store)
    return (fp, {k: v for k, v in stats.items()
                 if k not in TIMING_FIELDS}, log_pos, debt)


# ------------------------- worker pool unit behavior ---------------------------
def test_pool_workers_zero_is_inert():
    pool = MaintenanceWorkerPool(0)
    assert not pool.enabled
    assert pool.submit("k", lambda: 1) is False
    assert pool.take("k", lambda: 41 + 1) == 42
    assert pool._threads == [] and pool.submitted == 0
    assert pool.hits == 0 and pool.misses == 0   # inert, not "missing"


def test_pool_rejects_negative_workers():
    with pytest.raises(ValueError, match="workers"):
        MaintenanceWorkerPool(-1)


def test_pool_prepare_hit_and_stats():
    class FakeStats:
        bg_segments = 0
        bg_overlap_us = 0.0
    st = FakeStats()
    pool = MaintenanceWorkerPool(2, stats=st)
    assert pool.submit("a", lambda: np.arange(5) * 2)
    assert not pool.submit("a", lambda: None)    # dedup by key
    pool.drain()
    out = pool.take("a", lambda: pytest.fail("should consume the prepare"))
    np.testing.assert_array_equal(out, np.arange(5) * 2)
    assert pool.hits == 1 and st.bg_segments == 1
    assert st.bg_overlap_us > 0.0
    # consumed: a second take recomputes inline
    assert pool.take("a", lambda: "inline") == "inline"
    assert pool.misses == 1
    pool.close()


def test_pool_cancels_unstarted_and_surfaces_errors_as_misses():
    pool = MaintenanceWorkerPool(1)

    def boom():
        raise RuntimeError("prepare failed")
    pool.submit("bad", boom)
    pool.drain()
    # the worker swallowed the error; take falls back to fn() inline
    assert pool.take("bad", lambda: "fallback") == "fallback"
    assert pool.misses == 1
    pool.close()
    # a closed pool computes inline and refuses submits
    assert not pool.enabled
    assert pool.take("x", lambda: 7) == 7
    assert pool.submit("x", lambda: 8) is False
    pool.close()                                 # idempotent


def test_pool_eviction_counts_wasted():
    pool = MaintenanceWorkerPool(1, max_prepared=2)
    for i in range(4):
        pool.submit(("k", i), lambda i=i: i)
    pool.drain()
    assert pool.prepared == 4
    assert pool.wasted == 2                      # oldest two evicted
    assert pool.take(("k", 3), lambda: None) == 3
    pool.close()
    assert pool.wasted == 3                      # the unconsumed survivor


# --------------------- fuzzer invariants with workers on -----------------------
def worker_config(**kw):
    return small_config(maintenance_workers=2, **kw)


@pytest.mark.parametrize("shards", [1, 4])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_interleaved_schedule_with_workers_equals_inline(seed, shards):
    """The PR-7 fuzzer schedules, run with a 2-worker pool: store state,
    log, debt and all non-timing IOStats equal the inline run; replay
    (which never consults the pool with a warm key) is bit-identical."""
    events = gen_schedule(seed)
    inline, oracle = run_schedule(small_config(), events, shards)
    overl, _ = run_schedule(worker_config(), events, shards)
    assert masked_state(overl) == masked_state(inline), \
        f"seed {seed}: workers changed logical state"
    # worker-enabled run is itself deterministic modulo timing fields
    again, _ = run_schedule(worker_config(), events, shards)
    assert masked_state(again) == masked_state(overl)
    # replay determinism with workers on (recovered store worker-enabled)
    rec = recover(worker_config(), overl.wal.clone(), overl.manifest.clone())
    assert sharded_fingerprint(rec) == sharded_fingerprint(overl)
    assert exact_counters(rec) == exact_counters(overl)
    assert rec.log_pos == overl.log_pos
    # both answer the oracle identically
    for t, d in oracle.items():
        ks = np.fromiter(d.keys(), np.int64, len(d))
        if not len(ks):
            continue
        fi, vi = inline.read_batch(t, ks)
        fo, vo = overl.read_batch(t, ks)
        np.testing.assert_array_equal(fi, fo)
        np.testing.assert_array_equal(vi[fi], vo[fo])
    overl.arena.workers.close()
    again.arena.workers.close()


@pytest.mark.parametrize("drain_between", [False, True],
                         ids=["racing", "forced-complete"])
def test_worker_completion_order_is_immaterial(drain_between):
    """Two extreme completion schedules -- prepares racing the apply step
    vs every prepare forced to finish first (``drain`` between events) --
    bracket all interleavings; both must equal the inline store."""
    events = gen_schedule(seed=5)
    inline, _ = run_schedule(small_config(), events, shards=1)
    store = build(worker_config(), 1)
    from test_scheduler_interleave import apply_event
    oracle = {t: {} for t in TREES}
    for ev in events:
        apply_event(store, ev, oracle)
        if drain_between:
            store.arena.workers.drain()
    assert masked_state(store) == masked_state(inline)
    if drain_between:
        # forced-complete maximizes overlap consumption: prepares did land
        assert store.arena.workers.prepared > 0
    store.arena.workers.close()


def test_worker_overlap_actually_consumed():
    """The counters are not decorative: a mixed paced run with workers on
    consumes prepares -- bloom builds submitted at merge write-out are
    taken by the read path (bg_segments > 0, bg_overlap_us > 0)."""
    cfg = worker_config(pacer_interval_bytes=16 * KB,
                        pacer_segment_budget=1)
    svc = StorageService(ShardedStore(cfg, shards=1),
                         config=ServiceConfig(admission=False))
    for t in TREES:
        svc.create_tree(t)
    rng = np.random.default_rng(3)
    for i in range(40):
        ks = rng.integers(0, 2000, 250)
        svc.submit([Put(TREES[0], ks, ks + 1)])
        if i % 4 == 3:
            svc.store.arena.workers.drain()      # let prepares land
            svc.store.read_batch(TREES[0], rng.integers(0, 2000, 100))
    svc.drain()
    st = svc.store.disk.stats
    pool = svc.store.arena.workers
    assert pool.submitted > 0
    assert st.bg_segments == pool.hits
    assert st.bg_segments > 0, "no prepare was ever consumed"
    assert st.bg_overlap_us > 0.0
    pool.close()


# --------------------------- paced flush slices --------------------------------
def fill_to(store, frac, rng):
    """Write until shared write memory exceeds ``frac`` of the budget."""
    guard = 0
    while store.write_memory_used() <= frac * store.write_memory_bytes:
        ks = rng.integers(0, 2000, 60)
        store.write_batch(TREES[0], ks, ks + 1, tick=False)
        guard += 1
        assert guard < 2000


def test_flush_slice_fires_between_thresholds():
    cfg = small_config(pacer_flush_threshold=0.5)
    store = build(cfg, 1)
    rng = np.random.default_rng(9)
    # below the proactive threshold: the mem segment does nothing
    rep = store.scheduler.run_segment("mem")
    assert rep.flushes == 0 and store.disk.stats.flush_slices == 0
    # between proactive (0.5) and hard (0.95): exactly ONE slice
    fill_to(store, 0.55, rng)
    assert store.write_memory_used() \
        <= cfg.mem_flush_threshold * store.write_memory_bytes
    rep = store.scheduler.run_segment("mem")
    assert rep.flushes == 1
    assert store.disk.stats.flush_slices == 1
    # the slice did real work: usage dropped below the proactive line
    # (partitioned flush_partial seals + emits at least one SSTable)
    assert store.write_memory_used() < 0.55 * store.write_memory_bytes


def test_flush_slice_skipped_when_hard_threshold_flushed():
    """A mem segment that already paid a hard-threshold flush never adds
    a proactive slice on top (flush-averse, like the pacer's deferral)."""
    cfg = small_config(pacer_flush_threshold=0.5)
    store = build(cfg, 1)
    fill_to(store, 1.0, np.random.default_rng(9))
    rep = store.scheduler.run_segment("mem")
    assert rep.flushes >= 1
    assert store.disk.stats.flush_slices == 0


def test_flush_slices_replay_and_match_inline_workers():
    """Slices are store-state-pure: the same schedule with the threshold
    on replays bit-identically, and workers do not change slice counts
    (flush_slices is NOT a masked field)."""
    cfg = small_config(pacer_flush_threshold=0.3)
    events = gen_schedule(seed=1)
    store, _ = run_schedule(cfg, events, shards=4)
    assert store.disk.stats.flush_slices > 0, \
        "schedule never exercised a flush slice"
    rec = recover(cfg, store.wal.clone(), store.manifest.clone())
    assert sharded_fingerprint(rec) == sharded_fingerprint(store)
    assert rec.disk.stats.flush_slices == store.disk.stats.flush_slices
    withw, _ = run_schedule(small_config(pacer_flush_threshold=0.3,
                                         maintenance_workers=2),
                            events, shards=4)
    assert masked_state(withw) == masked_state(store)
    withw.arena.workers.close()


def test_flush_slices_defer_like_merge_slices():
    """Through the pacer, a flush slice counts as this pass's flush: the
    merge slice defers (flush-averse), exactly as for hard flushes."""
    cfg = small_config(pacer_flush_threshold=0.5,
                       pacer_interval_bytes=8 * KB, pacer_segment_budget=2)
    svc = StorageService(ShardedStore(cfg, shards=1),
                         config=ServiceConfig(admission=False))
    for t in TREES:
        svc.create_tree(t)
    store = svc.store
    rng = np.random.default_rng(9)
    fill_to(store, 0.55, rng)
    before = svc.pacer.deferrals
    rep = svc.pacer.on_submit(64 * KB)           # slice due, but it flushed
    assert rep.flushes == 1                      # ... via the flush slice
    assert store.disk.stats.flush_slices >= 1
    assert svc.pacer.deferrals == before + 1


# ----------------------------- pacer autotune ----------------------------------
class _StubService:
    """Minimum surface StallGovernor reads: pacer knobs, the stall
    histogram, and the op counter that gates its cycles."""

    class _Disk:
        class _Stats:
            ops = 0
        stats = _Stats()

    class _Store:
        def __init__(self):
            self.disk = _StubService._Disk()

    class _Pacer:
        def __init__(self):
            self.interval_bytes = 64 * KB
            self.segment_budget = 8

    def __init__(self):
        self.pacer = self._Pacer()
        self.stall = LatencyHistogram()
        self.store = self._Store()

    def cycle(self, gov, stall_us, n=4):
        """Advance one governor cycle observing ``n`` stalls of
        ``stall_us`` and actuate like StorageService._apply_plan."""
        for _ in range(n):
            self.stall.record(stall_us)
        self.store.disk.stats.ops += gov.ops_cycle
        plan = gov.observe(self)
        if plan is not None:
            if plan.pacer_interval_bytes is not None:
                self.pacer.interval_bytes = plan.pacer_interval_bytes
            if plan.pacer_segment_budget is not None:
                self.pacer.segment_budget = plan.pacer_segment_budget
        return plan


def test_stall_governor_tightens_to_convergence():
    """Sustained over-target stalls: the budget halves to 1, then the
    interval doubles to its cap -- and a converged governor goes quiet."""
    svc = _StubService()
    gov = StallGovernor(target_stall_us=1000.0, ops_cycle=8,
                        max_interval_bytes=256 * KB)
    assert svc.cycle(gov, 50_000.0) is None      # first cycle = snapshot
    budgets, intervals = [], []
    for _ in range(10):
        svc.cycle(gov, 50_000.0)
        budgets.append(svc.pacer.segment_budget)
        intervals.append(svc.pacer.interval_bytes)
    assert budgets[:3] == [4, 2, 1]              # slices shrink first
    assert svc.pacer.segment_budget == 1
    assert svc.pacer.interval_bytes == 256 * KB  # then slices spread out
    # at both caps there is nothing left to move: no further plans
    assert svc.cycle(gov, 50_000.0) is None
    assert all(r["stall_max_us"] > 1000 for r in gov.records)


def test_stall_governor_deadband_and_dwell():
    """In-band stalls hold the knobs; a direction reversal needs
    ``min_dwell`` consecutive cycles (held reversals are recorded)."""
    svc = _StubService()
    gov = StallGovernor(target_stall_us=1000.0, ops_cycle=8,
                        deadband=0.25, min_dwell=2)
    svc.cycle(gov, 2000.0)                       # snapshot
    svc.cycle(gov, 2000.0)                       # tighten: budget 8 -> 4
    assert svc.pacer.segment_budget == 4
    svc.cycle(gov, 1100.0)                       # in-band: hold
    assert svc.pacer.segment_budget == 4
    assert svc.cycle(gov, 500.0) is None         # reversal #1: held
    assert gov.records[-1]["held"] is True
    assert svc.pacer.segment_budget == 4
    svc.cycle(gov, 500.0)                        # reversal #2: acts
    assert (svc.pacer.interval_bytes, svc.pacer.segment_budget) \
        != (64 * KB, 4)


def test_stall_governor_relaxes_interval_before_budget():
    svc = _StubService()
    gov = StallGovernor(target_stall_us=1000.0, ops_cycle=8,
                        min_interval_bytes=16 * KB, max_segment_budget=32)
    svc.cycle(gov, 100.0)                        # snapshot
    svc.cycle(gov, 100.0)                        # 64K -> 32K
    assert (svc.pacer.interval_bytes, svc.pacer.segment_budget) \
        == (32 * KB, 8)
    svc.cycle(gov, 100.0)                        # floor at 16K
    assert svc.pacer.interval_bytes == 16 * KB
    assert svc.pacer.segment_budget == 8         # budget untouched so far
    svc.cycle(gov, 100.0)                        # then budget grows
    assert svc.pacer.segment_budget == 16


def test_autotune_wires_into_service_and_spares_config():
    """``pacer_autotune=True`` builds the governor; its plans move the
    LIVE pacer only -- StoreConfig keeps the configured knobs, so a
    recovered service re-paces from configuration."""
    cfg = small_config(pacer_interval_bytes=32 * KB,
                       pacer_segment_budget=4, pacer_autotune=True)
    svc = StorageService(ShardedStore(cfg, shards=1),
                         config=ServiceConfig(admission=False))
    assert svc.stall_governor is not None
    off = StorageService(ShardedStore(small_config(
        pacer_interval_bytes=32 * KB), shards=1))
    assert off.stall_governor is None
    svc._apply_plan(MemoryPlan(pacer_interval_bytes=8 * KB,
                               pacer_segment_budget=1, note="test"))
    assert (svc.pacer.interval_bytes, svc.pacer.segment_budget) \
        == (8 * KB, 1)
    assert (cfg.pacer_interval_bytes, cfg.pacer_segment_budget) \
        == (32 * KB, 4)


def test_autotune_converges_on_live_service():
    """End-to-end: a write-heavy paced run with autotune on emits plans
    and every actuated value stays within the governor's bounds."""
    cfg = small_config(pacer_interval_bytes=16 * KB,
                       pacer_segment_budget=8, pacer_autotune=True)
    svc = StorageService(ShardedStore(cfg, shards=1),
                         config=ServiceConfig(admission=False))
    for t in TREES:
        svc.create_tree(t)
    svc.stall_governor.ops_cycle = 256           # act often in a short run
    svc.stall_governor.target_stall_us = 50.0    # unreachably tight:
    rng = np.random.default_rng(13)              # guaranteed tightening
    for _ in range(60):
        ks = rng.integers(0, 2000, 200)
        svc.submit([Put(TREES[0], ks, ks + 3)])
    gov = svc.stall_governor
    assert gov.records, "governor never acted"
    assert any(p.note.startswith("pacer:") for p in svc.plans)
    assert svc.pacer.segment_budget <= 8
    assert gov.min_segment_budget <= svc.pacer.segment_budget
    assert svc.pacer.interval_bytes <= gov.max_interval_bytes


# --------------------------- async group commit --------------------------------
def _files_cfg(tmp_path, name, **kw):
    return small_config(storage_medium="files",
                        storage_dir=str(tmp_path / name),
                        fsync_policy="group", **kw)


def _drive_files(cfg, n=30):
    from repro.core.lsm.sstable import reset_sst_ids
    reset_sst_ids()
    store = ShardedStore(cfg, shards=1)
    for t in TREES:
        store.create_tree(t)
    rng = np.random.default_rng(21)
    for _ in range(n):
        ks = rng.integers(0, 2000, 200)
        store.write_batch(TREES[0], ks, ks + 1, tick=False)
        for name in ("upkeep", "mem", "log", "merge", "wal"):
            store.scheduler.run_segment(name)
    return store


def test_filewal_rejects_async_outside_group_policy(tmp_path):
    from repro.core.storage_io.wal_files import FileWAL
    with pytest.raises(ValueError, match="async_fsync requires"):
        FileWAL.create(str(tmp_path / "w"), fsync_policy="per_batch",
                       async_fsync=True)


def test_async_fsync_state_and_reopen_equal_blocking(tmp_path):
    """Same workload under blocking and async group commit: identical
    store state and identical durable state after the sync barrier."""
    blocking = _drive_files(_files_cfg(tmp_path, "b"))
    asyncw = _drive_files(_files_cfg(tmp_path, "a", wal_async_fsync=True))
    assert sharded_fingerprint(asyncw) == sharded_fingerprint(blocking)
    assert asyncw.log_pos == blocking.log_pos
    for s in (blocking, asyncw):
        s.wal.sync()
        assert s.wal.all_durable
    # commit acks flowed on both paths (exact counts legitimately differ:
    # the async worker's wait timer can make a group durable BEFORE the
    # next commit point asks, which then has no wait to record)
    assert blocking.wal.commit_hist.count > 0
    assert asyncw.wal.commit_hist.count > 0
    snapb = (sharded_fingerprint(blocking), blocking.log_pos)
    blocking.wal.close()
    asyncw.wal.close()
    from repro.core.storage_io import open_plane
    for name, want in (("b", snapb), ("a", snapb)):
        cfg = _files_cfg(tmp_path, name,
                         wal_async_fsync=(name == "a"))
        rec = recover(cfg, *open_plane(cfg))
        assert (sharded_fingerprint(rec), rec.log_pos) == want
        rec.wal.close()


def test_async_fsync_wait_accounting(tmp_path):
    """fsync_wait_us counts foreground time blocked on WAL durability in
    BOTH modes -- every inline fsync when blocking, only the residual
    sync/seal barrier waits when async -- so the two arms' foreground
    durability cost reads off one counter."""
    blocking = _drive_files(_files_cfg(tmp_path, "b"), n=10)
    blocking.wal.sync()
    assert blocking.wal.fsyncs > 0
    assert blocking.disk.stats.fsync_wait_us > 0.0
    blocking.wal.close()
    asyncw = _drive_files(_files_cfg(tmp_path, "a", wal_async_fsync=True),
                          n=10)
    asyncw.wal.sync()
    assert asyncw.wal.all_durable
    assert asyncw.wal.fsyncs > 0
    asyncw.wal.close()


def test_async_all_durable_tracks_inflight(tmp_path):
    """all_durable is False while a handoff is in flight: block the
    durability worker mid-group with a slow pending write, verify the
    flag, then release."""
    from repro.core.storage_io.wal_files import FileWAL
    w = FileWAL.create(str(tmp_path / "w"), fsync_policy="group",
                       group_bytes=1, group_max_wait_s=3600.0,
                       async_fsync=True)
    w.append_set_write_memory(1 << 20)
    with w._dcv:
        pending_before = bool(w._pending)
    assert pending_before or w._unfsynced or w.all_durable is not None
    w.commit(1)                                  # 1-byte threshold: handoff
    w.sync()
    assert w.all_durable
    assert w.fsyncs >= 1
    assert w.commit_hist.count == 1              # the commit was acked once
    w.close()
    # closed WAL: the durability thread is gone
    assert w._dthread is None
