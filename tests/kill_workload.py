"""Shared deterministic workload for the process-kill crash harness.

Both sides of the kill matrix import this module: ``crash_child.py`` runs
``drive()`` against a *files*-medium store and SIGKILLs itself at a chosen
boundary; ``test_crash_kill.py`` runs the identical ``drive()`` against a
*memory*-medium oracle and records a fingerprint at every boundary. The
workload is a pure function of the config (fixed rng seed, no wall-clock
coupling), so boundary ``k`` means the same store state in both runs.

Boundaries are placed after every batch submit AND after every paced
maintenance segment, so the kill matrix covers WAL-segment rollovers,
log-triggered flushes, checkpoint writes, and physical WAL truncation
(segment unlinks) -- the moments where a torn write could diverge state.
"""
from __future__ import annotations

import numpy as np

from repro.core.lsm.storage import StoreConfig

KB = 1024

# ordered maintenance segments of the paced scheduler
SEGMENTS = ("upkeep", "mem", "log", "merge", "wal")

TREES = ("alpha", "beta")
N_BATCHES = 4
BATCH = 256                       # keys per batch => 64 KB of LSN each

# one boundary after each batch, then one after each segment of the
# batch's maintenance pass
N_BOUNDARIES = N_BATCHES * (1 + len(SEGMENTS))


def kill_config(shards: int, *, medium: str, root=None,
                fsync_policy: str = "per_batch",
                mode: str = "full", workers: int = 0,
                wal_async: bool = False) -> StoreConfig:
    """Config small enough that the drive() workload crosses every
    interesting durability edge: 8 KB WAL segments (many rollovers),
    512 KB log cap (truncation + min-LSN flushes), 256 KB checkpoint
    interval (multiple checkpoints)."""
    seg = 64 * KB if mode == "group" else 8 * KB
    return StoreConfig(
        total_memory_bytes=8192 * KB, write_memory_bytes=256 * KB,
        sim_cache_bytes=64 * KB, page_bytes=4 * KB, entry_bytes=256,
        active_sstable_bytes=32 * KB, sstable_bytes=64 * KB,
        max_log_bytes=512 * KB, checkpoint_interval_bytes=256 * KB,
        scheme="partitioned", flush_policy="lsn",
        storage_medium=medium, storage_dir=root,
        fsync_policy=fsync_policy, wal_segment_bytes=seg,
        # group mode: a large byte threshold + effectively-infinite wait
        # keeps whole commit groups buffered across kill points
        group_commit_bytes=12 * KB, group_commit_max_wait_s=3600.0,
        maintenance_workers=workers, wal_async_fsync=wal_async)


def drive(store, on_boundary=None, *, mode: str = "full"):
    """Run the deterministic mixed workload.

    ``on_boundary(i)`` fires after boundary ``i`` completes (0-based).
    ``mode="group"`` drives writes only (no maintenance segments) so the
    userspace group-commit buffer stays the lone durability variable.
    """
    rng = np.random.default_rng(1234)
    boundary = 0
    for t in TREES:
        store.create_tree(t)

    def hit():
        nonlocal boundary
        if on_boundary is not None:
            on_boundary(boundary)
        boundary += 1

    if mode == "group":
        store.create_tree("gamma")
        for i in range(10):
            keys = rng.integers(0, 4096, size=BATCH)
            store.write_batch("gamma", keys, keys * 3 + i, tick=False)
            hit()
        return boundary

    for i in range(N_BATCHES):
        for t in TREES:
            keys = rng.integers(0, 4096, size=BATCH)
            if i % 3 == 2 and t == "beta":
                store.delete_batch(t, keys, tick=False)
            else:
                store.write_batch(t, keys, keys * 7 + i, tick=False)
        hit()
        for seg in SEGMENTS:
            store.scheduler.run_segment(seg)
            hit()
    return boundary
