"""Validate the tuner's derivative estimators against the paper's own
worked examples (§5.2 Example 5.1, §5.3 Example 5.2), plus an end-to-end
regression on a tiny fig15-style workload."""
import numpy as np
import pytest

from repro.core.lsm.sstable import partition_run, reset_sst_ids
from repro.core.lsm.storage import LSMStore, StoreConfig
from repro.core.tuner.derivatives import (TunerStats, cost_derivative,
                                          read_derivative, write_derivative)
from repro.core.tuner.tuner import AdaptiveMemoryController, TunerConfig

MiB = 1 << 20
GiB = 1 << 30


def example_51_stats():
    """Two LSM-trees, x=128MB; tree1 a=0.8, |L_N|=100GB, merge=1 page/op;
    tree2 a=0.2, |L_N|=50GB, merge=0.8 page/op; all memory-triggered."""
    return TunerStats(
        x=128 * MiB,
        merge_pages_per_op=np.array([1.0, 0.8]),
        last_level_bytes=np.array([100.0 * GiB, 50.0 * GiB]),
        alloc=np.array([0.8, 0.2]),
        flush_mem_bytes=np.array([1.0, 1.0]),
        flush_log_bytes=np.array([0.0, 0.0]),
        sim_bytes=32 * MiB,
        saved_q_per_op=0.01,
        saved_m_per_op=0.008,
        read_m_per_op=2.4,
        merge_per_op=1.8,
    )


def test_example_5_1_write_derivative():
    s = example_51_stats()
    wp = float(write_derivative(s.x, s.merge_pages_per_op,
                                s.last_level_bytes, s.alloc,
                                s.flush_mem_bytes, s.flush_log_bytes))
    # paper: write'_1 ~ -1.08e-9, write'_2 ~ -0.78e-9, total ~ -1.86e-9
    assert wp == pytest.approx(-1.86e-9, rel=0.02)


def test_example_5_1_per_tree_terms():
    s = example_51_stats()
    w1 = float(write_derivative(s.x, s.merge_pages_per_op[:1],
                                s.last_level_bytes[:1], s.alloc[:1],
                                s.flush_mem_bytes[:1], s.flush_log_bytes[:1]))
    w2 = float(write_derivative(s.x, s.merge_pages_per_op[1:],
                                s.last_level_bytes[1:], s.alloc[1:],
                                s.flush_mem_bytes[1:], s.flush_log_bytes[1:]))
    assert w1 == pytest.approx(-1.08e-9, rel=0.02)
    assert w2 == pytest.approx(-0.78e-9, rel=0.03)


def test_example_5_2_read_derivative():
    s = example_51_stats()
    wp = float(write_derivative(s.x, s.merge_pages_per_op,
                                s.last_level_bytes, s.alloc,
                                s.flush_mem_bytes, s.flush_log_bytes))
    rp = float(read_derivative(wp, s.saved_q_per_op, s.saved_m_per_op,
                               s.sim_bytes, s.read_m_per_op, s.merge_per_op))
    assert rp == pytest.approx(-1.94e-9, rel=0.02)


def test_cost_derivative_weights():
    s = example_51_stats()
    cp, wp, rp = cost_derivative(s, omega=1.0, gamma=1.0)
    assert cp == pytest.approx(wp + rp, rel=1e-6)
    cp2, _, _ = cost_derivative(s, omega=2.0, gamma=1.0)
    assert cp2 == pytest.approx(2 * wp + rp, rel=1e-6)


def test_log_triggered_flushes_zero_the_write_derivative():
    """§5.2: the scale factor kills write'(x) when flushes are log-bound."""
    s = example_51_stats()
    wp_mem = float(write_derivative(s.x, s.merge_pages_per_op,
                                    s.last_level_bytes, s.alloc,
                                    np.array([1.0, 1.0]),
                                    np.array([0.0, 0.0])))
    wp_log = float(write_derivative(s.x, s.merge_pages_per_op,
                                    s.last_level_bytes, s.alloc,
                                    np.array([0.0, 0.0]),
                                    np.array([1.0, 1.0])))
    wp_half = float(write_derivative(s.x, s.merge_pages_per_op,
                                     s.last_level_bytes, s.alloc,
                                     np.array([1.0, 1.0]),
                                     np.array([1.0, 1.0])))
    assert wp_log == 0.0
    assert wp_half == pytest.approx(wp_mem / 2, rel=1e-5)
    assert wp_mem < wp_half < wp_log


def test_tuner_moves_write_memory_in_cost_decreasing_direction():
    """Tiny fig15-style workload (write-heavy YCSB, one tree): within N
    tuning ticks ``MemoryTuner.propose`` must (a) only ever step *against*
    the sign of cost'(x) -- the cost-decreasing direction -- and (b) grow
    the write memory, since for a write-heavy workload write'(x) < 0
    dominates (Eq. 4: more write memory always cuts write cost)."""
    KB, MB = 1 << 10, 1 << 20
    reset_sst_ids()
    store = LSMStore(StoreConfig(
        total_memory_bytes=32 * MB, write_memory_bytes=2 * MB,
        sim_cache_bytes=1 * MB, page_bytes=4 * KB, entry_bytes=256,
        active_sstable_bytes=256 * KB, sstable_bytes=512 * KB,
        max_log_bytes=6 * MB, scheme="partitioned", flush_policy="lsn"))
    tree = store.create_tree("t")
    # pre-install a populated last level (fig15's bulk load, no I/O)
    keys = np.arange(0, 120_000, dtype=np.int64)
    tree.levels.levels = [partition_run(
        keys, keys, 0, 0, tree.entry_bytes, store.cfg.page_bytes,
        store.cfg.sstable_bytes)]
    tree.levels.adjust(store.cfg.active_sstable_bytes)
    ctrl = AdaptiveMemoryController(store, TunerConfig(
        min_step_bytes=128 * KB, min_write_mem=1 * MB, ops_cycle=8_000))
    x0 = store.write_memory_bytes
    rng = np.random.default_rng(0)
    n_ticks = 10
    while len(ctrl.tuner.records) < n_ticks:
        ks = rng.integers(0, 120_000, size=256)
        store.write_batch("t", ks, ks)
        ctrl.maybe_tune()
    recs = ctrl.tuner.records[:n_ticks]
    stepped = [r for r in recs if not r.stopped]
    assert stepped, "tuner never moved within N ticks"
    for r in stepped:      # every step goes downhill on the fitted cost
        assert np.sign(r.x_next - r.x) == -np.sign(r.cost_prime), vars(r)
    # write-heavy: the first observed gradient is negative (Eq. 4) and the
    # net trajectory grows write memory
    assert stepped[0].cost_prime < 0
    assert store.write_memory_bytes > x0


def test_write_derivative_negative_and_decreasing_in_x():
    """More write memory always helps (Eq. 4 is negative), with diminishing
    returns (|write'| decreases as x grows)."""
    s = example_51_stats()
    grads = []
    for x in [64 * MiB, 128 * MiB, 256 * MiB, 1 * GiB]:
        g = float(write_derivative(x, s.merge_pages_per_op,
                                   s.last_level_bytes, s.alloc,
                                   s.flush_mem_bytes, s.flush_log_bytes))
        assert g < 0
        grads.append(g)
    assert all(grads[i] < grads[i + 1] for i in range(len(grads) - 1))
