"""Subprocess side of the process-kill crash matrix.

Runs the shared deterministic workload (``kill_workload.drive``) against a
*files*-medium store rooted at ``--root`` and SIGKILLs its own process --
no atexit, no flush, no Python teardown -- the instant boundary
``--kill-at`` completes. The parent (``test_crash_kill.py``) then reopens
the storage plane from the surviving files and asserts bit-identical
recovery against a memory-medium oracle.

``--kill-at -1`` runs to completion, fsyncs, and exits 0 (clean-shutdown
control case).
"""
import argparse
import os
import signal
import sys

from kill_workload import drive, kill_config


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", required=True)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--kill-at", type=int, required=True)
    ap.add_argument("--policy", default="per_batch")
    ap.add_argument("--mode", default="full")
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--async-fsync", action="store_true")
    args = ap.parse_args()

    from repro.core.lsm.sstable import reset_sst_ids
    from repro.core.shard.sharded import ShardedStore

    reset_sst_ids()
    cfg = kill_config(args.shards, medium="files", root=args.root,
                      fsync_policy=args.policy, mode=args.mode,
                      workers=args.workers, wal_async=args.async_fsync)
    store = ShardedStore(cfg, shards=args.shards)

    def on_boundary(i):
        if i == args.kill_at:
            # hard kill: bypasses buffered file objects, atexit hooks and
            # interpreter shutdown -- only fsynced bytes survive
            os.kill(os.getpid(), signal.SIGKILL)

    drive(store, on_boundary, mode=args.mode)
    store.wal.sync()
    return 0


if __name__ == "__main__":
    sys.exit(main())
