"""Fused device-resident read path vs the staged per-SSTable loop.

The contract under test: with a ``DevicePagePool`` enabled, every lookup
batch must be bit-identical to the staged engine -- results, buffer-cache
page pins, ``IOStats`` -- across schemes, shard counts and backends; the
pool itself only changes *where* the probe computation runs. Plus the
direct backend seam (``prepare_tier`` / ``lookup_fused`` against the
staged primitives), eviction/shrink fallback mid-workload, Bloom
memoization across the manifest edit sites, the jit shape-cache counters,
and the ``MemoryPlan.device_pool_bytes`` actuation path.
"""
import numpy as np
import pytest

from repro.core.engine import NumpyBackend, PallasBackend
from repro.core.engine.backend import assign_bounds
from repro.core.lsm.sstable import partition_run, reset_sst_ids
from repro.core.lsm.storage import LSMStore, StoreConfig
from repro.core.service import (MemoryGovernor, MemoryPlan, Get, Put,
                                StorageService)
from repro.core.shard import ShardedStore
from repro.runtime.hbm_tuner import DevicePoolGovernor

KB, MB = 1 << 10, 1 << 20


@pytest.fixture(scope="module")
def backends():
    return NumpyBackend(), PallasBackend(interpret=True)


def small_config(**kw):
    base = dict(total_memory_bytes=32 * MB, write_memory_bytes=256 * KB,
                sim_cache_bytes=1 * MB, page_bytes=4 * KB, entry_bytes=256,
                active_sstable_bytes=64 * KB, sstable_bytes=128 * KB,
                max_log_bytes=8 * MB, scheme="partitioned",
                flush_policy="opt")
    base.update(kw)
    reset_sst_ids()
    return StoreConfig(**base)


def make_tier(rng, n_tables, per_table=700):
    """A disjoint, min_key-sorted lookup tier (what a disk level holds)."""
    keys = np.sort(rng.choice(200_000, size=n_tables * per_table,
                              replace=False)).astype(np.int64)
    vals = rng.integers(1, 2**30, size=len(keys)).astype(np.int64)
    return partition_run(keys, vals, 0, 0, 256, 4 * KB,
                         per_table * 256)


# --------------------------- backend seam -----------------------------------
@pytest.mark.parametrize("n_tables", [1, 3, 7])
def test_lookup_fused_matches_staged_primitives(backends, n_tables):
    """prepare_tier + lookup_fused == per-table bloom_probe + lookup_batch
    on every field, for both backends, including misses and off-tier keys."""
    rng = np.random.default_rng(n_tables)
    reset_sst_ids()
    tier = make_tier(rng, n_tables)
    hits = rng.choice(np.concatenate([t.keys for t in tier]), 300)
    queries = np.concatenate(
        [hits, rng.integers(0, 220_000, 200)]).astype(np.int64)
    starts = np.array([t.min_key for t in tier], np.int64)
    ends = np.array([t.max_key for t in tier], np.int64)
    ti, ok = assign_bounds(starts, ends, queries)
    for b in backends:
        view = b.prepare_tier(tier, lambda s: b.bloom_build(s.keys))
        assert view is not None, b.name
        r = b.lookup_fused(view, queries)
        assert r is not None, b.name
        np.testing.assert_array_equal(r.ti, ti)
        np.testing.assert_array_equal(r.ok, ok)
        for t_i in range(n_tables):
            sel = np.flatnonzero(ok & (ti == t_i))
            sst = tier[t_i]
            pos_ref = b.bloom_probe(b.bloom_build(sst.keys), queries[sel])
            np.testing.assert_array_equal(r.positive[sel], pos_ref,
                                          err_msg=f"{b.name} bloom t={t_i}")
            p, h = b.lookup_batch(sst.keys, queries[sel])
            np.testing.assert_array_equal(r.pos[sel], p)
            np.testing.assert_array_equal(r.hit[sel], h)
            np.testing.assert_array_equal(r.vals[sel][h], sst.vals[p[h]])


@pytest.mark.parametrize("shape", [(3, 2, 5), (1,), (2, 2)])
def test_lookup_store_fused_matches_per_tier(backends, shape):
    """prepare_store + lookup_store_fused == R independent prepare_tier +
    lookup_fused runs, field for field per tier, and the on-device winner
    equals the staged first-resolving-tier scan -- both backends."""
    rng = np.random.default_rng(sum(shape))
    reset_sst_ids()
    tiers = [make_tier(rng, n) for n in shape]
    allk = np.concatenate([t.keys for tier in tiers for t in tier])
    queries = np.concatenate(
        [rng.choice(allk, 300),
         rng.integers(0, 220_000, 200)]).astype(np.int64)
    for b in backends:
        bloom = lambda s: b.bloom_build(s.keys)             # noqa: E731
        sview = b.prepare_store(tiers, bloom)
        assert sview is not None, b.name
        assert sview.num_tiers == len(shape)
        assert sview.num_tables == sum(shape)
        r = b.lookup_store_fused(sview, queries)
        assert r is not None, b.name
        win_ref = np.full(len(queries), -1, np.int64)
        for rr, tier in enumerate(tiers):
            tv = b.prepare_tier(tier, bloom)
            f = b.lookup_fused(tv, queries)
            for fld in ("ti", "ok", "positive", "hit", "pos"):
                np.testing.assert_array_equal(
                    getattr(r, fld)[rr], getattr(f, fld),
                    err_msg=f"{b.name} tier={rr} field={fld}")
            np.testing.assert_array_equal(r.vals[rr][f.hit], f.vals[f.hit])
            first = (win_ref == -1) & f.hit
            win_ref[first] = rr
        np.testing.assert_array_equal(r.win, win_ref, err_msg=b.name)


def test_store_fused_newest_wins_three_tiers(backends):
    """The same key resident in three tiers must resolve from tier 0 (the
    newest): win == 0 and the resolved value is tier 0's, never a deeper
    tier's stale version."""
    keys = np.arange(0, 4000, 4, dtype=np.int64)
    tiers = []
    for r in range(3):
        reset_sst_ids()
        tiers.append(partition_run(keys, keys * 10 + r, 0, 0, 256,
                                   4 * KB, 64 * KB))
    q = keys[::7]
    for b in backends:
        sview = b.prepare_store(tiers, lambda s: b.bloom_build(s.keys))
        r = b.lookup_store_fused(sview, q)
        assert r is not None and (r.win == 0).all(), b.name
        np.testing.assert_array_equal(
            r.vals[0][np.arange(len(q))], q * 10, err_msg=b.name)


def test_store_fused_empty_and_all_miss(backends):
    """Degenerate batches: an empty tier list yields a (0, K) lookup with
    every query unresolved; an all-miss batch resolves nothing."""
    rng = np.random.default_rng(1)
    reset_sst_ids()
    tier = make_tier(rng, 2)                      # keys < 200_000
    q = rng.integers(300_000, 400_000, 128).astype(np.int64)
    for b in backends:
        bloom = lambda s: b.bloom_build(s.keys)             # noqa: E731
        empty = b.prepare_store([], bloom)
        r0 = b.lookup_store_fused(empty, q)
        assert r0 is not None and (r0.win == -1).all(), b.name
        assert r0.ti.shape == (0, len(q))
        r1 = b.lookup_store_fused(b.prepare_store([tier], bloom), q)
        assert (r1.win == -1).all() and not r1.hit.any(), b.name


def test_fused_refuses_out_of_domain(backends):
    """Out-of-int32 tiers/queries return None (staged fallback), never
    wrong results."""
    nb, pb = backends
    rng = np.random.default_rng(9)
    reset_sst_ids()
    big = np.sort(rng.choice(2**40, 500, replace=False)).astype(np.int64)
    tier = partition_run(big, big, 0, 0, 256, 4 * KB, 128 * KB)
    assert pb.prepare_tier(tier, lambda s: pb.bloom_build(s.keys)) is None
    tier2 = make_tier(rng, 2)
    view = pb.prepare_tier(tier2, lambda s: pb.bloom_build(s.keys))
    assert view is not None
    assert pb.lookup_fused(view, np.array([1, 2**40], np.int64)) is None
    # the numpy reference accepts the full int64 domain
    viewn = nb.prepare_tier(tier, lambda s: nb.bloom_build(s.keys))
    rn = nb.lookup_fused(viewn, big[:64])
    assert rn is not None and rn.hit.all()


# --------------------------- store differential -----------------------------
def drive_store(store, batches=90, read_tail=10, key_max=30_000, seed=0):
    """Mixed churn (flushes + merges retire SSTables under the pool) then a
    read-only tail (tiers stabilize, the pool warms, fused serves)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(batches):
        ks = rng.integers(0, key_max, 256)
        if i % 3 != 2:
            store.write_batch("t", ks, ks * 3)
        f, v = store.read_batch("t", rng.integers(0, key_max, 256))
        out.append((f, v))
    for _ in range(read_tail):
        f, v = store.read_batch("t", rng.integers(0, key_max, 256))
        out.append((f, v))
    return out


def _io_stats(s):
    """IOStats fields that must match bit-for-bit across read paths. The
    ``fused_*`` counters are observability of WHICH path served (launch
    collapse), not I/O accounting, so they are excluded by design."""
    return {k: v for k, v in vars(s.disk.stats).items()
            if not k.startswith("fused_")}


def assert_identical(s0, out0, s1, out1):
    for (f0, v0), (f1, v1) in zip(out0, out1):
        np.testing.assert_array_equal(f0, f1)
        np.testing.assert_array_equal(v0, v1)
    assert _io_stats(s0) == _io_stats(s1)
    assert (s0.disk.cache.hits, s0.disk.cache.misses) \
        == (s1.disk.cache.hits, s1.disk.cache.misses)


@pytest.mark.parametrize("backend,scheme", [
    ("numpy", "partitioned"),
    ("numpy", "accordion-data"),
    ("pallas", "partitioned"),
])
def test_store_fused_vs_staged_bit_identical(backend, scheme):
    batches = 90 if backend == "numpy" else 36
    runs = []
    for pool in (0, 32 * MB):
        s = LSMStore(small_config(backend=backend, scheme=scheme,
                                  device_pool_bytes=pool))
        s.create_tree("t")
        runs.append((s, drive_store(s, batches=batches)))
    (s0, o0), (s1, o1) = runs
    assert_identical(s0, o0, s1, o1)
    st = s1.device_pool.stats()
    assert st["tier_hits"] > 0, "fused path never fired"
    assert st["resident_pages"] <= st["capacity_pages"]


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_fused_scope_tier_vs_store_bit_identical(backend):
    """Three-way differential: staged, per-tier fused, cross-tier fused
    must agree bit-for-bit on results, pins and IOStats; the store scope
    must actually collapse launches (store_hits > 0, fewer launches than
    the per-tier twin for the same workload)."""
    batches = 60 if backend == "numpy" else 24
    runs = []
    for scope, pool in (("store", 0), ("tier", 32 * MB),
                        ("store", 32 * MB)):
        s = LSMStore(small_config(backend=backend, device_pool_bytes=pool,
                                  fused_scope=scope))
        s.create_tree("t")
        runs.append((s, drive_store(s, batches=batches)))
    (s0, o0), (s1, o1), (s2, o2) = runs
    assert_identical(s0, o0, s1, o1)
    assert_identical(s0, o0, s2, o2)
    assert s2.device_pool.stats()["store_hits"] > 0, \
        "one-launch store path never served"
    # per-tier scope covers exactly one tier per launch; store scope must
    # average above it (each store launch covers the whole tier list --
    # cold fallbacks to the per-tier loop dilute but cannot erase it)
    tpl = [s.disk.stats.fused_tiers / max(1, s.disk.stats.fused_launches)
           for s in (s1, s2)]
    assert tpl[0] == 1.0 and tpl[1] > tpl[0]


def test_store_scope_reads_before_any_flush():
    """Empty tier list at the tree level: reads served entirely from the
    mem component (no disk tiers yet) take the store-fused path's empty
    branch without touching the pool."""
    s = LSMStore(small_config(device_pool_bytes=32 * MB))
    s.create_tree("t")
    ks = np.arange(100, dtype=np.int64)
    s.write_batch("t", ks, ks + 1)
    f, v = s.read_batch("t", np.arange(200, dtype=np.int64))
    assert f[:100].all() and not f[100:].any()
    np.testing.assert_array_equal(v[:100], ks + 1)
    st = s.device_pool.stats()
    assert st["store_hits"] == 0 and st["store_misses"] == 0


def test_budget_shrink_races_prepare_store():
    """A budget shrink landing while prepare_store is staging (the
    generation guard): the acquire must return None and cache nothing --
    the next batch re-admits against the new budget instead of serving a
    view sized for the old one."""
    s = LSMStore(small_config(device_pool_bytes=32 * MB))
    s.create_tree("t")
    drive_store(s, batches=30, read_tail=4)
    pool = s.device_pool
    t = s.trees["t"]
    tiers = [ti for ti in t.l0.lookup_tiers() + t.levels.lookup_tiers()
             if ti]
    assert tiers
    pool._views.clear()                   # force a fresh prepare
    calls = {"n": 0}

    def tripwire(sst):
        calls["n"] += 1
        if calls["n"] == 1:               # shrink lands mid-prepare
            pool.set_budget_bytes(16 * MB)
        return t._bloom(sst)

    assert pool.acquire_store(tiers, tripwire) is None
    assert not pool._views, "stale store view cached across a shrink"
    # without the race the same acquire succeeds and caches (one extra
    # round in case the shrink evicted pages -> cold re-admission first)
    view = pool.acquire_store(tiers, t._bloom) \
        or pool.acquire_store(tiers, t._bloom)
    assert view is not None and pool._views


@pytest.mark.parametrize("shards", [1, 4])
def test_sharded_fused_vs_staged_bit_identical(shards):
    runs = []
    for pool in (0, 32 * MB):
        s = ShardedStore(small_config(device_pool_bytes=pool),
                         shards=shards)
        s.create_tree("t")
        runs.append((s, drive_store(s, batches=90)))
    (s0, o0), (s1, o1) = runs
    assert_identical(s0, o0, s1, o1)
    assert s1.device_pool.stats()["tier_hits"] > 0


def test_shrink_mid_workload_falls_back_staged():
    """Shrinking the budget mid-run (evictions drop the prepared views)
    must leave results and accounting identical to a staged-only twin:
    affected tiers re-admit or stay staged, never serve stale views."""
    s0 = LSMStore(small_config(device_pool_bytes=0))
    s0.create_tree("t")
    s1 = LSMStore(small_config(device_pool_bytes=32 * MB))
    s1.create_tree("t")
    rng0, rng1 = (np.random.default_rng(4) for _ in range(2))
    outs = [[], []]
    for i in range(80):
        for s, rng, out in ((s0, rng0, outs[0]), (s1, rng1, outs[1])):
            ks = rng.integers(0, 30_000, 256)
            if i % 3 != 2:
                s.write_batch("t", ks, ks * 3)
            out.append(s.read_batch("t", rng.integers(0, 30_000, 256)))
        if i == 40:
            assert s1.device_pool.stats()["resident_pages"] > 16
            s1.set_device_pool_bytes(16 * 4 * KB)   # violent shrink
            assert s1.device_pool.stats()["resident_pages"] <= 16
        if i == 60:
            s1.set_device_pool_bytes(0)             # disable entirely
            assert not s1.device_pool.enabled
    assert_identical(s0, outs[0], s1, outs[1])


def test_drop_sst_invalidates_pages_and_views():
    s = LSMStore(small_config(device_pool_bytes=32 * MB))
    s.create_tree("t")
    drive_store(s, batches=60, read_tail=8)
    pool = s.device_pool
    assert pool.stats()["tier_hits"] > 0
    # every cached view must be over live SSTables only
    live = {sst.sst_id for t in s.trees.values()
            for tier in t.l0.lookup_tiers() + t.levels.lookup_tiers()
            for sst in tier}
    for key in pool._views:
        assert set(pool._key_ssts(key)) <= live, \
            "view over a retired SSTable survived"
    # dropping a live SSTable kills its residency and every view over it
    tier = next(t for t in s.trees["t"].levels.lookup_tiers() if t)
    sst = tier[0]
    before = pool.stats()["resident_pages"]
    s.disk.drop_sst(sst)
    assert pool.stats()["resident_pages"] < before
    assert all(sst.sst_id not in pool._key_ssts(key)
               for key in pool._views)


# --------------------------- satellites -------------------------------------
def test_bloom_memoized_and_invalidated():
    s = LSMStore(small_config(device_pool_bytes=0))
    s.create_tree("t")
    t = s.trees["t"]
    drive_store(s, batches=40, read_tail=2)
    tier = next(ti for ti in t.levels.lookup_tiers() if ti)
    f1 = t._bloom(tier[0])
    f2 = t._bloom(tier[0])
    assert f1 is f2, "per-SSTable Bloom must be memoized"
    # more churn retires SSTables; the memo must only hold live ids
    drive_store(s, batches=40, read_tail=0, seed=1)
    live = {sst.sst_id for ti in t.l0.lookup_tiers() + t.levels.lookup_tiers()
            for sst in ti}
    assert set(t._bloom_cache) <= live, "stale Bloom memo entries"


def test_jit_shape_cache_counters():
    pb = PallasBackend(interpret=True)
    rng = np.random.default_rng(2)
    k = np.sort(rng.choice(10_000, 600, replace=False)).astype(np.int64)
    c0, h0 = pb.jit_compiles, pb.jit_cache_hits
    f = pb.bloom_build(k)
    pb.bloom_probe(f, k[:100])
    assert pb.jit_compiles > c0
    c1, h1 = pb.jit_compiles, pb.jit_cache_hits
    pb.bloom_probe(f, k[100:200])        # same pow2 bucket: cache hit
    assert (pb.jit_compiles, pb.jit_cache_hits) == (c1, h1 + 1)
    pb.bloom_probe(f, k[:550])           # new query bucket: recompile
    assert pb.jit_compiles == c1 + 1
    st = pb.jit_stats()
    assert st["jit_compiles"] == pb.jit_compiles
    assert st["jit_cache_hits"] == pb.jit_cache_hits


def test_memory_plan_actuates_device_pool_budget():
    class PinPool(MemoryGovernor):
        def __init__(self, budget):
            self.budget = budget

        def observe(self, service):
            return MemoryPlan(device_pool_bytes=self.budget,
                              note="test-pin")

    svc = StorageService(LSMStore(small_config(device_pool_bytes=0)),
                         governor=PinPool(8 * MB))
    svc.create_tree("t")
    assert not svc.store.device_pool.enabled
    ks = np.arange(256, dtype=np.int64)
    svc.submit_strict([Put("t", ks, ks)])
    assert svc.store.device_pool.budget_bytes == 8 * MB
    assert svc.store.device_pool.enabled


def test_device_pool_governor_grows_on_misses():
    gov = DevicePoolGovernor(min_bytes=1 * MB, max_bytes=8 * MB,
                             ops_cycle=256)
    svc = StorageService(LSMStore(small_config(device_pool_bytes=1 * MB)),
                         governor=gov)
    svc.create_tree("t")
    rng = np.random.default_rng(0)
    for i in range(60):
        ks = rng.integers(0, 30_000, 256)
        if i % 3 != 2:
            svc.submit_strict([Put("t", ks, ks * 3)])
        svc.submit_strict([Get("t", rng.integers(0, 30_000, 256))])
    # churn keeps tiers cold at 1MB -> misses dominate -> budget doubled
    assert svc.store.device_pool.budget_bytes > 1 * MB
    assert gov.records, "governor never decided"


def test_device_pool_bytes_validation():
    with pytest.raises(ValueError):
        small_config(device_pool_bytes=-1).validate()


def test_fused_scope_validation():
    with pytest.raises(ValueError):
        small_config(fused_scope="bogus").validate()


# --------------------------- governor stability ------------------------------
class _StubPool:
    def __init__(self, budget=8 * MB):
        self.budget_bytes = budget
        self.st = dict(tier_hits=0, tier_misses=0, store_hits=0,
                       store_misses=0, resident_pages=0,
                       capacity_pages=4096)

    def stats(self):
        return dict(self.st)


def _stub_service(pool):
    from types import SimpleNamespace
    disk = SimpleNamespace(stats=SimpleNamespace(ops=0))
    return SimpleNamespace(store=SimpleNamespace(disk=disk,
                                                 device_pool=pool))


def _cycle(gov, svc, pool, d_hit, d_miss, resident=0):
    """Feed one decision window of synthetic hit/miss deltas and apply
    any resulting plan (the StorageService actuation, inlined)."""
    pool.st["tier_hits"] += d_hit
    pool.st["tier_misses"] += d_miss
    pool.st["resident_pages"] = resident
    svc.store.disk.stats.ops += gov.ops_cycle
    plan = gov.observe(svc)
    if plan is not None and plan.device_pool_bytes is not None:
        pool.budget_bytes = plan.device_pool_bytes
    return plan


def test_governor_deadband_holds_steady_workload():
    """The oscillation fix, part 1: a steady ~50/50 hit/miss mix sits
    inside the deadband, so the budget converges (holds) instead of the
    old double/halve flapping on every cycle."""
    pool = _StubPool()
    gov = DevicePoolGovernor(min_bytes=1 * MB, max_bytes=64 * MB,
                             ops_cycle=256, deadband=0.15, min_dwell=2)
    svc = _stub_service(pool)
    gov.attach(svc.store)
    for hits, misses in [(100, 100), (110, 90), (90, 110), (104, 96),
                         (96, 104), (100, 100)]:
        assert _cycle(gov, svc, pool, hits, misses, resident=100) is None
    assert pool.budget_bytes == 8 * MB, "budget moved inside the deadband"
    assert not gov.records


def test_governor_dwell_blocks_single_cycle_reversal():
    """Part 2: one anomalous cycle cannot reverse direction -- the
    reversal is held (recorded with held=True) until the direction has
    dwelt ``min_dwell`` cycles; a sustained reversal then actuates."""
    pool = _StubPool()
    gov = DevicePoolGovernor(min_bytes=1 * MB, max_bytes=64 * MB,
                             ops_cycle=256, deadband=0.15, min_dwell=2)
    svc = _stub_service(pool)
    gov.attach(svc.store)
    p1 = _cycle(gov, svc, pool, 20, 180)            # miss-heavy: grow
    assert p1 is not None and pool.budget_bytes == 16 * MB
    p2 = _cycle(gov, svc, pool, 180, 20, resident=10)   # blip: held
    assert p2 is None and pool.budget_bytes == 16 * MB
    assert gov.records[-1]["held"] is True
    p3 = _cycle(gov, svc, pool, 180, 20, resident=10)   # sustained: shrink
    assert p3 is not None and pool.budget_bytes == 8 * MB
    assert gov.records[-1]["held"] is False


def test_governor_no_oscillation_under_alternation():
    """The pre-fix failure mode: strictly alternating miss-/hit-heavy
    cycles made the budget double and halve forever. With deadband+dwell
    the actuated budget must never immediately retrace the previous step
    (no A -> B -> A bounce between consecutive actuations)."""
    pool = _StubPool()
    gov = DevicePoolGovernor(min_bytes=1 * MB, max_bytes=64 * MB,
                             ops_cycle=256, deadband=0.15, min_dwell=2)
    svc = _stub_service(pool)
    gov.attach(svc.store)
    budgets = [pool.budget_bytes]
    for i in range(12):
        hit, miss = (20, 180) if i % 2 == 0 else (180, 20)
        if _cycle(gov, svc, pool, hit, miss, resident=10) is not None:
            budgets.append(pool.budget_bytes)
    for a, b, c in zip(budgets, budgets[1:], budgets[2:]):
        assert not (a == c and a != b), f"budget bounced {a}->{b}->{c}"
