"""Process-kill crash matrix over the file-backed storage plane.

The real-crash analogue of the clone-based matrix in ``test_recovery``:
a *subprocess* runs the deterministic ``kill_workload.drive`` workload on
a files-medium store and SIGKILLs itself at a chosen boundary -- no
flushes, no teardown; only fsynced bytes survive. The parent reopens the
plane from the surviving files and asserts the recovered store is
bit-identical (fingerprint + ``RECOVERY_EXACT_COUNTERS`` + ``log_pos``)
to a memory-medium oracle run at that same boundary.

Default: a spread of kill points x shards {1, 4}. Set
``DURABILITY_KILL_MATRIX=full`` (the CI durability-files job does) to run
every batch and maintenance-segment boundary -- every WAL-segment
rollover, log-triggered flush, checkpoint write and physical truncation
the workload crosses.

Also here: the torn-tail case (garbage + truncated frames appended to the
last surviving segment must be ignored) and the group-commit kill case
(recovery lands on the last *fsynced* group boundary, within the
configured group window of the kill point).
"""
import os
import signal
import subprocess
import sys

import pytest

from repro.core.durability import recover
from repro.core.durability.checkpoint import RECOVERY_EXACT_COUNTERS
from repro.core.lsm.sstable import reset_sst_ids
from repro.core.shard.sharded import ShardedStore
from repro.core.storage_io import open_plane, plane_paths
from repro.core.storage_io.format import build_frame

from kill_workload import N_BOUNDARIES, drive, kill_config
from test_differential import fingerprint

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
SRC_DIR = os.path.join(os.path.dirname(TESTS_DIR), "src")

FULL = os.environ.get("DURABILITY_KILL_MATRIX") == "full"
KILL_POINTS = list(range(N_BOUNDARIES)) if FULL else [0, 5, 11, 17, 23]


def snapshot(store):
    """Value-snapshot of everything the recovery contract promises."""
    return {
        "fp": [fingerprint(sh.store) for sh in store.shards],
        "counters": [{k: getattr(sh.store.disk.stats, k)
                      for k in RECOVERY_EXACT_COUNTERS}
                     for sh in store.shards],
        "log_pos": store.log_pos,
    }


_ORACLES: dict = {}


def oracle_run(shards: int, mode: str = "full"):
    """Memory-medium reference run; snapshots at every boundary."""
    key = (shards, mode)
    if key not in _ORACLES:
        reset_sst_ids()
        store = ShardedStore(kill_config(shards, medium="memory",
                                         mode=mode), shards=shards)
        snaps = []
        drive(store, lambda i: snaps.append(snapshot(store)), mode=mode)
        snaps.append(snapshot(store))          # post-run (clean shutdown)
        _ORACLES[key] = snaps
    return _ORACLES[key]


def run_child(root, *, shards, kill_at, policy="per_batch", mode="full",
              workers=0, wal_async=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + os.pathsep + TESTS_DIR
    argv = [sys.executable, os.path.join(TESTS_DIR, "crash_child.py"),
            "--root", str(root), "--shards", str(shards),
            "--kill-at", str(kill_at), "--policy", policy, "--mode", mode,
            "--workers", str(workers)]
    if wal_async:
        argv.append("--async-fsync")
    proc = subprocess.run(
        argv, env=env, capture_output=True, text=True, timeout=300)
    if kill_at < 0:
        assert proc.returncode == 0, proc.stderr
    else:
        assert proc.returncode == -signal.SIGKILL, (
            f"child should die by SIGKILL, got rc={proc.returncode}\n"
            f"{proc.stderr}")
    return proc


def recover_from(root, *, shards, policy="per_batch", mode="full",
                 workers=0):
    reset_sst_ids()
    cfg = kill_config(shards, medium="files", root=str(root),
                      fsync_policy=policy, mode=mode, workers=workers)
    wal, manifest = open_plane(cfg)
    return recover(cfg, wal, manifest)


# ------------------------------ kill matrix -----------------------------------
@pytest.mark.parametrize("shards", [1, 4])
@pytest.mark.parametrize("kill_at", KILL_POINTS)
def test_sigkill_recovers_bit_identical(tmp_path, shards, kill_at):
    run_child(tmp_path, shards=shards, kill_at=kill_at)
    rec = recover_from(tmp_path, shards=shards)
    # per_batch: every boundary is an fsync edge, so the recovered store
    # must land exactly on the oracle state at the kill boundary
    assert snapshot(rec) == oracle_run(shards)[kill_at]


def test_clean_shutdown_reopens_final_state(tmp_path):
    run_child(tmp_path, shards=4, kill_at=-1)
    rec = recover_from(tmp_path, shards=4)
    assert snapshot(rec) == oracle_run(4)[-1]
    # the workload must actually have exercised the physical edges the
    # matrix claims to cover: segment rollovers and truncation unlinks
    wal = rec.arena.wal
    assert wal.truncated_to > 0, "log truncation never fired"
    names = sorted(p.name for p in (tmp_path / "wal").iterdir()
                   if p.name.startswith("seg-"))
    assert names and names[0] != "seg-0000000000.wal", \
        "no sealed segment was ever unlinked"


def test_recovered_store_keeps_working(tmp_path):
    """A post-kill store is a full citizen: it serves reads and survives a
    second open."""
    run_child(tmp_path, shards=1, kill_at=KILL_POINTS[-1])
    rec = recover_from(tmp_path, shards=1)
    import numpy as np
    keys = np.arange(100, 140)
    rec.write_batch("alpha", keys, keys * 11)
    found, vals = rec.read_batch("alpha", keys)
    assert found.all() and (vals == keys * 11).all()
    post = snapshot(rec)
    rec.wal.sync()
    rec2 = recover_from(tmp_path, shards=1)
    assert snapshot(rec2) == post


# ------------------------- background workers on ------------------------------
@pytest.mark.parametrize("shards", [1, 4])
@pytest.mark.parametrize("kill_at", KILL_POINTS if FULL else [5, 17, 23])
def test_sigkill_with_workers_recovers_bit_identical(tmp_path, shards,
                                                     kill_at):
    """The prepare/apply determinism contract under real SIGKILL: a child
    running with maintenance_workers=2 dies at a boundary, and recovery
    (itself worker-enabled) lands on EXACTLY the workers=0 oracle state --
    workers change when wall-clock time is spent, never what survives."""
    run_child(tmp_path, shards=shards, kill_at=kill_at, workers=2)
    rec = recover_from(tmp_path, shards=shards, workers=2)
    assert snapshot(rec) == oracle_run(shards)[kill_at]


def test_clean_shutdown_with_workers_matches_oracle(tmp_path):
    run_child(tmp_path, shards=4, kill_at=-1, workers=2)
    rec = recover_from(tmp_path, shards=4, workers=2)
    assert snapshot(rec) == oracle_run(4)[-1]


# -------------------------------- torn tail -----------------------------------
def _last_segment(root):
    wal_dir = plane_paths(str(root))["wal"]
    segs = sorted(n for n in os.listdir(wal_dir)
                  if n.startswith("seg-") and n.endswith(".wal"))
    return os.path.join(wal_dir, segs[-1])


@pytest.mark.parametrize("junk", [
    b"\x00" * 37,                                  # zero tail (lost write)
    build_frame(10**6, b"x" * 64)[:-11],           # torn frame (cut short)
    b"\xde\xad\xbe\xef" + b"junk" * 8,             # garbage bytes
], ids=["zeros", "torn-frame", "garbage"])
def test_torn_tail_ignored(tmp_path, junk):
    run_child(tmp_path, shards=1, kill_at=-1)
    with open(_last_segment(tmp_path), "ab") as f:
        f.write(junk)
    rec = recover_from(tmp_path, shards=1)
    assert snapshot(rec) == oracle_run(1)[-1]


# ------------------------------ group commit ----------------------------------
@pytest.mark.parametrize("kill_at", [2, 5, 9])
def test_group_commit_kill_lands_on_group_boundary(tmp_path, kill_at):
    """Under group commit an un-fsynced tail of <= one group may be lost:
    recovery lands on the most recent *fsynced* boundary j <= kill point,
    within the group window, and is bit-identical to the oracle there."""
    run_child(tmp_path, shards=1, kill_at=kill_at, policy="group",
              mode="group")
    rec = recover_from(tmp_path, shards=1, policy="group", mode="group")
    snaps = oracle_run(1, mode="group")
    got = snapshot(rec)
    js = [j for j in range(kill_at + 1)
          if snaps[j]["log_pos"] == got["log_pos"]]
    assert js, (f"recovered log_pos {got['log_pos']} matches no oracle "
                f"boundary <= {kill_at}")
    j = js[-1]
    # group_commit_bytes admits ~3 batch frames before forcing an fsync
    assert kill_at - j <= 3, f"lost more than one group: j={j}"
    assert got == snaps[j]


def test_group_commit_sync_makes_all_durable(tmp_path):
    run_child(tmp_path, shards=1, kill_at=-1, policy="group", mode="group")
    rec = recover_from(tmp_path, shards=1, policy="group", mode="group")
    assert snapshot(rec) == oracle_run(1, mode="group")[-1]


# ---------------------------- async group commit -------------------------------
@pytest.mark.parametrize("kill_at", [2, 5, 9])
def test_async_fsync_kill_lands_on_fsynced_boundary(tmp_path, kill_at):
    """Async group commit keeps the durability INVARIANT (only fsynced
    bytes survive; recovery lands exactly on a committed boundary, never
    on torn state) while relaxing the freshness bound: at the kill
    instant the loss window is the userspace group plus every handoff
    the durability worker has not fsynced yet -- which is why acks carry
    ``durable=False`` until the covering fsync lands, and ``sync()``
    remains the freshness barrier (next test)."""
    run_child(tmp_path, shards=1, kill_at=kill_at, policy="group",
              mode="group", wal_async=True)
    rec = recover_from(tmp_path, shards=1, policy="group", mode="group")
    snaps = oracle_run(1, mode="group")
    got = snapshot(rec)
    if got["log_pos"] == 0:
        # nothing was fsynced before the kill: a virgin store (even the
        # tree creates were still in flight), not a torn one
        reset_sst_ids()
        virgin = ShardedStore(kill_config(1, medium="memory",
                                          mode="group"), shards=1)
        assert got == snapshot(virgin)
        return
    js = [j for j in range(kill_at + 1)
          if snaps[j]["log_pos"] == got["log_pos"]]
    assert js, (f"recovered log_pos {got['log_pos']} matches no oracle "
                f"boundary <= {kill_at}")
    assert got == snaps[js[-1]]


def test_async_fsync_clean_shutdown_all_durable(tmp_path):
    """sync() is a barrier through the durability worker: a clean child
    exit leaves nothing behind the blocking mode's final state."""
    run_child(tmp_path, shards=1, kill_at=-1, policy="group",
              mode="group", wal_async=True)
    rec = recover_from(tmp_path, shards=1, policy="group", mode="group")
    assert snapshot(rec) == oracle_run(1, mode="group")[-1]
