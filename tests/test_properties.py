"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.lsm.cost_model import optimal_allocation
from repro.core.lsm.storage import LSMStore, StoreConfig
from repro.core.tuner.tuner import TunerConfig, newton_step

KB, MB = 1 << 10, 1 << 20


def make_store(scheme, policy="opt", write_mem=2 * MB):
    return LSMStore(StoreConfig(
        total_memory_bytes=32 * MB, write_memory_bytes=write_mem,
        sim_cache_bytes=1 * MB, page_bytes=4 * KB, entry_bytes=256,
        active_sstable_bytes=128 * KB, sstable_bytes=256 * KB,
        max_log_bytes=8 * MB, scheme=scheme, flush_policy=policy))


@st.composite
def workload(draw):
    n_batches = draw(st.integers(5, 25))
    batches = []
    for _ in range(n_batches):
        tree = draw(st.sampled_from(["a", "b"]))
        seed = draw(st.integers(0, 2**31 - 1))
        size = draw(st.integers(50, 800))
        batches.append((tree, seed, size))
    return batches


@settings(max_examples=15, deadline=None)
@given(workload(), st.sampled_from(["partitioned", "btree-dynamic",
                                    "accordion-data"]),
       st.sampled_from(["mem", "lsn", "opt"]))
def test_reconciliation_and_invariants(batches, scheme, policy):
    store = make_store(scheme, policy)
    store.create_tree("a")
    store.create_tree("b")
    oracle = {"a": {}, "b": {}}
    for tree, seed, size in batches:
        rng = np.random.default_rng(seed)
        ks = rng.integers(0, 50_000, size=size)
        vs = rng.integers(0, 2**31, size=size)
        store.write(tree, ks, vs)
        for k, v in zip(ks.tolist(), vs.tolist()):
            oracle[tree][k] = v
    # 1) newest-wins reconciliation on a sample
    rng = np.random.default_rng(0)
    for tree, d in oracle.items():
        if not d:
            continue
        sample = rng.choice(list(d.keys()), size=min(len(d), 100))
        for k in sample.tolist():
            found, val = store.lookup(tree, k)
            assert found and val == d[k]
    for t in store.trees.values():
        # 2) disk levels: sorted + disjoint within each level
        for lvl in t.levels.levels:
            for s1, s2 in zip(lvl, lvl[1:]):
                assert s1.max_key < s2.min_key
        # 3) grouped L0: disjoint within each group
        if hasattr(t.l0, "groups"):
            for g in t.l0.groups:
                for s1, s2 in zip(g, g[1:]):
                    assert s1.max_key < s2.min_key
        # 4) every SSTable's keys sorted unique
        for s in (t.l0.all_tables()
                  + [s for lvl in t.levels.levels for s in lvl]):
            assert np.all(np.diff(s.keys) > 0)
    # 5) log bounded; memory respected
    assert store.log_length <= store.cfg.max_log_bytes
    st_ = store.disk.stats
    assert st_.pages_merge_written >= 0
    assert store.write_memory_used() <= store.write_memory_bytes * 1.10 \
        or st_.pages_flushed == 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.0, 1e9), min_size=1, max_size=8))
def test_optimal_allocation_sums_to_one(rates):
    a = np.asarray(optimal_allocation(np.array(rates, np.float32)))
    assert abs(float(a.sum()) - 1.0) < 1e-4
    assert np.all(a >= 0)


@settings(max_examples=30, deadline=None)
@given(st.floats(64.0, 1024.0), st.floats(-1e-6, 1e-6),
       st.integers(0, 2**31 - 1))
def test_newton_step_respects_clamps(x_mb, cp, seed):
    """§5.4: either region shrinks by at most 10% of itself, bounds hold."""
    cfg = TunerConfig(min_step_bytes=1 * MB, min_write_mem=16 * MB)
    total, sim = 2048 * MB, 64 * MB
    x = x_mb * MB
    rng = np.random.default_rng(seed)
    hx = [x * (1 + rng.uniform(-0.2, 0.2)) for _ in range(3)]
    hc = [cp * (1 + rng.uniform(-0.5, 0.5)) for _ in range(3)]
    x2 = newton_step(hx, hc, x, cp, total, sim, cfg)
    cache = total - x - sim
    assert x2 >= x - 0.10 * x - 1e-6            # write memory shrink cap
    assert x2 <= x + 0.10 * max(cache, 0) + 1e-6  # cache shrink cap
    assert cfg.min_write_mem - 1e-6 <= x2 \
        <= total - sim - cfg.min_write_mem + 1e-6


def synthetic_cost(x, total):
    """A convex cost(x): write cost falls ~1/log-ish, read cost rises."""
    return 2e9 / x + 3e9 / (total - x)


def test_tuner_converges_on_synthetic_convex_cost():
    """Gradient/Newton loop finds the analytic minimum of a convex cost."""
    total, sim = 4096 * MB, 64 * MB
    cfg = TunerConfig(min_step_bytes=4 * MB, min_write_mem=16 * MB,
                      min_rel_gain=0.0)
    x = 128.0 * MB
    hx, hc = [], []
    eps = 1.0
    for _ in range(60):
        cp = (synthetic_cost(x + eps, total)
              - synthetic_cost(x - eps, total)) / (2 * eps)
        hx.append(x)
        hc.append(cp)
        x = newton_step(hx[-3:], hc[-3:], x, cp, total, sim, cfg)
    # analytic optimum of 2e9/x + 3e9/(T-x): x* = T/(1+sqrt(1.5))
    x_opt = total / (1 + np.sqrt(1.5))
    assert abs(x - x_opt) / x_opt < 0.05
