"""Multi-device tests (subprocess with forced host device count): the
production sharding rules on a small mesh, pipeline parallelism, and
elastic checkpoint resharding across different mesh sizes."""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def run_py(code: str, devices: int = 8) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           # keep the subprocess off any real accelerator: without this,
           # images that bundle libtpu stall for minutes retrying the GCP
           # TPU-metadata query before falling back to CPU
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """A real sharded train step on a (2,2,2) pod/data/model mesh produces
    the same loss as the unsharded computation."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.models import build_model, init_params, make_shardings
from repro.models.params import abstract_params
from repro.runtime.sharding import activation_sharding, param_rules
from repro.runtime.training import TrainConfig, make_train_step, opt_state_specs
from repro.data.pipeline import DataConfig, SyntheticLM

cfg = reduced(get_config("yi-6b")).with_(num_kv_heads=2)
model = build_model(cfg)
pspec = model.param_specs()
ospec = opt_state_specs(pspec, cfg)
params = init_params(pspec, jax.random.key(0), cfg.param_dtype)
opt = init_params(ospec, jax.random.key(1), cfg.optstate_dtype)
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                              global_batch=8))
batch = jax.tree.map(jnp.asarray, data.batch(0))
step = make_train_step(model, TrainConfig())
_, _, m_ref = jax.jit(step)(params, opt, batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = param_rules(fsdp=True, multi_pod=True)
p_sh = make_shardings(pspec, mesh, rules)
o_sh = make_shardings(ospec, mesh, rules)
with mesh, activation_sharding(mesh, rules):
    p2 = jax.device_put(params, p_sh)
    o2 = jax.device_put(opt, o_sh)
    _, _, m_sh = jax.jit(step)(p2, o2, batch)
print("REF", float(m_ref["loss"]), "SHARDED", float(m_sh["loss"]))
assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 5e-3
print("OK")
""")
    assert "OK" in out


def test_pipeline_forward_matches_sequential():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime.pipeline import pipeline_forward
S, M, B, D = 4, 6, 3, 16
rng = np.random.default_rng(0)
W = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
b = jnp.asarray(rng.normal(size=(S, D)) * 0.1, jnp.float32)
x = jnp.asarray(rng.normal(size=(M, B, D)), jnp.float32)
def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])
params = {"w": W, "b": b}
# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ W[s] + b[s])
mesh = jax.make_mesh((S,), ("stage",))
got = pipeline_forward(stage_fn, params, x, mesh)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("OK")
""", devices=4)
    assert "OK" in out


def test_elastic_restore_across_mesh_sizes(tmp_path):
    """Checkpoint written under an 8-device mesh restores (resharded) under
    a 4-device mesh — elastic scaling."""
    out = run_py(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS
from repro.runtime.checkpoint import Checkpointer
mesh8 = jax.make_mesh((8,), ("data",))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh8, PS("data")))
ck = Checkpointer("{tmp_path}", async_save=False)
ck.save(5, {{"x": xs}})
# restore onto a DIFFERENT (4-device) mesh
devs = jax.devices()[:4]
mesh4 = jax.sharding.Mesh(np.array(devs), ("data",))
like = jax.ShapeDtypeStruct((8, 8), jnp.float32,
                            sharding=NamedSharding(mesh4, PS("data")))
restored, step = ck.restore({{"x": like}})
assert step == 5
np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
assert len(restored["x"].sharding.device_set) == 4
print("OK")
""")
    assert "OK" in out
