"""Merge kernel: Pallas (interpret mode) vs pure-jnp oracle vs numpy,
sweeping shapes and skews (hypothesis for the run-level composition)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.merge.merge import merge_tiles
from repro.kernels.merge.ops import merge_runs_dedup
from repro.kernels.merge.ref import merge_tiles_ref


def sorted_unique(rng, n, hi=2**30):
    return np.sort(rng.choice(hi, size=n, replace=False)).astype(np.int32)


@pytest.mark.parametrize("g,ba,bb", [(1, 128, 128), (4, 256, 128),
                                     (2, 512, 512), (3, 128, 384)])
def test_tile_merge_matches_ref(g, ba, bb):
    rng = np.random.default_rng(ba * bb + g)
    ka = np.stack([sorted_unique(rng, ba) for _ in range(g)])
    kb = np.stack([sorted_unique(rng, bb) for _ in range(g)])
    va = rng.integers(0, 2**30, (g, ba)).astype(np.int32)
    vb = rng.integers(0, 2**30, (g, bb)).astype(np.int32)
    got = merge_tiles(*map(jnp.asarray, (ka, va, kb, vb)), interpret=True)
    ref = merge_tiles_ref(*map(jnp.asarray, (ka, va, kb, vb)))
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(b).astype(a.dtype))


def test_tile_merge_tie_prefers_run_a():
    ka = jnp.array([[5, 10, 20, 2**30 - 1]], jnp.int32)
    kb = jnp.array([[5, 10, 30, 2**30 - 1]], jnp.int32)
    va = jnp.array([[1, 2, 3, 4]], jnp.int32)
    vb = jnp.array([[-0, 9, 8, 7]], jnp.int32)
    keys, vals, keep = merge_tiles(ka, va, kb, vb, interpret=True)
    keys, vals, keep = map(np.asarray, (keys, vals, keep))
    # first occurrence of duplicate key carries run A's value
    for dup in (5, 10, 2**30 - 1):
        i = int(np.argmax(keys[0] == dup))
        assert keep[0][i] == 1
        assert vals[0][i] in (1, 2, 3, 4)
        assert keep[0][i + 1] == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 3000), st.integers(0, 3000), st.integers(0, 2**31 - 1),
       st.sampled_from([128, 512]))
def test_run_merge_matches_numpy(na, nb, seed, tile):
    rng = np.random.default_rng(seed)
    ka = sorted_unique(rng, na) if na else np.zeros(0, np.int32)
    kb = sorted_unique(rng, nb) if nb else np.zeros(0, np.int32)
    va = np.arange(na, dtype=np.int32)
    vb = np.arange(nb, dtype=np.int32) + 10**6
    if na + nb == 0:
        return
    keys, vals = merge_runs_dedup(ka, va, kb, vb, tile=tile,
                                  use_kernel=False)
    # numpy oracle: newest (a) wins
    d = {int(k): int(v) for k, v in zip(kb, vb)}
    d.update({int(k): int(v) for k, v in zip(ka, va)})
    exp_keys = np.array(sorted(d), np.int32)
    np.testing.assert_array_equal(keys, exp_keys)
    np.testing.assert_array_equal(vals, np.array([d[int(k)] for k in exp_keys],
                                                 np.int32))


def test_run_merge_kernel_path_matches_ref_path():
    rng = np.random.default_rng(0)
    ka, kb = sorted_unique(rng, 1500), sorted_unique(rng, 700)
    va = np.arange(1500, dtype=np.int32)
    vb = np.arange(700, dtype=np.int32)
    k1, v1 = merge_runs_dedup(ka, va, kb, vb, tile=256, use_kernel=True,
                              interpret=True)
    k2, v2 = merge_runs_dedup(ka, va, kb, vb, tile=256, use_kernel=False)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(v1, v2)
