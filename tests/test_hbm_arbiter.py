"""Unified HBM arbiter: one device-byte budget leased across the
lookup-side ``DevicePagePool`` and the serving-side ``PagedKVPool`` /
prefix cache.

The contract under test: (1) the lease sum equals the configured total
byte-exactly after EVERY shift; (2) a read-heavy -> serving-heavy
workload flip migrates budget between the device pool and the KV pool in
the pressure's direction; (3) the adaptive split's aggregate miss cost is
no worse than the best static split on the same flip; (4) the KV pool's
region actuator grows with fresh page ids and shrinks without ever
invalidating a live page.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import LSMStore, StoreConfig
from repro.core.service import StorageService
from repro.runtime.hbm_arbiter import HBMArbiter, HBMArbiterConfig
from repro.runtime.kvcache import KVPoolConfig, PagedKVPool

KB, MB = 1 << 10, 1 << 20


# --------------------------- unit level (stubbed pressure) -------------------
class _StubPool:
    def __init__(self):
        self.budget_bytes = 4 * MB
        self.st = dict(tier_hits=0, tier_misses=0, store_hits=0,
                       store_misses=0, resident_pages=0,
                       capacity_pages=1024)

    def stats(self):
        return dict(self.st)


def _stub_service(pool):
    disk = SimpleNamespace(stats=SimpleNamespace(ops=0))
    return SimpleNamespace(store=SimpleNamespace(disk=disk,
                                                 device_pool=pool))


def _kv_pool(total=2048):
    return PagedKVPool(KVPoolConfig(page_tokens=16, total_pages=total,
                                    pool_pages=total // 2, sim_pages=64))


def _tick(arb, svc, pool, *, dev_miss=0, kv_off=0, pfx_miss=0):
    """One decision window of synthetic pressure."""
    pool.st["tier_misses"] += dev_miss
    svc.store.disk.stats.ops += arb.cfg.ops_cycle
    if arb.kv_pool is not None:
        arb.kv_pool.stats["offload_pages"] += kv_off
        arb.kv_pool.stats["prefix_misses"] += pfx_miss
    return arb.observe(svc)


def test_leases_conserve_total_byte_exactly():
    kvp = _kv_pool()
    arb = HBMArbiter(kvp, HBMArbiterConfig(total_bytes=48 * MB,
                                           ops_cycle=64))
    pool = _StubPool()
    svc = _stub_service(pool)
    arb.attach(svc.store)
    total = arb.cfg.total_bytes
    assert arb.total_leased() == total
    rng = np.random.default_rng(0)
    for i in range(40):                   # shifting pressure mixes
        _tick(arb, svc, pool,
              dev_miss=int(rng.integers(0, 200)) if i % 13 < 6 else 0,
              kv_off=int(rng.integers(0, 200)) if i % 13 >= 6 else 0,
              pfx_miss=int(rng.integers(0, 50)) if i % 5 == 0 else 0)
        assert arb.total_leased() == total, "lease sum drifted"
        assert all(arb.leases[r] >= arb.cfg.min_lease_bytes
                   for r in arb.REGIONS), "a region starved below floor"
    assert arb.shift_bytes_total > 0, "arbiter never shifted"
    assert sum(1 for r in arb.records if r["shift_bytes"]) > 0


def test_budget_migrates_with_workload_flip():
    """Device-only pressure pulls the device lease up; flipping to
    KV-offload pressure sends bytes back toward the KV pool."""
    kvp = _kv_pool()
    arb = HBMArbiter(kvp, HBMArbiterConfig(total_bytes=48 * MB,
                                           ops_cycle=64))
    pool = _StubPool()
    svc = _stub_service(pool)
    arb.attach(svc.store)
    dev0 = arb.leases["device"]
    for _ in range(6):                    # phase A: read-heavy
        _tick(arb, svc, pool, dev_miss=500)
    dev_read, kv_read = arb.leases["device"], arb.leases["kv"]
    assert dev_read > dev0, "device lease did not grow under read pressure"
    for _ in range(6):                    # phase B: serving-heavy
        _tick(arb, svc, pool, kv_off=500)
    assert arb.leases["kv"] > kv_read, "kv lease did not grow on the flip"
    assert arb.leases["device"] < dev_read, "device lease never donated"
    assert arb.total_leased() == arb.cfg.total_bytes


def test_zero_pressure_holds_all_leases():
    kvp = _kv_pool()
    arb = HBMArbiter(kvp, HBMArbiterConfig(total_bytes=48 * MB,
                                           ops_cycle=64))
    pool = _StubPool()
    svc = _stub_service(pool)
    arb.attach(svc.store)
    before = dict(arb.leases)
    for _ in range(5):
        assert _tick(arb, svc, pool) is None
    assert arb.leases == before


# --------------------------- end to end (real store + kv pool) ---------------
def _small_store_cfg(device_pool_bytes):
    reset_sst_ids()
    return StoreConfig(total_memory_bytes=32 * MB,
                       write_memory_bytes=256 * KB, sim_cache_bytes=1 * MB,
                       page_bytes=4 * KB, entry_bytes=256,
                       active_sstable_bytes=64 * KB, sstable_bytes=128 * KB,
                       max_log_bytes=8 * MB, flush_policy="opt",
                       device_pool_bytes=device_pool_bytes)


def _flip_cost(device_bytes, kv_pages, prefix_pages, governor=None,
               *, n_reads=36, n_serve=3000, key_max=80_000, seed=5):
    """Read-heavy phase then serving-heavy phase; returns aggregate
    miss-cost per op. A device residency miss is a BATCH-level event (the
    whole batch falls back to the staged probe), so its cost is the ops
    it staged; KV offloads and prefix misses are per-op events.
    ``governor=None`` pins a static split; passing the arbiter (whose
    leases must equal the same starting split) makes it adaptive."""
    from repro.core.service import Get, Put

    kvp = PagedKVPool(KVPoolConfig(page_tokens=16,
                                   total_pages=kv_pages + prefix_pages,
                                   pool_pages=kv_pages, sim_pages=64))
    if governor is not None:
        governor.kv_pool = kvp
    svc = StorageService(LSMStore(_small_store_cfg(device_bytes)),
                         governor=governor)
    svc.create_tree("t")
    pool = svc.store.device_pool
    rng = np.random.default_rng(seed)
    for i in range(80):                   # build a multi-tier store whose
        ks = rng.integers(0, key_max, 256)  # resident set needs ~5-6MB
        svc.submit_strict([Put("t", ks, ks * 3)])
    cost = 0

    def fused_get(batch):
        """One Get batch; returns its size if any tier fell back staged."""
        nonlocal cost
        h0 = pool.stats()
        svc.submit_strict([Get("t", rng.integers(0, key_max, batch))])
        h1 = pool.stats()
        missed = (h1["tier_misses"] - h0["tier_misses"]
                  + h1["store_misses"] - h0["store_misses"]) > 0
        served = (h1["tier_hits"] - h0["tier_hits"]
                  + h1["store_hits"] - h0["store_hits"]) > 0
        if missed or not served:
            cost += batch

    k0 = dict(kvp.stats)
    ops0 = svc.store.disk.stats.ops
    for _ in range(n_reads):              # phase A: read-heavy
        fused_get(256)
    streams = {}
    for i in range(n_serve):              # phase B: serving-heavy
        if rng.random() < 0.4:
            kvp.lookup_prefix(int(rng.integers(0, 180)))
        else:
            s = f"s{rng.integers(0, 8)}"
            kvp.append_tokens(s, 16)
            streams[s] = streams.get(s, 0) + 1
            if streams[s] >= 40:          # finite request lifetimes
                kvp.finish_stream(s)
                streams[s] = 0
        if i % 64 == 0:
            fused_get(32)
    k1 = kvp.stats
    ops = (svc.store.disk.stats.ops - ops0
           + k1["ops"] - k0.get("ops", 0))
    cost += (k1["offload_pages"] - k0["offload_pages"]
             + k1["prefix_misses"] - k0["prefix_misses"])
    return cost / max(1, ops)


def test_arbiter_beats_or_matches_best_static_split():
    """The acceptance bar: on a read-heavy -> serving-heavy flip the
    arbiter's aggregate miss cost is no worse than the best STATIC split
    of the same total budget (it spends phase A's idle KV bytes on the
    device pool, then hands them back)."""
    total, pgb = 12 * MB, 16 * KB
    # static A: device-rich (great phase A, starves serving)
    # static B: serving-rich (device pool thrashes in phase A)
    static = {
        "device_rich": _flip_cost(8 * MB, 128, 128),
        "serving_rich": _flip_cost(2 * MB, 320, 320),
    }
    arb = HBMArbiter(None, HBMArbiterConfig(total_bytes=total,
                                            kv_page_bytes=pgb,
                                            ops_cycle=512),
                     leases={"device": 4 * MB, "kv": 4 * MB,
                             "prefix": 4 * MB})
    adaptive = _flip_cost(4 * MB, 256, 256, governor=arb)
    assert arb.total_leased() == total
    assert arb.shift_bytes_total > 0, "arbiter never adapted"
    best = min(static.values())
    assert adaptive <= best * 1.05, \
        f"adaptive {adaptive:.4f} worse than best static {best:.4f} " \
        f"({static})"


# --------------------------- region actuator ---------------------------------
def test_set_regions_grow_mints_fresh_ids():
    kvp = _kv_pool(total=256)
    for s in range(4):
        kvp.append_tokens(f"s{s}", 16 * 20)       # 20 pages per stream
    live = {pid for st in kvp.streams.values() for pid, _ in st.pages}
    old_ids = set(kvp.free) | live
    kvp.set_regions(256, 128)                     # grow 256 -> 384
    assert kvp.total_pages == 384
    minted = set(kvp.free) - old_ids
    assert len(minted) == 128, "grow must mint exactly the delta"
    assert min(minted) >= 256, "grow reused a previously-issued page id"
    assert len(kvp.free) == len(set(kvp.free)), "duplicate free ids"


def test_set_regions_shrink_never_invalidates_live_pages():
    kvp = _kv_pool(total=512)
    for s in range(4):
        kvp.append_tokens(f"s{s}", 16 * 30)
    live_before = {pid for st in kvp.streams.values()
                   for pid, _ in st.pages}
    kvp.set_regions(128, 64)                      # shrink 512 -> 192
    live_after = {pid for st in kvp.streams.values()
                  for pid, _ in st.pages}
    assert kvp.total_pages <= 512
    assert live_after <= live_before, "shrink must only flush, never mint"
    assert live_after.isdisjoint(set(kvp.free)), \
        "a live page id landed on the free list"
    # accounting closes: every retired id is gone from both sets
    assert len(kvp.free) + len(live_after) <= kvp.total_pages \
        + len(kvp.prefix_store)
    # floors hold
    kvp.set_regions(1, 1)
    assert kvp.cfg.pool_pages >= 64
    assert kvp.total_pages - kvp.cfg.pool_pages >= 64
