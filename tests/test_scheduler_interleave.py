"""Deterministic-interleaving fuzzer for the paced maintenance pipeline.

The tentpole invariants behind ``engine/pacer.py``:

  1. **Segmentation is exact**: running the five tick segments in
     canonical order is bit-identical -- store structure, full IOStats,
     log position, carried debt -- to one stop-the-world ``tick()``.
  2. **Interleavings are deterministic**: any random schedule of tick
     segments interleaved with random write/delete batches produces the
     same store twice, and (because every segment is WAL-logged
     write-ahead) ``recover()`` replays the schedule bit-identically.
  3. **Slices are just placement**: at a quiescent point, draining merge
     debt in bounded slices equals draining it in one pass.
  4. **Pacing is a performance policy**: a paced ``StorageService`` is
     logically equal to a stop-the-world one (same answers, same
     enforced bounds) and crash-recovers bit-identically.

Hypothesis-driven when available (random schedules from a drawn seed);
a fixed seed matrix runs regardless. CI runs this file on numpy and
pallas-interpret (``REPRO_LSM_BACKEND``) via the maintenance-parity job.
"""
import numpy as np
import pytest

from repro.core.durability import recover
from repro.core.engine.pacer import MAX_DEFER_DEBT_SLICES, MaintenancePacer
from repro.core.engine.scheduler import SEGMENTS
from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import StoreConfig
from repro.core.service import Get, Put, ServiceConfig, StorageService
from repro.core.shard import ShardedStore

from test_differential import KB, MB, fingerprint
from test_recovery import exact_counters, sharded_fingerprint

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

TREES = ("a", "b")
KEY_SPACE = 2000


def small_config(**kw):
    base = dict(
        total_memory_bytes=32 * MB, write_memory_bytes=256 * KB,
        sim_cache_bytes=1 * MB, page_bytes=4 * KB, entry_bytes=256,
        active_sstable_bytes=32 * KB, sstable_bytes=64 * KB,
        max_log_bytes=512 * KB, scheme="partitioned", flush_policy="lsn")
    base.update(kw)
    return StoreConfig(**base)


def build(cfg, shards):
    reset_sst_ids()
    store = ShardedStore(cfg, shards=shards)
    for t in TREES:
        store.create_tree(t)
    return store


def state_of(store):
    """Everything that must be bit-identical: structure, FULL IOStats,
    log position, scheduler debt."""
    return (sharded_fingerprint(store), vars(store.disk.stats).copy(),
            store.log_pos, store.scheduler.carried_debt)


# --------------------------- 1. segmentation is exact --------------------------
@pytest.mark.parametrize("shards", [1, 4])
@pytest.mark.parametrize("scheme", ["partitioned", "btree-dynamic",
                                    "accordion-data"])
def test_canonical_segment_pass_equals_one_shot_tick(shards, scheme):
    """Writes + [all 5 segments in canonical order] == writes + tick(),
    for every scheme and shard count, at every boundary."""
    cfg = small_config(scheme=scheme,
                       flush_policy="mem" if scheme != "partitioned"
                       else "lsn")
    rng = np.random.default_rng(7)
    batches = [(TREES[int(rng.integers(0, 2))],
                rng.integers(0, KEY_SPACE, int(rng.integers(50, 250))),
                int(rng.integers(0, 2**31)))
               for _ in range(18)]

    def run(segmented):
        store = build(cfg, shards)
        states = []
        for t, ks, vseed in batches:
            vs = np.random.default_rng(vseed).integers(0, 2**31, len(ks))
            store.write_batch(t, ks, vs, tick=False)
            if segmented:
                for name in SEGMENTS:
                    store.scheduler.run_segment(name)
            else:
                store.scheduler.tick()
            states.append(state_of(store))
        return states

    seg, one = run(True), run(False)
    for bi, (a, b) in enumerate(zip(seg, one)):
        assert a == b, f"boundary {bi} diverged"


def test_run_segment_rejects_unknown_name():
    store = build(small_config(), shards=1)
    with pytest.raises(ValueError, match="unknown tick segment"):
        store.scheduler.run_segment("compact")
    # bare LSMStore scheduler validates too
    with pytest.raises(ValueError, match="unknown tick segment"):
        store.shards[0].store.scheduler.run_segment("")


# --------------------------- 2. interleavings are deterministic ----------------
def gen_schedule(seed, n_events=34):
    """Random interleaving of write/delete batches, individual tick
    segments (random merge budgets), and write-memory resizes."""
    rng = np.random.default_rng(seed)
    events = []
    for _ in range(n_events):
        r = rng.random()
        if r < 0.40:
            events.append(("write", TREES[int(rng.integers(0, 2))],
                           int(rng.integers(0, 2**31)),
                           int(rng.integers(40, 220))))
        elif r < 0.52:
            events.append(("delete", TREES[int(rng.integers(0, 2))],
                           int(rng.integers(0, 2**31)),
                           int(rng.integers(10, 80))))
        elif r < 0.92:
            name = SEGMENTS[int(rng.integers(0, len(SEGMENTS)))]
            budget = "default"
            if name == "merge":
                budget = [None, "default", 1,
                          int(rng.integers(2, 9))][int(rng.integers(0, 4))]
            events.append(("segment", name, budget))
        else:
            events.append(("setmem", int(rng.integers(256, 640)) * KB))
    # always settle with one canonical pass so min-LSN/truncation advance
    for name in SEGMENTS:
        events.append(("segment", name, None if name == "merge"
                       else "default"))
    return events


def apply_event(store, ev, oracle):
    kind = ev[0]
    if kind == "write":
        _, t, seed, size = ev
        rng = np.random.default_rng(seed)
        ks = rng.integers(0, KEY_SPACE, size)
        vs = rng.integers(0, 2**31, size)
        store.write_batch(t, ks, vs, tick=False)
        oracle[t].update(zip(ks.tolist(), vs.tolist()))
    elif kind == "delete":
        _, t, seed, size = ev
        ks = np.random.default_rng(seed).integers(0, KEY_SPACE, size)
        store.delete_batch(t, ks, tick=False)
        for k in ks.tolist():
            oracle[t][k] = None
    elif kind == "segment":
        _, name, budget = ev
        if budget == "default":
            store.scheduler.run_segment(name)
        else:
            store.scheduler.run_segment(name, merge_budget=budget)
    else:
        store.set_write_memory(ev[1])


def run_schedule(cfg, events, shards):
    store = build(cfg, shards)
    oracle = {t: {} for t in TREES}
    for ev in events:
        apply_event(store, ev, oracle)
    return store, oracle


def check_interleaving(seed, shards):
    cfg = small_config()
    events = gen_schedule(seed)
    store, oracle = run_schedule(cfg, events, shards)
    # determinism: the same schedule produces the same store twice
    again, _ = run_schedule(cfg, events, shards)
    assert state_of(again) == state_of(store), f"seed {seed} nondeterministic"
    # replay determinism: recover() re-runs the logged interleaving
    rec = recover(cfg, store.wal.clone(), store.manifest.clone())
    assert sharded_fingerprint(rec) == sharded_fingerprint(store), \
        f"seed {seed} replay diverged"
    assert exact_counters(rec) == exact_counters(store)
    assert rec.log_pos == store.log_pos
    assert rec.scheduler.carried_debt == store.scheduler.carried_debt
    # results: live and recovered stores answer the oracle identically
    for t, d in oracle.items():
        ks = np.fromiter(d.keys(), np.int64, len(d))
        if not len(ks):
            continue
        f_live, v_live = store.read_batch(t, ks)
        f_rec, v_rec = rec.read_batch(t, ks)
        np.testing.assert_array_equal(f_live, f_rec)
        np.testing.assert_array_equal(v_live[f_live], v_rec[f_rec])
        for i, k in enumerate(ks.tolist()):
            want = d[k]
            assert bool(f_live[i]) == (want is not None), (t, k)
            if want is not None:
                assert int(v_live[i]) == want, (t, k)


@pytest.mark.parametrize("shards", [1, 4])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_interleaved_schedule_deterministic_and_replayable(seed, shards):
    check_interleaving(seed, shards)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([1, 4]))
    def test_hypothesis_interleaved_schedules(seed, shards):
        check_interleaving(seed, shards)


# --------------------------- 3. slices are just placement ----------------------
@pytest.mark.parametrize("shards", [1, 4])
@pytest.mark.parametrize("slice_budget", [1, 3])
def test_merge_slices_until_dry_equal_one_drain(shards, slice_budget):
    """At a quiescent point (no intervening flushes) bounded merge slices
    serve exactly the step sequence one draining pass would."""
    cfg = small_config()

    def load(store):
        rng = np.random.default_rng(3)
        for _ in range(30):
            t = TREES[int(rng.integers(0, 2))]
            ks = rng.integers(0, KEY_SPACE, 300)
            store.write_batch(t, ks, ks + 1, tick=False)
            # mandatory enforcement only: flushes pile up merge debt
            for name in ("upkeep", "mem", "log"):
                store.scheduler.run_segment(name)

    drain = build(cfg, shards)
    load(drain)
    drain.scheduler.run_segment("merge", merge_budget=None)
    drain.scheduler.run_segment("wal")
    assert drain.scheduler.carried_debt == 0

    sliced = build(cfg, shards)
    load(sliced)
    slices = 0
    while True:
        rep = sliced.scheduler.run_segment("merge",
                                           merge_budget=slice_budget)
        slices += 1
        if rep.carried_debt == 0:
            break
        assert slices < 10_000
    sliced.scheduler.run_segment("wal")
    assert slices > 1          # the budget actually sliced the pass
    assert state_of(sliced) == state_of(drain)
    # and the sliced schedule replays bit-identically too
    rec = recover(cfg, sliced.wal.clone(), sliced.manifest.clone())
    assert sharded_fingerprint(rec) == sharded_fingerprint(sliced)
    assert exact_counters(rec) == exact_counters(sliced)


# --------------------------- 4. pacing is a performance policy -----------------
def _service(cfg, shards):
    reset_sst_ids()
    svc = StorageService(ShardedStore(cfg, shards=shards),
                         config=ServiceConfig(admission=False))
    for t in TREES:
        svc.create_tree(t)
    return svc


@pytest.mark.parametrize("shards", [1, 2])
def test_paced_service_logically_equals_stop_the_world(shards):
    """Same submits through a paced and a stop-the-world service: every
    read answers the oracle on both, the memory/log bounds hold on both,
    and the paced service crash-recovers bit-identically."""
    base = small_config()
    paced_cfg = small_config(pacer_interval_bytes=32 * KB,
                             pacer_segment_budget=2)
    rng = np.random.default_rng(11)
    submits = []
    for _ in range(30):
        t = TREES[int(rng.integers(0, 2))]
        ks = rng.integers(0, KEY_SPACE, int(rng.integers(80, 260)))
        vs = rng.integers(0, 2**31, len(ks))
        submits.append((t, ks, vs))

    oracle = {t: {} for t in TREES}
    for t, ks, vs in submits:
        oracle[t].update(zip(ks.tolist(), vs.tolist()))

    stores = {}
    for label, cfg in (("world-stop", base), ("paced", paced_cfg)):
        svc = _service(cfg, shards)
        assert (svc.pacer is not None) == (label == "paced")
        for t, ks, vs in submits:
            svc.submit([Put(t, ks, vs)])
            s = svc.store
            # the mandatory bounds hold after EVERY submit, paced or not
            assert s.write_memory_used() \
                <= cfg.mem_flush_threshold * s.write_memory_bytes
            assert s.log_length \
                <= cfg.mem_flush_threshold * cfg.max_log_bytes
        for t, d in oracle.items():
            ks = np.fromiter(d.keys(), np.int64, len(d))
            res = svc.submit([Get(t, ks)])[0]
            assert res.found.all()
            assert res.vals.tolist() == [d[k] for k in ks.tolist()]
        stores[label] = svc

    paced = stores["paced"]
    assert paced.pacer.slices > 0
    assert paced.store.scheduler.segments > 0
    assert stores["world-stop"].store.scheduler.segments == 0
    # submit latency + maintenance stalls were recorded
    assert paced.latency.count > 0 and paced.stall.count > 0
    # paced schedule crash-recovers bit-identically
    rec = recover(paced_cfg, paced.store.wal.clone(),
                  paced.store.manifest.clone())
    assert sharded_fingerprint(rec) == sharded_fingerprint(paced.store)
    assert exact_counters(rec) == exact_counters(paced.store)
    assert rec.scheduler.segments == paced.store.scheduler.segments


def test_paced_service_drain_converges_and_is_replayable():
    """drain() after a paced run clears all carried debt and the full
    schedule (paced passes + drain ticks) still replays exactly."""
    cfg = small_config(pacer_interval_bytes=64 * KB,
                       pacer_segment_budget=1)
    svc = _service(cfg, shards=2)
    rng = np.random.default_rng(5)
    for _ in range(20):
        ks = rng.integers(0, KEY_SPACE, 250)
        svc.submit([Put("a", ks, ks + 9)])
    svc.drain()
    assert svc.store.scheduler.carried_debt == 0
    rec = recover(cfg, svc.store.wal.clone(), svc.store.manifest.clone())
    assert sharded_fingerprint(rec) == sharded_fingerprint(svc.store)
    assert exact_counters(rec) == exact_counters(svc.store)


# --------------------------- pacer unit behavior -------------------------------
def test_pacer_releases_slices_proportional_to_write_rate():
    store = build(small_config(), shards=1)
    pacer = MaintenancePacer(store.scheduler, segment_budget=2,
                             interval_bytes=10 * KB)
    seg0 = store.scheduler.segments
    pacer.on_submit(0)                   # no writes, no debt: no slice
    assert pacer.slices == 0
    # every pass still ran the mandatory segments + wal (4 records)
    assert store.scheduler.segments == seg0 + 4
    pacer.on_submit(25 * KB)             # 2 intervals banked -> one slice
    assert pacer.slices == 1             # (budget 2*2 in ONE merge segment)
    assert pacer._pending == 0           # debt drained: burst fully paid
    pacer.on_submit(6 * KB)              # below the interval, no debt
    assert pacer.slices == 1
    pacer.on_submit(6 * KB)              # tops the interval up -> slice
    assert pacer.slices == 2


def test_pacer_drains_leftover_debt_without_new_writes():
    """Flush-induced debt with an idle write rate still converges: each
    idle pass releases one slice while carried debt remains."""
    store = build(small_config(), shards=1)
    rng = np.random.default_rng(2)
    for _ in range(30):
        t = TREES[int(rng.integers(0, 2))]
        ks = rng.integers(0, KEY_SPACE, 300)
        store.write_batch(t, ks, ks + 1, tick=False)
        for name in ("upkeep", "mem", "log"):
            store.scheduler.run_segment(name)
    # make carried_debt visible to the pacer without draining it
    store.scheduler.run_segment("merge", merge_budget=1)
    assert store.scheduler.carried_debt > 0
    pacer = MaintenancePacer(store.scheduler, segment_budget=4,
                             interval_bytes=1 * MB)
    passes = 0
    while store.scheduler.carried_debt > 0:
        pacer.on_submit(0)               # idle: no bytes observed
        passes += 1
        assert passes < 1000
    assert pacer.slices == passes        # one slice per idle pass


def test_pacer_defers_slices_past_flush_passes():
    """Flush-averse pacing: a pass whose mandatory segments flushed banks
    its slice (the stall already happened -- don't stack discretionary
    work on it); the next flush-free pass releases the banked budget.
    Once carried debt exceeds the ``MAX_DEFER_DEBT_SLICES`` override,
    slices release even on flush passes (backlog beats shaping)."""
    store = build(small_config(), shards=1)
    pacer = MaintenancePacer(store.scheduler, segment_budget=2,
                             interval_bytes=8 * KB)
    rng = np.random.default_rng(5)

    def overfill():
        guard = 0
        while store.write_memory_used() <= \
                store.cfg.mem_flush_threshold * store.write_memory_bytes:
            ks = rng.integers(0, KEY_SPACE, 300)
            store.write_batch("a", ks, ks + 1, tick=False)
            guard += 1
            assert guard < 1000

    overfill()
    rep = pacer.on_submit(64 * KB)       # interval banked, but it flushed
    assert rep.flushes > 0
    assert pacer.slices == 0 and pacer.deferrals == 1
    assert pacer._pending == 64 * KB     # banked, not consumed
    rep2 = pacer.on_submit(0)            # flush-free pass: catch-up slice
    assert rep2.flushes == 0
    assert pacer.slices == 1

    # pile carried debt past the override without serving it, then force
    # another flush pass: the slice must release anyway
    guard = 0
    while store.scheduler.carried_debt <= \
            MAX_DEFER_DEBT_SLICES * pacer.segment_budget:
        ks = rng.integers(0, KEY_SPACE, 300)
        store.write_batch(TREES[guard % 2], ks, ks + 1, tick=False)
        for name in ("upkeep", "mem", "log"):
            store.scheduler.run_segment(name)
        store.scheduler.run_segment("merge", merge_budget=1)
        guard += 1
        assert guard < 1000
    overfill()
    before = pacer.slices
    rep3 = pacer.on_submit(64 * KB)
    assert rep3.flushes > 0
    assert pacer.slices == before + 1    # released despite the flush


def test_pacer_rejects_bad_knobs():
    store = build(small_config(), shards=1)
    with pytest.raises(ValueError, match="segment_budget"):
        MaintenancePacer(store.scheduler, segment_budget=0,
                         interval_bytes=1024)
    with pytest.raises(ValueError, match="interval_bytes"):
        MaintenancePacer(store.scheduler, segment_budget=1,
                         interval_bytes=0)


def test_bare_store_segments_match_sharded_one_shard():
    """``MaintenanceScheduler.run_segment`` (bare store) and the global
    ``ShardedMaintenanceScheduler``'s (one shard) are bit-identical --
    the PR-4 single-shard equivalence extended to segment granularity."""
    from repro.core.lsm.storage import LSMStore
    cfg = small_config()
    events = gen_schedule(seed=9, n_events=24)

    reset_sst_ids()
    bare = LSMStore(cfg)
    for t in TREES:
        bare.create_tree(t)
    oracle = {t: {} for t in TREES}
    for ev in events:
        apply_event(bare, ev, oracle)

    sharded, _ = run_schedule(cfg, events, shards=1)
    assert fingerprint(bare) == fingerprint(sharded.shards[0].store)
    assert vars(bare.disk.stats) == vars(sharded.disk.stats)
    assert bare.scheduler.carried_debt == sharded.scheduler.carried_debt
