"""Backend parity: NumpyBackend vs PallasBackend (interpret mode) must
agree on merge reconciliation, Bloom probes (including false positives --
both backends share one hash geometry), and batched lookups; and the
store's batched read path must agree with the scalar lookup loop."""
import numpy as np
import pytest

from repro.core.engine import (NumpyBackend, PallasBackend, bloom_sizing,
                               get_backend)
from repro.core.lsm.cache import ClockCache, Disk
from repro.core.lsm.sstable import reset_sst_ids, sstable_from_run
from repro.core.lsm.storage import LSMStore, StoreConfig

KB, MB = 1 << 10, 1 << 20


@pytest.fixture(scope="module")
def backends():
    return NumpyBackend(), PallasBackend(interpret=True)


def small_config(**kw):
    base = dict(total_memory_bytes=32 * MB, write_memory_bytes=2 * MB,
                sim_cache_bytes=1 * MB, page_bytes=4 * KB, entry_bytes=256,
                active_sstable_bytes=64 * KB, sstable_bytes=128 * KB,
                max_log_bytes=8 * MB, scheme="partitioned",
                flush_policy="opt")
    base.update(kw)
    reset_sst_ids()
    return StoreConfig(**base)


# --------------------------- primitives -------------------------------------
def test_backend_registry_and_env(monkeypatch):
    monkeypatch.delenv("REPRO_LSM_BACKEND", raising=False)
    assert get_backend("numpy").name == "numpy"
    assert get_backend(None).name == "numpy"
    monkeypatch.setenv("REPRO_LSM_BACKEND", "pallas")
    assert get_backend(None).name == "pallas"      # env fills the default
    assert get_backend("numpy").name == "numpy"    # explicit choice wins
    monkeypatch.delenv("REPRO_LSM_BACKEND")
    with pytest.raises(ValueError):
        get_backend("cuda")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_merge_parity_newest_wins(backends, seed):
    nb, pb = backends
    rng = np.random.default_rng(seed)
    runs, oracle = [], {}
    for _ in range(rng.integers(2, 6)):
        n = int(rng.integers(1, 1200))
        k = np.sort(rng.choice(50_000, size=n, replace=False)).astype(np.int64)
        v = rng.integers(-2**31 + 1, 2**31, size=n).astype(np.int64)
        runs.append((k, v))
    for k, v in reversed(runs):          # oldest first: newer overwrites
        oracle.update(zip(k.tolist(), v.tolist()))
    k1, v1 = nb.merge_runs(runs)
    k2, v2 = pb.merge_runs(runs)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(v1, v2)
    assert k1.tolist() == sorted(oracle)
    assert v1.tolist() == [oracle[k] for k in k1.tolist()]


def test_merge_empty_and_single_run(backends):
    nb, pb = backends
    for b in (nb, pb):
        k, v = b.merge_runs([])
        assert len(k) == 0 and len(v) == 0
        k1 = np.array([3, 7, 9], np.int64)
        k, v = b.merge_runs([(k1, k1 * 2)])
        np.testing.assert_array_equal(k, k1)
        np.testing.assert_array_equal(v, k1 * 2)


def test_merge_out_of_int32_range_falls_back(backends):
    _, pb = backends
    k1 = np.array([1, 2**40], np.int64)          # beyond int32
    k2 = np.array([2], np.int64)
    before = pb.fallback_calls
    k, v = pb.merge_runs([(k1, k1), (k2, k2)])
    assert pb.fallback_calls == before + 1
    assert k.tolist() == [1, 2, 2**40]


@pytest.mark.parametrize("n", [100, 1500])
def test_bloom_parity_exact(backends, n):
    nb, pb = backends
    rng = np.random.default_rng(n)
    keys = rng.choice(2**30, size=n, replace=False).astype(np.int64)
    f_n = nb.bloom_build(keys)
    f_p = pb.bloom_build(keys)
    probes = np.concatenate([keys, rng.choice(2**30, 4000).astype(np.int64)])
    p_n = nb.bloom_probe(f_n, probes)
    p_p = pb.bloom_probe(f_p, probes)
    np.testing.assert_array_equal(p_n, p_p)      # incl. false positives
    assert p_n[:n].all(), "no false negatives"
    assert p_n[n:].mean() < 0.05


def test_bloom_probe_mixed_domain_no_false_negatives(backends):
    nb, pb = backends
    keys = np.array([5, 10, 20], np.int64)
    probes = np.array([5, 2**40, 20, 7], np.int64)   # mixed int32 domain
    results = []
    for b in (nb, pb):
        got = b.bloom_probe(b.bloom_build(keys), probes)
        assert got[0] and got[2], "present keys must stay positive"
        results.append(got)
    # parity extends to out-of-domain aliasing (both wrap to int32)
    np.testing.assert_array_equal(results[0], results[1])
    wrapped = np.array([2**32 + 5, 2**32 + 10], np.int64)   # alias to 5, 10
    for b in (nb, pb):
        assert b.bloom_probe(b.bloom_build(keys), wrapped).all()


def test_bloom_sizing_bucketed():
    n1, s1 = bloom_sizing(100)
    n2, s2 = bloom_sizing(256)
    assert (n1, s1) == (n2, s2)                  # same bucket
    assert s1 % 128 == 0


@pytest.mark.parametrize("seed", [0, 3])
def test_lookup_batch_parity(backends, seed):
    nb, pb = backends
    rng = np.random.default_rng(seed)
    sk = np.sort(rng.choice(20_000, 800, replace=False)).astype(np.int64)
    q = rng.integers(0, 20_000, 257).astype(np.int64)
    pos_n, f_n = nb.lookup_batch(sk, q)
    pos_p, f_p = pb.lookup_batch(sk, q)
    np.testing.assert_array_equal(pos_n, pos_p)
    np.testing.assert_array_equal(f_n, f_p)
    present = np.isin(q, sk)
    np.testing.assert_array_equal(f_n, present)


# --------------------------- store-level parity ------------------------------
def _drive(store, n_steps=25, batch=300):
    rng = np.random.default_rng(11)
    oracle = {}
    for _ in range(n_steps):
        ks = rng.integers(0, 30_000, size=batch)
        vs = rng.integers(0, 2**31, size=batch)
        store.write("t", ks, vs)
        oracle.update(zip(ks.tolist(), vs.tolist()))
    return oracle


def test_read_batch_matches_scalar_lookup_loop():
    store = LSMStore(small_config())
    store.create_tree("t")
    oracle = _drive(store)
    rng = np.random.default_rng(5)
    probe = np.concatenate([
        rng.choice(np.fromiter(oracle, np.int64), 400),
        rng.integers(40_000, 50_000, size=100)])     # absent keys
    found_b, vals_b = store.read_batch("t", probe)
    for i, k in enumerate(probe.tolist()):
        f, v = store.lookup("t", k)
        assert f == found_b[i], k
        assert v == vals_b[i], k
        assert f == (k in oracle)
        if f:
            assert v == oracle[k]


@pytest.mark.parametrize("scheme", ["partitioned", "btree-dynamic",
                                    "accordion-data"])
def test_store_end_to_end_pallas_backend(scheme):
    """A store configured with backend="pallas" (interpret mode on CPU)
    reconciles exactly like the numpy reference."""
    store_p = LSMStore(small_config(scheme=scheme, backend="pallas"))
    store_p.create_tree("t")
    oracle = _drive(store_p, n_steps=12, batch=200)
    store_n = LSMStore(small_config(scheme=scheme, backend="numpy"))
    store_n.create_tree("t")
    _drive(store_n, n_steps=12, batch=200)
    rng = np.random.default_rng(9)
    probe = np.concatenate([
        rng.choice(np.fromiter(oracle, np.int64), 150),
        rng.integers(40_000, 50_000, size=50)])
    found_p, vals_p = store_p.read_batch("t", probe)
    found_n, vals_n = store_n.read_batch("t", probe)
    np.testing.assert_array_equal(found_p, found_n)
    np.testing.assert_array_equal(vals_p, vals_n)
    for i, k in enumerate(probe.tolist()):
        assert bool(found_p[i]) == (k in oracle)
        if found_p[i]:
            assert int(vals_p[i]) == oracle[k]
    # identical structure -> identical I/O accounting across backends,
    # on the read path AND the write (flush/merge) path
    sp, sn = store_p.disk.stats, store_n.disk.stats
    assert sp.query_pins == sn.query_pins
    assert sp.pages_flushed == sn.pages_flushed
    assert sp.pages_merge_written == sn.pages_merge_written
    assert sp.merge_pins == sn.merge_pins
    assert sp.pages_merge_read == sn.pages_merge_read
    assert (sp.flushes_mem, sp.flushes_log) == (sn.flushes_mem,
                                                sn.flushes_log)


# --------------------------- write-pin accounting ----------------------------
def test_write_sst_accounting_flush_vs_merge():
    """Write-path mirror of the query_pin_many read assertions: flush vs
    merge writes land in the right counters, written pages (data + Bloom)
    install into the buffer cache without a miss, and drop_sst
    invalidates them."""
    cache = ClockCache(1024)
    disk = Disk(page_bytes=4 * KB, cache=cache)
    keys = np.arange(0, 100, dtype=np.int64)
    sst_f = sstable_from_run(keys, keys, 0, 0, 256, 4 * KB)
    sst_m = sstable_from_run(keys, keys, 0, 0, 256, 4 * KB)
    disk.write_sst(sst_f, flush=True)
    assert disk.stats.pages_flushed == sst_f.num_pages + sst_f.bloom_pages()
    assert disk.stats.pages_merge_written == 0
    disk.write_sst(sst_m, flush=False)
    assert disk.stats.pages_merge_written \
        == sst_m.num_pages + sst_m.bloom_pages()
    assert disk.stats.pages_flushed \
        == sst_f.num_pages + sst_f.bloom_pages()   # unchanged
    # freshly written pages are cache-resident: pins hit, no disk read
    misses0 = cache.misses
    for p in range(sst_f.num_pages):
        disk.query_pin(sst_f.sst_id, p)
    disk.query_pin(sst_f.sst_id, -1)               # bloom page unit
    assert cache.misses == misses0
    assert disk.stats.pages_query_read == 0
    # dropping the SSTable invalidates every page (data + bloom)
    disk.drop_sst(sst_f)
    disk.query_pin(sst_f.sst_id, 0)
    assert disk.stats.pages_query_read == 1


def test_write_path_accounting_batched_vs_scalar():
    """Flush/merge write accounting must be identical whether entries
    arrive as one batch or one-at-a-time (with the same tick sequence)."""
    def drive(batched):
        store = LSMStore(small_config(write_memory_bytes=512 * KB))
        store.create_tree("t")
        rng = np.random.default_rng(21)
        for _ in range(20):
            ks = rng.integers(0, 30_000, size=300)
            vs = rng.integers(0, 2**31, size=300)
            if batched:
                store.write_batch("t", ks, vs, tick=False)
            else:
                for k, v in zip(ks.tolist(), vs.tolist()):
                    store.write_batch("t", [k], [v], tick=False)
            store.scheduler.tick()
        return store.disk.stats
    sb, ss = drive(True), drive(False)
    assert sb.pages_flushed == ss.pages_flushed > 0
    assert sb.pages_merge_written == ss.pages_merge_written > 0
    assert sb.merge_pins == ss.merge_pins
    assert sb.pages_merge_read == ss.pages_merge_read
    assert (sb.flushes_mem, sb.flushes_log) == (ss.flushes_mem,
                                                ss.flushes_log)
    assert sb.entries_written == ss.entries_written


def test_ingest_run_parity_and_dedup(backends):
    """ingest_run: numpy and pallas agree bit-for-bit on sorted order,
    surviving values, and source positions (newest occurrence wins)."""
    nb, pb = backends
    rng = np.random.default_rng(4)
    for n, hi in [(1, 10), (257, 40), (1000, 10**6), (640, 25)]:
        keys = rng.integers(0, hi, size=n)
        vals = rng.integers(-2**31 + 1, 2**31, size=n)
        k1, v1, s1 = nb.ingest_run(keys, vals)
        k2, v2, s2 = pb.ingest_run(keys, vals)
        np.testing.assert_array_equal(k1, k2)
        np.testing.assert_array_equal(v1, v2)
        np.testing.assert_array_equal(s1, s2)
        oracle = {}
        for k, v in zip(keys.tolist(), vals.tolist()):
            oracle[k] = v
        assert k1.tolist() == sorted(oracle)
        assert v1.tolist() == [oracle[k] for k in k1.tolist()]
    # out-of-int32-domain keys fall back to the reference
    before = pb.fallback_calls
    k, v, s = pb.ingest_run(np.array([7, 2**40, 7], np.int64),
                            np.array([1, 2, 3], np.int64))
    assert pb.fallback_calls == before + 1
    assert k.tolist() == [7, 2**40] and v.tolist() == [3, 2]


def test_read_batch_counts_ops_like_scalar():
    store = LSMStore(small_config())
    store.create_tree("t")
    store.write("t", [1, 2, 3], [1, 2, 3])
    before = store.disk.stats.ops
    store.read_batch("t", np.arange(64))
    assert store.disk.stats.ops == before + 64
