"""Attention implementation equivalences: chunked (flash-style XLA) ==
full softmax across masks/softcaps; ds-layout grouped-GQA == sd-layout."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.attention import (chunked_attention, full_attention,
                                    full_attention_ds)


def cfg_with(**kw):
    return reduced(get_config("yi-6b")).with_(**kw)


def rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@pytest.mark.parametrize("window,softcap,causal", [
    (0, 0.0, True), (32, 0.0, True), (0, 30.0, True), (16, 50.0, True),
    (0, 0.0, False)])
def test_chunked_equals_full(window, softcap, causal):
    cfg = cfg_with(attn_chunk_q=32, attn_chunk_kv=16, attn_softcap=softcap)
    rng = np.random.default_rng(window + int(softcap))
    b, s, h, kv, hd = 2, 128, 4, 2, 16
    q, k, v = rand(rng, (b, s, h, hd)), rand(rng, (b, s, kv, hd)), \
        rand(rng, (b, s, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    a = full_attention(cfg, q, k, v, pos, pos, window=window,
                       softcap_val=softcap, causal=causal)
    c = chunked_attention(cfg, q, k, v, pos, pos, window=window,
                          softcap_val=softcap, causal=causal)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a), rtol=2e-5,
                               atol=2e-5)


def test_ds_layout_equals_sd_layout():
    cfg = cfg_with()
    rng = np.random.default_rng(1)
    b, s, h, kv, hd = 2, 64, 8, 2, 16
    q = rand(rng, (b, s, h, hd))
    k = rand(rng, (b, s, kv, hd))
    v = rand(rng, (b, s, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ref = full_attention(cfg, q, k, v, pos, pos)
    got = full_attention_ds(cfg, q, k.transpose(0, 2, 3, 1),
                            v.transpose(0, 2, 3, 1), pos, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_paged_decode_matches_teacher_forcing():
    """Gather-based paged KV pool (device-side page tables) decodes to the
    same logits as the dense teacher-forcing forward."""
    import jax
    from repro.models import build_model, init_params
    cfg = cfg_with(kv_layout="paged", kv_page_tokens=8)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0),
                         cfg.param_dtype)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))
    full = model.apply(params, tokens)
    cache = init_params(model.cache_specs(2, 32), jax.random.key(1),
                        cfg.param_dtype)
    n_pages = 4
    for blk in cache["blocks"]:
        if "page_table" in blk:
            layers = blk["page_table"].shape[0]
            pt = jnp.broadcast_to(
                jnp.arange(2 * n_pages, dtype=jnp.int32).reshape(1, 2,
                                                                 n_pages),
                (layers, 2, n_pages))
            blk["page_table"] = pt
    logits, c = None, cache
    for t in range(16):
        logits, c = model.decode_step(params, tokens[:, t:t + 1], c,
                                      jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, -1]), rtol=2e-2,
                               atol=2e-2)
