"""Durability plane: WAL round-trips, checkpointed manifests, and the
differential crash-recovery contract.

The load-bearing invariant: crash at ANY batch boundary of a random mixed
workload -> ``recover(cfg, wal, manifest)`` -> the rebuilt store is
*bit-identical* to the uncrashed store at that boundary -- memory-component
structure, L0 groups, disk levels, ``log_pos``, write-memory size, and the
write-path IOStats counters (``RECOVERY_EXACT_COUNTERS``) -- and continuing
the workload on the recovered store reproduces the uncrashed run's
subsequent read/scan results and final state exactly. Verified for
shards in {1, 4} on the session backend (CI runs numpy and
pallas-interpret via ``REPRO_LSM_BACKEND``).

Also here: the WAL record encode/decode round-trip property (hypothesis-
driven when available), physical-truncation invariants (``tail_bytes ==
log_length`` after every tick), the crash-mid-maintenance redo case, and
the service-level proof that ``Deferred`` writes never reach the log.
"""
import numpy as np
import pytest

from repro.core.durability import (RECOVERY_EXACT_COUNTERS,
                                   DeleteBatchRecord, TickRecord,
                                   TreeCreateRecord, WriteBatchRecord,
                                   decode_record, encode_record, recover)
from repro.core.durability.wal import SetWriteMemoryRecord
from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import LSMStore, StoreConfig
from repro.core.service import (Deferred, Put, ServiceConfig,
                                StorageService)
from repro.core.shard import ShardedStore, ShardRouter

from test_differential import KB, MB, fingerprint

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

TREES = ("a", "b")
KEY_SPACE = 2000


def small_config(**kw):
    base = dict(
        total_memory_bytes=32 * MB, write_memory_bytes=256 * KB,
        sim_cache_bytes=1 * MB, page_bytes=4 * KB, entry_bytes=256,
        active_sstable_bytes=32 * KB, sstable_bytes=64 * KB,
        # tight log cap: log-triggered (min-LSN) flushes and physical
        # truncation fire within the small test workloads
        max_log_bytes=512 * KB, scheme="partitioned", flush_policy="lsn")
    base.update(kw)
    return StoreConfig(**base)


def sharded_fingerprint(store: ShardedStore):
    return [fingerprint(sh.store) for sh in store.shards]


def exact_counters(store) -> dict:
    return {k: getattr(store.disk.stats, k)
            for k in RECOVERY_EXACT_COUNTERS}


# --------------------------- WAL record round-trip -----------------------------
def _roundtrip(rec):
    out = decode_record(encode_record(rec))
    assert type(out) is type(rec)
    return out


def test_wal_record_roundtrip_fixed():
    w = WriteBatchRecord("tree-x", 4096, 256,
                         np.array([5, -3, 2**40], np.int64),
                         np.array([1, 2, 3], np.int64), op=True)
    out = _roundtrip(w)
    assert out.tree == "tree-x" and out.lsn0 == 4096
    assert out.entry_bytes == 256 and out.op is True
    np.testing.assert_array_equal(out.keys, w.keys)
    np.testing.assert_array_equal(out.vals, w.vals)
    assert out.lsn_end == 4096 + 3 * 256

    d = DeleteBatchRecord("t", 0, 128, np.array([], np.int64), op=False)
    out = _roundtrip(d)
    assert len(out.keys) == 0 and out.op is False and out.lsn_end == 0

    for tc in (TreeCreateRecord("orders", dataset="ds", entry_bytes=512),
               TreeCreateRecord("orders", dataset=None, entry_bytes=None)):
        out = _roundtrip(tc)
        assert (out.tree, out.dataset, out.entry_bytes) \
            == (tc.tree, tc.dataset, tc.entry_bytes)

    for budget in ("default", "drain", 0, 7):
        out = _roundtrip(TickRecord(lsn0=99, merge_budget=budget))
        assert out.merge_budget == budget and out.lsn0 == 99
        assert out.segment == "full"
        # segment-granular tick records (paced maintenance) round-trip
        for seg in ("upkeep", "mem", "log", "merge", "wal"):
            out = _roundtrip(TickRecord(lsn0=7, merge_budget=budget,
                                        segment=seg))
            assert (out.merge_budget, out.segment) == (budget, seg)

    out = _roundtrip(SetWriteMemoryRecord(write_memory_bytes=1 << 22,
                                          lsn0=10))
    assert out.write_memory_bytes == 1 << 22


if HAVE_HYPOTHESIS:
    key_arrays = st.lists(st.integers(-2**62, 2**62 - 1),
                          max_size=64).map(lambda v: np.array(v, np.int64))

    @settings(max_examples=60, deadline=None)
    @given(st.text(max_size=24).filter(lambda s: "\x00" not in s),
           st.integers(0, 2**50), st.integers(1, 4096), key_arrays,
           key_arrays, st.booleans(), st.booleans())
    def test_hypothesis_wal_batch_roundtrip(tree, lsn0, entry_bytes,
                                            keys, vals, op, delete):
        """Encode/decode is identity for arbitrary key/val batches --
        including empty-val delete records and empty batches."""
        if delete:
            rec = DeleteBatchRecord(tree, lsn0, entry_bytes, keys, op=op)
        else:
            vals = np.resize(vals, keys.shape) if len(keys) else keys
            rec = WriteBatchRecord(tree, lsn0, entry_bytes, keys, vals,
                                   op=op)
        out = _roundtrip(rec)
        assert out.tree == tree and out.lsn0 == lsn0
        assert out.entry_bytes == entry_bytes and out.op == op
        np.testing.assert_array_equal(out.keys, keys)
        if not delete:
            np.testing.assert_array_equal(out.vals, rec.vals)
        assert out.lsn_end == lsn0 + len(keys) * entry_bytes


# --------------------------- config validation ---------------------------------
def test_validate_rejects_bad_durability_knobs():
    with pytest.raises(ValueError, match="max_log_bytes"):
        small_config(max_log_bytes=0).validate()
    with pytest.raises(ValueError, match="max_log_bytes"):
        small_config(max_log_bytes=-4096).validate()
    with pytest.raises(ValueError, match="checkpoint_interval_bytes"):
        small_config(checkpoint_interval_bytes=0).validate()
    with pytest.raises(ValueError, match="checkpoint_interval_bytes"):
        small_config(checkpoint_interval_bytes=-1).validate()
    # valid values still pass
    small_config(checkpoint_interval_bytes=1 * MB).validate()
    small_config(checkpoint_interval_bytes=None).validate()


# --------------------------- workload driver -----------------------------------
def gen_batches(seed, n_batches=25):
    """Deterministic mixed workload: per-batch op specs, replayable from
    any boundary. Write-path ops drive the durable state; reads/scans
    interleave to pin result-identity (they are volatile by design)."""
    rng = np.random.default_rng(seed)
    batches = []
    for _ in range(n_batches):
        r = rng.random()
        tree = TREES[int(rng.integers(0, len(TREES)))]
        seed2 = int(rng.integers(0, 2**31))
        size = int(rng.integers(60, 260))
        if r < 0.45:
            batches.append(("write", tree, seed2, size))
        elif r < 0.60:
            batches.append(("delete", tree, seed2, max(10, size // 3)))
        elif r < 0.75:
            batches.append(("lookup", tree, seed2, size))
        elif r < 0.85:
            batches.append(("scan", tree, int(rng.integers(0, KEY_SPACE)),
                            int(rng.integers(10, 400))))
        elif r < 0.95:
            batches.append(("tick",))
        else:
            # keep the pool small enough that flushes (and so min-LSN
            # advancement + log truncation) keep happening
            batches.append(("setmem", int(rng.integers(256, 640)) * KB))
    return batches


def apply_batch(store, batch, oracle, outputs):
    kind = batch[0]
    if kind == "write":
        _, t, seed, size = batch
        rng = np.random.default_rng(seed)
        ks = rng.integers(0, KEY_SPACE, size)
        vs = rng.integers(0, 2**31, size)
        store.write_batch(t, ks, vs)
        oracle[t].update(zip(ks.tolist(), vs.tolist()))
    elif kind == "delete":
        _, t, seed, size = batch
        ks = np.random.default_rng(seed).integers(0, KEY_SPACE, size)
        store.delete_batch(t, ks)
        for k in ks.tolist():
            oracle[t][k] = None
    elif kind == "lookup":
        _, t, seed, size = batch
        ks = np.random.default_rng(seed).integers(0, KEY_SPACE + 500, size)
        found, vals = store.read_batch(t, ks)
        for i, k in enumerate(ks.tolist()):
            want = oracle[t].get(k)
            assert bool(found[i]) == (want is not None), (t, k)
            if want is not None:
                assert int(vals[i]) == want, (t, k)
        outputs.append(("lookup", found.tolist(), vals.tolist()))
    elif kind == "scan":
        _, t, lo, width = batch
        n = store.scan(t, lo, width)
        want = sum(1 for k, v in oracle[t].items()
                   if lo <= k < lo + width and v is not None)
        assert n == want, (t, lo, width)
        outputs.append(("scan", n))
    elif kind == "tick":
        store.scheduler.tick()
    elif kind == "setmem":
        store.set_write_memory(batch[1])
    else:                                         # pragma: no cover
        raise AssertionError(batch)


def run_workload(cfg, batches, *, shards, crash_after=None,
                 checkpoint_interval=None):
    """Drive ``batches`` on a fresh sharded store; returns the store plus
    per-boundary durable snapshots (WAL/manifest clones), fingerprints and
    counters when ``crash_after is None``, or just the store driven up to
    boundary ``crash_after``."""
    if checkpoint_interval is not None:
        cfg = StoreConfig(**{**vars(cfg),
                             "checkpoint_interval_bytes": checkpoint_interval})
    reset_sst_ids()
    store = ShardedStore(cfg, shards=shards)
    for t in TREES:
        store.create_tree(t)
    oracle = {t: {} for t in TREES}
    outputs: list = []
    snaps = []
    for bi, batch in enumerate(batches):
        apply_batch(store, batch, oracle, outputs)
        if crash_after is None:
            snaps.append({
                "wal": store.wal.clone(),
                "manifest": store.manifest.clone(),
                "fp": sharded_fingerprint(store),
                "counters": exact_counters(store),
                "log_pos": store.log_pos,
                "log_length": store.log_length,
            })
        if crash_after is not None and bi == crash_after:
            break
    return store, oracle, outputs, snaps


# --------------------------- crash-point matrix --------------------------------
@pytest.mark.parametrize("shards", [1, 4])
def test_crash_recovery_matrix(shards):
    """Crash at EVERY batch boundary of a ~200-op mixed oracle workload:
    the recovered store must be bit-identical (structure + exact counters
    + log position) to the uncrashed store at that boundary, with the WAL
    physically truncated (tail == log_length) throughout."""
    cfg = small_config()
    batches = gen_batches(seed=11, n_batches=25)
    # ~200+ logical write-path ops across the batches
    assert sum(b[3] for b in batches if b[0] in ("write", "delete")) >= 200
    store, oracle, _, snaps = run_workload(cfg, batches, shards=shards)
    truncations = 0
    for bi, snap in enumerate(snaps):
        # physical truncation invariant at every batch boundary: the WAL's
        # retained tail is exactly the paper's log_length
        assert snap["wal"].tail_bytes == snap["log_length"], f"boundary {bi}"
        recovered = recover(cfg, snap["wal"], snap["manifest"])
        assert recovered.n_shards == shards
        assert sharded_fingerprint(recovered) == snap["fp"], f"boundary {bi}"
        assert exact_counters(recovered) == snap["counters"], f"boundary {bi}"
        assert recovered.log_pos == snap["log_pos"]
        truncations += snap["wal"].truncated_to > 0
    # the scheduler's log enforcement actually truncated along the way
    assert truncations > 0
    # live-store invariant after the full run: tail == log_length
    assert store.wal.tail_bytes == store.log_length


@pytest.mark.parametrize("shards", [1, 4])
def test_crash_recovery_continuation_bit_identical(shards):
    """Recover at a few crash points, then continue the remaining
    workload on the recovered store: subsequent read/scan results and the
    final structural state must equal the uncrashed run's exactly."""
    cfg = small_config()
    batches = gen_batches(seed=29, n_batches=22)
    full_store, _, full_outputs, snaps = run_workload(cfg, batches,
                                                      shards=shards)
    final_fp = sharded_fingerprint(full_store)
    for crash_at in (0, len(batches) // 2, len(batches) - 2):
        snap = snaps[crash_at]
        recovered = recover(cfg, snap["wal"], snap["manifest"])
        # rebuild the oracle as of the crash point, then continue
        oracle = {t: {} for t in TREES}
        outputs: list = []
        for b in batches[:crash_at + 1]:
            _replay_oracle_only(b, oracle, outputs)
        for b in batches[crash_at + 1:]:
            apply_batch(recovered, b, oracle, outputs)
        assert outputs == full_outputs, f"crash at {crash_at}"
        assert sharded_fingerprint(recovered) == final_fp, \
            f"crash at {crash_at}"
        assert exact_counters(recovered) == exact_counters(full_store), \
            f"crash at {crash_at}"


def _replay_oracle_only(batch, oracle, outputs):
    """Advance the oracle (and expected read outputs) without a store:
    the pre-crash prefix of the workload, whose reads the crashed store
    already answered."""
    kind = batch[0]
    if kind == "write":
        _, t, seed, size = batch
        rng = np.random.default_rng(seed)
        ks = rng.integers(0, KEY_SPACE, size)
        vs = rng.integers(0, 2**31, size)
        oracle[t].update(zip(ks.tolist(), vs.tolist()))
    elif kind == "delete":
        _, t, seed, size = batch
        ks = np.random.default_rng(seed).integers(0, KEY_SPACE, size)
        for k in ks.tolist():
            oracle[t][k] = None
    elif kind == "lookup":
        _, t, seed, size = batch
        ks = np.random.default_rng(seed).integers(0, KEY_SPACE + 500, size)
        found = [oracle[t].get(k) is not None for k in ks.tolist()]
        vals = [oracle[t].get(k) or 0 for k in ks.tolist()]
        outputs.append(("lookup", found, vals))
    elif kind == "scan":
        _, t, lo, width = batch
        outputs.append(("scan", sum(
            1 for k, v in oracle[t].items()
            if lo <= k < lo + width and v is not None)))


def test_recovered_store_can_crash_and_recover_again():
    """A recovered store is a full citizen of the durability plane: it
    keeps appending to the same WAL/manifest and recovers again."""
    cfg = small_config()
    batches = gen_batches(seed=5, n_batches=12)
    _, oracle, _, snaps = run_workload(cfg, batches, shards=2)
    snap = snaps[6]
    rec1 = recover(cfg, snap["wal"], snap["manifest"])
    oracle2 = {t: {} for t in TREES}
    for b in batches[:7]:
        _replay_oracle_only(b, oracle2, [])
    for b in batches[7:]:
        apply_batch(rec1, b, oracle2, [])
    fp1 = sharded_fingerprint(rec1)
    rec2 = recover(cfg, rec1.wal.clone(), rec1.manifest.clone())
    assert sharded_fingerprint(rec2) == fp1
    assert exact_counters(rec2) == exact_counters(rec1)


# --------------------------- schemes / policies --------------------------------
@pytest.mark.parametrize("scheme", ["btree-dynamic", "accordion-data"])
def test_crash_recovery_other_schemes(scheme):
    """Monolithic and Accordion memory components checkpoint/replay too."""
    cfg = small_config(scheme=scheme, flush_policy="mem")
    batches = gen_batches(seed=13, n_batches=16)
    _, _, _, snaps = run_workload(cfg, batches, shards=2)
    for bi in (3, 9, len(snaps) - 1):
        snap = snaps[bi]
        recovered = recover(cfg, snap["wal"], snap["manifest"])
        assert sharded_fingerprint(recovered) == snap["fp"], f"boundary {bi}"
        assert exact_counters(recovered) == snap["counters"]


def test_crash_recovery_opt_policy_rate_windows():
    """The OPT flush policy ranks victims by per-tree write-rate windows;
    recovery must restore them (checkpoint) and rebuild them (replay) so
    post-recovery flush decisions match."""
    cfg = small_config(flush_policy="opt")
    batches = gen_batches(seed=17, n_batches=18)
    full_store, _, full_outputs, snaps = run_workload(cfg, batches, shards=1)
    crash_at = len(batches) // 2
    snap = snaps[crash_at]
    recovered = recover(cfg, snap["wal"], snap["manifest"])
    oracle = {t: {} for t in TREES}
    outputs: list = []
    for b in batches[:crash_at + 1]:
        _replay_oracle_only(b, oracle, outputs)
    for b in batches[crash_at + 1:]:
        apply_batch(recovered, b, oracle, outputs)
    assert outputs == full_outputs
    assert sharded_fingerprint(recovered) == sharded_fingerprint(full_store)
    # the OPT decision state itself round-tripped
    live = full_store.shards[0].store
    rec = recovered.shards[0].store
    assert {n: list(w) for n, w in live._rate_win.items()} \
        == {n: list(w) for n, w in rec._rate_win.items()}
    assert live._share_ewma == rec._share_ewma


# --------------------------- truncation / checkpoint ---------------------------
def test_scheduler_truncation_physically_drops_records():
    """Log enforcement is physical: after every tick the WAL's retained
    tail equals ``log_length``, and records below min-LSN are gone."""
    cfg = small_config(max_log_bytes=2 * MB)
    reset_sst_ids()
    store = ShardedStore(cfg, shards=2)
    store.create_tree("a")
    rng = np.random.default_rng(0)
    dropped = False
    for _ in range(60):
        ks = rng.integers(0, KEY_SPACE, 300)
        store.write_batch("a", ks, ks + 1)     # tick per batch
        assert store.wal.tail_bytes == store.log_length
        if store.wal.truncated_to > 0:
            dropped = True
            # every retained record ends above the truncation watermark
            assert all(r.lsn_end > store.wal.truncated_to
                       for r in store.wal.records())
    assert dropped
    # checkpoints were forced ahead of truncation: the tail above the
    # latest checkpoint is always replayable
    ck = store.manifest.latest_checkpoint
    assert ck is not None and ck.watermark >= store.wal.truncated_to


def test_checkpoint_interval_bounds_replay_tail():
    """The checkpoint-interval knob caps the WAL replay tail (and so the
    recovery time) independently of flush/truncation activity."""
    cfg = small_config(max_log_bytes=64 * MB)    # log cap never binds
    batches = [("write", "a", s, 100) for s in range(40)]

    def replayed(interval):
        reset_sst_ids()
        c = StoreConfig(**{**vars(cfg),
                           "checkpoint_interval_bytes": interval})
        store = ShardedStore(c, shards=1)
        store.create_tree("a")
        oracle = {"a": {}, "b": {}}
        for b in batches:
            apply_batch(store, b, oracle, [])
        rec = recover(c, store.wal.clone(), store.manifest.clone())
        assert sharded_fingerprint(rec) == sharded_fingerprint(store)
        return rec.recovery_info["replayed_records"]

    unbounded = replayed(None)
    bounded = replayed(256 * KB)
    assert bounded < unbounded


# --------------------------- segment-boundary crash matrix ---------------------
@pytest.mark.parametrize("shards", [1, 4])
def test_crash_at_every_segment_boundary(shards):
    """Paced maintenance logs one TickRecord per resumable segment; a
    crash landing BETWEEN logged segments must recover bit-identically --
    every segment boundary of a random interleaved schedule is a crash
    point, not just batch boundaries."""
    from repro.core.engine.scheduler import SEGMENTS
    cfg = small_config()
    reset_sst_ids()
    store = ShardedStore(cfg, shards=shards)
    for t in TREES:
        store.create_tree(t)
    rng = np.random.default_rng(41)
    snaps = []

    def snap():
        snaps.append({"wal": store.wal.clone(),
                      "manifest": store.manifest.clone(),
                      "fp": sharded_fingerprint(store),
                      "counters": exact_counters(store),
                      "log_pos": store.log_pos,
                      "carried_debt": store.scheduler.carried_debt})

    for _ in range(12):
        t = TREES[int(rng.integers(0, 2))]
        ks = rng.integers(0, KEY_SPACE, int(rng.integers(80, 260)))
        store.write_batch(t, ks, ks + 3, tick=False)
        # a paced pass: mandatory segments + a bounded merge slice, with
        # a crash point captured after EVERY segment
        for name in SEGMENTS:
            if name == "merge":
                store.scheduler.run_segment(name, merge_budget=2)
            else:
                store.scheduler.run_segment(name)
            snap()
    assert len(snaps) == 12 * len(SEGMENTS)
    for bi, s in enumerate(snaps):
        recovered = recover(cfg, s["wal"], s["manifest"])
        assert sharded_fingerprint(recovered) == s["fp"], f"boundary {bi}"
        assert exact_counters(recovered) == s["counters"], f"boundary {bi}"
        assert recovered.log_pos == s["log_pos"], f"boundary {bi}"
        assert recovered.scheduler.carried_debt == s["carried_debt"], \
            f"boundary {bi}"


def test_crash_mid_segment_redoes_the_segment():
    """Segments are logged write-ahead: a crash after a segment's record
    landed but before (or while) its phase ran redoes exactly that
    segment -- the segment-granular twin of the mid-tick redo case."""
    cfg = small_config()
    reset_sst_ids()
    store = ShardedStore(cfg, shards=2)
    store.create_tree("a")
    rng = np.random.default_rng(6)
    for _ in range(6):
        store.write_batch("a", rng.integers(0, KEY_SPACE, 300),
                          rng.integers(0, 2**31, 300), tick=False)
        store.scheduler.run_segment("upkeep")
    # hand-open the "mem" segment: log it write-ahead, then CRASH before
    # the flush phase runs (run_segment = append_tick + phase)
    sch = store.scheduler
    store.wal.append_tick("default", segment="mem")
    sch.segments += 1
    wal_c, man_c = store.wal.clone(), store.manifest.clone()
    # reference: the segment completes on the live store
    sch._enforce_memory()
    ref_fp = sharded_fingerprint(store)
    recovered = recover(cfg, wal_c, man_c)
    assert sharded_fingerprint(recovered) == ref_fp
    assert exact_counters(recovered) == exact_counters(store)
    assert recovered.scheduler.segments == sch.segments

    # same for a bounded merge segment: record down, phase not yet run
    store.wal.append_tick(2, segment="merge")
    sch.segments += 1
    wal_c, man_c = store.wal.clone(), store.manifest.clone()
    sch._run_merges(2)
    recovered = recover(cfg, wal_c, man_c)
    assert sharded_fingerprint(recovered) == sharded_fingerprint(store)
    assert exact_counters(recovered) == exact_counters(store)
    assert recovered.scheduler.carried_debt == sch.carried_debt


def test_crash_mid_maintenance_redoes_the_tick():
    """Crash after a tick's flush emitted its manifest edits but before
    WAL enforcement truncated: the tick is logged write-ahead, so
    recovery redoes the WHOLE tick and lands on the completed-tick state
    (manifest rebases to the checkpoint; the orphan edits are dropped)."""
    cfg = small_config()
    reset_sst_ids()
    store = ShardedStore(cfg, shards=2)
    store.create_tree("a")
    rng = np.random.default_rng(4)
    for _ in range(6):
        store.write_batch("a", rng.integers(0, KEY_SPACE, 300),
                          rng.integers(0, 2**31, 300))
    # hand-run one tick: log it write-ahead, run the flush phases, then
    # CRASH before the merge pass + WAL enforcement complete.
    sch = store.scheduler
    store.wal.append_tick("default")
    sch.ticks += 1
    for s in sch.stores:
        s.scheduler._mem_upkeep()
    sch._enforce_memory()
    sch._enforce_log()      # manifest edits emitted; truncation NOT run
    wal_c, man_c = store.wal.clone(), store.manifest.clone()
    # reference: the same tick completes on the live store
    sch._run_merges(sch.merge_budget)
    from repro.core.engine.scheduler import enforce_wal
    enforce_wal(store.arena, sch)
    ref_fp = sharded_fingerprint(store)
    # recovery from the mid-tick crash redoes the tick deterministically
    recovered = recover(cfg, wal_c, man_c)
    assert sharded_fingerprint(recovered) == ref_fp
    assert exact_counters(recovered) == exact_counters(store)


# --------------------------- WAL replay safety ---------------------------------
def test_recover_rejects_wrong_router_and_config():
    cfg = small_config()
    reset_sst_ids()
    store = ShardedStore(cfg, shards=4)
    store.create_tree("a")
    store.write_batch("a", np.arange(100), np.arange(100))
    wal_c, man_c = store.wal.clone(), store.manifest.clone()
    with pytest.raises((ValueError, RuntimeError)):
        recover(cfg, wal_c, man_c, router=ShardRouter(3))
    with pytest.raises(ValueError, match="manifest"):
        recover(small_config(entry_bytes=512), store.wal.clone(),
                store.manifest.clone())
    # the undamaged pair still recovers
    rec = recover(cfg, store.wal.clone(), store.manifest.clone())
    assert sharded_fingerprint(rec) == sharded_fingerprint(store)


def test_bare_lsmstore_recovers_as_one_shard_store():
    """A standalone LSMStore's private arena carries the same durability
    plane; its log recovers as the bit-identical one-shard store."""
    reset_sst_ids()
    cfg = small_config()
    store = LSMStore(cfg)
    store.create_tree("a", dataset="ds0")
    store.create_tree("b", entry_bytes=128)
    rng = np.random.default_rng(1)
    for _ in range(20):
        t = TREES[int(rng.integers(0, 2))]
        ks = rng.integers(0, KEY_SPACE, 150)
        store.write_batch(t, ks, ks + 7)
    store.delete_batch("a", rng.integers(0, KEY_SPACE, 60))
    recovered = recover(cfg, store.wal.clone(), store.manifest.clone())
    assert recovered.n_shards == 1
    assert fingerprint(store) == fingerprint(recovered.shards[0].store)
    assert exact_counters(store) == exact_counters(recovered)
    # schema round-tripped (datasets, per-tree entry bytes)
    s = recovered.shards[0].store
    assert s.tree_dataset == store.tree_dataset
    assert s.trees["b"].entry_bytes == 128


def test_control_records_at_watermark_survive_truncation():
    """Regression: zero-LSN-span control records (SetWriteMemory, Tick)
    logged at exactly the latest checkpoint's watermark are part of the
    replay tail -- truncation must never drop them, or recovery silently
    loses their effects (wrong write-memory size, missed ticks)."""
    reset_sst_ids()
    # monolithic component: memory enforcement flush is a FULL flush, so
    # the post-checkpoint tick empties write memory entirely (min_lsn ->
    # INF) with no new writes, landing trunc exactly on the watermark
    cfg = small_config(total_memory_bytes=64 * MB,
                       write_memory_bytes=4 * MB, max_log_bytes=64 * MB,
                       scheme="btree-dynamic", flush_policy="mem")
    store = ShardedStore(cfg, shards=1)
    store.create_tree("a")
    rng = np.random.default_rng(8)
    for _ in range(8):
        ks = rng.integers(0, 20_000, 1000)   # ~2MB buffered, no flushes
        store.write_batch("a", ks, ks + 1)
    assert store.write_memory_used() > 1 * MB
    store.checkpoint()                       # watermark == head
    store.set_write_memory(1 * MB)           # control record AT watermark
    store.scheduler.tick()                   # full flush -> min_lsn=INF
    store.scheduler.tick()                   # -> trunc == head == watermark
    assert store.min_lsn() >= 2**62
    assert store.wal.truncated_to == store.log_pos
    assert store.write_memory_bytes == 1 * MB
    recovered = recover(cfg, store.wal.clone(), store.manifest.clone())
    assert recovered.write_memory_bytes == 1 * MB
    assert sharded_fingerprint(recovered) == sharded_fingerprint(store)
    assert exact_counters(recovered) == exact_counters(store)
    assert recovered.scheduler.ticks == store.scheduler.ticks


def test_tree_created_after_checkpoint_recovers_via_tail():
    """A tree created after the last checkpoint exists only as a WAL
    TreeCreate record; replay must rebuild it with its schema args."""
    reset_sst_ids()
    cfg = small_config()
    store = ShardedStore(cfg, shards=2)
    store.create_tree("a")
    rng = np.random.default_rng(3)
    for _ in range(20):     # flushes advance min-LSN -> forced checkpoint
        ks = rng.integers(0, KEY_SPACE, 250)
        store.write_batch("a", ks, ks + 1)
    assert store.manifest.latest_checkpoint is not None
    store.create_tree("late", dataset="dsl", entry_bytes=128)
    store.write_batch("late", np.arange(50), np.arange(50) * 2)
    rec = recover(cfg, store.wal.clone(), store.manifest.clone())
    assert sharded_fingerprint(rec) == sharded_fingerprint(store)
    s = rec.shards[0].store
    assert s.trees["late"].entry_bytes == 128
    assert s.tree_dataset["late"] == "dsl"
    found, vals = rec.read_batch("late", np.arange(50))
    assert found.all() and (vals == np.arange(50) * 2).all()


def test_empty_store_recovers():
    reset_sst_ids()
    cfg = small_config()
    store = ShardedStore(cfg, shards=3)
    rec = recover(cfg, store.wal.clone(), store.manifest.clone())
    assert rec.n_shards == 3 and rec.log_pos == 0
    assert rec.recovery_info["replayed_records"] == 0


# --------------------------- service front door --------------------------------
def test_deferred_writes_provably_absent_from_log():
    """Admission control refuses a write BEFORE the WAL append, so a
    Deferred request's keys appear in no WriteBatch record and recovery
    cannot resurrect them -- while admitted keys of the same submit are
    durable."""
    reset_sst_ids()
    cfg = small_config()
    store = ShardedStore(cfg, router=ShardRouter.ranges(2, KEY_SPACE))
    svc = StorageService(store, config=ServiceConfig(admission=True))
    svc.create_tree("a")
    hot = store.shard_tree(0, "a")
    for _ in range(cfg.l0_max_groups):        # stall shard 0's tree
        ks = np.arange(0, 900)
        store.shards[0].store.write_batch("a", ks, ks + 1, tick=False)
        store.shards[0].store.scheduler.flush_tree(
            hot, trigger="mem", forced_kind="full")
    assert svc.stalled_trees() == ["a@0"]
    # spy on the WAL append boundary: every batch that reaches the log
    # passes through here, before any tick-time truncation
    appended: set = set()
    orig_append = store.wal.append_batch

    def spy(tree, keys, vals, **kw):
        if vals is not None:
            appended.update(zip(np.asarray(keys).tolist(),
                                np.asarray(vals).tolist()))
        return orig_append(tree, keys, vals, **kw)

    store.wal.append_batch = spy
    keys = np.array([10, 1500, 20, 1600])      # 2 hot (deferred), 2 cold
    res = svc.submit([Put("a", keys, keys + 5)])
    store.wal.append_batch = orig_append
    assert isinstance(res[0], Deferred) and res[0].reason == "l0-stall"
    deferred = set(res[0].request.keys.tolist())
    assert deferred == {10, 20}
    # the deferred (key, value) writes never reached the WAL append --
    # admission refused them first -- while the admitted cold-shard keys
    # did (the spy sees every append before any truncation can drop it)
    assert not ({(10, 15), (20, 25)} & appended)
    assert {(1500, 1505), (1600, 1605)} <= appended
    # and nothing retained in the log carries them either
    for rec in store.wal.records():
        if isinstance(rec, WriteBatchRecord):
            assert not ({(10, 15), (20, 25)}
                        & set(zip(rec.keys.tolist(), rec.vals.tolist())))
    # crash + StorageService.recover: the admitted cold-shard keys are
    # durable with their new values; the deferred hot-shard keys read
    # back their PRE-submit values (from the stall-setup flushes, carried
    # by the checkpointed manifest) -- the deferred write left no trace
    svc2 = StorageService.recover(cfg, store.wal.clone(),
                                  store.manifest.clone())
    found, vals = svc2.store.read_batch("a", keys)
    assert found.all()
    assert vals.tolist() == [11, 1505, 21, 1605]
    assert svc2.store.recovery_info["from_checkpoint"]


def test_service_workload_recovers_through_front_door():
    """End-to-end through submit(): mixed typed requests, crash, recover
    via the service front door, continue submitting."""
    from repro.core.service import Delete, Get
    reset_sst_ids()
    cfg = small_config()
    svc = StorageService(ShardedStore(cfg, shards=3),
                         config=ServiceConfig(admission=False))
    for t in TREES:
        svc.create_tree(t)
    rng = np.random.default_rng(2)
    oracle = {t: {} for t in TREES}
    for _ in range(12):
        t = TREES[int(rng.integers(0, 2))]
        ks = rng.integers(0, KEY_SPACE, 120)
        vs = rng.integers(0, 2**31, 120)
        dk = rng.integers(0, KEY_SPACE, 30)
        svc.submit([Put(t, ks, vs), Delete(t, dk)])
        oracle[t].update(zip(ks.tolist(), vs.tolist()))
        for k in dk.tolist():
            oracle[t][k] = None
    live_fp = sharded_fingerprint(svc.store)
    svc2 = StorageService.recover(cfg, svc.store.wal.clone(),
                                  svc.store.manifest.clone())
    assert sharded_fingerprint(svc2.store) == live_fp
    # recovered service serves reads and accepts writes
    for t, d in oracle.items():
        ks = np.fromiter(d.keys(), np.int64, len(d))
        res = svc2.submit([Get(t, ks)])[0]
        for i, k in enumerate(ks.tolist()):
            want = d[k]
            assert bool(res.found[i]) == (want is not None)
            if want is not None:
                assert int(res.vals[i]) == want
    svc2.submit([Put("a", np.array([42]), np.array([43]))])
    found, vals = svc2.store.read_batch("a", np.array([42]))
    assert found[0] and vals[0] == 43


# --------------------------- manifest consistency ------------------------------
def test_manifest_live_set_matches_tree_state():
    """The edit-maintained live set must equal the SSTables actually
    reachable from L0s and levels -- edits are the durable bookkeeping,
    never rebuilt by scanning."""
    cfg = small_config()
    batches = gen_batches(seed=23, n_batches=15)
    store, _, _, _ = run_workload(cfg, batches, shards=2)
    reachable = {s.sst_id
                 for sh in store.shards
                 for t in sh.store.trees.values()
                 for s in t.l0.all_tables()
                 + [x for lvl in t.levels.levels for x in lvl]}
    assert set(store.manifest.live) == reachable
    # version advanced with every edit; watermark recorded
    assert store.manifest.version >= len(store.manifest.edits)
