"""Correctness tests for the LSM engine: paper's worked examples for the
grouped L0 (§4.1.2), dynamic levels (§4.1.3), flush policies (§4.2), and
end-to-end store reconciliation against a dict oracle."""
import numpy as np
import pytest

from repro.core.lsm.grouped_l0 import GroupedL0
from repro.core.lsm.levels import DiskLevels
from repro.core.lsm.sstable import merge_runs, sstable_from_run
from repro.core.lsm.storage import LSMStore, StoreConfig

KB = 1 << 10
MB = 1 << 20


def sst(lo, hi, n=100, lsn=0):
    keys = np.linspace(lo, hi, n).astype(np.int64)
    keys = np.unique(keys)
    return sstable_from_run(keys, keys, lsn, lsn + n, entry_bytes=100,
                            page_bytes=4 * KB)


# --------------------------- merge_runs -----------------------------------
def test_merge_runs_newest_wins():
    newer = (np.array([1, 3, 5], np.int64), np.array([10, 30, 50], np.int64))
    older = (np.array([1, 2, 3], np.int64), np.array([-1, -2, -3], np.int64))
    keys, vals = merge_runs([newer, older])
    assert keys.tolist() == [1, 2, 3, 5]
    assert vals.tolist() == [10, -2, 30, 50]


# --------------------------- grouped L0 (§4.1.2) ---------------------------
def figure3_l0():
    l0 = GroupedL0()
    g0 = [sst(10, 30), sst(32, 55), sst(60, 80)]
    g1 = [sst(0, 23), sst(25, 50)]
    l0.groups = [sorted(g0, key=lambda s: s.min_key),
                 sorted(g1, key=lambda s: s.min_key)]
    return l0


def test_l0_insert_goes_to_oldest_group():
    # Paper: flushing 81-99 inserts into the (older) group 0.
    l0 = figure3_l0()
    l0.insert(sst(81, 99))
    assert l0.num_groups == 2
    assert any(s.min_key == 81 for s in l0.groups[0])


def test_l0_insert_creates_new_group_on_overlap():
    # Paper: flushing 25-53 creates a new group (25-50 overlaps in group 1).
    l0 = figure3_l0()
    l0.insert(sst(25, 53))
    assert l0.num_groups == 3
    assert any(s.min_key == 25 and s.max_key == 53 for s in l0.groups[2])


def test_l0_greedy_merge_selection_matches_paper():
    # Paper: group 1 selected (fewest SSTables); 0-23 chosen (ratio 1 < 4/3),
    # merged together with 10-30 from group 0 and L1 SSTables 0-15, 20-35.
    l0 = figure3_l0()
    l1 = [sst(0, 15), sst(20, 35), sst(37, 48), sst(50, 60)]
    tables, (a, b) = l0.pick_merge(l1, greedy=True)
    ranges = sorted((t.min_key, t.max_key) for t in tables)
    assert ranges == [(0, 23), (10, 30)]
    assert [t.min_key for t in l1[a:b]] == [0, 20]
    # The merge set is ordered newest group first for reconciliation.
    assert tables[0].min_key == 0  # from group 1 (newer)


def test_l0_nongreedy_takes_oldest_leftmost():
    l0 = figure3_l0()
    l1 = [sst(0, 15), sst(20, 35), sst(37, 48), sst(50, 60)]
    tables, _ = l0.pick_merge(l1, greedy=False)
    assert (10, 30) in [(t.min_key, t.max_key) for t in tables]


# --------------------------- dynamic levels (§4.1.3) ------------------------
def test_levels_add_l1_when_memory_shrinks():
    lv = DiskLevels(size_ratio=10)
    lv.levels = [[sst(0, 10_000, n=5000)]]     # one last level, 500KB
    # tiny write memory: |L1|max = 500KB > 10 * write_mem -> insert empty L1s
    lv.adjust(write_mem_bytes=4 * KB)
    assert lv.num_levels >= 2
    assert lv.levels[0] == []


def test_levels_delete_l1_waits_for_factor_f():
    lv = DiskLevels(size_ratio=10, shrink_factor=1.5)
    l2 = [sst(0, 10_000, n=5000)]              # last level: 500KB
    lv.levels = [[sst(0, 5000, n=400)], l2]    # L1: 40KB, |L1|max=50KB
    # write_mem*T slightly above |L2|max (=500KB) but below f*|L2|max
    lv.adjust(write_mem_bytes=51 * KB)
    assert not lv.deleting_l1
    # grows past f*|L2|max -> deletion scheduled
    lv.adjust(write_mem_bytes=80 * KB)
    assert lv.deleting_l1
    assert lv.l0_target_level() == 1           # Figure 4: L0 merges into L2
    # drain L1 and it disappears
    lv.levels[0] = []
    lv.adjust(write_mem_bytes=80 * KB)
    assert lv.num_levels == 1
    assert not lv.deleting_l1


# --------------------------- store end-to-end ------------------------------
def small_config(**kw):
    base = dict(total_memory_bytes=48 * MB, write_memory_bytes=8 * MB,
                sim_cache_bytes=2 * MB, page_bytes=4 * KB, entry_bytes=256,
                active_sstable_bytes=256 * KB, sstable_bytes=512 * KB,
                max_log_bytes=16 * MB, scheme="partitioned",
                flush_policy="opt")
    base.update(kw)
    return StoreConfig(**base)


@pytest.mark.parametrize("scheme", ["partitioned", "btree-dynamic",
                                    "btree-static", "accordion-index",
                                    "accordion-data"])
def test_store_reconciliation_oracle(scheme):
    rng = np.random.default_rng(42)
    store = LSMStore(small_config(scheme=scheme, write_memory_bytes=2 * MB,
                                  max_log_bytes=8 * MB))
    store.create_tree("t0")
    store.create_tree("t1")
    oracle = {"t0": {}, "t1": {}}
    for step in range(60):
        tree = "t0" if rng.random() < 0.7 else "t1"
        keys = rng.integers(0, 100_000, size=500)
        vals = rng.integers(0, 2**31, size=500)
        store.write(tree, keys, vals)
        for k, v in zip(keys.tolist(), vals.tolist()):
            oracle[tree][k] = v
    # every key readable with its newest value
    for tree, d in oracle.items():
        probe = rng.choice(list(d.keys()), size=300)
        for k in probe.tolist():
            found, val = store.lookup(tree, k)
            assert found, (tree, k)
            assert val == d[k], (tree, k)
    # absent keys stay absent
    for k in rng.integers(200_000, 300_000, size=100).tolist():
        found, _ = store.lookup("t0", k)
        assert not found
    # sane accounting
    st = store.disk.stats
    assert st.pages_flushed > 0
    assert st.entries_written == 60 * 500
    assert store.write_memory_used() <= store.write_memory_bytes * 1.05


def test_store_scan_counts():
    rng = np.random.default_rng(0)
    store = LSMStore(small_config())
    store.create_tree("t")
    keys = rng.permutation(np.arange(0, 50_000, dtype=np.int64))
    for i in range(0, len(keys), 1000):
        store.write("t", keys[i:i + 1000], keys[i:i + 1000])
    n = store.scan("t", 1000, 500)
    assert n >= 500  # all live keys in [1000, 1500) found (dense keyspace)


def test_log_truncation_bounds_log_length():
    store = LSMStore(small_config(max_log_bytes=4 * MB))
    store.create_tree("hot")
    store.create_tree("cold")
    store.write("cold", [1, 2, 3], [1, 2, 3])   # tiny, old LSN
    rng = np.random.default_rng(1)
    for _ in range(80):
        ks = rng.integers(0, 100_000, size=400)
        store.write("hot", ks, ks)
    assert store.log_length <= store.cfg.max_log_bytes
    assert store.disk.stats.flushes_log > 0


def test_flush_policy_selection():
    for policy, expect in [("mem", "big"), ("lsn", "old")]:
        store = LSMStore(small_config(flush_policy=policy,
                                      write_memory_bytes=8 * MB))
        big, old = store.create_tree("big"), store.create_tree("old")
        store.write("old", [0], [0])            # oldest LSN, tiny
        rng = np.random.default_rng(7)
        ks = rng.integers(0, 10**9, size=20_000)
        store.write("big", ks, ks, op=True)     # huge memory user
        t = store._pick_flush_tree()
        assert t.name == expect, policy


def test_scheduler_tick_flush_invariants():
    """After any scheduler tick: store min-LSN is monotone non-decreasing
    (log truncation can only advance), no key is lost across flush + L0
    merge, and write-memory usage respects the configured share."""
    rng = np.random.default_rng(12)
    store = LSMStore(small_config(write_memory_bytes=1 * MB,
                                  max_log_bytes=6 * MB))
    store.create_tree("x")
    store.create_tree("y")
    oracle = {"x": {}, "y": {}}
    INF = 2**62
    last_min_lsn = 0
    budget = store.cfg.mem_flush_threshold * store.write_memory_bytes
    for step in range(50):
        tree = "x" if rng.random() < 0.8 else "y"
        ks = rng.integers(0, 60_000, size=400)
        vs = rng.integers(0, 2**31, size=400)
        store.write_batch(tree, ks, vs, tick=False)
        oracle[tree].update(zip(ks.tolist(), vs.tolist()))
        rep = store.scheduler.tick()
        # min-LSN monotonicity: flushes only drain *old* entries
        m = store.min_lsn()
        assert m >= last_min_lsn, step
        # an empty store reports the INF sentinel; future entries log at
        # >= log_pos, so that's the effective floor
        last_min_lsn = store.log_pos if m >= INF else m
        # memory bound holds after every tick
        assert store.write_memory_used() <= budget * 1.05, step
        # default budget drains all merge debt every tick
        assert rep.carried_debt == 0, step
    assert store.disk.stats.pages_flushed > 0          # flushes happened
    assert store.disk.stats.pages_merge_written > 0    # L0 merges happened
    # no key loss across flush + L0 merge: every write still readable
    for tree, d in oracle.items():
        probe = np.fromiter(d.keys(), np.int64, len(d))
        found, vals = store.read_batch(tree, probe)
        assert found.all(), tree
        np.testing.assert_array_equal(
            vals, np.array([d[int(k)] for k in probe], np.int64))


def test_scheduler_bounded_merge_budget_carries_debt():
    """With a tiny per-tick merge budget, debt carries across ticks but
    mandatory memory/log enforcement still holds the memory bound."""
    rng = np.random.default_rng(3)
    store = LSMStore(small_config(write_memory_bytes=1 * MB,
                                  merge_budget=1))
    store.create_tree("t")
    saw_debt = False
    for _ in range(40):
        ks = rng.integers(0, 60_000, size=400)
        store.write_batch("t", ks, ks)
        saw_debt = saw_debt or store.scheduler.carried_debt > 0
        assert store.write_memory_used() \
            <= store.write_memory_bytes * 1.05
    assert saw_debt
    # engineer leftover debt: a big deferred batch, then one single-unit
    # tick -- flushes run (mandatory) but merge work stays owed
    store.write_batch("t", rng.integers(0, 60_000, size=4000),
                      np.zeros(4000, np.int64), tick=False)
    rep = store.scheduler.tick(merge_budget=1)
    assert rep.merge_steps == 1
    assert store.scheduler.carried_debt > 0
    # an explicit-None tick overrides the bounded default and drains it
    rep = store.scheduler.tick(merge_budget=None)
    assert rep.merge_steps > 0
    assert store.scheduler.carried_debt == 0


def test_no_inline_maintenance_outside_scheduler_tick():
    """With tick=False the write path must do no flush/merge work at all:
    the scheduler is the sole owner of maintenance."""
    store = LSMStore(small_config(write_memory_bytes=1 * MB))
    store.create_tree("t")
    rng = np.random.default_rng(0)
    for _ in range(30):
        ks = rng.integers(0, 60_000, size=400)
        store.write_batch("t", ks, ks, tick=False)
    st = store.disk.stats
    assert st.pages_flushed == 0 and st.pages_merge_written == 0
    assert store.write_memory_used() > store.write_memory_bytes  # over!
    store.scheduler.tick()
    assert store.write_memory_used() <= store.write_memory_bytes * 1.05
    assert store.disk.stats.pages_flushed > 0


def test_opt_policy_allocates_by_write_rate():
    """§4.2: under OPT, hot trees keep write memory share ~ write rate."""
    store = LSMStore(small_config(flush_policy="opt",
                                  write_memory_bytes=8 * MB))
    store.create_tree("hot")
    store.create_tree("cold")
    rng = np.random.default_rng(3)
    for i in range(300):
        tree = "hot" if i % 10 else "cold"      # 90/10 write split
        ks = rng.integers(0, 10**6, size=300)
        store.write(tree, ks, ks)
    hot = store.trees["hot"].mem_bytes
    cold = store.trees["cold"].mem_bytes
    assert hot > 2 * cold
