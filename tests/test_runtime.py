"""Runtime tests: checkpoint/restart determinism, crash safety, straggler
monitor, paged KV pool policies, HBM tuner direction."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model, init_params
from repro.runtime.checkpoint import Checkpointer
from repro.runtime.elastic import StragglerMonitor, run_elastic
from repro.runtime.hbm_tuner import HBMTuner, HBMTunerConfig
from repro.runtime.kvcache import KVPoolConfig, PagedKVPool
from repro.runtime.training import TrainConfig, make_train_step
from repro.runtime.training import opt_state_specs


def tiny_setup():
    cfg = reduced(get_config("minicpm-2b"))
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.key(0),
                         cfg.param_dtype)
    opt = init_params(opt_state_specs(model.param_specs(), cfg),
                      jax.random.key(1), cfg.optstate_dtype)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(model, tcfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4))
    return cfg, model, params, opt, step, data


def run_steps(step, params, opt, data, steps, start=0):
    loss = None
    for i in range(start, start + steps):
        params, opt, m = step(params, opt,
                              jax.tree.map(jnp.asarray, data.batch(i)))
        loss = float(m["loss"])
    return params, opt, loss


def test_train_checkpoint_restart_determinism(tmp_path):
    _, _, params, opt, step, data = tiny_setup()
    # straight run of 4 steps
    p4, o4, _ = run_steps(step, params, opt, data, 4)
    # run 2, checkpoint, "crash", restore, run 2 more
    p2, o2, _ = run_steps(step, params, opt, data, 2)
    ck = Checkpointer(tmp_path / "ckpt", keep=2, async_save=True)
    ck.save(2, {"params": p2, "opt": o2})
    ck.wait()
    like = {"params": p2, "opt": o2}
    restored, at = ck.restore(like)
    assert at == 2
    pr, orr, _ = run_steps(step, restored["params"], restored["opt"],
                           data, 2, start=2)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5,
        atol=1e-6), p4, pr)


def test_checkpoint_crash_safety_and_keep(tmp_path):
    ck = Checkpointer(tmp_path, keep=2, async_save=False)
    state = {"w": jnp.arange(8.0)}
    for s in (1, 2, 3):
        ck.save(s, state)
    assert ck.all_steps() == [2, 3]          # keep-N garbage collection
    # a torn checkpoint (no MANIFEST_DONE) must be ignored
    torn = Path(tmp_path) / "step_9"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert ck.latest_step() == 3
    restored, at = ck.restore(state)
    assert at == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))


def test_run_elastic_restarts_after_failure(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    calls = {"n": 0}

    def make_state():
        return {"w": jnp.zeros(4)}

    def train_loop(state, start):
        calls["n"] += 1
        for s in range(start, 6):
            state = {"w": state["w"] + 1}
            ck.save(s + 1, state)
            ck.wait()
            if calls["n"] == 1 and s == 2:
                raise RuntimeError("simulated node failure")
        return state

    out = run_elastic(make_state, train_loop, ck)
    assert calls["n"] == 2
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full(4, 6.0))


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, patience=3)
    assert not any(mon.observe(1.0) for _ in range(10))
    assert not mon.observe(5.0)
    assert not mon.observe(5.0)
    assert mon.observe(5.0)                  # third consecutive slow step


# ----------------------------- KV pool ----------------------------------------
def test_kv_pool_accounting_and_policies():
    pool = PagedKVPool(KVPoolConfig(page_tokens=4, total_pages=64,
                                    pool_pages=32, policy="opt"))
    for i in range(10):
        pool.append_tokens("hot", 16)        # 4 pages per call
        if i % 5 == 0:
            pool.append_tokens("cold", 4)
    assert pool.pool_pages_used <= pool.cfg.pool_pages
    hot, cold = pool.stream("hot"), pool.stream("cold")
    assert hot.allocated > cold.allocated
    # OPT keeps the hot stream's share near its allocation rate
    assert len(hot.pages) >= len(cold.pages)
    # finishing a stream frees its pages
    used = pool.pool_pages_used
    pool.finish_stream("hot")
    assert pool.pool_pages_used < used


def test_kv_pool_min_lsn_policy_evicts_oldest():
    pool = PagedKVPool(KVPoolConfig(page_tokens=1, total_pages=16,
                                    pool_pages=8, policy="lsn"))
    pool.append_tokens("old", 4)
    pool.append_tokens("new", 4)
    pool.append_tokens("new", 4)             # forces flushes
    assert pool.stream("old").offloaded >= 1
    assert pool.stream("new").offloaded == 0


def test_hbm_tuner_moves_toward_prefix_cache_under_reuse():
    """Prefix-heavy workload: ghost hits make the tuner shrink the pool."""
    pool = PagedKVPool(KVPoolConfig(page_tokens=4, total_pages=256,
                                    pool_pages=192, sim_pages=64))
    tuner = HBMTuner(pool, HBMTunerConfig(ops_cycle=64))
    rng = np.random.default_rng(0)
    x0 = pool.cfg.pool_pages
    for step in range(2000):
        # shared prompt chunks cycling through a working set > cache size
        pool.lookup_prefix(int(rng.integers(0, 96)))
        if step % 17 == 0:
            pool.append_tokens("s", 4)
        tuner.maybe_tune()
    assert pool.cfg.pool_pages < x0, \
        (pool.cfg.pool_pages, [r["x_next"] for r in tuner.records])
