"""``StoreConfig.validate`` error-message contract.

Every rejection must *render* the offending field name AND the offending
value -- a config error message you cannot act on is a bug. The cases
here assert on the actual rendered text, not just the exception type, so
a refactor that drops the value from the message fails loudly.
"""
import pytest

from repro.core.lsm.storage import StoreConfig

KB, MB = 1024, 1024 * 1024


def base(**kw):
    d = dict(total_memory_bytes=32 * MB, write_memory_bytes=1 * MB,
             sim_cache_bytes=1 * MB, page_bytes=4 * KB, entry_bytes=256,
             active_sstable_bytes=64 * KB, sstable_bytes=128 * KB)
    d.update(kw)
    return StoreConfig(**d)


# (overrides, fragments that must all appear in the rendered message)
CASES = [
    (dict(scheme="lsm2000"),
     ["scheme", "'lsm2000'", "partitioned"]),
    (dict(flush_policy="yolo"),
     ["flush_policy", "'yolo'"]),
    (dict(backend="quantum"),
     ["backend", "'quantum'", "registered backends"]),
    (dict(entry_bytes=0),
     ["entry_bytes", "got 0"]),
    (dict(entry_bytes=-8),
     ["entry_bytes", "got -8"]),
    (dict(device_pool_bytes=-1),
     ["device_pool_bytes", "got -1"]),
    (dict(merge_budget=-3),
     ["merge_budget", "got -3"]),
    (dict(max_log_bytes=0),
     ["max_log_bytes", "got 0"]),
    (dict(checkpoint_interval_bytes=0),
     ["checkpoint_interval_bytes", "got 0"]),
    (dict(pacer_interval_bytes=-2),
     ["pacer_interval_bytes", "got -2"]),
    (dict(pacer_segment_budget=0),
     ["pacer_segment_budget", "got 0"]),
    (dict(pacer_flush_threshold=0.0),
     ["pacer_flush_threshold", "(0, 1)", "got 0.0"]),
    (dict(pacer_flush_threshold=1.5),
     ["pacer_flush_threshold", "(0, 1)", "got 1.5"]),
    (dict(pacer_autotune=True),
     ["pacer_autotune", "pacer_interval_bytes"]),
    (dict(maintenance_workers=-1),
     ["maintenance_workers", "got -1"]),
    (dict(wal_async_fsync=True),
     ["wal_async_fsync", "fsync_policy", "'per_batch'"]),
    (dict(wal_async_fsync=True, fsync_policy="per_record"),
     ["wal_async_fsync", "fsync_policy", "'per_record'"]),
    # -- physical storage plane --------------------------------------------
    (dict(storage_medium="tape"),
     ["storage_medium", "'tape'", "memory", "files"]),
    (dict(storage_medium="files", storage_dir=None),
     ["storage_dir", "storage_medium='files'", "None"]),
    (dict(storage_medium="files", storage_dir=""),
     ["storage_dir", "''"]),
    (dict(fsync_policy="eventually"),
     ["fsync_policy", "'eventually'", "per_record", "per_batch", "group"]),
    (dict(wal_segment_bytes=0),
     ["wal_segment_bytes", "got 0"]),
    (dict(wal_segment_bytes=-4096),
     ["wal_segment_bytes", "got -4096"]),
    (dict(group_commit_bytes=0),
     ["group_commit_bytes", "got 0"]),
    (dict(group_commit_max_wait_s=0),
     ["group_commit_max_wait_s", "got 0"]),
    (dict(group_commit_max_wait_s=-0.5),
     ["group_commit_max_wait_s", "got -0.5"]),
    # ----------------------------------------------------------------------
    (dict(write_memory_bytes=20 * MB, sim_cache_bytes=20 * MB),
     ["write_memory_bytes", "sim_cache_bytes", "total_memory_bytes",
      str(20 * MB), str(32 * MB)]),
]


@pytest.mark.parametrize("overrides,fragments", CASES,
                         ids=[next(iter(c[0])) + "=" +
                              repr(c[0][next(iter(c[0]))])
                              for c in CASES])
def test_validate_message_names_field_and_value(overrides, fragments):
    with pytest.raises(ValueError) as ei:
        base(**overrides).validate()
    msg = str(ei.value)
    for frag in fragments:
        assert frag in msg, f"message {msg!r} missing {frag!r}"


def test_valid_configs_pass(tmp_path):
    assert base().validate() is not None
    # files medium with a directory is legal, as are all fsync policies
    for policy in ("per_record", "per_batch", "group"):
        base(storage_medium="files", storage_dir=str(tmp_path),
             fsync_policy=policy).validate()
    # None sentinels mean "feature off", not "invalid"
    base(checkpoint_interval_bytes=None, pacer_interval_bytes=None,
         merge_budget=None).validate()
    # overlapped-maintenance knobs in their legal combinations
    base(maintenance_workers=4, pacer_interval_bytes=64 * KB,
         pacer_segment_budget=2, pacer_flush_threshold=0.5,
         pacer_autotune=True).validate()
    base(storage_medium="files", storage_dir=str(tmp_path),
         fsync_policy="group", wal_async_fsync=True).validate()
