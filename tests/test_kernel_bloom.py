"""Bloom kernels vs oracle: no false negatives, bounded false positives,
kernel == ref across shapes/hash counts."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bloom.bloom import build_filter, probe_filter
from repro.kernels.bloom.ops import bloom_build, bloom_probe, slots_for
from repro.kernels.bloom.ref import build_ref, probe_ref


@pytest.mark.parametrize("n,k_hashes", [(256, 7), (512, 4), (1024, 7)])
def test_kernel_matches_ref(n, k_hashes):
    rng = np.random.default_rng(n + k_hashes)
    keys = rng.choice(2**30, size=n, replace=False).astype(np.int32)
    n_slots = slots_for(n)
    f_k = build_filter(jnp.asarray(keys), n_slots=n_slots,
                       k_hashes=k_hashes, interpret=True)
    f_r = build_ref(jnp.asarray(keys), n_slots, k_hashes)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
    probes = np.concatenate([keys[:128],
                             rng.choice(2**30, 128).astype(np.int32)])
    p_k = probe_filter(f_k, jnp.asarray(probes), k_hashes=k_hashes,
                       interpret=True)
    p_r = probe_ref(f_r, jnp.asarray(probes), k_hashes)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("use_kernel", [True, False])
def test_no_false_negatives_and_fp_rate(use_kernel):
    rng = np.random.default_rng(7)
    keys = rng.choice(2**30, size=2000, replace=False).astype(np.int32)
    filt = bloom_build(keys, use_kernel=use_kernel)
    assert bloom_probe(filt, keys, use_kernel=use_kernel).all(), \
        "bloom filters must never produce false negatives"
    absent = rng.choice(2**30, size=4000).astype(np.int32)
    absent = np.setdiff1d(absent, keys)
    fp = bloom_probe(filt, absent, use_kernel=use_kernel).mean()
    assert fp < 0.05, f"false-positive rate too high: {fp}"
