"""Service-layer tests: request planning, sessions + admission control,
MemoryGovernor equivalence, query_pin_many fast-path parity, and the
StoreConfig.validate error messages.
"""
import numpy as np
import pytest

from repro.core.lsm.cache import ClockCache, Disk, IOStats
from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import LSMStore, StoreConfig
from repro.core.service import (AdaptiveGovernor, Deferred, Delete, Get,
                                GetResult, Put, Scan, ScanResult,
                                ServiceConfig, StaticGovernor,
                                StorageService, WriteAck, build_plan)
from repro.core.tuner.tuner import AdaptiveMemoryController, TunerConfig

KB, MB = 1 << 10, 1 << 20


def small_config(**kw):
    cfg = dict(
        total_memory_bytes=32 * MB, write_memory_bytes=256 * KB,
        sim_cache_bytes=1 * MB, page_bytes=4 * KB, entry_bytes=256,
        active_sstable_bytes=32 * KB, sstable_bytes=64 * KB,
        max_log_bytes=8 * MB, scheme="partitioned", flush_policy="lsn")
    cfg.update(kw)
    return StoreConfig(**cfg)


def make_service(store_kw=None, **svc_kw) -> StorageService:
    reset_sst_ids()
    return StorageService(LSMStore(small_config(**(store_kw or {}))),
                          **svc_kw)


# ------------------------------ planner ---------------------------------------
def test_plan_groups_by_tree_kind_in_first_appearance_order():
    ks = np.arange(4)
    plan = build_plan([Put("a", ks), Get("b", ks), Put("b", ks),
                       Put("a", ks + 4), Scan("a", 0, 10), Get("b", ks)])
    assert [(s.tree, s.kind, len(s.requests)) for s in plan.steps] == [
        ("a", "put", 2), ("b", "get", 2), ("b", "put", 1), ("a", "scan", 1)]
    # concatenation preserves within-group submission order
    np.testing.assert_array_equal(plan.steps[0].concat_keys(),
                                  np.concatenate([ks, ks + 4]))
    assert plan.steps[0].indices == [0, 3]
    assert "put:a[2r/8k]" in plan.describe()


def test_build_plan_rejects_foreign_objects():
    with pytest.raises(TypeError):
        build_plan([Put("a", [1]), "not-a-request"])


def test_submit_returns_typed_results_in_submission_order():
    svc = make_service()
    svc.create_tree("a")
    svc.create_tree("b")
    ks = np.arange(100)
    res = svc.submit([Put("a", ks, ks + 7), Get("b", ks[:5]),
                      Put("b", ks, ks), Delete("a", ks[:50]),
                      Get("a", ks[:10]), Scan("b", 0, 100)])
    assert [type(r) for r in res] == [WriteAck, GetResult, WriteAck,
                                      WriteAck, GetResult, ScanResult]
    # group order: (a,put) ran before (a,delete)... but (a,get) first
    # appears after (a,delete), so the Get sees the tombstones
    assert not res[4].found.any()
    assert res[5].count == 100
    # Put on 'b' first appears (index 2) before Scan on 'b' (index 5)
    found, vals = svc.store.read_batch("a", ks[50:60], op=False)
    np.testing.assert_array_equal(vals, ks[50:60] + 7)


def test_empty_and_scalar_requests():
    svc = make_service()
    svc.create_tree("a")
    assert svc.submit([]) == []
    r = svc.put("a", 5, 50)
    assert isinstance(r, WriteAck) and r.n == 1
    g = svc.get("a", 5)
    assert bool(g.found[0]) and int(g.vals[0]) == 50


# ------------------------------ sessions / admission --------------------------
def test_session_quota_defers_writes_with_metering():
    svc = make_service()
    svc.create_tree("a")
    sess = svc.session("tenant", max_outstanding_keys=64)
    assert svc.session("tenant") is sess
    res = sess.submit([Put("a", np.arange(50)), Put("a", np.arange(50))])
    # planner fuses both Puts into one 100-key step: over the 64-key window
    assert all(isinstance(r, Deferred) and r.reason == "session-quota"
               for r in res)
    assert sess.stats.deferred_events == 1
    assert sess.stats.deferred_keys == 100
    # session quota is client-side backpressure, not an engine write stall
    assert svc.stats.write_stalls == 0
    ok = sess.submit([Put("a", np.arange(60))])
    assert isinstance(ok[0], WriteAck)
    assert sess.stats.executed_keys == 60
    # reads are never metered against the write window
    assert isinstance(sess.submit([Get("a", np.arange(1000))])[0], GetResult)


def test_l0_stall_backpressure_defers_then_drain_clears():
    # merge_budget=0: flushes pile L0 groups up and nothing ever merges
    # them; memory slack disabled so the L0 gate is what trips
    svc = make_service(store_kw=dict(merge_budget=0),
                       config=ServiceConfig(memory_admit_slack=None))
    svc.create_tree("a")
    ks = np.arange(1200)        # ~300KB: every submit forces a mem flush
    deferred = None
    for i in range(12):
        res = svc.submit([Put("a", ks, ks + i)])
        if isinstance(res[0], Deferred):
            deferred = res[0]
            break
    assert deferred is not None, "L0 groups never reached the stall gate"
    assert deferred.reason == "l0-stall"
    assert svc.stats.write_stalls >= 1
    assert svc.stalled_trees() == ["a"]
    ticks = svc.drain()
    assert ticks >= 1 and svc.stalled_trees() == []
    res = svc.submit([deferred.request])
    assert isinstance(res[0], WriteAck)
    # submit_all performs the drain+retry loop transparently
    res = svc.submit_all([Put("a", ks, ks + 99) for _ in range(6)])
    assert all(isinstance(r, WriteAck) for r in res)
    found, vals = svc.store.read_batch("a", ks[:10], op=False)
    assert found.all()


def test_engine_deferral_does_not_charge_session_window():
    """A write step the engine refuses (l0-stall) must not consume the
    session's admission window: later steps in the same submit that fit
    the quota still execute."""
    svc = make_service(store_kw=dict(merge_budget=0),
                       config=ServiceConfig(memory_admit_slack=None))
    svc.create_tree("a")
    svc.create_tree("b")
    ks = np.arange(1200)
    for i in range(12):                       # stall tree 'a' only
        if svc.stalled_trees():
            break
        svc.submit([Put("a", ks, ks + i)])
    assert svc.stalled_trees() == ["a"]
    sess = svc.session("t", max_outstanding_keys=1000)
    res = sess.submit([Put("a", np.arange(800)),      # refused by engine
                       Put("b", np.arange(300))])     # must still fit quota
    assert isinstance(res[0], Deferred) and res[0].reason == "l0-stall"
    assert isinstance(res[1], WriteAck)


def test_submit_all_terminates_on_unsatisfiable_quota():
    """A single request over the session window can never succeed: it must
    come back Deferred after a bounded number of submits, not spin through
    max_rounds of drain ticks."""
    svc = make_service()
    svc.create_tree("a")
    sess = svc.session("t", max_outstanding_keys=512)
    res = sess.submit_all([Put("a", np.arange(2048))])
    assert isinstance(res[0], Deferred)
    assert res[0].reason == "session-quota"
    assert sess.stats.submits <= 3            # initial + one futile retry
    assert svc.store.scheduler.ticks == 0     # no pointless drain ticks
    # quota deferrals crowded out by same-submit siblings DO succeed on
    # retry (one request per fresh window)
    res = sess.submit_all([Put("a", np.arange(400)), Put("a", np.arange(400))])
    assert all(isinstance(r, WriteAck) for r in res)


def test_submit_strict_raises_on_lost_writes_and_session_cap_updates():
    svc = make_service()
    svc.create_tree("a")
    # explicit cap on an existing session must take effect, not be ignored
    sess = svc.session("t")
    assert sess.max_outstanding_keys is None
    assert svc.session("t", max_outstanding_keys=64) is sess
    assert sess.max_outstanding_keys == 64
    with pytest.raises(RuntimeError, match="session-quota"):
        svc.submit_strict([Put("a", np.arange(100))], session=sess)
    svc.session("t", max_outstanding_keys=None)       # explicit None relaxes
    res = svc.submit_strict([Put("a", np.arange(100))], session=sess)
    assert isinstance(res[0], WriteAck)


def test_memory_pressure_defers_oversized_submit():
    svc = make_service(config=ServiceConfig(memory_admit_slack=1.0))
    svc.create_tree("a")
    # one submit bigger than the whole write memory (256KB / 256B = 1024)
    res = svc.submit([Put("a", np.arange(2000))])
    assert isinstance(res[0], Deferred)
    assert res[0].reason == "memory-pressure"
    assert svc.stats.write_stalls == 1
    # a fitting batch is admitted
    assert isinstance(svc.submit([Put("a", np.arange(500))])[0], WriteAck)


# ------------------------------ governor --------------------------------------
def _drive(submit, maybe_tune, n_batches=60):
    rng = np.random.default_rng(9)
    for i in range(n_batches):
        ks = rng.integers(0, 20_000, size=256)
        if i % 3 == 2:
            submit("get", ks)
        else:
            submit("put", ks)
        if maybe_tune is not None:
            maybe_tune()


def test_adaptive_governor_matches_hand_wired_controller():
    tcfg = dict(min_step_bytes=16 * KB, min_write_mem=64 * KB,
                ops_cycle=2_000)
    # hand-wired: direct store calls + controller per batch (the old API)
    reset_sst_ids()
    store = LSMStore(small_config())
    store.create_tree("t")
    ctrl = AdaptiveMemoryController(store, TunerConfig(**tcfg))

    def direct(kind, ks):
        if kind == "put":
            store.write_batch("t", ks, ks)
        else:
            store.read_batch("t", ks)
    _drive(direct, ctrl.maybe_tune)

    # service: same traffic, tuner as the default MemoryGovernor
    gov = AdaptiveGovernor(TunerConfig(**tcfg))
    svc = make_service(governor=gov)
    svc.create_tree("t")

    def via_service(kind, ks):
        svc.submit([Put("t", ks, ks) if kind == "put" else Get("t", ks)])
    _drive(via_service, None)

    recs_a = [(r.x, r.x_next, r.cost_prime, r.stopped)
              for r in ctrl.tuner.records]
    recs_b = [(r.x, r.x_next, r.cost_prime, r.stopped)
              for r in gov.records]
    assert recs_a == recs_b and len(recs_a) > 0
    assert store.write_memory_bytes == svc.store.write_memory_bytes
    assert vars(store.disk.stats) == vars(svc.store.disk.stats)


def test_static_governor_pins_allocation_once():
    svc = make_service(governor=StaticGovernor(
        write_memory_bytes=2 * MB, flush_policy="opt"))
    svc.create_tree("a")
    svc.submit([Put("a", np.arange(10))])
    assert svc.store.write_memory_bytes == 2 * MB
    assert svc.store.cfg.flush_policy == "opt"
    assert len(svc.plans) == 1
    svc.submit([Put("a", np.arange(10))])
    assert len(svc.plans) == 1          # pinned once, then silent


# ------------------------------ query_pin_many fast path ----------------------
def _fresh_disk(capacity):
    return Disk(4 * KB, ClockCache(capacity), None, IOStats())


@pytest.mark.parametrize("capacity", [0, 4, 64])
def test_query_pin_many_parity_with_scalar_loop(capacity):
    rng = np.random.default_rng(3)
    seqs = []
    for _ in range(40):
        n = int(rng.integers(1, 30))
        pages = rng.integers(0, 12, size=n)
        if rng.random() < 0.3:
            pages = np.full(n, -1)               # Bloom-style all-repeat
        if rng.random() < 0.3:
            pages = np.sort(pages)               # long duplicate runs
        seqs.append((int(rng.integers(0, 5)), pages))
    batched, scalar = _fresh_disk(capacity), _fresh_disk(capacity)
    for sst_id, pages in seqs:
        batched.query_pin_many(sst_id, pages)
        for p in pages:
            scalar.query_pin(sst_id, int(p))
    assert vars(batched.stats) == vars(scalar.stats)
    assert batched.cache.hits == scalar.cache.hits
    assert batched.cache.misses == scalar.cache.misses
    assert set(batched.cache._slot_of) == set(scalar.cache._slot_of)


def test_query_pin_many_collapses_duplicate_runs():
    d = _fresh_disk(64)
    d.query_pin_many(1, [-1] * 100)              # bloom batch: 1 real pin
    assert d.stats.query_pins == 100
    assert d.stats.pages_query_read == 1         # single miss
    assert d.cache.hits == 99


# ------------------------------ config validation -----------------------------
@pytest.mark.parametrize("kw,msg", [
    (dict(scheme="nope"), "unknown scheme"),
    (dict(flush_policy="nope"), "unknown flush_policy"),
    (dict(backend="nope"), "unknown backend"),
    (dict(entry_bytes=0), "entry_bytes"),
    (dict(entry_bytes=-1), "entry_bytes"),
    (dict(merge_budget=-1), "merge_budget"),
    (dict(pacer_interval_bytes=0), "pacer_interval_bytes"),
    (dict(pacer_interval_bytes=-4096), "pacer_interval_bytes"),
    (dict(pacer_segment_budget=0), "pacer_segment_budget"),
    (dict(pacer_segment_budget=-3), "pacer_segment_budget"),
    (dict(write_memory_bytes=40 * MB), "exceed"),
])
def test_store_config_validate_raises_value_error(kw, msg):
    with pytest.raises(ValueError, match=msg):
        small_config(**kw).validate()


def test_store_config_validate_accepts_zero_merge_budget():
    assert small_config(merge_budget=0).validate().merge_budget == 0


def test_store_config_validate_accepts_pacing_knobs():
    cfg = small_config(pacer_interval_bytes=32 * KB,
                       pacer_segment_budget=2).validate()
    assert cfg.pacer_interval_bytes == 32 * KB
    assert cfg.pacer_segment_budget == 2
    # pacing off (the default) is valid regardless of the budget knob
    assert small_config(pacer_interval_bytes=None).validate() \
        .pacer_interval_bytes is None
