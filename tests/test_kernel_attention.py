"""Flash attention kernel (interpret mode) vs pure-jnp oracle: shape/dtype
sweep incl. GQA, sliding window, softcap, and head-dim padding."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_ref


def rand_qkv(rng, b, s, h, kv, hd, dtype):
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), dtype)
    return q, k, v


def expand(x, rep):
    return jnp.repeat(x, rep, axis=2) if rep > 1 else x


@pytest.mark.parametrize("b,s,h,kv,hd,dtype", [
    (1, 128, 2, 2, 64, jnp.float32),
    (2, 256, 4, 2, 128, jnp.float32),
    (1, 256, 4, 1, 128, jnp.bfloat16),
    (1, 128, 2, 2, 80, jnp.float32),       # zamba2's hd=80 -> padded to 128
])
def test_flash_matches_ref(b, s, h, kv, hd, dtype):
    rng = np.random.default_rng(s + hd)
    q, k, v = rand_qkv(rng, b, s, h, kv, hd, dtype)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, expand(k, h // kv), expand(v, h // kv),
                        causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window,softcap", [(64, 0.0), (0, 30.0),
                                            (128, 50.0)])
def test_flash_window_and_softcap(window, softcap):
    rng = np.random.default_rng(window + int(softcap))
    q, k, v = rand_qkv(rng, 1, 256, 2, 2, 64, jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          softcap=softcap, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention_path():
    """Kernel == the model's XLA chunked-attention implementation."""
    from repro.configs import get_config, reduced
    from repro.models.attention import chunked_attention
    cfg = reduced(get_config("yi-6b")).with_(attn_chunk_q=64, attn_chunk_kv=64)
    rng = np.random.default_rng(0)
    q, k, v = rand_qkv(rng, 2, 128, 4, 4, 16, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    ref = chunked_attention(cfg, q, k, v, pos, pos)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
