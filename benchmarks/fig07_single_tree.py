"""Fig. 7: single LSM-tree, schemes x workloads x write-memory sizes.

Paper claims validated: Partitioned best on write-dominated workloads;
B+-static worst (1/8 of write memory); B+-dynamic ~ B+-static-tuned;
Accordion no better than B+-dynamic; throughput plateaus once flushes are
log-triggered.
"""
from __future__ import annotations

from .common import MB, Workload, bulk_load, fmt_row, make_store, measure

SCHEMES = ["btree-static", "btree-static-tuned", "btree-dynamic",
           "accordion-index", "accordion-data", "partitioned"]
WORKLOADS = {"write_only": (1.0, 0.0), "write_heavy": (0.5, 0.0),
             "read_heavy": (0.05, 0.0), "scan_heavy": (0.05, 0.95)}


def one(scheme, workload, write_mem_mb, n_records, read_ops=30_000):
    wf, sf = WORKLOADS[workload]
    kw = {}
    if scheme == "btree-static-tuned":
        kw = dict(scheme="btree-static", max_active_datasets=1)
    store = make_store(scheme=kw.get("scheme", scheme),
                       write_memory_bytes=write_mem_mb * MB,
                       max_active_datasets=kw.get("max_active_datasets", 8),
                       flush_policy="lsn")
    store.create_tree("t")
    bulk_load(store, "t", n_records)
    w = Workload(store, ["t"], n_records)
    if wf >= 0.5:   # write-dominated: push ~16x the write memory through
        n_ops = int(16 * write_mem_mb * MB / 256 / max(wf, 0.5))
    else:
        n_ops = read_ops
    return measure(store, lambda: w.run(n_ops, write_frac=wf, scan_frac=sf))


def run(full: bool = False, smoke: bool = False):
    if smoke:   # tiny-ops CI preset: one point per scheme, wiring only
        return [fmt_row(f"fig07/smoke/{scheme}",
                        one(scheme, "write_heavy", 1, 20_000,
                            read_ops=2_000)["throughput"])
                for scheme in SCHEMES]
    rows = []
    n_recs = 300_000 if full else 150_000
    mems = [1, 2, 4, 8] if full else [2, 8]
    wls = list(WORKLOADS) if full else ["write_only", "write_heavy",
                                        "read_heavy"]
    for wl in wls:
        for mem in mems:
            for scheme in SCHEMES:
                m = one(scheme, wl, mem, n_recs,
                        read_ops=30_000 if full else 12_000)
                rows.append(fmt_row(
                    f"fig07/{wl}/mem{mem}MB/{scheme}", m["throughput"],
                    f"io_per_op={m['io_pages_per_op']:.3f};"
                    f"wamp={m['write_amp']:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
