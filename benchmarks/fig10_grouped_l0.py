"""Fig. 10: L0 structures — Original (recency list) vs Grouped vs
Greedy-Grouped. Paper claim: Original < Grouped < Greedy-Grouped on write
throughput (write amplification decreases as the structure exploits
disjointness and greedy victim selection)."""
from __future__ import annotations

from .common import MB, Workload, bulk_load, fmt_row, make_store, measure

VARIANTS = {"original": dict(l0_grouped=False, l0_greedy=False),
            "grouped": dict(l0_grouped=True, l0_greedy=False),
            "greedy_grouped": dict(l0_grouped=True, l0_greedy=True)}


def one(variant, n_records=150_000, write_mem_mb=2):
    store = make_store(scheme="partitioned", flush_policy="lsn",
                       write_memory_bytes=write_mem_mb * MB,
                       l0_target_groups=4, l0_max_groups=4,
                       **VARIANTS[variant])
    store.create_tree("t")
    bulk_load(store, "t", n_records)
    w = Workload(store, ["t"], n_records)
    return measure(store, lambda: w.run(140_000, write_frac=1.0))


def run(full: bool = False):
    rows = []
    for variant in VARIANTS:
        m = one(variant, 300_000 if full else 150_000)
        rows.append(fmt_row(f"fig10/{variant}", m["throughput"],
                            f"wamp={m['write_amp']:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
