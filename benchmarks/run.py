"""Run reduced versions of every paper-figure benchmark.

Prints ``name,value,derived`` CSV (one line per measured point). All
drivers go through the ``StorageService`` front door (typed request plans,
sessions, governor-owned tuning). Full-size figures: run each module
directly, e.g. ``python -m benchmarks.fig07_single_tree``. ``--smoke``
runs a tiny-ops subset (single-tree schemes, TPC-C transaction plans,
governor-driven tuner, LSM hot-key skew, the shuffled mixed-op
``service_mixed`` scenario and the sharded hot-shard scenario) as a CI
wiring check for the service layer, the sharded data plane, the batched
write path and the maintenance scheduler.

``--json`` additionally writes ``BENCH_<module>.json`` next to the cwd:
one structured record per measured row ({name, value, scheme?, shards?,
throughput?, stalls?, derived{...}}), so the performance trajectory of the
repo is recorded run-over-run (CI uploads these as artifacts). Every
record carries run metadata -- ``seed`` (``--seed N``, default 0, offsets
every driver's rng coherently), ``git_sha``, ``backend`` (the resolved
``REPRO_LSM_BACKEND``) and ``medium`` (the storage medium the row ran
on) -- so rows from different machines/checkouts stay attributable.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time


def run_metadata(seed: int) -> dict:
    """Provenance stamped onto every JSON row."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        sha = "unknown"
    return {"seed": seed, "git_sha": sha,
            "backend": os.environ.get("REPRO_LSM_BACKEND", "numpy")}


def parse_row(row: str) -> dict:
    """``name,value,derived`` -> a structured record. ``derived`` is a
    ``k=v;k=v`` string; numeric values are coerced, and the well-known
    keys (scheme, shards, stalls) are lifted to the top level."""
    name, value, derived = row.split(",", 2)
    rec: dict = {"name": name, "value": float(value)}
    fields: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            fields[k] = int(v)
        except ValueError:
            try:
                fields[k] = float(v)
            except ValueError:
                fields[k] = v
    rec["derived"] = fields
    for k in ("scheme", "shards", "stalls"):
        if k in fields:
            rec[k] = fields[k]
    if "throughput" not in fields and name.startswith("kv_serving/"):
        rec["throughput"] = rec["value"]
    return rec


def main() -> None:
    from . import (fig07_single_tree, fig08_memory_merge_overhead,
                   fig09_flush_heuristics, fig10_grouped_l0,
                   fig11_dynamic_levels, fig12_multi_primary,
                   fig13_secondary, fig14_tpcc, fig15_tuner_ycsb,
                   fig16_tuner_accuracy, fig17_tuner_responsiveness,
                   kv_serving, recovery)
    full = "--full" in sys.argv
    smoke = "--smoke" in sys.argv
    json_out = "--json" in sys.argv
    seed = 0
    if "--seed" in sys.argv:
        seed = int(sys.argv[sys.argv.index("--seed") + 1])
        from .common import set_run_seed
        set_run_seed(seed)
    meta = run_metadata(seed)
    if smoke:
        modules = [fig07_single_tree, fig14_tpcc, fig15_tuner_ycsb,
                   kv_serving, recovery]
    else:
        modules = [fig07_single_tree, fig08_memory_merge_overhead,
                   fig09_flush_heuristics, fig10_grouped_l0,
                   fig11_dynamic_levels, fig12_multi_primary, fig13_secondary,
                   fig14_tpcc, fig15_tuner_ycsb, fig16_tuner_accuracy,
                   fig17_tuner_responsiveness, kv_serving, recovery]
    print("name,value,derived")
    for mod in modules:
        t0 = time.time()
        rows = list(mod.run(full=False, smoke=True) if smoke
                    else mod.run(full=full))
        for row in rows:
            print(row)
        elapsed = time.time() - t0
        print(f"# {mod.__name__}: {elapsed:.1f}s", file=sys.stderr)
        if json_out:
            short = mod.__name__.rsplit(".", 1)[-1]
            records = [parse_row(r) for r in rows]
            for rec in records:
                rec["preset"] = ("smoke" if smoke
                                 else "full" if full else "default")
                rec.update(meta)
                # rows name their medium when they ran on files; the
                # default engine configuration is the in-memory medium
                rec["medium"] = rec["derived"].get("medium", "memory")
            path = f"BENCH_{short}.json"
            with open(path, "w") as f:
                json.dump(records, f, indent=1)
            print(f"# wrote {path} ({len(records)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
