"""Run reduced versions of every paper-figure benchmark.

Prints ``name,value,derived`` CSV (one line per measured point). All
drivers go through the ``StorageService`` front door (typed request plans,
sessions, governor-owned tuning). Full-size figures: run each module
directly, e.g. ``python -m benchmarks.fig07_single_tree``. ``--smoke``
runs a tiny-ops subset (single-tree schemes, TPC-C transaction plans,
governor-driven tuner, LSM hot-key skew + the shuffled mixed-op
``service_mixed`` scenario) as a CI wiring check for the service layer,
the batched write path and the maintenance scheduler.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (fig07_single_tree, fig08_memory_merge_overhead,
                   fig09_flush_heuristics, fig10_grouped_l0,
                   fig11_dynamic_levels, fig12_multi_primary,
                   fig13_secondary, fig14_tpcc, fig15_tuner_ycsb,
                   fig16_tuner_accuracy, fig17_tuner_responsiveness,
                   kv_serving)
    full = "--full" in sys.argv
    smoke = "--smoke" in sys.argv
    if smoke:
        modules = [fig07_single_tree, fig14_tpcc, fig15_tuner_ycsb,
                   kv_serving]
    else:
        modules = [fig07_single_tree, fig08_memory_merge_overhead,
                   fig09_flush_heuristics, fig10_grouped_l0,
                   fig11_dynamic_levels, fig12_multi_primary, fig13_secondary,
                   fig14_tpcc, fig15_tuner_ycsb, fig16_tuner_accuracy,
                   fig17_tuner_responsiveness, kv_serving]
    print("name,value,derived")
    for mod in modules:
        t0 = time.time()
        for row in (mod.run(full=False, smoke=True) if smoke
                    else mod.run(full=full)):
            print(row)
        print(f"# {mod.__name__}: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
