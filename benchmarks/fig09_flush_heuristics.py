"""Fig. 9: flush strategies for the partitioned memory component.

Paper claims: Round-Robin wins at small write memory (memory-triggered),
Oldest wins mid-range, Full wins at large memory (log-triggered), and the
Adaptive heuristic (§4.1.4, beta=0.5) tracks the best of the three.
"""
from __future__ import annotations

from .common import MB, Workload, bulk_load, fmt_row, make_store, measure

STRATS = {"round_robin": "partial_rr", "oldest": "partial_oldest",
          "full": "full", "adaptive": None}


def one(strategy, write_mem_mb, n_records=150_000):
    store = make_store(scheme="partitioned", flush_policy="lsn",
                       write_memory_bytes=write_mem_mb * MB,
                       max_log_bytes=8 * MB,
                       forced_flush_kind=STRATS[strategy])
    store.create_tree("t")
    bulk_load(store, "t", n_records)
    w = Workload(store, ["t"], n_records)
    n_ops = int(16 * write_mem_mb * MB / 256)
    return measure(store, lambda: w.run(max(n_ops, 60_000), write_frac=1.0))


def run(full: bool = False):
    mems = [1, 2, 4, 8] if full else [1, 4]
    rows = []
    for mem in mems:
        for strat in STRATS:
            m = one(strat, mem)
            rows.append(fmt_row(
                f"fig09/mem{mem}MB/{strat}", m["throughput"],
                f"wamp={m['write_amp']:.2f};logf={m['flushes_log']}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
