"""Shared benchmark harness for the paper-figure reproductions.

Everything is scaled down from the paper's testbed by ~64x (records,
memory, log) with the paper's *ratios* preserved: 16KB pages -> 4KB, 1KB
records -> 256B, T=10, active SSTable 32MB -> 512KB, bloom 10 bits/key,
clock buffer cache, 95% thresholds. Throughput is the simulated-time proxy
of repro.core.lsm.storage.TimeModel (NVMe bandwidths + CPU constants
calibrated to the paper's relative overheads).
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import get_backend
from repro.core.lsm.sstable import partition_run, reset_sst_ids
from repro.core.lsm.storage import LSMStore, StoreConfig
from repro.core.service import Get, Put, Scan, StorageService
from repro.core.shard import ShardedStore, ShardRouter

KB, MB = 1 << 10, 1 << 20

# Run-level seed offset (``run.py --seed N``): every driver combines it
# with its own fixed per-scenario seed, so seed 0 (the default) keeps
# historical rows reproducible while any other value re-rolls the whole
# suite coherently.
_RUN_SEED = 0


def set_run_seed(n: int) -> None:
    global _RUN_SEED
    _RUN_SEED = int(n)


def run_seed() -> int:
    return _RUN_SEED


BASE = dict(
    total_memory_bytes=64 * MB,
    write_memory_bytes=4 * MB,
    sim_cache_bytes=1 * MB,
    page_bytes=4 * KB,
    entry_bytes=256,
    size_ratio=10,
    active_sstable_bytes=256 * KB,
    sstable_bytes=512 * KB,
    max_log_bytes=16 * MB,
)


def make_store(**kw) -> LSMStore:
    reset_sst_ids()
    cfg = dict(BASE)
    cfg.update(kw)
    return LSMStore(StoreConfig(**cfg))


def make_service(*, governor=None, service_config=None, **kw) -> StorageService:
    """A StorageService front door over a scaled-down store (the way new
    drivers talk to the engine; ``make_store`` remains for internals)."""
    return StorageService(make_store(**kw), governor=governor,
                          config=service_config)


def make_sharded_service(*, shards: int | None = None,
                         router: ShardRouter | None = None, governor=None,
                         service_config=None, **kw) -> StorageService:
    """A StorageService over a ``ShardedStore``: N shards behind one
    shared memory arena (scaled-down config)."""
    reset_sst_ids()
    cfg = dict(BASE)
    cfg.update(kw)
    store = ShardedStore(StoreConfig(**cfg), shards=shards, router=router)
    return StorageService(store, governor=governor, config=service_config)


def _install_last_level(store, tree_name: str, keys) -> None:
    t = store.trees[tree_name]
    ssts = partition_run(keys, keys, 0, 0, t.entry_bytes,
                         store.cfg.page_bytes, store.cfg.sstable_bytes)
    t.levels.levels = [ssts]
    t.levels.adjust(store.cfg.active_sstable_bytes)


def bulk_load(store, tree_name: str, n_records: int,
              key_stride: int = 1) -> None:
    """Install n_records directly into the tree's last level (no I/O).
    Over a ``ShardedStore``, keys are routed and installed per shard."""
    keys = np.arange(0, n_records * key_stride, key_stride, dtype=np.int64)
    if isinstance(store, ShardedStore):
        for si, sel in store.router.split(keys):
            _install_last_level(store.shards[si].store, tree_name, keys[sel])
        return
    _install_last_level(store, tree_name, keys)


class Workload:
    """YCSB-like driver: batched mixed ops against one or more trees.

    Drives everything through the ``StorageService`` front door (typed
    requests + submit): pass either a service or a bare ``LSMStore`` (which
    gets wrapped). Deferred (backpressured) writes are drained and retried
    by ``submit_strict``, so stalls show up in ``IOStats.write_stalls``;
    a request that stays deferred after retries raises rather than being
    silently dropped from the measured op count."""

    def __init__(self, store, trees, key_max, *, zipf_a=0.99,
                 tree_probs=None, seed=0, scan_len=100):
        self.service = (store if isinstance(store, StorageService)
                        else StorageService(store))
        self.store = self.service.store
        self.trees = list(trees)
        self.key_max = key_max
        self.scan_len = scan_len
        self.rng = np.random.default_rng(seed + _RUN_SEED)
        self.tree_probs = tree_probs

    def _keys(self, n):
        # bounded zipf(a~1) over the whole keyspace: rank = N^u, then a
        # multiplicative hash scatters ranks across the key range.
        u = self.rng.random(n)
        rank = np.floor(self.key_max ** u).astype(np.int64)
        return (rank * 2654435761) % self.key_max

    def _tree(self):
        if self.tree_probs is None:
            return self.trees[0]
        return self.trees[self.rng.choice(len(self.trees),
                                          p=self.tree_probs)]

    def run(self, n_ops, *, write_frac=1.0, scan_frac=0.0, batch=256,
            on_batch=None):
        done = 0
        while done < n_ops:
            b = min(batch, n_ops - done)
            tree = self._tree()
            r = self.rng.random()
            if r < write_frac:
                # one typed Put request -> one ingest_run backend call plus
                # one maintenance-scheduler tick per submit
                keys = self._keys(b)
                self.service.submit_strict([Put(tree, keys, keys)])
            elif r < write_frac + scan_frac:
                self.service.submit_strict(
                    [Scan(tree, int(lo), self.scan_len)
                     for lo in self._keys(max(1, b // 16))])
            else:
                # one typed Get request -> one lookup_batch per submit
                # (Bloom probes issued as one backend call per SSTable)
                self.service.submit_strict([Get(tree, self._keys(b))])
            done += b
            if on_batch is not None:
                on_batch(self.store)


def measure(store, fn) -> dict:
    """Run fn() and report deltas: throughput proxy + I/O per op.
    Accepts a bare ``LSMStore`` or a ``StorageService``. ``write_stalls``
    (backpressure deferrals) is surfaced as the ``stalls`` row field.

    Backend jit-shape-cache deltas (compiles vs cache hits over the
    measured window -- recompile churn from new pow2 buckets, e.g. the
    fused read path's tier stacks) land on the ``IOStats`` delta and the
    row; when the store runs a device page pool, the window's fused-tier
    hit rate rides along as ``device_pool_hit_rate``.

    When measuring a ``StorageService``, the window's request-latency and
    maintenance-stall tails (from the service's streaming histograms)
    land on the delta and the row as ``p50_us`` / ``p99_us`` /
    ``p999_us`` / ``max_stall_us`` -- the tail-latency SLO columns."""
    service = store if isinstance(store, StorageService) else None
    store = getattr(store, "store", store)     # unwrap a StorageService
    backend = getattr(store, "backend", None) \
        or get_backend(store.cfg.backend)
    pool = getattr(store, "device_pool", None)
    store.sync_mem_stats()
    before = store.disk.stats.copy()
    js0 = backend.jit_stats()
    ps0 = pool.stats() if pool is not None else None
    lat0 = service.latency.copy() if service is not None else None
    stall0 = service.stall.copy() if service is not None else None
    fn()
    store.sync_mem_stats()
    d = store.disk.stats.delta(before)
    js1 = backend.jit_stats()
    d.jit_compiles = js1["jit_compiles"] - js0["jit_compiles"]
    d.jit_cache_hits = js1["jit_cache_hits"] - js0["jit_cache_hits"]
    if service is not None:
        dl = service.latency.delta(lat0)
        ds = service.stall.delta(stall0)
        d.lat_p50_us = dl.p50
        d.lat_p99_us = dl.p99
        d.lat_p999_us = dl.p999
        d.max_stall_us = ds.max_value
    io, cpu = store.cfg.time_model.elapsed(d, scheme=store.cfg.scheme)
    ops = max(d.ops, 1)
    out = {
        "ops": d.ops,
        "throughput": ops / max(io, cpu, 1e-9),
        "io_pages_per_op": (d.pages_written + d.pages_read) / ops,
        "write_pages_per_op": d.pages_written / ops,
        "read_pages_per_op": d.pages_read / ops,
        "write_amp": (d.pages_written * store.cfg.page_bytes
                      / max(d.entries_written * store.cfg.entry_bytes, 1)),
        "stalls": d.write_stalls,
        "flushes_log": d.flushes_log,
        "flushes_mem": d.flushes_mem,
        "jit_compiles": d.jit_compiles,
        "jit_cache_hits": d.jit_cache_hits,
        # One-launch read path: device launches over the window and the
        # average number of lookup tiers each launch covered (per-tier
        # fused -> ~1.0; cross-tier fused -> the whole store per launch).
        "fused_launches": d.fused_launches,
        "fused_tiers_per_launch": d.fused_tiers / max(1, d.fused_launches),
        # Overlapped maintenance & durability: prepares consumed from the
        # worker pool (and the off-thread compute time they covered),
        # foreground time blocked on the async durability worker, and
        # proactive pacer flush slices over the window.
        "bg_segments": d.bg_segments,
        "bg_overlap_us": d.bg_overlap_us,
        "fsync_wait_us": d.fsync_wait_us,
        "flush_slices": d.flush_slices,
    }
    if service is not None:
        out["p50_us"] = d.lat_p50_us
        out["p99_us"] = d.lat_p99_us
        out["p999_us"] = d.lat_p999_us
        out["max_stall_us"] = d.max_stall_us
    if ps0 is not None:
        ps1 = pool.stats()
        dh = (ps1["tier_hits"] - ps0["tier_hits"]
              + ps1.get("store_hits", 0) - ps0.get("store_hits", 0))
        dm = (ps1["tier_misses"] - ps0["tier_misses"]
              + ps1.get("store_misses", 0) - ps0.get("store_misses", 0))
        out["device_pool_hit_rate"] = dh / max(1, dh + dm)
        out["device_pool_resident_pages"] = ps1["resident_pages"]
    return out


def fmt_row(name: str, value: float, derived: str = "") -> str:
    return f"{name},{value:.6g},{derived}"
