"""Fig. 13: one dataset with a primary tree + 10 secondary-index trees.

Every write updates the primary and k secondary indexes (hotspot-
distributed choice of updated fields) and performs a primary lookup for
index cleanup (as in the paper). Claims: same ordering as fig12; skew
matters less (secondaries are small); more updated fields ~ proportional
slowdown for all schemes.
"""
from __future__ import annotations

import numpy as np

from .common import MB, Workload, bulk_load, fmt_row, make_store, measure

N_SEC = 10


def one(scheme, policy, write_mem_mb=2, skew=(0.8, 0.2), fields_per_write=1,
        n_records=60_000, n_ops=60_000, seed=0):
    real = "btree-static" if scheme == "btree-static-tuned" else scheme
    store = make_store(scheme=real, flush_policy=policy,
                       write_memory_bytes=write_mem_mb * MB,
                       max_log_bytes=8 * MB)
    store.create_tree("primary", dataset="ds", entry_bytes=512)
    for i in range(N_SEC):
        store.create_tree(f"sec{i}", dataset="ds", entry_bytes=64)
    bulk_load(store, "primary", n_records)
    for i in range(N_SEC):
        bulk_load(store, f"sec{i}", n_records // 4)
    rng = np.random.default_rng(seed)
    hot = max(1, int(N_SEC * skew[1]))
    fp = np.full(N_SEC, (1 - skew[0]) / (N_SEC - hot))
    fp[:hot] = skew[0] / hot
    w = Workload(store, ["primary"], n_records)

    def body():
        done = 0
        while done < n_ops:
            b = 128
            keys = w._keys(b)
            # index cleanup: primary lookups per write, one batched probe
            store.read_batch("primary", keys[:16], op=False)
            store.write("primary", keys, keys, op=False)
            for f in rng.choice(N_SEC, fields_per_write, replace=False,
                                p=fp):
                store.write(f"sec{f}", keys, keys, op=False)
            store.note_ops(b)
            done += b

    return measure(store, body)


def run(full: bool = False):
    rows = []
    schemes = [("btree-static-tuned", "lsn"), ("btree-dynamic", "mem"),
               ("btree-dynamic", "opt"), ("partitioned", "mem"),
               ("partitioned", "opt")]
    mems = [1, 2, 4] if full else [2]
    for mem in mems:
        for s, p in schemes:
            m = one(s, p, write_mem_mb=mem)
            rows.append(fmt_row(f"fig13a/mem{mem}MB/{s}-{p}",
                                m["throughput"],
                                f"wamp={m['write_amp']:.2f}"))
    if full:
        for skew in [(0.5, 0.5), (0.95, 0.1)]:
            for s, p in schemes:
                m = one(s, p, skew=skew)
                rows.append(fmt_row(
                    f"fig13b/skew{int(skew[0]*100)}/{s}-{p}",
                    m["throughput"], ""))
    for k in ([1, 3, 5] if full else [1, 3]):
        m = one("partitioned", "opt", fields_per_write=k)
        rows.append(fmt_row(f"fig13c/fields{k}/part-OPT", m["throughput"],
                            ""))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
