"""Fig. 15: memory-tuner mechanics on YCSB (single tree, mixed R/W).

Paper claims: (1) higher write ratio => the tuner allocates more write
memory; (2) larger total memory => more write memory (cache gains
plateau); (3) total I/O cost falls over the tuning trajectory.
"""
from __future__ import annotations

from repro.core.tuner.tuner import AdaptiveMemoryController, TunerConfig

from .common import MB, Workload, bulk_load, fmt_row, make_store, measure


def one(write_ratio, total_mb, n_ops=400_000, n_records=150_000,
        ops_cycle=25_000):
    store = make_store(total_memory_bytes=total_mb * MB,
                       write_memory_bytes=2 * MB, max_log_bytes=6 * MB,
                       sim_cache_bytes=1 * MB, flush_policy="lsn")
    store.create_tree("t")
    bulk_load(store, "t", n_records)
    ctrl = AdaptiveMemoryController(store, TunerConfig(
        min_step_bytes=256 * 1024, ops_cycle=ops_cycle, min_write_mem=1 * MB))
    w = Workload(store, ["t"], n_records)
    m = measure(store, lambda: w.run(
        n_ops, write_frac=write_ratio,
        on_batch=lambda s: ctrl.maybe_tune()))
    recs = ctrl.tuner.records
    m["x_mb"] = store.write_memory_bytes / MB
    m["cost_first"] = recs[0].cost_per_op if recs else 0
    m["cost_last"] = recs[-1].cost_per_op if recs else 0
    m["tuning_steps"] = len(recs)
    return m


def run(full: bool = False, smoke: bool = False):
    rows = []
    ratios = [0.1, 0.25, 0.5] if full else ([0.5] if smoke else [0.1, 0.5])
    totals = [32, 96] if full else ([32] if smoke else [32, 96])
    n = 400_000 if full else (12_000 if smoke else 120_000)
    for total in totals:
        for r in ratios:
            # smoke: shrink the tuning cycle so the tuner actually ticks
            m = one(r, total, n_ops=n,
                    ops_cycle=3_000 if smoke else 25_000)
            rows.append(fmt_row(
                f"fig15/total{total}MB/write{int(r*100)}", m["x_mb"],
                f"steps={m['tuning_steps']};cost0={m['cost_first']:.3f};"
                f"cost={m['cost_last']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
