"""Fig. 15: memory-tuner mechanics on YCSB (single tree, mixed R/W).

Paper claims: (1) higher write ratio => the tuner allocates more write
memory; (2) larger total memory => more write memory (cache gains
plateau); (3) total I/O cost falls over the tuning trajectory.

The tuner runs as the service's default ``MemoryGovernor``
(``AdaptiveGovernor`` wrapping ``AdaptiveMemoryController`` unchanged):
the service observes it once per submit, replacing the old hand-wired
``on_batch=ctrl.maybe_tune()`` callback.
"""
from __future__ import annotations

from repro.core.service import AdaptiveGovernor
from repro.core.tuner.tuner import TunerConfig

from .common import MB, Workload, bulk_load, fmt_row, make_service, measure


def one(write_ratio, total_mb, n_ops=400_000, n_records=150_000,
        ops_cycle=25_000):
    governor = AdaptiveGovernor(TunerConfig(
        min_step_bytes=256 * 1024, ops_cycle=ops_cycle, min_write_mem=1 * MB))
    svc = make_service(total_memory_bytes=total_mb * MB,
                       write_memory_bytes=2 * MB, max_log_bytes=6 * MB,
                       sim_cache_bytes=1 * MB, flush_policy="lsn",
                       governor=governor)
    svc.create_tree("t")
    bulk_load(svc.store, "t", n_records)
    w = Workload(svc, ["t"], n_records)
    m = measure(svc, lambda: w.run(n_ops, write_frac=write_ratio))
    recs = governor.records
    m["x_mb"] = svc.store.write_memory_bytes / MB
    m["cost_first"] = recs[0].cost_per_op if recs else 0
    m["cost_last"] = recs[-1].cost_per_op if recs else 0
    m["tuning_steps"] = len(recs)
    return m


def run(full: bool = False, smoke: bool = False):
    rows = []
    ratios = [0.1, 0.25, 0.5] if full else ([0.5] if smoke else [0.1, 0.5])
    totals = [32, 96] if full else ([32] if smoke else [32, 96])
    n = 400_000 if full else (12_000 if smoke else 120_000)
    for total in totals:
        for r in ratios:
            # smoke: shrink the tuning cycle so the tuner actually ticks
            m = one(r, total, n_ops=n,
                    ops_cycle=3_000 if smoke else 25_000)
            rows.append(fmt_row(
                f"fig15/total{total}MB/write{int(r*100)}", m["x_mb"],
                f"steps={m['tuning_steps']};cost0={m['cost_first']:.3f};"
                f"cost={m['cost_last']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
