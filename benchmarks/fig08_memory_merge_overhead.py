"""Fig. 8: CPU overhead of memory merges (pure in-memory workload).

Paper claim: Partitioned trades 20-40% in-memory throughput vs B+-dynamic
for lower disk write amplification (memory write amp ~11x).
"""
from __future__ import annotations

from .common import MB, Workload, fmt_row, make_store, measure


def one(scheme, n_ops=120_000):
    store = make_store(scheme=scheme, write_memory_bytes=48 * MB,
                       total_memory_bytes=64 * MB,
                       max_log_bytes=1 << 40)          # logging disabled
    store.create_tree("t")
    w = Workload(store, ["t"], 120_000)
    m = measure(store, lambda: w.run(n_ops, write_frac=1.0))
    st = store.disk.stats
    m["mem_write_amp"] = (st.entries_merged_mem + st.entries_written) \
        / max(st.entries_written, 1)
    return m


def run(full: bool = False):
    n = 200_000 if full else 60_000
    rows = []
    base = one("btree-dynamic", n)["throughput"]
    for scheme in ["btree-dynamic", "accordion-index", "accordion-data",
                   "partitioned"]:
        m = one(scheme, n)
        rows.append(fmt_row(f"fig08/in_memory/{scheme}", m["throughput"],
                            f"vs_btree={m['throughput']/base:.2f};"
                            f"mem_wamp={m['mem_write_amp']:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
