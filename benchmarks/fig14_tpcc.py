"""Fig. 14: TPC-C — throughput + per-transaction disk writes by scheme.

Paper claims: B+-static has the highest I/O cost (even allocation across
hot/cold tables); min-LSN/OPT beat MEM; Partitioned-OPT has the lowest
write cost, though its extra memory-merge CPU can cost throughput when the
workload is CPU-bound.
"""
from __future__ import annotations

from .common import MB, fmt_row, make_store, measure
from .tpcc import TPCC

SCHEMES = [("btree-static", "lsn", "b+static"),
           ("btree-dynamic", "mem", "b+dyn-MEM"),
           ("btree-dynamic", "lsn", "b+dyn-LSN"),
           ("btree-dynamic", "opt", "b+dyn-OPT"),
           ("partitioned", "mem", "part-MEM"),
           ("partitioned", "lsn", "part-LSN"),
           ("partitioned", "opt", "part-OPT")]


def one(scheme, policy, write_mem_mb=4, n_txns=6_000):
    store = make_store(scheme=scheme, flush_policy=policy,
                       write_memory_bytes=write_mem_mb * MB,
                       total_memory_bytes=96 * MB, max_log_bytes=12 * MB,
                       max_active_datasets=8)
    drv = TPCC(store)
    m = measure(store, lambda: drv.run(n_txns))
    m["write_kb_per_txn"] = (m["write_pages_per_op"]
                             * store.cfg.page_bytes / 1024)
    return m


def run(full: bool = False, smoke: bool = False):
    rows = []
    n = 300 if smoke else (12_000 if full else 4_000)
    for scheme, policy, label in SCHEMES:
        m = one(scheme, policy, n_txns=n)
        rows.append(fmt_row(f"fig14/{label}", m["throughput"],
                            f"write_kb_per_txn={m['write_kb_per_txn']:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
